"""End-to-end training driver: train an LM with the full substrate stack
(data pipeline, AdamW, checkpointing, auto-resume).

    PYTHONPATH=src python examples/train_lm.py                   # ~10M, fast
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is the "train a ~100M model for a few hundred steps" driver;
on CPU it is slow — the default preset demonstrates the identical code path
at toy scale.
"""
import argparse

from repro.config import (CheckpointConfig, ModelConfig, OptimizerConfig,
                          ShapeConfig, TrainConfig)
from repro.train.trainer import Trainer

PRESETS = {
    "10m": ModelConfig(name="lm-10m", num_layers=4, d_model=256, num_heads=8,
                       num_kv_heads=4, d_ff=1024, vocab_size=8192,
                       remat="none"),
    "100m": ModelConfig(name="lm-100m", num_layers=12, d_model=768,
                        num_heads=12, num_kv_heads=4, d_ff=3072,
                        vocab_size=32768, qk_norm=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = TrainConfig(
        model=PRESETS[args.preset],
        shape=ShapeConfig("train", "train", args.seq, args.batch),
        optimizer=OptimizerConfig(lr=3e-4, warmup_steps=20,
                                  total_steps=args.steps),
        checkpoint=CheckpointConfig(directory=args.ckpt_dir, every_steps=25,
                                    keep=2),
        log_every=10,
    )
    n = cfg.model.param_count()
    print(f"model {cfg.model.name}: {n/1e6:.1f}M params; "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")
    result = Trainer(cfg).run(max_steps=args.steps)
    print(f"ran {result.steps_run} steps "
          f"(resumed from {result.resumed_from}); "
          f"loss {result.losses[0]:.3f} -> {result.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
