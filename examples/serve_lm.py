"""Serve a small LM with batched requests (prefill + slot-based decode).

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.config import ModelConfig
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine

cfg = ModelConfig(name="serve-demo", num_layers=4, d_model=128, num_heads=4,
                  num_kv_heads=2, d_ff=512, vocab_size=4096, remat="none")
model = Model(cfg)
params = model.init(jax.random.key(0))

engine = ServeEngine(model, batch_slots=4, max_len=128)
rng = np.random.default_rng(7)
requests = [
    Request(prompt=rng.integers(0, cfg.vocab_size, size=(plen,),
                                dtype=np.int32),
            max_new_tokens=12)
    for plen in [5, 9, 16, 7, 11, 4, 20, 8]  # two waves of 4 slots
]
print(f"serving {len(requests)} requests on {engine.b} slots")
done = engine.generate(params, requests)
for i, r in enumerate(done):
    print(f"req{i} prompt_len={len(r.prompt)} -> {r.out_tokens}")
assert all(r.done for r in done)
print("all requests completed")
