"""Quickstart: simulate one LArTPC event end-to-end (the paper's pipeline).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.config import LArTPCConfig
from repro.core import generate_depos, make_sim_fn

# a small detector so the example runs in seconds on CPU
cfg = LArTPCConfig(num_wires=256, num_ticks=1024, num_depos=2000)

key = jax.random.key(42)
depos = generate_depos(key, cfg)
print(f"generated {depos.n} energy depositions "
      f"(total charge {float(depos.charge.sum()):.3g} electrons)")

sim = make_sim_fn(cfg)            # jit'd fig-4 pipeline (one dispatch)
out = sim(key, depos)

adc = np.asarray(out.adc)
print(f"ADC grid: {adc.shape}, dtype {adc.dtype}")
print(f"baseline {cfg.adc_baseline:.0f}, observed mean {adc.mean():.1f}, "
      f"max deviation {np.abs(adc - cfg.adc_baseline).max():.0f} counts")

# induction-plane response is bipolar: both over- and under-shoots appear
over = (adc > cfg.adc_baseline + 3).sum()
under = (adc < cfg.adc_baseline - 3).sum()
print(f"bipolar signal: {over} pixels above / {under} below baseline")

# crude hit finding: per-wire max deviation
dev = np.abs(adc.astype(np.int32) - int(cfg.adc_baseline)).max(axis=1)
print(f"wires with hits (>5 counts): {(dev > 5).sum()} / {cfg.num_wires}")
