"""Multi-device LArTPC simulation: depo-parallel rasterization, reduce-scatter
scatter-add, pencil-decomposed distributed FFT — the distributed executor of
the same SimGraph the single-event and batched paths run.

    PYTHONPATH=src python examples/sim_distributed.py [--devices N] [--smoke]

Device count defaults to 8 forced host devices; ``--devices 2 --smoke`` is
the CI distributed smoke (any even N or N=1 works).
"""
import argparse
import os

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=8,
                help="forced host device count (even, or 1)")
ap.add_argument("--smoke", action="store_true",
                help="small grid/depo sizes (CI-friendly)")
ap.add_argument("--planes", type=int, default=1,
                help="readout planes (1 = seed single-plane, 3 = U/V/W)")
ap.add_argument("--recon", action="store_true",
                help="also run the recon stages (pencil-FFT deconvolve + "
                     "per-shard hit finding) and report hit counts")
args = ap.parse_args()

os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={args.devices} "
    + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.config import LArTPCConfig  # noqa: E402
from repro.core.depo import (generate_depos,  # noqa: E402
                             generate_physical_depos)
from repro.core.distributed import (make_distributed_sim,  # noqa: E402
                                    padded_grid_shape, shard_depos)
from repro.core.response import (make_distributed_plane_responses,  # noqa: E402
                                 make_distributed_response)

if args.smoke:
    cfg = LArTPCConfig(num_wires=128, num_ticks=512, num_depos=512,
                       response_wires=11, response_ticks=64)
else:
    cfg = LArTPCConfig(num_wires=256, num_ticks=1024, num_depos=4096,
                       response_wires=11, response_ticks=64)
if args.planes > 1:
    import dataclasses
    cfg = dataclasses.replace(cfg, num_planes=args.planes)

n_dev = len(jax.devices())
shape = (n_dev // 2, 2) if n_dev % 2 == 0 else (n_dev, 1)
mesh = jax.make_mesh(shape, ("data", "model"))
print(f"mesh: {dict(mesh.shape)} over {n_dev} devices")

w_pad, _, _ = padded_grid_shape(cfg, n_dev)
key = jax.random.key(0)
if cfg.num_planes > 1:
    # multi-plane runs take PHYSICAL depos: the in-graph drift stage
    # projects them onto every plane's wire direction
    resp = make_distributed_plane_responses(cfg, w_pad)
    depos = generate_physical_depos(key, cfg)
else:
    resp = make_distributed_response(cfg, w_pad)
    depos = generate_depos(key, cfg)
sharded = shard_depos(depos, mesh)
print(f"depos sharded: {sharded[0].sharding}")

sim = make_distributed_sim(mesh, cfg, resp, recon=args.recon)
if args.recon:
    adc, decon, hits = sim(key, sharded)
    print(f"decon out: {decon.shape} {decon.dtype}, "
          f"sharding {decon.sharding}")
    stored = int(np.asarray(hits.mask).sum())
    found = int(np.asarray(hits.n_hits).sum())
    print(f"hits: {stored} stored / {found} found "
          f"(wires {int(np.asarray(hits.wire)[np.asarray(hits.mask)].min())}"
          f"..{int(np.asarray(hits.wire)[np.asarray(hits.mask)].max())})"
          if stored else "hits: none")
    assert stored > 0, "distributed recon found no hits"
else:
    adc = sim(key, sharded)
print(f"ADC out: {adc.shape} {adc.dtype}, sharding {adc.sharding}")
a = np.asarray(adc)[..., :cfg.num_wires, :]
planes = a.reshape((-1,) + a.shape[-2:])
for p, plane in enumerate(planes):
    hit = (np.abs(plane.astype(int) - int(cfg.adc_baseline)) > 5).sum()
    print(f"plane {p}: signal deviation max "
          f"{np.abs(plane - cfg.adc_baseline).max()} counts; "
          f"{hit} hit pixels")
    assert hit > 0, f"distributed sim produced an empty readout (plane {p})"
print("OK")
