"""Multi-device LArTPC simulation: depo-parallel rasterization, reduce-scatter
scatter-add, pencil-decomposed distributed FFT (8 forced host devices).

    PYTHONPATH=src python examples/sim_distributed.py
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax
import numpy as np

from repro.config import LArTPCConfig
from repro.core.depo import generate_depos
from repro.core.distributed import (make_distributed_sim, padded_grid_shape,
                                    shard_depos)
from repro.core.response import make_distributed_response

cfg = LArTPCConfig(num_wires=256, num_ticks=1024, num_depos=4096,
                   response_wires=11, response_ticks=64)
mesh = jax.make_mesh((4, 2), ("data", "model"))
print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

w_pad, _, _ = padded_grid_shape(cfg, 8)
resp = make_distributed_response(cfg, w_pad)
key = jax.random.key(0)
depos = generate_depos(key, cfg)
sharded = shard_depos(depos, mesh)
print(f"depos sharded: {sharded.wire.sharding}")

sim = make_distributed_sim(mesh, cfg, resp)
adc = sim(key, sharded)
print(f"ADC out: {adc.shape} {adc.dtype}, sharding {adc.sharding}")
a = np.asarray(adc)[:cfg.num_wires]
print(f"signal deviation max {np.abs(a - cfg.adc_baseline).max()} counts; "
      f"{(np.abs(a.astype(int) - int(cfg.adc_baseline)) > 5).sum()} hit pixels")
