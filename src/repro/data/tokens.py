"""Synthetic sharded LM data pipeline.

Deterministic, seekable token stream (restart-safe: the checkpoint stores the
step counter and the pipeline resumes at exactly the next batch), zipf-like
unigram statistics plus local structure so losses actually decrease.
Host-side numpy generation, async prefetch, device_put with the batch
sharding — the TPU never waits on the host (paper F1 applied to input).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator

import jax
import numpy as np

from repro.config import ModelConfig, ShapeConfig
from repro.parallel.sharding import ACT_RULES, named_sharding


def _batch_rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def synth_tokens(rng: np.random.Generator, batch: int, seq: int,
                 vocab: int) -> np.ndarray:
    """Zipf-ish tokens with Markov-ish local structure (learnable)."""
    base = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
    toks = (base - 1) % vocab
    # inject copy structure: second half partially repeats the first half
    half = seq // 2
    mask = rng.random((batch, half)) < 0.5
    toks[:, half:half * 2][mask] = toks[:, :half][mask]
    return toks.astype(np.int32)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int,
               step: int) -> Dict[str, np.ndarray]:
    rng = _batch_rng(seed, step)
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.frontend == "vision" and cfg.frontend_tokens:
        f = cfg.frontend_tokens
        s_text = s - f
        batch["tokens"] = synth_tokens(rng, b, s_text, cfg.vocab_size)
        batch["frontend_embeds"] = rng.standard_normal(
            (b, f, cfg.d_model), dtype=np.float32)
    elif cfg.is_encoder_decoder:
        batch["tokens"] = synth_tokens(rng, b, s, cfg.vocab_size)
        batch["enc_embeds"] = rng.standard_normal(
            (b, s, cfg.d_model), dtype=np.float32) * 0.02
    else:
        batch["tokens"] = synth_tokens(rng, b, s, cfg.vocab_size)
    return batch


_BATCH_NAMES = {
    "tokens": ("batch", None),
    "frontend_embeds": ("batch", None, None),
    "enc_embeds": ("batch", None, None),
    "loss_mask": ("batch", None),
}


def shard_batch(batch: Dict[str, np.ndarray], mesh=None):
    """device_put with the batch sharding (no-op mapping without a mesh)."""
    out = {}
    for k, v in batch.items():
        sh = named_sharding(v.shape, _BATCH_NAMES[k], ACT_RULES, mesh)
        out[k] = jax.device_put(v, sh) if sh is not None else jax.device_put(v)
    return out


class DataPipeline:
    """Prefetching, seekable pipeline. `state()` -> step for checkpointing."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 start_step: int = 0, prefetch: int = 2, mesh=None):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.step = start_step
        self.mesh = mesh
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, self.shape, self.seed, step)
            try:
                self._q.put((step, batch), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        while True:
            step, batch = self._q.get()
            if step < self.step:
                continue  # discard stale prefetches after a seek
            self.step = step + 1
            return shard_batch(batch, self.mesh)

    def __iter__(self) -> Iterator:
        return self

    def state(self) -> int:
        return self.step

    def close(self):
        self._stop.set()
