"""Deterministic fault injection for the streaming fault-tolerance layer.

Every recovery path in ``stream_simulate`` — quarantine, retry-with-
degradation, fail-fast, journal resume, corrupted-cache recovery — must be
*exercised*, not just written. This harness injects faults at the exact
boundaries the production code defends, keyed by event/batch id so every
run (tests, the CI ``fault-smoke`` job, a manual ``--inject-faults`` ...)
reproduces the same failure schedule:

  nan@EV       : event EV's depos get NaN charge + Inf position
                 (ingest validation must quarantine it)
  neg@EV       : event EV gets a negative charge value
  oversize@EV  : event EV's depo count doubles past the padded capacity
  oom@B[xN]    : dispatch of batch B raises an OOM-class error N times
                 (default 1) before succeeding (retry/degradation path)
  error@B      : dispatch of batch B raises a NON-retryable error
                 (fail-fast path: stream dies with SimBatchError)

plus ``corrupt_tune_cache`` for the autotune-cache recovery paths.

The plan is plain data + tiny numpy edits; it never touches the jit graph,
so a run with an empty plan is byte-identical to a run with no plan.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, FrozenSet

import numpy as np


class InjectedOOM(RuntimeError):
    """Stands in for the runtime's allocation failure. The message carries
    RESOURCE_EXHAUSTED so ``repro.core.validate.is_oom_error`` classifies it
    exactly like a real ``XlaRuntimeError`` OOM."""


class InjectedDispatchError(RuntimeError):
    """A non-retryable dispatch failure (no OOM marker): the retry policy
    must fail fast instead of degrading."""


_SPEC_RE = re.compile(r"^(nan|neg|oversize|oom|error)@(\d+)(?:x(\d+))?$")


@dataclasses.dataclass
class FaultPlan:
    """A deterministic failure schedule, keyed by event id / batch id."""

    nan_events: FrozenSet[int] = frozenset()
    negative_events: FrozenSet[int] = frozenset()
    oversized_events: FrozenSet[int] = frozenset()
    #: batch id -> remaining injected OOM failures (mutates as they fire)
    oom_batches: Dict[int, int] = dataclasses.field(default_factory=dict)
    error_batches: FrozenSet[int] = frozenset()

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse a comma-separated fault spec, e.g.
        ``"nan@0,neg@3,oversize@2,oom@1,oom@4x2,error@5"``."""
        nan, neg, over, err = set(), set(), set(), set()
        oom: Dict[int, int] = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            m = _SPEC_RE.match(part)
            if not m:
                raise ValueError(
                    f"bad fault spec {part!r}; expected kind@id with kind in "
                    "nan|neg|oversize|oom|error (oom accepts @BxN for N "
                    "failures)")
            kind, ident, count = m.group(1), int(m.group(2)), m.group(3)
            if count is not None and kind != "oom":
                raise ValueError(f"xN count only applies to oom, got {part!r}")
            if kind == "nan":
                nan.add(ident)
            elif kind == "neg":
                neg.add(ident)
            elif kind == "oversize":
                over.add(ident)
            elif kind == "error":
                err.add(ident)
            else:
                oom[ident] = oom.get(ident, 0) + (int(count) if count else 1)
        return cls(nan_events=frozenset(nan), negative_events=frozenset(neg),
                   oversized_events=frozenset(over), oom_batches=oom,
                   error_batches=frozenset(err))

    # -- ingest-side injection ---------------------------------------------

    def corrupt_event(self, ev: int, depos):
        """Return ``depos`` with this event's scheduled corruption applied
        (untouched when event ``ev`` has none). Works on detector-frame
        ``DepoSet``s and physical ``PhysicalDepoSet``s, with or without a
        leading plane axis."""
        if ev not in (self.nan_events | self.negative_events
                      | self.oversized_events):
            return depos
        leaves = {f: np.array(np.asarray(getattr(depos, f)))
                  for f in depos._fields}
        charge_field = "charge" if "charge" in leaves else "q"
        pos_field = "wire" if "wire" in leaves else "x"
        if ev in self.nan_events:
            q = leaves[charge_field].reshape(-1)
            q[ev % max(q.size, 1)] = np.nan
            p = leaves[pos_field].reshape(-1)
            p[ev % max(p.size, 1)] = np.inf
        if ev in self.negative_events:
            q = leaves[charge_field].reshape(-1)
            q[ev % max(q.size, 1)] = -1234.5
        if ev in self.oversized_events:
            # double the depo axis: past any pad_to <= the original count
            leaves = {f: np.concatenate([a, a], axis=-1)
                      for f, a in leaves.items()}
        return type(depos)(**{f: np.asarray(a, np.float32)
                              for f, a in leaves.items()})

    # -- dispatch-side injection -------------------------------------------

    def before_dispatch(self, batch: int) -> None:
        """Raise this batch's scheduled dispatch fault, if any. Injected
        OOMs are count-limited (``oom@BxN``): each firing decrements the
        budget, so the retry path eventually succeeds — exactly the
        transient-allocation-failure shape the policy degrades for."""
        if batch in self.error_batches:
            raise InjectedDispatchError(
                f"injected non-retryable dispatch failure on batch {batch}")
        remaining = self.oom_batches.get(batch, 0)
        if remaining > 0:
            self.oom_batches[batch] = remaining - 1
            raise InjectedOOM(
                f"RESOURCE_EXHAUSTED: injected device OOM on batch {batch} "
                f"({remaining - 1} more scheduled)")


def corrupt_tune_cache(path: str, mode: str = "truncate") -> None:
    """Corrupt an autotune cache file in place, the ways disks actually do:

    truncate : cut the file mid-JSON (torn write)
    garbage  : replace with non-JSON bytes
    foreign  : valid JSON, but entries from some other tool/schema — must be
               ignored per-entry (schema-version check), not crash the run
    """
    if mode == "truncate":
        with open(path, "rb") as f:
            data = f.read()
        with open(path, "wb") as f:
            f.write(data[: max(len(data) // 2, 1)])
    elif mode == "garbage":
        with open(path, "wb") as f:
            f.write(b"\x00\xffnot json at all{{{")
    elif mode == "foreign":
        import json

        foreign = {
            "some|other|tool|key": "just a string, not a record",
            "scatter_add|cpu|cpu|num_depos=256": {
                "strategy": "xla", "schema": "bogus-9000"},
        }
        with open(path, "w") as f:
            json.dump(foreign, f)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}; "
                         "expected truncate|garbage|foreign")
