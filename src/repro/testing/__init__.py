"""Test/CI support code shipped with the package (fault injection)."""
from repro.testing.faults import (FaultPlan, InjectedDispatchError,
                                  InjectedOOM, corrupt_tune_cache)

__all__ = ["FaultPlan", "InjectedDispatchError", "InjectedOOM",
           "corrupt_tune_cache"]
