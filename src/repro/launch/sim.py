"""LArTPC simulation launcher (the paper's workload):

    python -m repro.launch.sim [--smoke] [--events N] [--batch-events E]
                               [--pipeline fig3|fig4] [--tune] [--retune]
                               [--strategy <scatter>] [--stage-board]
                               [--recon] [--set key=value ...]

``--tune`` autotunes every registered hot op (drift, scatter-add,
charge-grid, FFT-convolve) on the live backend at this config's shape before
running, caching winners to disk; a repeated run reports cache hits instead
of re-measuring (see docs/tuning.md). ``--strategy`` forces the scatter-add
strategy, overriding both the config and the tuner. ``--stage-board`` prints
per-stage device timings (the papers' stage-cost table) before streaming.
``--recon`` closes the sim->recon loop: the streamed graph also deconvolves
the ADC and finds hits, and each batch reports its hit counts.

The fig4 path streams *batches* of events through one vmap'd device program
(``repro.core.batch``): while batch b computes on device, the host generates
and stages batch b+1 (double buffering), so H2D transfer and host-side event
generation overlap with device compute — the paper's "minimize data movement"
prescription applied at the event level. ``--batch-events 1`` degenerates to
the classic one-event-per-launch loop; fig3 keeps the faithful per-depo
host-loop baseline.
"""
from __future__ import annotations

import argparse
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.config import LArTPCConfig, apply_overrides, get_config
from repro.core import generate_depos, simulate
from repro.core.batch import (empty_event, event_keys, make_batched_sim_fn,
                              pack_events, shard_events)
from repro.core.depo import generate_plane_depos
from repro.core.response import make_response


def stream_simulate(cfg: LArTPCConfig, num_events: int, batch_events: int = 1,
                    seed: int = 0, sim: Optional[Callable] = None,
                    pad_to: Optional[int] = None,
                    on_batch: Optional[Callable] = None,
                    recon: bool = False) -> dict:
    """Double-buffered streaming driver for the batched engine — the
    streaming executor of the canonical ``SimGraph`` (its device program is
    ``make_batched_sim_fn``'s jit'd vmap over ``SimGraph.run``).

    Pipelined schedule per step b:
      1. host generates + packs batch b            (overlaps device batch b-1)
      2. ``shard_events`` stages batch b to device (async H2D)
      3. dispatch ``sim(keys, batch_b)``           (async — device now busy)
      4. block on batch b-1's result and report it

    The final batch is padded with zero-depo events so every launch has the
    same static (E, N_max) shape — one trace, no re-jit. Returns aggregate
    stats: events, depos, wall_s, plus per-batch records.
    """
    if batch_events < 1:
        raise ValueError(f"batch_events must be >= 1, got {batch_events}")
    # every launch stages a FRESH batch, so the input buffers are donated:
    # XLA recycles their device memory for outputs (cuts the steady-state
    # footprint by one (E, N_max) batch + keys). CPU never implements
    # donation — skip it there to avoid a pointless warning per compile.
    if sim is None:
        sim = make_batched_sim_fn(cfg, donate=jax.default_backend() != "cpu",
                                  recon=recon)
    key = jax.random.key(seed)
    num_batches = -(-num_events // batch_events)
    # fixed depo padding across batches -> a single compiled program
    pad_to = pad_to if pad_to is not None else cfg.num_depos

    # multi-plane configs stream per-plane pre-drifted events (leading
    # plane axis on every leaf) through the same packed-batch machinery
    gen = (generate_plane_depos if cfg.num_planes > 1 else generate_depos)

    def make_batch(b: int):
        ids = list(range(b * batch_events,
                         min((b + 1) * batch_events, num_events)))
        events = [gen(jax.random.fold_in(key, ev), cfg) for ev in ids]
        n_valid = len(ids)
        events += [empty_event(planes=cfg.num_planes)] * (
            batch_events - n_valid)
        ids += list(range(num_events + b * batch_events,
                          num_events + b * batch_events + batch_events - n_valid))
        return ids, n_valid, pack_events(events, pad_to=pad_to)

    stats = {"events": 0, "depos": 0, "wall_s": 0.0, "batches": []}
    t_start = time.perf_counter()
    inflight = None

    def finish(entry):
        b, n_valid, n_depos, t0, out = entry
        jax.block_until_ready(out.adc)
        dt = time.perf_counter() - t0
        stats["events"] += n_valid
        stats["depos"] += n_depos
        stats["batches"].append({"batch": b, "events": n_valid,
                                 "depos": n_depos, "wall_s": dt})
        if on_batch is not None:
            on_batch(b, n_valid, n_depos, dt, out)

    for b in range(num_batches):
        ids, n_valid, batch = make_batch(b)        # host gen (overlaps b-1)
        keys = event_keys(key, ids)
        n_depos = batch.total_depos
        batch = shard_events(batch)                # async H2D staging
        t0 = time.perf_counter()
        out = sim(keys, batch)                     # async dispatch
        if inflight is not None:
            finish(inflight)                       # block on batch b-1
        inflight = (b, n_valid, n_depos, t0, out)
    if inflight is not None:
        finish(inflight)
    stats["wall_s"] = time.perf_counter() - t_start
    return stats


def _run_fig3(cfg: LArTPCConfig, num_events: int, seed: int) -> None:
    """The faithful per-depo host-loop baseline (paper Fig. 3)."""
    resp = make_response(cfg)
    key = jax.random.key(seed)
    for ev in range(num_events):
        k = jax.random.fold_in(key, ev)
        depos = generate_depos(k, cfg)
        t0 = time.perf_counter()
        out = simulate(k, depos, cfg, resp=resp)
        jax.block_until_ready(out.adc)
        dt = time.perf_counter() - t0
        adc = np.asarray(out.adc)
        print(f"event {ev}: {depos.n} depos -> {adc.shape} ADC in "
              f"{dt*1e3:.0f} ms ({depos.n/dt:.3g} depos/s), "
              f"max dev {np.abs(adc - cfg.adc_baseline).max()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--events", type=int, default=2)
    ap.add_argument("--batch-events", type=int, default=1,
                    help="events per device launch (vmap batch size E)")
    ap.add_argument("--depos", type=int, default=0)
    ap.add_argument("--planes", type=int, default=0,
                    help="readout planes per event (1 = seed single-plane; "
                         "3 = MicroBooNE-like U/V/W triple)")
    ap.add_argument("--pipeline", choices=["fig3", "fig4"], default=None)
    ap.add_argument("--tune", action="store_true",
                    help="autotune kernel strategies for this config/backend "
                         "(cached; repeated runs report a cache hit)")
    ap.add_argument("--retune", action="store_true",
                    help="with --tune: ignore the cache and re-measure")
    ap.add_argument("--strategy", default=None,
                    help="force the scatter-add strategy (see repro.tune; "
                         "'auto' resolves via the tuning cache)")
    ap.add_argument("--stage-board", action="store_true",
                    help="print per-stage device timings for this config "
                         "before streaming (drift/charge_grid/convolve/"
                         "noise/digitize, plus deconvolve/hit_find "
                         "with --recon)")
    ap.add_argument("--recon", action="store_true",
                    help="append the deconvolve + hit_find recon stages "
                         "and report per-batch hit counts (fig4 only)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--set", nargs="*", default=[])
    args = ap.parse_args()

    cfg = get_config("lartpc-uboone", smoke=args.smoke)
    if args.depos:
        cfg = apply_overrides(cfg, {"num_depos": args.depos})
    if args.planes:
        cfg = apply_overrides(cfg, {"num_planes": args.planes})
    if args.pipeline:
        cfg = apply_overrides(cfg, {"pipeline": args.pipeline})
    if args.set:
        cfg = apply_overrides(cfg, dict(kv.split("=", 1) for kv in args.set))

    if args.tune:
        from repro.tune import resolve_config_with_decisions

        cfg, decisions = resolve_config_with_decisions(
            cfg, tune=True, force=args.retune, tune_explicit=True)
        for d in decisions:
            print(d.describe())
    if args.strategy:
        from repro.tune import strategies

        known = sorted(strategies("scatter_add")) + ["auto"]
        if args.strategy not in known:
            raise SystemExit(f"unknown --strategy {args.strategy!r}; "
                             f"known: {known}")
        cfg = apply_overrides(cfg, {"scatter_strategy": args.strategy})

    if args.stage_board:
        from repro.core import build_sim_graph, generate_physical_depos
        from repro.tune import resolve_config

        rcfg = resolve_config(cfg)
        graph = build_sim_graph(rcfg, recon=args.recon)
        key = jax.random.key(args.seed)
        pdepos = generate_physical_depos(key, rcfg)
        _, timings = graph.timed(key, pdepos)
        total = sum(timings.values())
        for name, sec in timings.items():
            print(f"stage {name:<12} {sec * 1e3:8.2f} ms "
                  f"({100 * sec / total:5.1f}%)")
        if rcfg.num_planes > 1:
            # per-plane rows — the papers' per-plane cost tables: the same
            # graph restricted to one plane at a time
            for p in range(rcfg.num_planes):
                _, pt = build_sim_graph(rcfg, planes=(p,),
                                        recon=args.recon).timed(key, pdepos)
                for name, sec in pt.items():
                    print(f"stage plane{p}/{name:<10} {sec * 1e3:8.2f} ms "
                          f"({100 * sec / total:5.1f}%)")

    if cfg.pipeline == "fig3":
        if args.recon:
            raise SystemExit("--recon needs the batched fig4 pipeline "
                             "(drop --pipeline fig3)")
        _run_fig3(cfg, args.events, args.seed)
        return

    def report(b, n_valid, n_depos, dt, out):
        adc = np.asarray(out.adc[:n_valid])
        line = (f"batch {b}: {n_valid} events / {n_depos} depos -> "
                f"{out.adc.shape} ADC in {dt*1e3:.0f} ms "
                f"({n_depos/dt:.3g} depos/s), "
                f"max dev {np.abs(adc - cfg.adc_baseline).max()}")
        if args.recon:
            stored = int(np.asarray(out.hits.mask[:n_valid]).sum())
            found = int(np.asarray(out.hits.n_hits[:n_valid]).sum())
            line += (f", {stored} hits"
                     + (f" ({found} found)" if found != stored else ""))
        print(line)

    stats = stream_simulate(cfg, args.events, args.batch_events,
                            seed=args.seed, on_batch=report,
                            recon=args.recon)
    ev_s = stats["events"] / stats["wall_s"]
    dp_s = stats["depos"] / stats["wall_s"]
    print(f"total: {stats['events']} events / {stats['depos']} depos in "
          f"{stats['wall_s']:.2f} s ({ev_s:.3g} events/s, {dp_s:.3g} depos/s)")


if __name__ == "__main__":
    main()
