"""LArTPC simulation launcher (the paper's workload):
``python -m repro.launch.sim [--events N] [--pipeline fig3|fig4] [...]``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import LArTPCConfig, apply_overrides, get_config
from repro.core import generate_depos, make_sim_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--events", type=int, default=2)
    ap.add_argument("--depos", type=int, default=0)
    ap.add_argument("--set", nargs="*", default=[])
    args = ap.parse_args()

    cfg = get_config("lartpc-uboone", smoke=args.smoke)
    if args.depos:
        cfg = apply_overrides(cfg, {"num_depos": args.depos})
    if args.set:
        cfg = apply_overrides(cfg, dict(kv.split("=", 1) for kv in args.set))

    sim = make_sim_fn(cfg)
    key = jax.random.key(0)
    for ev in range(args.events):
        k = jax.random.fold_in(key, ev)
        depos = generate_depos(k, cfg)
        t0 = time.perf_counter()
        out = sim(k, depos)
        jax.block_until_ready(out.adc)
        dt = time.perf_counter() - t0
        adc = np.asarray(out.adc)
        print(f"event {ev}: {depos.n} depos -> {adc.shape} ADC in "
              f"{dt*1e3:.0f} ms ({depos.n/dt:.3g} depos/s), "
              f"max dev {np.abs(adc - cfg.adc_baseline).max()}")


if __name__ == "__main__":
    main()
