"""LArTPC simulation launcher (the paper's workload):

    python -m repro.launch.sim [--smoke] [--events N] [--batch-events E]
                               [--pipeline fig3|fig4] [--tune] [--retune]
                               [--strategy <scatter>] [--stage-board]
                               [--recon] [--set key=value ...]

``--tune`` autotunes every registered hot op (drift, scatter-add,
charge-grid, FFT-convolve) on the live backend at this config's shape before
running, caching winners to disk; a repeated run reports cache hits instead
of re-measuring (see docs/tuning.md). ``--strategy`` forces the scatter-add
strategy, overriding both the config and the tuner. ``--stage-board`` prints
per-stage device timings (the papers' stage-cost table) before streaming.
``--recon`` closes the sim->recon loop: the streamed graph also deconvolves
the ADC and finds hits, and each batch reports its hit counts.

The fig4 path streams *batches* of events through one vmap'd device program
(``repro.core.batch``): while batch b computes on device, the host generates
and stages batch b+1 (double buffering), so H2D transfer and host-side event
generation overlap with device compute — the paper's "minimize data movement"
prescription applied at the event level. ``--batch-events 1`` degenerates to
the classic one-event-per-launch loop; fig3 keeps the faithful per-depo
host-loop baseline.
"""
from __future__ import annotations

import argparse
import hashlib
import time
import warnings
from typing import Callable, Optional

import jax
import numpy as np

from repro.config import LArTPCConfig, apply_overrides, get_config
from repro.core import generate_depos, simulate
from repro.core.batch import (empty_event, event_keys, make_batched_sim_fn,
                              pack_events, screen_events, shard_events)
from repro.core.depo import generate_plane_depos
from repro.core.response import make_response
from repro.core.validate import RunHealth, SimBatchError, is_oom_error
from repro.launch.journal import RunJournal, run_fingerprint


def stream_donation(backend: Optional[str] = None) -> bool:
    """The streaming executor's donation policy, as a testable predicate.

    Every launch stages a FRESH batch, so the input buffers are donated:
    XLA recycles their device memory for outputs (cuts the steady-state
    footprint by one (E, N_max) batch + keys). CPU never implements
    donation — skip it there to avoid a pointless warning per compile.
    The contract auditor pins the accelerator-side request
    (``p*/streaming`` donated_args) through this same function.
    """
    if backend is None:
        backend = jax.default_backend()
    return backend != "cpu"


def make_streaming_sim_fn(cfg: LArTPCConfig, recon: bool = False,
                          donate: Optional[bool] = None):
    """The device program ``stream_simulate`` drives: ``make_batched_sim_fn``
    with the streaming donation policy applied (``donate=None`` reads
    ``stream_donation()`` for the live backend)."""
    if donate is None:
        donate = stream_donation()
    return make_batched_sim_fn(cfg, donate=donate, recon=recon)


def stream_simulate(cfg: LArTPCConfig, num_events: int, batch_events: int = 1,
                    seed: int = 0, sim: Optional[Callable] = None,
                    pad_to: Optional[int] = None,
                    on_batch: Optional[Callable] = None,
                    recon: bool = False,
                    journal: Optional[str] = None, resume: bool = False,
                    validate: bool = True, max_retries: int = 3,
                    retry_backoff_s: float = 0.0,
                    faults=None) -> dict:
    """Double-buffered streaming driver for the batched engine — the
    streaming executor of the canonical ``SimGraph`` (its device program is
    ``make_batched_sim_fn``'s jit'd vmap over ``SimGraph.run``).

    Pipelined schedule per step b:
      1. host generates + packs batch b            (overlaps device batch b-1)
      2. ``shard_events`` stages batch b to device (async H2D)
      3. dispatch ``sim(keys, batch_b)``           (async — device now busy)
      4. block on batch b-1's result and report it

    The final batch is padded with zero-depo events so every launch has the
    same static (E, N_max) shape — one trace, no re-jit. Returns aggregate
    stats: events, depos, wall_s, per-batch records, plus a ``health`` dict
    (``repro.core.validate.RunHealth``) of fault-tolerance counters.

    Fault tolerance (docs/robustness.md):

    * ``validate=True`` (default) screens every generated event through
      ``check_depos``; invalid events (NaN/negative charge, frame-bound
      violations, oversized) are quarantined into dead-letter records —
      surviving events keep their ids/keys, so their ADCs are bit-identical
      to a clean run. The checks are host-side and read-only: clean-input
      output is bit-identical with validation on or off.
    * ``journal`` names an append-only JSONL batch journal (atomic,
      fsync'd appends); ``resume=True`` skips batches it records as
      complete. Event keys derive from ``fold_in(key, event_id)``, so a
      resumed run reproduces the remaining batches bit-for-bit.
    * OOM-class dispatch failures (``is_oom_error``) retry up to
      ``max_retries`` times, halving the batch's event count each attempt
      (re-padding keeps per-event results bit-identical to the unhalved
      launch); other failures — and an exhausted retry budget — surface a
      structured ``SimBatchError`` naming the batch.
    * an ``on_batch`` callback exception can no longer lose the in-flight
      batch's stats: the batch is recorded first and callback errors become
      warnings.
    * ``faults`` (a ``repro.testing.faults.FaultPlan``) deterministically
      injects corrupt events and dispatch failures so every path above is
      exercised by tests and the CI fault-smoke — None injects nothing.
    """
    if batch_events < 1:
        raise ValueError(f"batch_events must be >= 1, got {batch_events}")
    if num_events < 0:
        raise ValueError(f"num_events must be >= 0, got {num_events}")
    if resume and journal is None:
        raise ValueError("resume=True needs a journal path")
    if sim is None:
        sim = make_streaming_sim_fn(cfg, recon=recon)
    key = jax.random.key(seed)
    num_batches = -(-num_events // batch_events)
    # fixed depo padding across batches -> a single compiled program
    pad_to = pad_to if pad_to is not None else cfg.num_depos
    health = RunHealth()

    jrn = None
    if journal is not None:
        fp = run_fingerprint(cfg, seed=seed, batch_events=batch_events,
                             pad_to=pad_to, num_events=num_events,
                             recon=recon)
        jrn = RunJournal(journal, fingerprint=fp, resume=resume)

    # multi-plane configs stream per-plane pre-drifted events (leading
    # plane axis on every leaf) through the same packed-batch machinery
    gen = (generate_plane_depos if cfg.num_planes > 1 else generate_depos)

    def make_batch(b: int):
        """Generate, (optionally) fault-corrupt, screen, and pad batch b.

        Returns the full padded row list (kept events + zero-depo padding),
        the per-row ids (kept ids keep their original ``fold_in`` keys —
        quarantine never perturbs a surviving event's ADC), and the kept
        count. Padding ids continue the same schedule as before this layer
        existed, so a clean run is bit-identical to the pre-journal code.
        """
        ids = list(range(b * batch_events,
                         min((b + 1) * batch_events, num_events)))
        events = [gen(jax.random.fold_in(key, ev), cfg) for ev in ids]
        if faults is not None:
            events = [faults.corrupt_event(ev, d)
                      for ev, d in zip(ids, events)]
        if validate:
            events, ids, _ = screen_events(events, ids, cfg, pad_to=pad_to,
                                           batch=b, health=health)
        n_valid = len(ids)
        rows = events + [empty_event(planes=cfg.num_planes)] * (
            batch_events - n_valid)
        row_ids = ids + list(range(
            num_events + b * batch_events,
            num_events + b * batch_events + batch_events - n_valid))
        return rows, row_ids, n_valid

    def launch_rows(b: int, rows, row_ids):
        """One device launch over the given event rows (fresh keys + fresh
        packed buffers every time, so donation can never invalidate a
        retry's inputs)."""
        if faults is not None:
            faults.before_dispatch(b)
        keys = event_keys(key, row_ids)
        batch = shard_events(pack_events(rows, pad_to=pad_to))
        return sim(keys, batch)

    def run_degraded(b: int, rows, row_ids, first_exc: BaseException):
        """Bounded retry with graceful degradation: halve the event count
        per OOM-class attempt and launch the sub-batches sequentially.
        Row-wise vmap independence + the fixed ``pad_to`` make the halved
        results bit-identical to the unhalved launch; non-retryable causes
        and an exhausted budget surface a structured ``SimBatchError``."""
        import jax.numpy as jnp

        exc, sub, attempts = first_exc, len(rows), 0
        while True:
            if not is_oom_error(exc):
                raise SimBatchError(b, attempts + 1, sub, exc) from exc
            attempts += 1
            if attempts > max_retries:
                raise SimBatchError(b, attempts, sub, exc) from exc
            health.retries += 1
            if sub > 1:
                sub = -(-sub // 2)
                health.halvings += 1
            if retry_backoff_s:
                time.sleep(retry_backoff_s * attempts)
            try:
                outs = []
                for s in range(0, len(rows), sub):
                    o = launch_rows(b, rows[s:s + sub], row_ids[s:s + sub])
                    jax.block_until_ready(o.adc)
                    outs.append(o)
                if len(outs) == 1:
                    return outs[0]
                return jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, axis=0), *outs)
            except Exception as e:  # noqa: BLE001 — classified above
                exc = e

    stats = {"events": 0, "depos": 0, "wall_s": 0.0, "batches": []}
    t_start = time.perf_counter()
    inflight = None

    def finish(entry):
        b, rows, row_ids, n_valid, n_depos, t0, out = entry
        try:
            jax.block_until_ready(out.adc)
        except Exception as e:  # noqa: BLE001 — run_degraded classifies
            out = run_degraded(b, rows, row_ids, e)
        dt = time.perf_counter() - t0
        # record the batch BEFORE the user callback runs: a callback
        # exception must not lose the batch's stats or journal entry
        health.events_ok += n_valid
        stats["events"] += n_valid
        stats["depos"] += n_depos
        rec = {"batch": b, "events": n_valid, "depos": n_depos, "wall_s": dt}
        if out.finite_ok is not None:
            bad = int(np.count_nonzero(
                ~np.asarray(out.finite_ok)[:n_valid]))
            rec["nonfinite"] = bad
            health.nonfinite_events += bad
        if recon and out.hits is not None:
            rec["hits"] = int(np.asarray(out.hits.mask[:n_valid]).sum())
        if jrn is not None:
            adc = np.ascontiguousarray(np.asarray(out.adc[:n_valid]))
            jrec = dict(rec, ids=[int(i) for i in row_ids[:n_valid]],
                        adc_sha=hashlib.sha256(adc.tobytes()).hexdigest(),
                        quarantined=sum(
                            1 for d in health.dead_letters
                            if d["batch"] == b))
            jrec.pop("wall_s")
            jrn.append_batch(jrec)
        stats["batches"].append(rec)
        if on_batch is not None:
            try:
                on_batch(b, n_valid, n_depos, dt, out)
            except Exception as e:  # noqa: BLE001 — user code, not ours
                health.callback_errors += 1
                warnings.warn(
                    f"on_batch callback failed for batch {b} "
                    f"(stats already recorded): {type(e).__name__}: {e}",
                    RuntimeWarning, stacklevel=2)

    try:
        for b in range(num_batches):
            if jrn is not None and b in jrn.completed:
                done = jrn.completed[b]
                health.resumed += int(done.get("events", 0))
                stats["events"] += int(done.get("events", 0))
                stats["depos"] += int(done.get("depos", 0))
                stats["batches"].append({
                    "batch": b, "events": int(done.get("events", 0)),
                    "depos": int(done.get("depos", 0)), "wall_s": 0.0,
                    "resumed": True})
                continue
            rows, row_ids, n_valid = make_batch(b)  # host gen (overlaps b-1)
            n_depos = sum(int(d.n) for d in rows[:n_valid])
            t0 = time.perf_counter()
            try:
                try:
                    out = launch_rows(b, rows, row_ids)  # async dispatch
                except Exception as e:  # noqa: BLE001 — classified below
                    out = run_degraded(b, rows, row_ids, e)
            except SimBatchError:
                # batch b is lost, but b-1 already computed: record it (and
                # journal it) before surfacing the error, so a --resume run
                # only redoes the batch that actually failed
                if inflight is not None:
                    finish(inflight)
                    inflight = None
                raise
            if inflight is not None:
                finish(inflight)                     # block on batch b-1
            inflight = (b, rows, row_ids, n_valid, n_depos, t0, out)
        if inflight is not None:
            finish(inflight)
    finally:
        if jrn is not None:
            jrn.close()
    stats["wall_s"] = time.perf_counter() - t_start
    stats["health"] = health.as_dict()
    return stats


def _run_fig3(cfg: LArTPCConfig, num_events: int, seed: int) -> None:
    """The faithful per-depo host-loop baseline (paper Fig. 3)."""
    resp = make_response(cfg)
    key = jax.random.key(seed)
    for ev in range(num_events):
        k = jax.random.fold_in(key, ev)
        depos = generate_depos(k, cfg)
        t0 = time.perf_counter()
        out = simulate(k, depos, cfg, resp=resp)
        jax.block_until_ready(out.adc)
        dt = time.perf_counter() - t0
        adc = np.asarray(out.adc)
        print(f"event {ev}: {depos.n} depos -> {adc.shape} ADC in "
              f"{dt*1e3:.0f} ms ({depos.n/dt:.3g} depos/s), "
              f"max dev {np.abs(adc - cfg.adc_baseline).max()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--events", type=int, default=2)
    ap.add_argument("--batch-events", type=int, default=1,
                    help="events per device launch (vmap batch size E)")
    ap.add_argument("--depos", type=int, default=0)
    ap.add_argument("--planes", type=int, default=0,
                    help="readout planes per event (1 = seed single-plane; "
                         "3 = MicroBooNE-like U/V/W triple)")
    ap.add_argument("--pipeline", choices=["fig3", "fig4"], default=None)
    ap.add_argument("--tune", action="store_true",
                    help="autotune kernel strategies for this config/backend "
                         "(cached; repeated runs report a cache hit)")
    ap.add_argument("--retune", action="store_true",
                    help="with --tune: ignore the cache and re-measure")
    ap.add_argument("--strategy", default=None,
                    help="force the scatter-add strategy (see repro.tune; "
                         "'auto' resolves via the tuning cache)")
    ap.add_argument("--stage-board", action="store_true",
                    help="print per-stage device timings for this config "
                         "before streaming (drift/charge_grid/convolve/"
                         "noise/digitize, plus deconvolve/hit_find "
                         "with --recon)")
    ap.add_argument("--recon", action="store_true",
                    help="append the deconvolve + hit_find recon stages "
                         "and report per-batch hit counts (fig4 only)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="append-only JSONL batch journal for this run "
                         "(atomic, fsync'd); enables --resume")
    ap.add_argument("--resume", action="store_true",
                    help="skip batches the --journal records as complete "
                         "(bit-identical continuation; docs/robustness.md)")
    ap.add_argument("--check-finite", action="store_true",
                    help="compile a per-event isfinite sentinel into every "
                         "float stage output (jit-cheap; off by default — "
                         "the default graph is untouched)")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip host-side ingest validation / quarantine")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="OOM-class dispatch retries per batch, halving the "
                         "batch's event count each attempt")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="deterministic fault schedule, e.g. "
                         "'nan@0,oversize@2,oom@1x2,error@3' "
                         "(repro.testing.faults; exercises quarantine/"
                         "retry/fail-fast paths)")
    ap.add_argument("--set", nargs="*", default=[])
    args = ap.parse_args()

    if args.resume and not args.journal:
        raise SystemExit("--resume needs --journal PATH")

    cfg = get_config("lartpc-uboone", smoke=args.smoke)
    if args.depos:
        cfg = apply_overrides(cfg, {"num_depos": args.depos})
    if args.planes:
        cfg = apply_overrides(cfg, {"num_planes": args.planes})
    if args.pipeline:
        cfg = apply_overrides(cfg, {"pipeline": args.pipeline})
    if args.check_finite:
        cfg = apply_overrides(cfg, {"check_finite": True})
    if args.set:
        cfg = apply_overrides(cfg, dict(kv.split("=", 1) for kv in args.set))

    if args.tune:
        from repro.tune import resolve_config_with_decisions

        cfg, decisions = resolve_config_with_decisions(
            cfg, tune=True, force=args.retune, tune_explicit=True)
        for d in decisions:
            print(d.describe())
    if args.strategy:
        from repro.tune import strategies

        known = sorted(strategies("scatter_add")) + ["auto"]
        if args.strategy not in known:
            raise SystemExit(f"unknown --strategy {args.strategy!r}; "
                             f"known: {known}")
        cfg = apply_overrides(cfg, {"scatter_strategy": args.strategy})

    if args.stage_board:
        from repro.core import build_sim_graph, generate_physical_depos
        from repro.tune import resolve_config

        rcfg = resolve_config(cfg)
        graph = build_sim_graph(rcfg, recon=args.recon)
        key = jax.random.key(args.seed)
        pdepos = generate_physical_depos(key, rcfg)
        _, timings = graph.timed(key, pdepos)
        total = sum(timings.values())
        for name, sec in timings.items():
            print(f"stage {name:<12} {sec * 1e3:8.2f} ms "
                  f"({100 * sec / total:5.1f}%)")
        if rcfg.num_planes > 1:
            # per-plane rows — the papers' per-plane cost tables: the same
            # graph restricted to one plane at a time
            for p in range(rcfg.num_planes):
                _, pt = build_sim_graph(rcfg, planes=(p,),
                                        recon=args.recon).timed(key, pdepos)
                for name, sec in pt.items():
                    print(f"stage plane{p}/{name:<10} {sec * 1e3:8.2f} ms "
                          f"({100 * sec / total:5.1f}%)")

    faults = None
    if args.inject_faults:
        from repro.testing.faults import FaultPlan

        faults = FaultPlan.parse(args.inject_faults)

    if cfg.pipeline == "fig3":
        if args.recon:
            raise SystemExit("--recon needs the batched fig4 pipeline "
                             "(drop --pipeline fig3)")
        for flag in ("journal", "resume", "inject_faults"):
            if getattr(args, flag):
                raise SystemExit(f"--{flag.replace('_', '-')} needs the "
                                 "batched fig4 pipeline (drop "
                                 "--pipeline fig3)")
        _run_fig3(cfg, args.events, args.seed)
        return

    def report(b, n_valid, n_depos, dt, out):
        if n_valid == 0:
            print(f"batch {b}: 0 events (all quarantined or padding) in "
                  f"{dt*1e3:.0f} ms")
            return
        adc = np.asarray(out.adc[:n_valid])
        line = (f"batch {b}: {n_valid} events / {n_depos} depos -> "
                f"{out.adc.shape} ADC in {dt*1e3:.0f} ms "
                f"({n_depos/dt:.3g} depos/s), "
                f"max dev {np.abs(adc - cfg.adc_baseline).max()}")
        if out.finite_ok is not None:
            bad = int(np.count_nonzero(~np.asarray(out.finite_ok)[:n_valid]))
            if bad:
                line += f", {bad} NON-FINITE"
        if args.recon:
            stored = int(np.asarray(out.hits.mask[:n_valid]).sum())
            found = int(np.asarray(out.hits.n_hits[:n_valid]).sum())
            line += (f", {stored} hits"
                     + (f" ({found} found)" if found != stored else ""))
        print(line)

    try:
        stats = stream_simulate(cfg, args.events, args.batch_events,
                                seed=args.seed, on_batch=report,
                                recon=args.recon, journal=args.journal,
                                resume=args.resume,
                                validate=not args.no_validate,
                                max_retries=args.max_retries, faults=faults)
    except SimBatchError as e:
        raise SystemExit(
            f"stream failed: {e}" + ("" if not args.journal else
                                     f" — rerun with --resume to continue "
                                     f"from the journal at {args.journal}"))
    ev_s = stats["events"] / stats["wall_s"]
    dp_s = stats["depos"] / stats["wall_s"]
    print(f"total: {stats['events']} events / {stats['depos']} depos in "
          f"{stats['wall_s']:.2f} s ({ev_s:.3g} events/s, {dp_s:.3g} depos/s)")
    health = stats["health"]
    if any(health[k] for k in ("quarantined", "retries", "halvings",
                               "resumed", "nonfinite_events",
                               "callback_errors")):
        print("health: " + ", ".join(
            f"{k}={v}" for k, v in health.items() if k != "dead_letters"))
        for d in health.get("dead_letters", []):
            print(f"  dead-letter event {d['event']} (batch {d['batch']}): "
                  + "; ".join(d["reasons"]))


if __name__ == "__main__":
    main()
