"""ShapeDtypeStruct input specs + sharding specs for every (arch x shape) cell.

Everything here is allocation-free: dry-runs lower against ShapeDtypeStruct
stand-ins (weak-type-correct, shardable).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import (ModelConfig, OptimizerConfig, ParallelConfig,
                          ShapeConfig)
from repro.models.model import Model
from repro.models.transformer import init_caches
from repro.optim.adamw import OptState
from repro.parallel.sharding import ACT_RULES, build_spec, current_act_rules
from repro.train.train_step import make_train_step


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# Batch input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Batch ShapeDtypeStructs for a train/prefill step."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.frontend == "vision" and cfg.frontend_tokens:
        out["tokens"] = sds((b, s - cfg.frontend_tokens), jnp.int32)
        out["frontend_embeds"] = sds((b, cfg.frontend_tokens, cfg.d_model),
                                     jnp.float32)
    elif cfg.is_encoder_decoder:
        out["tokens"] = sds((b, s), jnp.int32)
        out["enc_embeds"] = sds((b, s, cfg.d_model), jnp.float32)
    else:
        out["tokens"] = sds((b, s), jnp.int32)
    return out


_BATCH_NAMES = {
    "tokens": ("batch", None),
    "frontend_embeds": ("batch", None, None),
    "enc_embeds": ("batch", None, None),
}


def batch_shardings(batch_specs, mesh: Mesh):
    rules = current_act_rules()
    return {k: NamedSharding(mesh, build_spec(v.shape, _BATCH_NAMES[k], mesh,
                                              rules))
            for k, v in batch_specs.items()}


# ---------------------------------------------------------------------------
# Cache specs + shardings
# ---------------------------------------------------------------------------

#: logical names per cache leaf field, keyed by (field, ndim)
_CACHE_NAMES = {
    ("k", 5): ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    ("v", 5): ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    ("pos", 2): ("layers", "kv_seq"),
    ("index", 1): ("layers",),
    ("c_kv", 4): ("layers", "batch", "kv_seq", None),
    ("k_rope", 4): ("layers", "batch", "kv_seq", None),
    ("state", 5): ("layers", "batch", "heads", "head_dim", "state"),
    ("state", 3): ("layers", "batch", "mlp"),     # rg-lru h
    ("h", 3): ("layers", "batch", "mlp"),
    ("conv", 4): ("layers", "batch", None, "mlp"),
}

#: decode rules: KV-cache sequence dim sharded over `model` (SP decode)
DECODE_RULES = dict(ACT_RULES)
DECODE_RULES["kv_seq"] = "model"
DECODE_RULES["heads"] = "model"


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct tree of the decode caches."""
    return jax.eval_shape(
        lambda: init_caches(cfg, batch, max_len, jnp.dtype(cfg.dtype)))


def cache_shardings(cache_tree, mesh: Mesh, rules=None):
    rules = rules or DECODE_RULES

    def one(path, leaf):
        field = None
        for p in reversed(path):
            name = getattr(p, "name", None)
            if name is not None:
                field = str(name)
                break
            key = getattr(p, "key", None)
            if key is not None and str(key) in ("conv", "h"):
                field = str(key)
                break
        names = _CACHE_NAMES.get((field, len(leaf.shape)))
        if names is None:
            names = (None,) * len(leaf.shape)
        return NamedSharding(mesh, build_spec(leaf.shape, names, mesh, rules))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


# ---------------------------------------------------------------------------
# Step builders (lowered by the dry-run and the launcher)
# ---------------------------------------------------------------------------

def build_train(arch_cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                opt_cfg: Optional[OptimizerConfig] = None,
                parallel: Optional[ParallelConfig] = None,
                zero1: bool = False):
    """Returns (step_fn, example_args, in_shardings) for jit lowering.

    zero1: params are TP-sharded only (replicated over data); optimizer
    moments/master stay fully sharded (ZeRO-1). GSPMD then materializes the
    classic reduce-scatter(grads) + all-gather(params) update instead of
    per-layer FSDP gathers / activation all-reduces.
    """
    from repro.parallel.sharding import PARAM_RULES, rules_without_fsdp

    model = Model(arch_cfg)
    opt_cfg = opt_cfg or OptimizerConfig()

    params = model.shapes()
    prules = rules_without_fsdp(PARAM_RULES) if zero1 else PARAM_RULES
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            model.specs(mesh, rules=prules))
    opt_param_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 model.specs(mesh)) if zero1 else param_sh)
    step_fn = make_train_step(model, opt_cfg, parallel,
                              grad_shardings=opt_param_sh if zero1 else None)
    low_precision = jnp.dtype(arch_cfg.param_dtype) != jnp.float32
    f32_like = jax.tree.map(lambda p: sds(p.shape, jnp.float32), params)
    opt_state = OptState(
        step=sds((), jnp.int32),
        m=f32_like, v=f32_like,
        master=f32_like if low_precision else None)
    opt_sh = OptState(
        step=NamedSharding(mesh, P()),
        m=opt_param_sh, v=opt_param_sh,
        master=opt_param_sh if low_precision else None)

    batch = input_specs(arch_cfg, shape)
    batch_sh = batch_shardings(batch, mesh)
    repl = NamedSharding(mesh, P())
    metrics_sh = {"loss": repl, "aux": repl, "lr": repl, "grad_norm": repl}
    return (step_fn, (params, opt_state, batch),
            (param_sh, opt_sh, batch_sh),
            {"out_shardings": (param_sh, opt_sh, metrics_sh),
             "donate_argnums": (0, 1)})


def build_decode(arch_cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """serve_step: one new token against a seq_len cache."""
    model = Model(arch_cfg)
    b = shape.global_batch
    max_len = shape.seq_len
    params = model.shapes()
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            model.specs(mesh))
    caches = cache_specs(arch_cfg, b, max_len)
    caches_sh = cache_shardings(caches, mesh)
    tok = sds((b, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, build_spec((b, 1), ("batch", None), mesh,
                                            ACT_RULES))
    index = sds((), jnp.int32)
    index_sh = NamedSharding(mesh, P())

    if arch_cfg.is_encoder_decoder:
        enc_len = min(max_len, 4096)
        enc = (sds((b, enc_len, arch_cfg.d_model), jnp.dtype(arch_cfg.dtype)),
               sds((b, enc_len), jnp.int32))
        enc_sh = (NamedSharding(mesh, build_spec(
            (b, enc_len, arch_cfg.d_model), ("batch", None, None), mesh,
            ACT_RULES)),
            NamedSharding(mesh, build_spec((b, enc_len), ("batch", None),
                                           mesh, ACT_RULES)))

        def serve_step(params, tok, caches, index, enc_out):
            return model.decode_step(params, {"tokens": tok}, caches, index,
                                     extras={"enc_out": enc_out})

        return (serve_step, (params, tok, caches, index, enc),
                (param_sh, tok_sh, caches_sh, index_sh, enc_sh),
                {"out_shardings": (None, caches_sh), "donate_argnums": (2,)})

    def serve_step(params, tok, caches, index):
        return model.decode_step(params, {"tokens": tok}, caches, index)

    return (serve_step, (params, tok, caches, index),
            (param_sh, tok_sh, caches_sh, index_sh),
            {"out_shardings": (None, caches_sh), "donate_argnums": (2,)})


def build_prefill(arch_cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """prefill step: full prompt through the model, filling caches."""
    model = Model(arch_cfg)
    b, s = shape.global_batch, shape.seq_len
    params = model.shapes()
    param_sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                            model.specs(mesh))
    batch = input_specs(arch_cfg, shape)
    batch_sh = batch_shardings(batch, mesh)
    caches = cache_specs(arch_cfg, b, s)
    caches_sh = cache_shardings(caches, mesh)

    def prefill_step(params, batch, caches):
        logits, caches, _ = model.prefill(params, batch, caches)
        return logits, caches

    return (prefill_step, (params, batch, caches),
            (param_sh, batch_sh, caches_sh),
            {"out_shardings": (None, caches_sh), "donate_argnums": (2,)})
