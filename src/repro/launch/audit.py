"""One-command static-analysis gate: repro-lint + the contract audit.

    PYTHONPATH=src python -m repro.launch.audit            # what CI runs
    PYTHONPATH=src python -m repro.launch.audit --planes 1 --devices 2

Runs ``repro.analysis.lint`` over ``src/`` first (stdlib-only, fails fast
and cheap), then ``repro.analysis.audit --check`` against the committed
``AUDIT_contracts.json``. Exit is non-zero when either layer finds
anything. Audit-layer options (``--planes/--devices/--baseline/--json/
--programs``) pass straight through; ``--update`` refreshes the baseline
instead of checking (lint still runs).

Like ``launch/fit.py``, nothing here imports jax at module scope: the
audit layer pins ``XLA_FLAGS``/``JAX_PLATFORMS`` before its first jax
import, and the lint layer never needs jax at all.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.audit",
        description="repro-lint + compiled-program contract audit "
                    "(docs/analysis.md)")
    ap.add_argument("--lint-paths", nargs="+", default=["src"],
                    help="paths repro-lint sweeps (default: src)")
    ap.add_argument("--skip-lint", action="store_true")
    ap.add_argument("--skip-audit", action="store_true")
    args, audit_args = ap.parse_known_args(argv)

    rc = 0
    if not args.skip_lint:
        from repro.analysis import lint

        print(f"== repro-lint {' '.join(args.lint_paths)}", flush=True)
        rc = max(rc, lint.main(list(args.lint_paths)))
    if not args.skip_audit:
        from repro.analysis import audit

        if not any(a in ("--check", "--update") for a in audit_args):
            audit_args = ["--check", *audit_args]
        print(f"== contract audit {' '.join(audit_args)}", flush=True)
        rc = max(rc, audit.main(audit_args))
    return rc


if __name__ == "__main__":
    sys.exit(main())
