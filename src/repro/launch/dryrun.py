import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * 16x16 (256 chips, one v5e pod) and 2x16x16 (512 chips, two pods)
  * every assigned architecture x its input shapes
  * records memory_analysis (fits?), cost_analysis (FLOPs/bytes), and the
    collective schedule (bytes per collective op parsed from the HLO)

Results are cached to benchmarks/results/dryrun/<cell>.json so repeated runs
(and the roofline report) are incremental.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch ID] [--shape NAME]
      [--multi-pod | --single-pod] [--force]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import numpy as np

from repro.config import SHAPES, get_config
from repro.configs import ARCH_IDS
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_decode, build_prefill, build_train
from repro.parallel.sharding import act_rules_for, use_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

#: cells skipped by design (sub-quadratic requirement), see DESIGN.md
LONG_OK = {"mamba2-780m", "recurrentgemma-2b"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|c64|f64|s64|u64|s16|u16)"
                       r"\[([0-9,]*)\]")

_BYTES = {"f64": 8, "c64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum output-shape bytes of every collective op in the HLO, by kind."""
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "<shape> <name> = <op>(" where op contains a collective kind
        m = re.match(r"(?:ROOT )?[%\w.\-]+ = (.*?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(?:-start|-done)?\(", s)
        if not m:
            continue
        if "-done(" in s:
            continue  # bytes counted on the -start op
        kind = m.group(2)
        per_kind[kind] += _shape_bytes(m.group(1))
        counts[kind] += 1
    return {"bytes_by_kind": per_kind, "counts": counts,
            "total_bytes": sum(per_kind.values())}


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             force: bool = False) -> Dict[str, Any]:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh_tag = "pod2" if multi_pod else "pod1"
    cell = f"{arch_id}__{shape_name}__{mesh_tag}"
    path = os.path.join(RESULTS_DIR, cell + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    shape = SHAPES[shape_name]
    result: Dict[str, Any] = {"cell": cell, "arch": arch_id,
                              "shape": shape_name, "mesh": mesh_tag}

    if shape_name == "long_500k" and arch_id not in LONG_OK:
        result["status"] = "skipped"
        result["reason"] = ("full-attention arch: 500k decode requires "
                            "sub-quadratic attention (DESIGN.md)")
        _save(path, result)
        return result
    cfg = get_config(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with use_mesh(mesh, act_rules_for(cfg, mesh)):
            if shape.kind == "train":
                fn, args, shardings, jit_kw = build_train(cfg, shape, mesh)
            elif shape.kind == "prefill":
                fn, args, shardings, jit_kw = build_prefill(cfg, shape, mesh)
            else:
                fn, args, shardings, jit_kw = build_decode(cfg, shape, mesh)
            lowered = jax.jit(fn, in_shardings=shardings,
                              **jit_kw).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)
            # trip-count-aware accounting (XLA's cost_analysis counts while
            # bodies once; scan-over-layers would be undercounted by L)
            from repro.launch.hlo_cost import analyze as hlo_analyze

            acc = hlo_analyze(hlo)

        result.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops": float(acc["flops"]),
            "bytes_accessed": float(acc["hbm_bytes"]),
            "flops_xla_uncorrected": float(cost.get("flops", -1.0)),
            "bytes_xla_uncorrected": float(cost.get("bytes accessed", -1.0)),
            "memory": _mem_dict(mem),
            "collectives": {
                "bytes_by_kind": acc["collectives"],
                "counts": coll["counts"],
                "total_bytes": float(acc["collective_bytes"]),
                "static_text_bytes": coll["total_bytes"],
            },
            "n_devices": int(np.prod(list(mesh.shape.values()))),
        })
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    _save(path, result)
    return result


def _mem_dict(mem) -> Dict[str, float]:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        try:
            out[k] = float(getattr(mem, k))
        except Exception:  # noqa: BLE001
            pass
    return out


def _save(path, result):
    with open(path, "w") as f:
        json.dump(result, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ([True] if args.multi_pod else
              [False] if args.single_pod else [False, True])

    failures = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, multi_pod, force=args.force)
                status = r["status"]
                extra = ""
                if status == "ok":
                    per_dev = r["memory"].get("temp_size_in_bytes", 0) / 2**30
                    extra = (f"flops={r['flops']:.3g} "
                             f"coll={r['collectives']['total_bytes']:.3g}B "
                             f"temp={per_dev:.2f}GiB "
                             f"[{r.get('lower_s', 0):.0f}+"
                             f"{r.get('compile_s', 0):.0f}s]")
                elif status == "error":
                    failures += 1
                    extra = r["error"][:120]
                print(f"{r['cell']:<55} {status:<8} {extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
