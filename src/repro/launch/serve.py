"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Batched request serving with the slot engine (greedy sampling).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import get_config, list_archs
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_archs()))
    # BooleanOptionalAction so the default-on flag is actually switchable:
    # --no-smoke selects the full-size config (the old action="store_true"
    # with default=True made the flag a no-op)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="use the smoke-scale config (--no-smoke for full)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family == "lartpc":
        raise SystemExit("use repro.launch.sim for the lartpc workload")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServeEngine(model, batch_slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=(args.prompt_len,),
                                        dtype=np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    done = engine.generate(params, reqs)
    dt = time.perf_counter() - t0
    total = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    for i, r in enumerate(done[:4]):
        print(f"  req{i}: {r.out_tokens}")


if __name__ == "__main__":
    main()
