"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — a
scan-over-layers program is undercounted by the trip count (64x for a 64-layer
model). This analyzer parses the optimized HLO text, builds the computation
call graph, and scales while bodies by their ``known_trip_count``:

  flops            : 2 * prod(out) * prod(contracted dims) per dot
  collective bytes : operand bytes per collective op, by kind
  hbm bytes        : operand+output bytes of top-level (post-fusion)
                     instructions — fusion internals excluded

Returned totals are per-device (the input is the SPMD-partitioned module).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_BYTES = {"f64": 8, "c64": 8, "c128": 16, "s64": 8, "u64": 8, "f32": 4,
          "s32": 4, "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
          "s8": 1, "u8": 1, "pred": 1, "token": 0, "f8e4m3fn": 1,
          "f8e5m2": 1, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+|[\w\.\-]+)\s*=\s*(.+?)\s+"
                       r"([\w\-]+)\(")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\(")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def _first_shape(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m or m.group(1) not in _BYTES:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


class Instruction:
    __slots__ = ("name", "type_str", "op", "rest")

    def __init__(self, name, type_str, op, rest):
        self.name = name
        self.type_str = type_str
        self.op = op
        self.rest = rest


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.instrs: List[Instruction] = []
        self.symbols: Dict[str, str] = {}   # instr name -> type string


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hdr = _COMP_HDR.match(line)
        if hdr and line.endswith("{") and " -> " in line:
            name = hdr.group(1).lstrip("%")
            cur = Computation(name)
            comps[name] = cur
            if line.startswith("ENTRY"):
                entry = name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            # parameters: "%p = f32[..] parameter(0)" matches; skip others
            continue
        name = m.group(1).lstrip("%")
        instr = Instruction(name, m.group(2), m.group(3), line)
        cur.instrs.append(instr)
        cur.symbols[name] = m.group(2)
    return comps, entry


_CALLED = re.compile(r"(?:body|to_apply|calls)=(%?[\w\.\-]+)")
_CONDITION = re.compile(r"condition=(%?[\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count\\?":{\\?"n\\?":\\?"(\d+)')
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPERANDS = re.compile(r"\(([^)]*)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _operand_names(rest: str) -> List[str]:
    m = _OPERANDS.search(rest[rest.index("("):] if "(" in rest else rest)
    if not m:
        return []
    out = []
    for tok in m.group(1).split(","):
        tok = tok.strip()
        if tok.startswith("%"):
            out.append(tok[1:])
        elif re.match(r"^[\w\.\-]+$", tok) and not tok.isdigit():
            out.append(tok)
    return out


def _dot_flops(instr: Instruction, comp: Computation) -> float:
    out = _first_shape(instr.type_str)
    if out is None:
        return 0.0
    _, out_dims = out
    prod_out = 1
    for d in out_dims:
        prod_out *= d
    ops = _operand_names(instr.rest)
    contract = _CONTRACT.search(instr.rest)
    k = 1
    if ops and contract is not None:
        lhs_type = comp.symbols.get(ops[0])
        if lhs_type:
            sh = _first_shape(lhs_type)
            if sh:
                dims = sh[1]
                for idx in contract.group(1).split(","):
                    if idx:
                        i = int(idx)
                        if i < len(dims):
                            k *= dims[i]
    return 2.0 * prod_out * k


def _inplace_update(ins: Instruction, comp: Computation, out_b: int) -> bool:
    """True when a fusion's output aliases its largest operand (in-place
    dynamic-update-slice pattern inside scans)."""
    op_bytes = [_shape_bytes(comp.symbols.get(o, ""))
                for o in _operand_names(ins.rest)]
    return bool(op_bytes) and max(op_bytes) == out_b and out_b > (1 << 20)


def analyze(hlo: str) -> Dict[str, float]:
    comps, entry = parse_module(hlo)
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collective_bytes": 0.0,
                "collectives": {}}

    memo: Dict[str, Dict] = {}
    top: List[Tuple[float, str]] = []   # (bytes*trip, "kind op_name")
    _META = re.compile(r'op_name="([^"]+)"')

    _SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "iota", "partition-id",
                   "replica-id"}

    trip_stack: List[int] = [1]

    def comp_cost(name: str) -> Dict:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        zero = {"flops": 0.0, "hbm": 0.0,
                "coll": {k: 0.0 for k in _COLLECTIVES}}
        if comp is None:
            memo[name] = zero
            return zero
        memo[name] = zero  # cycle guard
        flops = 0.0
        hbm = 0.0
        coll = {k: 0.0 for k in _COLLECTIVES}
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                called_w = _CALLED.findall(ins.rest)
                called = called_w
                tm = _TRIP.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                else:
                    # infer the trip count from the loop bound constant in
                    # the condition computation (scan bounds are static)
                    trip = 1
                    cm = _CONDITION.search(ins.rest)
                    if cm:
                        cond = comps.get(cm.group(1).lstrip("%"))
                        if cond is not None:
                            bounds = [int(x) for i2 in cond.instrs
                                      for x in _CONST_INT.findall(i2.rest)]
                            if bounds:
                                trip = max(bounds)
                for c in called:
                    trip_stack.append(trip_stack[-1] * trip)
                    sub = comp_cost(c.lstrip("%"))
                    trip_stack.pop()
                    flops += trip * sub["flops"]
                    hbm += trip * sub["hbm"]
                    for k in _COLLECTIVES:
                        coll[k] += trip * sub["coll"][k]
                continue
            if op in ("call", "conditional"):
                for c in _CALLED.findall(ins.rest):
                    sub = comp_cost(c.lstrip("%"))
                    flops += sub["flops"]
                    hbm += sub["hbm"]
                    for k in _COLLECTIVES:
                        coll[k] += sub["coll"][k]
                continue
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                nbytes = sum(
                    _shape_bytes(comp.symbols.get(o, ""))
                    for o in _operand_names(ins.rest))
                if nbytes == 0:
                    nbytes = _shape_bytes(ins.type_str)
                coll[base] += nbytes
                hbm += nbytes
                meta = _META.search(ins.rest)
                top.append((nbytes * trip_stack[-1],
                            f"{base} {meta.group(1) if meta else ins.name}"))
                continue
            if op.endswith("-done") or op in _SKIP_BYTES:
                continue
            if op == "dot":
                flops += _dot_flops(ins, comp)
            if op == "fusion":
                # estimate fused dot flops: scan called fusion computation
                for c in _CALLED.findall(ins.rest):
                    fcomp = comps.get(c.lstrip("%"))
                    if fcomp:
                        for fins in fcomp.instrs:
                            if fins.op == "dot":
                                flops += _dot_flops(fins, fcomp)
            # HBM traffic estimator: ~2x output bytes per materialized value
            # (written once, read ~once downstream). Operand sums would charge
            # full stacked arrays to every dynamic-slice; in-place update
            # patterns (output aliases the big operand) are charged the
            # *update* bytes instead.
            out_b = _shape_bytes(ins.type_str)
            if op in ("dynamic-update-slice", "scatter") or (
                    op == "fusion" and _inplace_update(ins, comp, out_b)):
                op_bytes = [
                    _shape_bytes(comp.symbols.get(o, ""))
                    for o in _operand_names(ins.rest)]
                small = sum(b for b in op_bytes if b != max(op_bytes or [0]))
                hbm += 2 * min(small, out_b)
            else:
                hbm += 2 * out_b
        result = {"flops": flops, "hbm": hbm, "coll": coll}
        memo[name] = result
        return result

    total = comp_cost(entry)
    top.sort(reverse=True)
    return {
        "flops": total["flops"],
        "hbm_bytes": total["hbm"],
        "collective_bytes": sum(total["coll"].values()),
        "collectives": total["coll"],
        "top_collectives": top[:12],
    }
