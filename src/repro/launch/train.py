"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Builds the mesh (if >1 device), resolves the arch config, applies CLI
overrides, and runs the fault-tolerant trainer.
"""
from __future__ import annotations

import argparse

import jax

from repro.config import (CheckpointConfig, OptimizerConfig, ShapeConfig,
                          SHAPES, TrainConfig, apply_overrides, get_config,
                          list_archs)
from repro.parallel.sharding import act_rules_for, use_mesh
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_archs()))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--mesh", default=None,
                    help="e.g. 4x2 -> (data=4, model=2)")
    ap.add_argument("--set", nargs="*", default=[],
                    help="config overrides key=value")
    args = ap.parse_args()

    model_cfg = get_config(args.arch, smoke=args.smoke)
    overrides = dict(kv.split("=", 1) for kv in args.set)
    if overrides:
        model_cfg = apply_overrides(model_cfg, overrides)

    shape = (SHAPES[args.shape] if args.shape
             else ShapeConfig("cli", "train", args.seq, args.batch))
    cfg = TrainConfig(
        model=model_cfg, shape=shape,
        optimizer=OptimizerConfig(total_steps=args.steps),
        checkpoint=CheckpointConfig(directory=args.ckpt_dir),
    )

    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(dims)]
        mesh = jax.make_mesh(dims, axes)

    with use_mesh(mesh, act_rules_for(model_cfg, mesh)):
        result = Trainer(cfg, mesh=mesh).run(max_steps=args.steps)
    print(f"done: {result.steps_run} steps, final loss "
          f"{result.losses[-1]:.4f}, stragglers {result.straggler_steps}")


if __name__ == "__main__":
    main()
