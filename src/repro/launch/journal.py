"""Append-only JSONL batch journal: checkpoint/resume for the streaming run.

A million-event campaign that dies at batch 9_999 must not recompute batches
0..9_998. ``stream_simulate`` records every completed batch here; a
``--resume`` run replays the journal, skips completed batches, and computes
only the remainder — bit-identically, because per-event ADCs derive only
from ``fold_in(key, event_id)`` and the fixed padded depo shape, neither of
which depends on which run computes the batch (proven SHA-for-SHA in
``tests/test_robustness.py``).

File format (one JSON object per line):

  line 1   : header — {"kind": "header", "version": 1, "fingerprint": ...,
             "num_events": ..., "batch_events": ..., "pad_to": ...}
  line 2.. : batch records — {"kind": "batch", "batch": b, "ids": [...],
             "events": n, "depos": n, "adc_sha": "...", "quarantined": n}

Durability contract: records append with flush + fsync, so a completed batch
survives a crash of the very next statement. A torn final line (the process
died mid-write) is tolerated on read — parsing stops at the first
undecodable line and everything before it counts as completed; the torn
batch simply recomputes. The header writes atomically (tmp + ``os.replace``)
so a half-created journal can never be mistaken for a resumable one.

The fingerprint pins the run parameters a resume must reproduce (config,
seed, batching, padding): resuming under a different config would silently
mix incompatible ADC streams, so it is an error instead.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Dict, List, Optional

JOURNAL_VERSION = 1


class JournalError(ValueError):
    """The journal cannot serve this run (missing, unreadable header, or a
    fingerprint mismatch — the run parameters differ from the recorded
    ones)."""


def run_fingerprint(cfg, **params: Any) -> str:
    """Digest of everything a resumed run must reproduce exactly: the full
    config repr (strategy fields included — they change the traced program)
    plus the streaming parameters (seed, batch_events, pad_to, ...)."""
    payload = repr(sorted(params.items())) + "|" + repr(cfg)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


class RunJournal:
    """One streaming run's append-only batch journal.

    ``resume=True`` loads an existing journal (validating version and
    fingerprint) and exposes its completed batches; otherwise a fresh
    journal is created, atomically replacing any stale file at ``path``.
    """

    def __init__(self, path: str, fingerprint: str, resume: bool = False):
        self.path = path
        self.fingerprint = fingerprint
        #: batch id -> recorded batch dict (completed in a previous run)
        self.completed: Dict[int, dict] = {}
        if resume:
            self._load_existing()
            self._f = open(self.path, "a")
        else:
            self._create(fingerprint)

    # -- creation / loading -------------------------------------------------

    def _create(self, fingerprint: str) -> None:
        header = {"kind": "header", "version": JOURNAL_VERSION,
                  "fingerprint": fingerprint}
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        tmp = f"{self.path}.{os.getpid()}.tmp"
        with open(tmp, "w") as f:
            f.write(json.dumps(header, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        self._f = open(self.path, "a")

    def _load_existing(self) -> None:
        try:
            with open(self.path) as f:
                lines = f.read().splitlines()
        except OSError as e:
            raise JournalError(
                f"cannot resume: journal {self.path!r} is unreadable "
                f"({e})") from e
        if not lines:
            raise JournalError(f"cannot resume: journal {self.path!r} is "
                               "empty (no header)")
        try:
            header = json.loads(lines[0])
        except json.JSONDecodeError as e:
            raise JournalError(f"cannot resume: journal {self.path!r} has "
                               "an unreadable header line") from e
        if not isinstance(header, dict) or header.get("kind") != "header":
            raise JournalError(f"cannot resume: {self.path!r} does not look "
                               "like a run journal (bad header)")
        if header.get("version") != JOURNAL_VERSION:
            raise JournalError(
                f"cannot resume: journal version {header.get('version')!r} "
                f"!= supported {JOURNAL_VERSION}")
        if header.get("fingerprint") != self.fingerprint:
            raise JournalError(
                "cannot resume: journal was written by a run with different "
                "parameters (config/seed/batching changed — fingerprint "
                f"{header.get('fingerprint')!r} != {self.fingerprint!r}); "
                "resuming would mix incompatible ADC streams")
        for line in lines[1:]:
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break  # torn final write: everything before it is durable
            if isinstance(rec, dict) and rec.get("kind") == "batch":
                self.completed[int(rec["batch"])] = rec

    # -- appending ----------------------------------------------------------

    def append_batch(self, record: Dict[str, Any]) -> None:
        """Durably record one completed batch (single line, flush + fsync)."""
        rec = dict(record, kind="batch")
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())
        self.completed[int(rec["batch"])] = rec

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "RunJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def load_journal_records(path: str) -> Optional[List[dict]]:
    """Read-only view of a journal's completed batch records, sorted by
    batch id (None when the file is missing/unreadable) — for post-run
    inspection and tests. Tolerates a torn final line like resume does."""
    try:
        with open(path) as f:
            lines = f.read().splitlines()
    except OSError:
        return None
    records: Dict[int, dict] = {}
    for line in lines[1:]:
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            break
        if isinstance(rec, dict) and rec.get("kind") == "batch":
            records[int(rec["batch"])] = rec
    return [records[b] for b in sorted(records)]
