"""Production mesh definitions.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is pure
data parallelism over the (slower) inter-pod links.

Functions, not module constants: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. ((2, 4), ("data", "model")))."""
    return jax.make_mesh(tuple(shape), tuple(axes))
