"""Pipeline parallelism: GPipe schedule over a mesh axis via ppermute.

Each device along the `stage` axis holds one stage's parameters; microbatches
stream through the ring with ``collective_permute``. Used for depth-dominated
models when TP+DP alone can't hold a stage's working set; composes with the
other axes (the stage axis is just another mesh axis).

    y = pipeline_apply(stage_fn, stage_params, x_microbatches, mesh, "stage")

``stage_params`` leaves are stacked (n_stages, ...) and sharded so stage i's
slice lives on stage-axis index i.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params: Any, x: jax.Array,
                   mesh: Mesh, axis: str = "stage"):
    """Run x through n_stages stages with a GPipe schedule.

    stage_fn(params_slice, h) -> h  (one stage's computation)
    stage_params: pytree, leaves (n_stages, ...)
    x: (n_micro, mb, ...) microbatched input (activation-shaped: stage 0
       consumes it; the output collects stage n-1's results).
    Returns (n_micro, mb, ...) outputs.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    total = n_micro + n_stages - 1
    fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(params_slice, xs):
        # params_slice: (1, ...) leaves — my stage; xs: (n_micro, mb, ...)
        params_local = jax.tree.map(lambda p: p[0], params_slice)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        carry_in = jnp.zeros(mb_shape, xs.dtype)
        outs = jnp.zeros_like(xs)

        def step(state, t):
            carry, outs = state
            # stage 0 injects microbatch t (if still in range)
            inject = jnp.where(t < n_micro, 1, 0)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            h_in = jnp.where((stage == 0) & (inject == 1),
                             xs[mb_idx], carry)
            h_out = stage_fn(params_local, h_in)
            # last stage commits microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            commit = (stage == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                commit,
                lambda o: jax.lax.dynamic_update_slice(
                    o, h_out[None], (jnp.maximum(out_idx, 0),)
                    + (0,) * (o.ndim - 1)),
                lambda o: o, outs)
            # ship to next stage
            carry = jax.lax.ppermute(h_out, axis, fwd)
            return (carry, outs), None

        (carry, outs), _ = jax.lax.scan(
            step, (carry_in, outs), jnp.arange(total))
        # only the last stage holds real outputs; broadcast via psum of the
        # masked tensor so every stage returns the same value
        mask = (stage == n_stages - 1).astype(xs.dtype)
        return jax.lax.psum(outs * mask, axis)

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(pspec, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x)
