"""Logical-axis sharding rules -> concrete PartitionSpecs.

Every tensor in the framework is described by *logical* dim names; the rules
table maps names to mesh axes (DP/FSDP/TP/EP/SP). ``build_spec`` drops any
mapping whose axis size does not divide the dim — small models gracefully
lose TP on dims that don't split (e.g. 8 kv-heads on a 16-way model axis).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]

# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

#: parameter dims
PARAM_RULES: Dict[str, AxisName] = {
    "vocab": "model",
    "embed": "data",          # FSDP / ZeRO-3: shard the embed dim over data
    "heads": "model",         # TP: attention heads
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",           # TP: MLP hidden
    "experts": "model",       # EP: routed experts
    "expert_mlp": None,
    "kv_lora": None,
    "layers": None,           # scan axis, never sharded
    "conv": None,
    "state": None,
}

#: activation dims
ACT_RULES: Dict[str, AxisName] = {
    "batch": ("pod", "data"),
    # Megatron-style sequence parallelism: the residual stream (block-level
    # activations, incl. the remat-saved mix_out/ffn_out) shards its seq dim
    # over `model`; GSPMD inserts the all-gather before qkv/mlp projections
    # and the reduce-scatter after. Cuts saved-activation memory by the TP
    # degree. Divisibility fallback handles seq=1 decode.
    "seq": "model",
    "attn_seq": None,   # attention-internal q/k/v seq dim (never forced)
    "kv_seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "capacity": None,
    "vocab": "model",
    "state": None,
    # LArTPC sim
    "depos": ("pod", "data", "model"),
    "events": ("pod", "data"),   # event axis of a multi-event batch (DP)
    "wires": "model",
    "ticks": None,
}


#: DP-heavy activation rules for small archs whose head count does not
#: divide the model axis (e.g. 14 heads on 16): the batch claims every mesh
#: axis (pure data parallelism, the production layout for ~1-2B models);
#: per-tensor divisibility fallback drops the `model` axis from any dim that
#: cannot take it, so TP dims that do divide still shard when batch cannot.
DP_ACT_RULES: Dict[str, AxisName] = dict(
    ACT_RULES, batch=("pod", "data", "model"),
)


def act_rules_for(cfg, mesh: Optional["Mesh"]) -> Dict[str, AxisName]:
    """Pick TP (heads over model) or DP-heavy activation rules per arch."""
    if mesh is None or "model" not in mesh.shape:
        return ACT_RULES
    nh = getattr(cfg, "num_heads", 0)
    if nh and nh % mesh.shape["model"] != 0:
        return DP_ACT_RULES
    return ACT_RULES


def rules_without_fsdp(rules: Dict[str, AxisName]) -> Dict[str, AxisName]:
    out = dict(rules)
    out["embed"] = None
    return out


# ---------------------------------------------------------------------------
# Mesh context (our own tracker; avoids depending on jax internals)
# ---------------------------------------------------------------------------

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_act_rules() -> Dict[str, AxisName]:
    return getattr(_state, "act_rules", None) or ACT_RULES


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], act_rules: Optional[Dict] = None):
    prev = current_mesh()
    prev_rules = getattr(_state, "act_rules", None)
    _state.mesh = mesh
    _state.act_rules = act_rules
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev
        _state.act_rules = prev_rules


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------


def build_spec(shape: Sequence[int], names: Sequence[Optional[str]],
               mesh: Optional[Mesh], rules: Dict[str, AxisName]) -> P:
    """PartitionSpec for `shape` given logical `names`, with divisibility
    fallback (drop axes that don't divide, trailing-first for tuples)."""
    if mesh is None:
        return P()
    assert len(shape) == len(names), (shape, names)
    used: set = set()
    entries = []
    for dim, name in zip(shape, names):
        axis = rules.get(name) if name else None
        if axis is None:
            entries.append(None)
            continue
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        # keep only axes present in the mesh and unused so far
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        # drop axes (from the right) until the product divides the dim
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if prod and dim % prod == 0:
                break
            axes = axes[:-1]
        if not axes:
            entries.append(None)
        else:
            used.update(axes)
            entries.append(axes if len(axes) > 1 else axes[0])
    return P(*entries)


def named_sharding(shape, names, rules=None, mesh=None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    spec = build_spec(shape, names, mesh, rules or ACT_RULES)
    return NamedSharding(mesh, spec)


def logical(x: jax.Array, names: Sequence[Optional[str]],
            rules: Optional[Dict[str, AxisName]] = None) -> jax.Array:
    """with_sharding_constraint by logical names (no-op without a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = build_spec(x.shape, names, mesh, rules or current_act_rules())
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def spec_tree(shapes, names_tree, rules=None, mesh=None):
    """Map a pytree of (shape, names) -> pytree of PartitionSpec."""
    mesh = mesh or current_mesh()
    rules = rules or PARAM_RULES

    def one(leaf):
        shape, names = leaf
        return build_spec(shape, names, mesh, rules)

    return jax.tree.map(one, shapes, is_leaf=lambda l: isinstance(l, tuple)
                        and len(l) == 2 and isinstance(l[0], tuple))
