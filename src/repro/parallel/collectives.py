"""Distributed-optimization collectives: gradient compression, pod-level DP.

int8 error-feedback compression for the cross-pod gradient all-reduce:
pods are connected by the slowest links, so the pod-axis all-reduce is the
one worth compressing. Per-tensor scale, int8 quantize, all-reduce in int32
(exact), dequantize, and feed the quantization error back into the next
step's gradient (error feedback keeps SGD/Adam convergence).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis: str, error: Optional[Any] = None):
    """int8 error-feedback all-reduce over `axis` (inside shard_map).

    Returns (mean_grads, new_error). `error` is the residual pytree from the
    previous step (or None).
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        g32 = g.astype(jnp.float32)
        if e is not None:
            g32 = g32 + e
        q, scale = quantize_int8(g32)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        scale_sum = jax.lax.psum(scale, axis)  # conservative shared scale
        deq = total.astype(jnp.float32) * (scale_sum / n)
        mean = deq / n
        new_e = g32 - dequantize_int8(q, scale)
        return mean.astype(g.dtype), new_e

    if error is None:
        error = jax.tree.map(lambda _: None, grads,
                             is_leaf=lambda x: x is None)
        flat_e = [None] * len(jax.tree.leaves(grads))
    else:
        flat_e = jax.tree.leaves(error)
    flat_g, treedef = jax.tree.flatten(grads)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in out])
    return mean, new_err


def psum_mean(tree, axis: str):
    n = jax.lax.psum(1, axis)
    return jax.tree.map(lambda x: jax.lax.psum(x, axis) / n, tree)
