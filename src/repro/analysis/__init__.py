"""Static-analysis gate for the stage graph (docs/analysis.md).

Two layers:

* ``repro.analysis.hlo`` + ``repro.analysis.audit`` — compiled-program
  contracts (collectives, dtypes, donation, host calls, recompiles) diffed
  against the committed ``AUDIT_contracts.json``.
* ``repro.analysis.lint`` — repo-specific JAX AST lint rules.

This package imports lazily on purpose: ``hlo`` and ``lint`` are stdlib-
only, and ``audit`` must be imported AFTER the fake-device environment is
pinned — so nothing here eagerly imports jax.
"""
from repro.analysis import hlo  # noqa: F401  (stdlib-only, always safe)

__all__ = ["hlo"]
