"""repro-lint: AST-level lint rules for the repo's JAX discipline.

ruff covers generic Python; these rules encode the *repo-specific* mistakes
the stage graph keeps inviting — the ones that compile fine, run fine on
one backend, and quietly break reproducibility or portability:

  key-reuse            : a PRNG key consumed by two sampler calls without a
                         ``split``/``fold_in`` between them — correlated
                         noise that no test of either call alone catches.
  traced-branch        : Python ``if``/``while`` on a likely-traced value
                         inside a stage/jitted function — a
                         ConcretizationTypeError on the traced path, or
                         worse, a silently baked-in branch.
  host-sync            : ``.item()`` / ``float()`` / ``np.asarray()`` on a
                         traced value in jitted code — a device->host
                         round-trip per call (the paper's host/device
                         data-movement tax) or a tracer leak.
  mutable-default      : mutable default argument — shared state across
                         calls; in this repo usually a cache that aliases
                         between configs.
  config-replace-guard : ``dataclasses.replace(cfg, field=traced)`` inside
                         a trace without the ``isinstance(x, jax.Array)``
                         guard pattern PR 7 established — the replace
                         silently hashes a tracer into the config and
                         retriggers compilation per call.
  f64-literal          : explicit ``float64`` dtype — dead under the
                         default x64-disabled runtime and a 2x memory-
                         traffic bomb the day someone enables x64.

Run as ``python -m repro.analysis.lint src/`` (text findings, exit 1 when
any) or with ``--json`` for machine-readable output. Suppress a deliberate
exception on its own line with ``# repro-lint: disable=<rule>[,<rule>]``,
or file-wide with ``# repro-lint: disable-file=<rule>``; suppressions are
grep-audit-able by design.

Scope heuristics (documented, deliberately simple — no cross-module
analysis): a function counts as *traced* when it (a) is decorated with or
passed to a jax transform (``jit``/``vmap``/``grad``/``shard_map``/
``lax.scan``/...), (b) is passed to ``Stage(...)`` or a
``*graph*.replace(stage=fn)`` call, or (c) is an inner def returned from a
``*_stage``/``make_*`` factory. Likely-traced *values* are the traced
function's parameters (minus ``cfg``/``config``/``self``) plus anything
assigned from them; references through static attributes
(``.shape``/``.ndim``/``.dtype``), ``len()``, or ``isinstance()`` do not
count — those are trace-time constants.

Pure stdlib on purpose: the lint half of the CI gate must run with or
without jax installed.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import os
import re
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

#: rule name -> one-line description (the docs/analysis.md catalog source)
RULES: Dict[str, str] = {
    "key-reuse": "PRNG key consumed by more than one sampler call without "
                 "an intervening split/fold_in (correlated randomness)",
    "traced-branch": "Python if/while on a likely-traced value inside a "
                     "traced function (ConcretizationTypeError or a "
                     "baked-in branch)",
    "host-sync": ".item()/float()/np.asarray() on a traced value inside a "
                 "traced function (device->host sync per call)",
    "mutable-default": "mutable default argument (state shared across "
                       "calls)",
    "config-replace-guard": "dataclasses.replace(config, ...) with a "
                            "traced value and no isinstance(jax.Array) "
                            "guard (retrace per call)",
    "f64-literal": "explicit float64 dtype (x64 leak / 2x memory traffic)",
}

_DISABLE_RE = re.compile(r"#\s*repro-lint:\s*disable=([\w\-,\s]+)")
_DISABLE_FILE_RE = re.compile(r"#\s*repro-lint:\s*disable-file=([\w\-,\s]+)")

#: jax transform callables (tail attribute name) whose function-valued args
#: become traced
_TRANSFORMS = {"jit", "vmap", "pmap", "grad", "value_and_grad", "shard_map",
               "checkpoint", "remat", "scan", "while_loop", "cond",
               "fori_loop", "switch", "custom_jvp", "custom_vjp",
               "named_call", "pure_callback"}

#: jax.random samplers that CONSUME a key (arg 0); split/fold_in/key
#: constructors derive fresh keys instead and are exempt
_KEY_DERIVERS = {"split", "fold_in", "key", "PRNGKey", "clone",
                 "wrap_key_data", "key_data"}

#: params that are trace-time static by repo convention
_STATIC_PARAMS = {"cfg", "config", "self", "cls", "spec", "resp", "mesh",
                  "axes", "pool"}

#: attribute accesses that are static under jit (shape metadata)
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding",
                 "name", "names", "stages", "stage_names"}


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}"

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _tail_name(func: ast.expr) -> str:
    """'jax.lax.scan' -> 'scan'; bare Name -> its id; else ''."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _dotted(node: ast.expr) -> str:
    """Best-effort dotted path of an expression ('jax.lax.scan', 'np')."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


# ---------------------------------------------------------------------------
# Traced-scope discovery
# ---------------------------------------------------------------------------


def _decorated_traced(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        target = dec.func if isinstance(dec, ast.Call) else dec
        if _tail_name(target) in _TRANSFORMS:
            return True
        # functools.partial(jax.jit, ...) style
        if isinstance(dec, ast.Call):
            for arg in dec.args:
                if _tail_name(arg) in _TRANSFORMS:
                    return True
    return False


class _TracedScopeCollector(ast.NodeVisitor):
    """Names of functions that end up inside a jax trace (module-local)."""

    def __init__(self) -> None:
        self.traced: Set[str] = set()
        self._factory_stack: List[ast.AST] = []

    def _mark(self, node: ast.expr) -> None:
        if isinstance(node, ast.Name):
            self.traced.add(node.id)
        elif isinstance(node, ast.Call):  # jax.jit(fn) nested in a call
            for a in node.args:
                self._mark(a)

    def visit_Call(self, node: ast.Call) -> None:
        tail = _tail_name(node.func)
        if tail in _TRANSFORMS:
            for arg in node.args:
                self._mark(arg)
            for kw in node.keywords:
                if kw.arg in ("fun", "f", "fn", "body_fun", "cond_fun",
                              "callback"):
                    self._mark(kw.value)
        elif tail == "Stage":
            # Stage("name", fn, ...) — every function-valued arg is traced
            for arg in node.args[1:]:
                self._mark(arg)
            for kw in node.keywords:
                self._mark(kw.value)
        elif tail == "replace" and isinstance(node.func, ast.Attribute):
            # <graph>.replace(stage=fn): the SimGraph specialization hook
            if "graph" in _dotted(node.func.value).lower():
                for kw in node.keywords:
                    self._mark(kw.value)
        self.generic_visit(node)

    def _visit_factory(self, node) -> None:
        name = node.name
        if name.endswith("_stage") or name.startswith("make_"):
            # inner defs returned from a stage/executor factory are traced
            inner = {n.name for n in ast.walk(node)
                     if isinstance(n, (ast.FunctionDef,
                                       ast.AsyncFunctionDef))} - {name}
            for ret in ast.walk(node):
                if isinstance(ret, ast.Return) and ret.value is not None:
                    for sub in ast.walk(ret.value):
                        if isinstance(sub, ast.Name) and sub.id in inner:
                            self.traced.add(sub.id)
        if _decorated_traced(node):
            self.traced.add(name)
        self.generic_visit(node)

    visit_FunctionDef = _visit_factory
    visit_AsyncFunctionDef = _visit_factory


def traced_function_names(tree: ast.Module) -> Set[str]:
    col = _TracedScopeCollector()
    col.visit(tree)
    return col.traced


# ---------------------------------------------------------------------------
# Taint (likely-traced values inside one function)
# ---------------------------------------------------------------------------


def _assigned_names(target: ast.expr) -> Iterable[str]:
    for node in ast.walk(target):
        if isinstance(node, ast.Name):
            yield node.id


def _static_param_names(fn: ast.AST) -> Set[str]:
    """Params jit treats as python-static: the conventional names plus
    anything named by ``static_argnames``/``static_argnums`` in a jit
    decorator (``@partial(jax.jit, static_argnames=(...))``)."""
    out = set(_STATIC_PARAMS)
    pos = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    for dec in getattr(fn, "decorator_list", []):
        if not isinstance(dec, ast.Call):
            continue
        for kw in dec.keywords:
            if kw.arg in ("static_argnames", "static_broadcasted_argnums"):
                out.update(c.value for c in ast.walk(kw.value)
                           if isinstance(c, ast.Constant)
                           and isinstance(c.value, str))
            elif kw.arg == "static_argnums":
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) \
                            and isinstance(c.value, int) \
                            and 0 <= c.value < len(pos):
                        out.add(pos[c.value])
    return out


def tainted_names(fn: ast.AST) -> Set[str]:
    """Likely-traced locals of a traced function: parameters (minus the
    static-by-convention and jit-static ones) plus anything assigned from
    a traced *value* — one forward propagation pass in statement order
    (good enough: the repo's stage fns are straight-line). Assignments
    whose tainted references only reach through static metadata
    (``x.shape``/``len(x)``) do NOT taint their target: shapes are
    trace-time constants."""
    args = fn.args
    params = [a.arg for a in (args.posonlyargs + args.args + args.kwonlyargs)]
    if args.vararg:
        params.append(args.vararg.arg)
    if args.kwarg:
        params.append(args.kwarg.arg)
    static = _static_param_names(fn)
    tainted = {p for p in params if p not in static
               and not p.startswith("_")}
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            value = node.value
            if value is None:
                continue
            if _value_refs(value, tainted):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    tainted.update(_assigned_names(t))
    return tainted


def _value_refs(node: ast.expr, tainted: Set[str]) -> List[ast.Name]:
    """Tainted Name references in ``node`` that reach a traced *value* —
    skipping static metadata (``x.shape``/``len(x)``/``isinstance(x,..)``)."""
    skip: Set[int] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
            for inner in ast.walk(sub.value):
                skip.add(id(inner))
        elif isinstance(sub, ast.Call):
            tail = _tail_name(sub.func)
            if tail in ("len", "isinstance", "hasattr", "getattr", "type",
                        "id", "repr"):
                for a in sub.args:
                    for inner in ast.walk(a):
                        skip.add(id(inner))
        elif isinstance(sub, ast.Compare):
            # `x is None` / `x is not None` — a python-level structure check
            if any(isinstance(op, (ast.Is, ast.IsNot)) for op in sub.ops):
                for inner in ast.walk(sub):
                    skip.add(id(inner))
    return [n for n in ast.walk(node)
            if isinstance(n, ast.Name) and n.id in tainted
            and id(n) not in skip]


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


def _rule_mutable_default(tree: ast.Module, path: str) -> List[Finding]:
    out = []
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for default in fn.args.defaults + [d for d in fn.args.kw_defaults
                                           if d is not None]:
            if isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
                    isinstance(default, ast.Call)
                    and _tail_name(default.func) in ("list", "dict", "set",
                                                     "defaultdict")):
                out.append(Finding(
                    path, default.lineno, default.col_offset,
                    "mutable-default",
                    f"mutable default argument in {fn.name}() is shared "
                    "across calls; default to None and build inside"))
    return out


def _rule_f64_literal(tree: ast.Module, path: str) -> List[Finding]:
    """``np/jnp.float64`` attributes and ``"float64"`` strings in dtype
    positions (``dtype=`` kwargs, ``.astype(...)`` args). Attribute uses
    inside a comparison are exempt — ``x.dtype in (f32, f64)`` *checks*
    a dtype, it doesn't create one."""
    out = []
    compare_members: Set[int] = set()
    dtype_positions: List[ast.expr] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Compare):
            for sub in ast.walk(node):
                compare_members.add(id(sub))
        elif isinstance(node, ast.Call):
            dtype_positions += [kw.value for kw in node.keywords
                                if kw.arg == "dtype"]
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "astype":
                dtype_positions += list(node.args)
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "float64" \
                and id(node) not in compare_members:
            root = _dotted(node).split(".")[0]
            if root in ("np", "numpy", "jnp", "jax"):
                out.append(Finding(
                    path, node.lineno, node.col_offset, "f64-literal",
                    f"explicit {_dotted(node)}: dead under the default "
                    "x64-disabled runtime, 2x memory traffic if enabled"))
    for pos in dtype_positions:
        for node in ast.walk(pos):
            if isinstance(node, ast.Constant) \
                    and node.value in ("float64", "f64", "double"):
                out.append(Finding(
                    path, node.lineno, node.col_offset, "f64-literal",
                    f"dtype literal {node.value!r}"))
    return out


def _is_key_consumer(call: ast.Call) -> bool:
    """jax.random sampler call that consumes its key argument."""
    dotted = _dotted(call.func)
    parts = dotted.split(".")
    if "random" not in parts[:-1]:
        return False
    return parts[-1] not in _KEY_DERIVERS


def _branch_path(stack: Tuple[Tuple[int, str], ...]) -> Tuple:
    return stack


class _KeyReuseVisitor(ast.NodeVisitor):
    """Per-function key-consumption tracker.

    A *consumption* is passing name K as the key (first) argument of a
    ``jax.random.<sampler>`` call. Two consumptions of the same name
    conflict when no reassignment of K sits between them and neither lives
    in a sibling branch of the other (if/else arms are alternative paths).
    """

    def __init__(self, path: str) -> None:
        self.path = path
        self.findings: List[Finding] = []
        # name -> list of (branch_path, lineno, col)
        self._uses: Dict[str, List[Tuple[Tuple, int, int]]] = {}
        self._branch: List[Tuple[int, str]] = []

    def _conflicts(self, a: Tuple, b: Tuple) -> bool:
        # same path, or one path is an ancestor of the other
        shorter, longer = sorted((a, b), key=len)
        return longer[:len(shorter)] == shorter

    def _consume(self, name: str, node: ast.AST) -> None:
        here = tuple(self._branch)
        for prev_path, line, _col in self._uses.get(name, []):
            if self._conflicts(prev_path, here):
                self.findings.append(Finding(
                    self.path, node.lineno, node.col_offset, "key-reuse",
                    f"key {name!r} already consumed at line {line}; "
                    "split/fold_in before sampling again"))
                break
        self._uses.setdefault(name, []).append(
            (here, node.lineno, node.col_offset))

    def _reassign(self, name: str) -> None:
        self._uses.pop(name, None)

    def visit_Call(self, node: ast.Call) -> None:
        if _is_key_consumer(node) and node.args:
            key_arg = node.args[0]
            if isinstance(key_arg, ast.Name):
                self._consume(key_arg.id, node)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)  # RHS consumption first
        for t in node.targets:
            for name in _assigned_names(t):
                self._reassign(name)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.generic_visit(node)
        for name in _assigned_names(node.target):
            self._reassign(name)

    def visit_For(self, node: ast.For) -> None:
        # loop bodies execute repeatedly: a single consumption inside the
        # body of a loop is a reuse across iterations UNLESS the key is
        # derived fresh per iteration — approximated by treating the loop
        # target as a reassignment and keeping body uses in their own
        # branch path (distinct per visit, so same-body pairs still flag)
        for name in _assigned_names(node.target):
            self._reassign(name)
        self._branch.append((node.lineno, "for"))
        for stmt in node.body:
            self.visit(stmt)
        self._branch.pop()
        for stmt in node.orelse:
            self.visit(stmt)

    def _drop_prefix(self, prefix: Tuple) -> None:
        for name in list(self._uses):
            kept = [u for u in self._uses[name]
                    if u[0][:len(prefix)] != prefix]
            if kept:
                self._uses[name] = kept
            else:
                del self._uses[name]

    @staticmethod
    def _terminates(stmts: List[ast.stmt]) -> bool:
        return bool(stmts) and isinstance(
            stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))

    def visit_If(self, node: ast.If) -> None:
        self.visit(node.test)
        for arm, body in (("if", node.body), ("else", node.orelse)):
            self._branch.append((node.lineno, arm))
            prefix = tuple(self._branch)
            for stmt in body:
                self.visit(stmt)
            self._branch.pop()
            if self._terminates(body):
                # a returning/raising arm can't flow into later code: its
                # consumptions die with it (`if ...: return sample(k)` then
                # `return other_sample(k)` is NOT a reuse)
                self._drop_prefix(prefix)

    def _skip_nested(self, node) -> None:
        pass  # nested defs get their own visitor pass

    visit_FunctionDef = _skip_nested
    visit_AsyncFunctionDef = _skip_nested
    visit_Lambda = _skip_nested


def _rule_key_reuse(tree: ast.Module, path: str) -> List[Finding]:
    out: List[Finding] = []
    scopes = [n for n in ast.walk(tree)
              if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in scopes:
        visitor = _KeyReuseVisitor(path)
        for stmt in fn.body:
            visitor.visit(stmt)
        out.extend(visitor.findings)
    return out


def _rule_traced_branch(fn: ast.AST, tainted: Set[str],
                        path: str) -> List[Finding]:
    out = []
    own_nested = {id(sub) for n in ast.walk(fn)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and n is not fn
                  for sub in ast.walk(n)}
    for node in ast.walk(fn):
        if id(node) in own_nested:
            continue
        if isinstance(node, (ast.If, ast.While)):
            refs = _value_refs(node.test, tainted)
            if refs:
                kind = "while" if isinstance(node, ast.While) else "if"
                out.append(Finding(
                    path, node.lineno, node.col_offset, "traced-branch",
                    f"python `{kind}` on likely-traced {refs[0].id!r} "
                    "inside a traced function; use jnp.where/lax.cond or "
                    "guard with isinstance(x, jax.Array)"))
    return out


_HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_HOST_SYNC_CALLS = {"float", "int", "bool"}
_HOST_SYNC_NP = {"asarray", "array", "copyto"}


def _rule_host_sync(fn: ast.AST, tainted: Set[str],
                    path: str) -> List[Finding]:
    out = []
    own_nested = {id(sub) for n in ast.walk(fn)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and n is not fn
                  for sub in ast.walk(n)}
    for node in ast.walk(fn):
        if id(node) in own_nested or not isinstance(node, ast.Call):
            continue
        func = node.func
        hit = None
        if (isinstance(func, ast.Attribute)
                and func.attr in _HOST_SYNC_METHODS
                and _value_refs(func.value, tainted)):
            hit = f".{func.attr}()"
        elif (isinstance(func, ast.Name) and func.id in _HOST_SYNC_CALLS
              and node.args and _value_refs(node.args[0], tainted)):
            hit = f"{func.id}()"
        elif (isinstance(func, ast.Attribute)
              and func.attr in _HOST_SYNC_NP
              and _dotted(func.value).split(".")[0] in ("np", "numpy", "onp")
              and node.args and _value_refs(node.args[0], tainted)):
            hit = f"np.{func.attr}()"
        elif _dotted(func) in ("jax.device_get",) and node.args \
                and _value_refs(node.args[0], tainted):
            hit = "jax.device_get()"
        if hit:
            out.append(Finding(
                path, node.lineno, node.col_offset, "host-sync",
                f"{hit} on a likely-traced value inside a traced function "
                "forces a device->host sync (or leaks a tracer)"))
    return out


def _rule_config_replace(fn: ast.AST, tainted: Set[str],
                         path: str) -> List[Finding]:
    """``dataclasses.replace(cfg, field=<traced>)`` inside a traced scope
    must sit under the PR 7 ``isinstance(x, jax.Array)`` guard — detected
    here as: replace() with a tainted kwarg and no ``isinstance`` anywhere
    in the enclosing function (the guard is a sibling branch, so a scope-
    level check is the right granularity for a linter)."""
    has_guard = any(isinstance(n, ast.Call)
                    and _tail_name(n.func) == "isinstance"
                    for n in ast.walk(fn))
    if has_guard:
        return []
    out = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        if _tail_name(node.func) != "replace":
            continue
        dotted = _dotted(node.func)
        looks_dc = dotted.startswith(("dataclasses.", "dc.")) or \
            dotted == "replace"
        if not looks_dc or not node.args:
            continue
        target = node.args[0]
        target_name = _dotted(target)
        if "cfg" not in target_name and "config" not in target_name:
            continue
        bad = [kw.arg for kw in node.keywords
               if kw.value is not None and _value_refs(kw.value, tainted)]
        if bad:
            out.append(Finding(
                path, node.lineno, node.col_offset, "config-replace-guard",
                f"dataclasses.replace on config with traced value(s) "
                f"{bad} inside a traced function without an "
                "isinstance(x, jax.Array) guard (PR 7 pattern) — the "
                "tracer is hashed into the config and retraces per call"))
    return out


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def lint_source(src: str, path: str) -> List[Finding]:
    """All findings for one file's source text (suppressions applied)."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as exc:
        return [Finding(path, exc.lineno or 0, exc.offset or 0,
                        "parse-error", str(exc))]
    findings: List[Finding] = []
    findings += _rule_mutable_default(tree, path)
    findings += _rule_f64_literal(tree, path)
    findings += _rule_key_reuse(tree, path)

    traced = traced_function_names(tree)
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn.name not in traced and not _decorated_traced(fn):
            continue
        tainted = tainted_names(fn)
        findings += _rule_traced_branch(fn, tainted, path)
        findings += _rule_host_sync(fn, tainted, path)
        findings += _rule_config_replace(fn, tainted, path)

    return _apply_suppressions(src, findings)


def _apply_suppressions(src: str, findings: List[Finding]) -> List[Finding]:
    lines = src.splitlines()
    file_disabled: Set[str] = set()
    line_disabled: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = _DISABLE_FILE_RE.search(line)
        if m:
            file_disabled.update(r.strip() for r in m.group(1).split(","))
        m = _DISABLE_RE.search(line)
        if m:
            line_disabled[i] = {r.strip() for r in m.group(1).split(",")}
    out = []
    for f in findings:
        if f.rule in file_disabled or "all" in file_disabled:
            continue
        rules = line_disabled.get(f.line, set())
        if f.rule in rules or "all" in rules:
            continue
        out.append(f)
    return sorted(out, key=lambda f: (f.path, f.line, f.col, f.rule))


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for root in paths:
        if os.path.isfile(root):
            files = [root]
        else:
            files = sorted(
                os.path.join(dirpath, name)
                for dirpath, _dirs, names in os.walk(root)
                for name in names if name.endswith(".py"))
        for fp in files:
            with open(fp, encoding="utf-8") as fh:
                findings.extend(lint_source(fh.read(), fp))
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule))


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="repo-specific JAX lint rules (see docs/analysis.md)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule in sorted(RULES):
            print(f"{rule}: {RULES[rule]}")
        return 0
    findings = lint_paths(args.paths)
    if args.json:
        print(json.dumps([f.as_dict() for f in findings], indent=2))
    else:
        for f in findings:
            print(f.format())
        print(f"{len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
