"""Compiled-program contract auditor: the static-analysis gate for every
production entry point of the stage graph.

The paper's portability lesson is that program-level properties — kernel
fusion, memory traffic, host<->device movement — decide whether a port is
fast, and that they silently regress when code is retargeted. This module
pins them the way ADC SHA goldens pin numerics: every production executor is
traced and compiled (on fake devices, CPU backend), a *contract* is
extracted from the compiled text via ``repro.analysis.hlo``, and the result
is diffed against the committed ``AUDIT_contracts.json`` baseline.

Per-program contract fields:

  collectives       : instruction count per collective kind (nonzero only)
  dtypes            : every dtype appearing in the program (f64 = hard fail)
  scatter_dtypes    : scatter-accumulation output dtypes (bf16/f16 = fail)
  donated_args      : donation requested at the jit boundary
  realized_aliases  : input->output aliases the executable established
  host_calls        : host round-trips compiled into the program (must be 0)
  recompiles        : jit-cache misses beyond the first same-shape call

Hard policy (baseline-independent): no f64, no host calls, no bf16/f16
scatter accumulation, no recompiles, and no collective kinds outside what
the program's data-movement strategy declares (``repro.tune`` strategy
metadata for single-device programs; ``SCATTER_REDUCTION_COLLECTIVES`` for
the distributed executor). Everything else — counts drifting, donation
vanishing, a new dtype appearing — fails only against the baseline, and
``--update`` refreshes it when the change is intentional.

Usage (the CI ``audit`` job):

    PYTHONPATH=src python -m repro.analysis.audit --check            # gate
    PYTHONPATH=src python -m repro.analysis.audit --update           # re-pin
    PYTHONPATH=src python -m repro.analysis.audit --check --json out.json

``--inject`` seeds a deliberate regression (f64 cast, disabled donation,
host callback, per-plane collective chains) so the gate's failure mode is
itself testable — the fault-injection pattern of ``repro.testing.faults``.

jax is imported lazily: ``main`` forces the fake-device count and the CPU
backend *before* the first jax import, exactly like ``launch/fit.py``.
"""
from __future__ import annotations

import argparse
import dataclasses
import fnmatch
import json
import os
import sys
import warnings
from typing import Callable, Dict, List, Optional, Tuple

from repro.analysis import hlo

#: default committed baseline, at the repo root next to BENCH_*.json
DEFAULT_BASELINE = "AUDIT_contracts.json"
SCHEMA_VERSION = 1

#: seeded-regression modes (see ``--inject``): each perturbs exactly the
#: property the auditor claims to pin, so tests can prove the gate trips
INJECT_MODES = ("f64_noise", "x64", "no_donate", "host_callback",
                "extra_collective")

#: collective kinds each distributed scatter-reduction strategy is allowed
#: to emit (the pencil FFT's all-to-all chain rides along in both).
#: ``psum_scatter`` reduces partial grids with one reduce-scatter per mesh
#: axis; ``halo`` psums strips over the non-halo axes (all-reduce) and ring-
#: exchanges margins (collective-permute).
SCATTER_REDUCTION_COLLECTIVES = {
    "psum_scatter": ("reduce-scatter", "all-to-all", "all-reduce"),
    "halo": ("all-reduce", "collective-permute", "all-to-all"),
}


@dataclasses.dataclass(frozen=True)
class AuditContext:
    """Everything a program builder needs."""

    cfg: object               # the pinned audit LArTPCConfig
    planes: int
    devices: int
    inject: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class AuditProgram:
    """One production entry point the auditor compiles.

    build   : ``ctx -> (jitfn, make_args)`` — ``make_args(i)`` builds FRESH
              operands for call ``i`` (the recompile detector re-invokes).
    planes  : plane counts this program is audited at.
    needs_devices : minimum device count (distributed programs).
    collective_source : which data-movement strategy bounds the allowed
              collective kinds — "none" means the single-device policy
              (only kinds declared by ``repro.tune`` strategy metadata).
    """

    name: str
    build: Callable[[AuditContext], Tuple[object, Callable[[int], tuple]]]
    planes: Tuple[int, ...] = (1, 3)
    needs_devices: int = 1
    collective_source: str = "none"


def audit_config(planes: int = 1):
    """The pinned audit workload: the smoke config with every ``"auto"``
    strategy field made explicit, so contracts cannot drift with the
    on-disk tuning cache (the audit is hermetic by construction)."""
    import dataclasses as dc

    from repro.config import get_config

    cfg = get_config("lartpc-uboone", smoke=True)
    repl = {"hitfind_strategy": "scan"}
    if planes > 1:
        repl["num_planes"] = planes
    return dc.replace(cfg, **repl)


# ---------------------------------------------------------------------------
# Program builders (jax imported lazily inside each)
# ---------------------------------------------------------------------------


def _x64_trace(ctx: AuditContext) -> bool:
    return ctx.inject in ("x64", "f64_noise")


def _fold_key(i: int):
    import jax

    return jax.random.fold_in(jax.random.key(0), i)


def _single_graph(ctx: AuditContext, recon: bool = False):
    """The single-event graph, with the seeded host-callback / f64-cast
    regressions spliced into the noise stage when injected."""
    import jax
    import jax.numpy as jnp

    from repro.core.stages import build_sim_graph

    graph = build_sim_graph(ctx.cfg, None, recon=recon)
    orig = graph.stage("noise").fn
    if ctx.inject == "host_callback" and not recon:

        def host_noise(state):
            state = orig(state)
            sig = jax.pure_callback(
                lambda x: x,
                jax.ShapeDtypeStruct(state.signal.shape, state.signal.dtype),
                state.signal)
            return state._replace(signal=sig)

        graph = graph.replace(noise=host_noise)
    if ctx.inject == "f64_noise" and not recon:

        def f64_noise(state):
            state = orig(state)
            # a genuine f64 compute step (the *1+eps blocks XLA from
            # eliding the convert pair); requires x64 tracing to survive.
            # repro-lint suppressions: this injection exists to PROVE the
            # auditor catches exactly this.
            sig = (state.signal.astype(jnp.float64)  # repro-lint: disable=f64-literal
                   * jnp.float64(1.0 + 1e-12)).astype(jnp.float32)  # repro-lint: disable=f64-literal
            return state._replace(signal=sig)

        graph = graph.replace(noise=f64_noise)
    return graph


def _build_single(ctx: AuditContext):
    import jax

    from repro.core.depo import generate_physical_depos

    fn = jax.jit(_single_graph(ctx).run)

    def make_args(i):
        key = _fold_key(i)
        return key, generate_physical_depos(key, ctx.cfg)

    return fn, make_args


def _build_recon(ctx: AuditContext):
    import jax

    from repro.core.depo import generate_physical_depos

    fn = jax.jit(_single_graph(ctx, recon=True).run)

    def make_args(i):
        key = _fold_key(i)
        return key, generate_physical_depos(key, ctx.cfg)

    return fn, make_args


def _batch_args(ctx: AuditContext, i: int, events: int = 2):
    import jax

    from repro.core.batch import event_keys, pack_events
    from repro.core.depo import generate_depos, generate_plane_depos

    gen = generate_plane_depos if ctx.planes > 1 else generate_depos
    key = _fold_key(i)
    evs = [gen(jax.random.fold_in(key, e), ctx.cfg) for e in range(events)]
    return event_keys(key, range(events)), pack_events(evs)


def _build_batched(ctx: AuditContext):
    from repro.core.batch import make_batched_sim_fn

    return make_batched_sim_fn(ctx.cfg), lambda i: _batch_args(ctx, i)


def _build_streaming(ctx: AuditContext):
    """The device program ``stream_simulate`` drives, with the donation the
    streaming policy requests on accelerators — the request is captured at
    the jit boundary, so it is auditable even on CPU where XLA never
    realizes an alias for these shapes."""
    from repro.launch.sim import make_streaming_sim_fn, stream_donation

    donate = False if ctx.inject == "no_donate" else stream_donation("tpu")
    return (make_streaming_sim_fn(ctx.cfg, donate=donate),
            lambda i: _batch_args(ctx, i))


def _dist_setup(ctx: AuditContext, shape: Optional[Tuple[int, int]] = None):
    import jax

    from repro.core.distributed import padded_grid_shape
    from repro.core.response import (make_distributed_plane_responses,
                                     make_distributed_response)

    n_dev = ctx.devices
    if shape is None:  # the examples/sim_distributed.py convention
        shape = (n_dev // 2, 2) if n_dev % 2 == 0 else (n_dev, 1)
    mesh = jax.make_mesh(shape, ("data", "model"))
    w_pad, _, _ = padded_grid_shape(ctx.cfg, n_dev)
    resp = (make_distributed_plane_responses(ctx.cfg, w_pad)
            if ctx.planes > 1 else make_distributed_response(ctx.cfg, w_pad))
    return mesh, resp, w_pad


def _build_distributed_psum(ctx: AuditContext):
    import dataclasses as dc

    from repro.core.distributed import make_distributed_sim, shard_depos
    from repro.core.depo import generate_depos, generate_physical_depos

    cfg = ctx.cfg
    if ctx.inject == "extra_collective" and ctx.planes > 1:
        # the PR 9 regression: per-plane collective chains instead of one
        cfg = dc.replace(cfg, plane_batching="loop")
    mesh, resp, _ = _dist_setup(ctx)
    fn = make_distributed_sim(mesh, cfg, resp)
    gen = generate_physical_depos if ctx.planes > 1 else generate_depos

    def make_args(i):
        key = _fold_key(i)
        return key, shard_depos(gen(key, cfg), mesh)

    return fn, make_args


def _build_distributed_halo(ctx: AuditContext):
    from repro.core.distributed import (bin_depos_by_wire,
                                       make_distributed_sim, shard_depos)
    from repro.core.depo import generate_depos

    # halo strips live on the FIRST mesh axis: put every device there so
    # the ring exchange is a real neighbour pattern, not a 1-strip no-op
    mesh, resp, w_pad = _dist_setup(ctx, shape=(ctx.devices, 1))
    fn = make_distributed_sim(mesh, ctx.cfg, resp,
                              scatter_reduction="halo")
    n_strips = mesh.shape["data"]
    # one fixed event: the binning pads each strip's bucket to a DATA-
    # dependent max, so per-call fresh events would change the depo shape
    # and read as (false) recompiles; fresh shard_depos still re-stages
    binned = bin_depos_by_wire(generate_depos(_fold_key(0), ctx.cfg),
                               n_strips=n_strips, w_pad=w_pad)

    def make_args(i):
        return _fold_key(i), shard_depos(binned, mesh)

    return fn, make_args


def _fit_pieces(ctx: AuditContext):
    import jax

    from repro.core.fit import (make_fit_loss, make_fit_targets,
                                spec_from_names)

    cfg = ctx.cfg
    spec = spec_from_names(("electron_lifetime_us", "recombination"), cfg)
    targets = make_fit_targets(cfg, jax.random.key(7), num_events=2)
    loss = make_fit_loss(cfg, spec, targets)
    theta0 = spec.init_theta(cfg)
    return loss, theta0


def _build_fit_loss(ctx: AuditContext):
    import jax

    loss, theta0 = _fit_pieces(ctx)
    return jax.jit(loss), lambda i: (theta0 + 0.0,)


def _build_fit_grad(ctx: AuditContext):
    import jax

    loss, theta0 = _fit_pieces(ctx)
    return jax.jit(jax.grad(loss)), lambda i: (theta0 + 0.0,)


#: the auditable production surface: all four executors + recon + fit.
#: (fit programs are single-plane: the calibration path's contract is
#: plane-count independent — the loss vmaps the same graph.)
PROGRAMS: Tuple[AuditProgram, ...] = (
    AuditProgram("single", _build_single),
    AuditProgram("batched", _build_batched),
    AuditProgram("streaming", _build_streaming),
    AuditProgram("recon", _build_recon),
    AuditProgram("distributed_psum", _build_distributed_psum,
                 needs_devices=2, collective_source="psum_scatter"),
    AuditProgram("distributed_halo", _build_distributed_halo, planes=(1,),
                 needs_devices=2, collective_source="halo"),
    AuditProgram("fit_loss", _build_fit_loss, planes=(1,)),
    AuditProgram("fit_grad", _build_fit_grad, planes=(1,)),
)


def program_names(planes: Tuple[int, ...] = (1, 3)) -> List[str]:
    """Every contract name ``collect_contracts`` emits for ``planes``."""
    return [f"p{p}/{prog.name}" for p in planes for prog in PROGRAMS
            if p in prog.planes]


# ---------------------------------------------------------------------------
# Contract extraction
# ---------------------------------------------------------------------------


def extract_contract(jitfn, make_args, *, x64: bool = False) -> Dict:
    """Compile ``jitfn`` on ``make_args(0)`` and distill its contract."""
    import contextlib

    import jax

    ctx = (jax.experimental.enable_x64() if x64
           else contextlib.nullcontext())
    with ctx, warnings.catch_warnings():
        # donated-but-unusable buffers warn per lowering; the *contract*
        # records that state explicitly (donated_args vs realized_aliases)
        warnings.simplefilter("ignore")
        lowered = jitfn.lower(*make_args(0))
        compiled = lowered.compile()
        txt = compiled.as_text()
        recompiles = (hlo.recompile_misses(jitfn, make_args)
                      if hasattr(jitfn, "_cache_size") else 0)
    return {
        "collectives": {k: n for k, n in hlo.collective_counts(txt).items()
                        if n},
        "dtypes": sorted(hlo.dtype_census(txt)),
        "scatter_dtypes": sorted(hlo.scatter_output_dtypes(txt)),
        "donated_args": hlo.donated_arg_count(lowered),
        "realized_aliases": hlo.realized_alias_count(txt),
        "host_calls": hlo.host_call_count(txt),
        "recompiles": recompiles,
    }


def collect_contracts(planes: Tuple[int, ...] = (1, 3), devices: int = 2,
                      patterns: Optional[List[str]] = None,
                      inject: Optional[str] = None,
                      log: Callable[[str], None] = lambda s: None) -> Dict:
    """Compile every (selected) production program and extract contracts.

    Returns ``{name: contract}`` with names ``p<planes>/<program>``.
    ``patterns`` restricts by fnmatch glob; ``inject`` seeds a deliberate
    regression (see ``INJECT_MODES``).
    """
    if inject is not None and inject not in INJECT_MODES:
        raise ValueError(f"unknown inject mode {inject!r}; "
                         f"known: {INJECT_MODES}")
    out: Dict[str, Dict] = {}
    for p in planes:
        cfg = audit_config(p)
        ctx = AuditContext(cfg=cfg, planes=p, devices=devices, inject=inject)
        for prog in PROGRAMS:
            if p not in prog.planes:
                continue
            name = f"p{p}/{prog.name}"
            if patterns and not any(fnmatch.fnmatch(name, pat)
                                    for pat in patterns):
                continue
            if devices < prog.needs_devices:
                log(f"skip {name}: needs >= {prog.needs_devices} devices "
                    f"(have {devices})")
                continue
            log(f"compile {name} ...")
            jitfn, make_args = prog.build(ctx)
            out[name] = extract_contract(jitfn, make_args,
                                         x64=_x64_trace(ctx))
    return out


# ---------------------------------------------------------------------------
# Policy (baseline-independent invariants)
# ---------------------------------------------------------------------------


def _declared_local_collectives() -> set:
    """Collective kinds any registered single-device strategy declares it
    may emit (``repro.tune`` strategy metadata) — empty today, so the local
    executors' policy is collective-free programs."""
    from repro.tune import registry

    return set(registry.declared_collectives())


def _program_for(name: str) -> Optional[AuditProgram]:
    base = name.split("/", 1)[-1]
    for prog in PROGRAMS:
        if prog.name == base:
            return prog
    return None


def policy_violations(name: str, contract: Dict) -> List[str]:
    """Hard invariants a contract must satisfy regardless of the baseline."""
    v = []
    if "f64" in contract["dtypes"]:
        v.append("f64 present (x64 leak or explicit double cast: every f64 "
                 "value doubles memory traffic on accelerator paths)")
    if contract["host_calls"]:
        v.append(f"{contract['host_calls']} host call(s) compiled into a "
                 "jitted path (python callback / infeed: a device<->host "
                 "round-trip per execution)")
    bad_acc = set(contract["scatter_dtypes"]) & {"bf16", "f16"}
    if bad_acc:
        v.append(f"scatter accumulates in {sorted(bad_acc)} — bf16 paths "
                 "must accumulate in f32 (PR 3 memory-traffic contract)")
    if contract["recompiles"]:
        v.append(f"{contract['recompiles']} jit-cache miss(es) on repeated "
                 "same-shape calls (silent recompilation)")
    prog = _program_for(name)
    observed = set(contract["collectives"])
    if prog is None or prog.collective_source == "none":
        allowed = _declared_local_collectives()
    else:
        allowed = set(SCATTER_REDUCTION_COLLECTIVES[prog.collective_source])
    extra = observed - allowed
    if extra:
        v.append(f"collective kind(s) {sorted(extra)} outside the declared "
                 f"set {sorted(allowed)} for this program's data-movement "
                 "strategy")
    return v


# ---------------------------------------------------------------------------
# Baseline diff (the check_regression glob-gating machinery, for contracts)
# ---------------------------------------------------------------------------


def expand_contract_names(patterns: List[str], baseline: Dict,
                          fresh: Dict) -> List[str]:
    """Expand ``--programs`` globs against baseline+fresh contract names.

    Same semantics as ``benchmarks/check_regression.expand_records``: a glob
    matching no *baseline* contract gates nothing run after run, so it
    returns [] (the caller fails loudly); plain names pass through so a
    fully missing contract still reports as MISSING.
    """
    known = sorted(set(baseline) | set(fresh))
    names: List[str] = []
    for pat in patterns:
        if any(c in pat for c in "*?["):
            hits = [n for n in known if fnmatch.fnmatch(n, pat)]
            if not hits:
                print(f"error: --programs pattern {pat!r} matched no "
                      "contracts", file=sys.stderr)
                return []
            if not any(h in baseline for h in hits):
                print(f"error: --programs pattern {pat!r} matched no "
                      "BASELINE contracts — commit the baseline "
                      "(--update) or fix the pattern", file=sys.stderr)
                return []
            names.extend(h for h in hits if h not in names)
        elif pat not in names:
            names.append(pat)
    return names


def diff_contracts(baseline: Dict, fresh: Dict,
                   patterns: Optional[List[str]] = None) -> int:
    """Print a per-contract diff table; return 1 on drift or policy
    violation, 0 when every gated contract matches."""
    patterns = patterns or sorted(
        {n.split("/", 1)[0] + "/*" for n in fresh})
    names = expand_contract_names(patterns, baseline, fresh)
    if not names:
        return 1
    failed = False
    for name in names:
        b, f = baseline.get(name), fresh.get(name)
        if f is None:
            print(f"{name}: MISSING from fresh run (program vanished or "
                  "was skipped)  FAIL")
            failed = True
            continue
        problems = []
        if b is None:
            print(f"{name}: (new — not in baseline; --update to pin)")
        else:
            for field in sorted(set(b) | set(f)):
                if b.get(field) != f.get(field):
                    problems.append(
                        f"  {field}: {b.get(field)!r} -> {f.get(field)!r}")
        for viol in policy_violations(name, f):
            problems.append(f"  policy: {viol}")
        if problems:
            print(f"{name}: FAIL")
            for line in problems:
                print(line)
            failed = True
        elif b is not None:
            print(f"{name}: ok")
    print(f"gated {len(names)} contract(s)")
    if failed:
        print("\ncontract drift: the compiled-program contract changed — "
              "if intentional, refresh with "
              "`python -m repro.analysis.audit --update` (docs/analysis.md)",
              file=sys.stderr)
    return 1 if failed else 0


def load_baseline(path: str) -> Dict:
    with open(path) as fh:
        data = json.load(fh)
    if data.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"baseline {path!r} has schema "
                         f"{data.get('schema')!r}, expected {SCHEMA_VERSION}")
    return data["contracts"]


def write_baseline(path: str, contracts: Dict, devices: int,
                   merge_into: Optional[str] = None) -> None:
    merged: Dict[str, Dict] = {}
    if merge_into and os.path.exists(merge_into):
        try:
            merged = load_baseline(merge_into)
        except (ValueError, KeyError, json.JSONDecodeError):
            merged = {}
    merged.update(contracts)
    import jax

    data = {
        "schema": SCHEMA_VERSION,
        "devices": devices,
        "backend": jax.default_backend(),
        "note": "compiled-program contracts; refresh with "
                "`python -m repro.analysis.audit --update` "
                "(see docs/analysis.md)",
        "contracts": {k: merged[k] for k in sorted(merged)},
    }
    with open(path, "w") as fh:
        json.dump(data, fh, indent=2, sort_keys=False)
        fh.write("\n")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _parse_planes(text: str) -> Tuple[int, ...]:
    try:
        planes = tuple(int(p) for p in text.split(",") if p)
    except ValueError:
        raise SystemExit(f"--planes expects e.g. '1,3', got {text!r}")
    if not planes:
        raise SystemExit("--planes expects at least one plane count")
    return planes


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.audit",
        description="compile every production entry point and check its "
                    "program contract against the committed baseline")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--check", action="store_true",
                      help="diff fresh contracts against --baseline "
                           "(default mode)")
    mode.add_argument("--update", action="store_true",
                      help="regenerate and (re)write --baseline")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"contract baseline path (default {DEFAULT_BASELINE})")
    ap.add_argument("--planes", default="1,3",
                    help="comma-separated plane counts to audit (default 1,3)")
    ap.add_argument("--devices", type=int, default=2,
                    help="forced fake host device count (default 2; "
                         "distributed contracts need >= 2)")
    ap.add_argument("--programs", action="append", default=None,
                    help="contract name or fnmatch glob to gate (repeatable; "
                         "default: every program of the selected planes)")
    ap.add_argument("--json", default=None,
                    help="also write the fresh contracts to this path "
                         "(the CI artifact)")
    ap.add_argument("--inject", default=None, choices=INJECT_MODES,
                    help="seed a deliberate contract regression (test the "
                         "gate itself)")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-program compile progress")
    args = ap.parse_args(argv)

    if "jax" not in sys.modules:
        # force the fake-device fleet and a deterministic backend BEFORE
        # the first jax import (the launch/fit.py lazy-import pattern)
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
    planes = _parse_planes(args.planes)
    log = (lambda s: None) if args.quiet else (
        lambda s: print(f"[audit] {s}", file=sys.stderr))
    fresh = collect_contracts(planes=planes, devices=args.devices,
                              patterns=args.programs, inject=args.inject,
                              log=log)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"schema": SCHEMA_VERSION, "contracts": fresh}, fh,
                      indent=2)
            fh.write("\n")
    if args.update:
        write_baseline(args.baseline, fresh, args.devices,
                       merge_into=args.baseline)
        print(f"wrote {len(fresh)} contract(s) to {args.baseline}")
        return 0
    if not os.path.exists(args.baseline):
        print(f"error: no contract baseline {args.baseline!r} — generate "
              "one with `python -m repro.analysis.audit --update` and "
              "commit it (the audit gate needs a committed baseline, "
              "unlike the bench gate)", file=sys.stderr)
        return 1
    baseline = load_baseline(args.baseline)
    return diff_contracts(baseline, fresh, patterns=args.programs)


if __name__ == "__main__":
    sys.exit(main())
