"""Compiled-program inspection: one home for every HLO-text property check.

The paper's portability argument is program-level: fusion, data movement,
and collective traffic decide whether a port is fast, and those properties
live in the *compiled* program, not the Python source. PR 9 asserted one of
them (collective counts) with an inline ``txt.count(...)`` inside a test;
``launch/hlo_cost.py`` parses the same text for a cost model. This module is
the shared API both — and the contract auditor (``repro.analysis.audit``) —
read compiled programs through:

  collective_counts    : instructions per collective kind (all-reduce,
                         reduce-scatter, all-to-all, all-gather,
                         collective-permute), ``-start`` forms merged and
                         ``-done`` forms skipped so async pairs count once.
  dtype_census         : instruction-output dtypes -> instruction count
                         (the f64-creep / bf16-accumulation detector).
  scatter_output_dtypes: output dtypes of scatter accumulations (the
                         "bf16 paths must accumulate in f32" check).
  host_call_count      : host round-trips compiled INTO the program —
                         python-callback custom-calls, infeed/outfeed,
                         host-transfer send/recv. Must be 0 on jitted paths.
  realized_alias_count : input->output aliases the executable actually
                         established (donation that *took*).
  donated_arg_count    : donation *requested* at the jit boundary (counted
                         from ``Lowered.args_info`` — a donated-but-
                         unaliasable buffer still counts, so disabling
                         ``donate_argnums`` is visible even when shapes
                         never alias).
  recompile_misses     : jit-cache misses beyond the first call across
                         repeated same-shape calls (the silent-recompile
                         detector).

Everything here is text/duck-typed on purpose: no jax import, so the module
loads anywhere (including the jax-free lint CI path) and works on HLO text
from any source — a live ``Compiled``, a golden file, a subprocess pipe.
"""
from __future__ import annotations

import re
from typing import Callable, Dict, Iterator, Set, Tuple

#: collective instruction kinds, the cross-device data-movement vocabulary
COLLECTIVE_KINDS = ("all-gather", "all-reduce", "all-to-all",
                    "collective-permute", "reduce-scatter")

#: dtype tokens that appear in HLO shapes (subset of launch/hlo_cost._BYTES)
DTYPE_TOKENS = ("pred", "s4", "s8", "s16", "s32", "s64", "u4", "u8", "u16",
                "u32", "u64", "f8e4m3fn", "f8e5m2", "f16", "bf16", "f32",
                "f64", "c64", "c128")

# "  %name = f32[2,3]{1,0} opcode(...)" / "  ROOT %r = (f32[], pred[]) op(...)"
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(?:%[\w.\-]+|[\w.\-]+)\s*=\s*(.+?)\s+"
                       r"([\w\-]+)\(")
_DTYPE_RE = re.compile(r"\b(%s)\[" % "|".join(DTYPE_TOKENS))
# one "{out_index}: (param, {param_index}, kind)" entry per realized alias
_ALIAS_ENTRY_RE = re.compile(r"\{[0-9,\s]*\}:\s*\(\d+")
# the map nests one level of {output_index} braces: match them explicitly
# (a lazy .*? would stop at the FIRST nested '}' and undercount)
_ALIAS_MAP_RE = re.compile(
    r"input_output_alias=\{((?:[^{}]|\{[0-9,\s]*\})*)\}")
_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')

#: custom-call targets that round-trip through the host python runtime
_HOST_CALLBACK_MARKERS = ("callback", "py_func", "host_func")


def iter_instructions(hlo: str) -> Iterator[Tuple[str, str, str]]:
    """Yield ``(opcode, output_type_str, full_line)`` per HLO instruction."""
    for line in hlo.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            yield m.group(2), m.group(1), line


def collective_counts(hlo: str) -> Dict[str, int]:
    """Instructions per collective kind (every kind present, zeros kept).

    Async pairs count once: ``all-reduce-start`` folds into ``all-reduce``
    and the matching ``-done`` is skipped — so the count is the number of
    collective *operations* the program performs per execution, which is
    what the paper's data-movement budget (and PR 9's one-chain-per-step
    property) cares about.
    """
    counts = {k: 0 for k in COLLECTIVE_KINDS}
    for op, _, _ in iter_instructions(hlo):
        if op.endswith("-done"):
            continue
        base = op[:-len("-start")] if op.endswith("-start") else op
        if base in counts:
            counts[base] += 1
    return counts


def dtype_census(hlo: str) -> Dict[str, int]:
    """Instruction count per output dtype appearing anywhere in the program.

    Tuple-typed outputs contribute every element dtype. The census is the
    f64-creep detector: a single f64 instruction in a production program
    means a literal, an accidental numpy promotion, or an x64 leak doubled
    someone's memory traffic.
    """
    census: Dict[str, int] = {}
    for _, type_str, _ in iter_instructions(hlo):
        for dt in _DTYPE_RE.findall(type_str):
            census[dt] = census.get(dt, 0) + 1
    return census


def scatter_output_dtypes(hlo: str) -> Set[str]:
    """Output dtypes of ``scatter`` instructions (the accumulation ops).

    The repo's bf16 strategies keep *patches* in bf16 but must accumulate
    the charge grid in f32 (PR 3's memory-traffic contract); a bf16 scatter
    output means someone dropped the upcast.

    CPU caveat: XLA's scatter expander rewrites scatter into dynamic-
    update-slice loops on CPU, so the set is typically empty there — the
    check has teeth on the accelerator backends, where scatter survives
    (the dtype census still catches bf16 *presence* everywhere).
    """
    out: Set[str] = set()
    for op, type_str, _ in iter_instructions(hlo):
        if op == "scatter":
            out.update(_DTYPE_RE.findall(type_str))
    return out


def host_call_count(hlo: str) -> int:
    """Host round-trips compiled into the program (must be 0 in jitted
    production paths): python-callback custom-calls, infeed/outfeed, and
    host-transfer send/recv. Backend FFT/linalg custom-calls (ducc_fft,
    lapack, cublas, ...) are device-side and do NOT count."""
    n = 0
    for op, _, line in iter_instructions(hlo):
        if op in ("infeed", "outfeed"):
            n += 1
        elif op in ("send", "recv") and "is_host_transfer=true" in line:
            n += 1
        elif op == "custom-call":
            m = _TARGET_RE.search(line)
            target = (m.group(1) if m else "").lower()
            if any(s in target for s in _HOST_CALLBACK_MARKERS):
                n += 1
    return n


def realized_alias_count(hlo: str) -> int:
    """Input->output aliases the compiled executable established.

    Parsed from the module header's ``input_output_alias={ ... }`` map; a
    program whose donation never took (no shape/dtype-compatible output)
    has no header entry and counts 0 — pair with ``donated_arg_count`` to
    tell "donation disabled" apart from "donation unusable"."""
    m = _ALIAS_MAP_RE.search(hlo)
    if not m:
        return 0
    return len(_ALIAS_ENTRY_RE.findall(m.group(1)))


def donated_arg_count(lowered) -> int:
    """Number of donated argument buffers of a ``jax.stages.Lowered``.

    Counted from ``args_info`` (the jit-boundary donation *request*), so it
    is independent of whether XLA could alias anything — removing
    ``donate_argnums`` from an executor changes this count even when every
    realized alias count was already 0.
    """
    import jax  # local: keep this module importable without jax

    n = 0
    for info in jax.tree.leaves(lowered.args_info,
                                is_leaf=lambda x: hasattr(x, "donated")):
        n += bool(getattr(info, "donated", False))
    return n


def recompile_misses(jitfn, make_args: Callable[[int], tuple],
                     calls: int = 2) -> int:
    """Jit-cache misses beyond the first call across ``calls`` same-shape
    calls of ``jitfn`` (``make_args(i)`` builds FRESH operands per call, so
    donated buffers are never re-used). 0 means the program is trace-stable;
    anything else is a silent recompile — a weak-typed literal flipping per
    call, a python-hashed closure, a shape leak."""
    import jax

    before = jitfn._cache_size()
    for i in range(calls):
        out = jitfn(*make_args(i))
        jax.block_until_ready(out)
    return max(jitfn._cache_size() - before, 1) - 1
