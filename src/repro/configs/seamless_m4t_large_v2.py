"""seamless-m4t-large-v2 [audio] — enc-dec multimodal backbone
[arXiv:2308.11596; hf].

24L(enc)+24L(dec) d_model=1024 16H d_ff=8192 vocab=256206. The speech
frontend (w2v-BERT conformer feature extractor) is a STUB: ``input_specs``
provides precomputed frame embeddings for the encoder.
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        is_encoder_decoder=True,
        num_layers=24,
        num_encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256206,
        mlp_kind="gelu",
        norm_kind="layernorm",
        frontend="speech",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke",
        family="audio",
        is_encoder_decoder=True,
        num_layers=2,
        num_encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        mlp_kind="gelu",
        norm_kind="layernorm",
        frontend="speech",
    )


register("seamless-m4t-large-v2", full, smoke)
