"""mamba2-780m [ssm] — SSD state-space duality [arXiv:2405.21060].

48L d_model=1536, attention-free, vocab=50280, ssm_state=128.
"""
from repro.config import ModelConfig, SSMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        num_heads=48,             # d_inner / head_dim = 3072 / 64
        num_kv_heads=48,
        d_ff=0,
        vocab_size=50280,
        attn_kind="none",
        norm_kind="rmsnorm",
        tie_embeddings=True,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                      chunk=256),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=128,
        attn_kind="none",
        tie_embeddings=True,
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, conv_width=4,
                      chunk=16),
    )


register("mamba2-780m", full, smoke)
