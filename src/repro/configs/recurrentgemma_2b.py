"""recurrentgemma-2b [hybrid] — RG-LRU + local attention 1:2 (Griffin)
[arXiv:2402.19427; hf].

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, window 2048.
26 layers = 8 full (rec, rec, attn) groups + a (rec, rec) tail.
Bounded state -> ``long_500k`` runs.
"""
from repro.config import ModelConfig, RGLRUConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        attn_kind="local",
        window_size=2048,
        mlp_kind="gelu",
        norm_kind="rmsnorm",
        tie_embeddings=True,
        embedding_scale=True,
        rglru=RGLRUConfig(lru_width=2560, conv_width=4,
                          block_pattern=("recurrent", "recurrent", "attention")),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        num_layers=5,  # 1 full group + (rec, rec) tail
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        attn_kind="local",
        window_size=8,
        mlp_kind="gelu",
        tie_embeddings=True,
        rglru=RGLRUConfig(lru_width=64, conv_width=4,
                          block_pattern=("recurrent", "recurrent", "attention")),
    )


register("recurrentgemma-2b", full, smoke)
