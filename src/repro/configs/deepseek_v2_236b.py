"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].

60L d_model=5120 128H expert d_ff=1536 vocab=102400; first layer dense
(d_ff=12288). The MLA compressed KV cache (512+64 per token, all heads) is
what makes the 32k/500k decode shapes cheap.
"""
from repro.config import MLAConfig, ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        d_ff=1536,
        vocab_size=102400,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=160, num_shared=2, top_k=6, expert_ff=1536,
                      first_moe_layer=1, dense_ff=12288),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        vocab_size=128,
        mla=MLAConfig(kv_lora_rank=32, rope_head_dim=8, nope_head_dim=16,
                      v_head_dim=16),
        moe=MoEConfig(num_experts=8, num_shared=2, top_k=2, expert_ff=32,
                      first_moe_layer=1, dense_ff=128),
    )


register("deepseek-v2-236b", full, smoke)
