"""stablelm-12b [dense] — GQA [hf:stabilityai/stablelm-2-12b].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=160,
        d_ff=13824,
        vocab_size=100352,
        mlp_kind="swiglu",
        norm_kind="layernorm",
        qk_norm=True,            # stablelm-2 uses per-head qk layernorm
        rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        norm_kind="layernorm",
        qk_norm=True,
    )


register("stablelm-12b", full, smoke)
