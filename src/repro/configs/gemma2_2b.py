"""gemma2-2b [dense] — local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
``long_500k`` is SKIPPED for this arch: the global layers are full
quadratic attention (see DESIGN.md §Arch-applicability).
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        num_layers=26,
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        attn_kind="local_global",
        window_size=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        mlp_kind="gelu",
        norm_kind="rmsnorm",
        tie_embeddings=True,
        embedding_scale=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        attn_kind="local_global",
        window_size=8,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        mlp_kind="gelu",
        tie_embeddings=True,
        embedding_scale=True,
    )


register("gemma2-2b", full, smoke)
