"""qwen3-32b [dense] — qk-norm, GQA [hf:Qwen/Qwen3-8B family scaling].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=25600,
        vocab_size=151936,
        qk_norm=True,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        qk_norm=True,
    )


register("qwen3-32b", full, smoke)
