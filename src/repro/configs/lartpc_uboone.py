"""The paper's own workload: MicroBooNE-scale LArTPC signal simulation."""
from repro.config import LArTPCConfig, register


def full() -> LArTPCConfig:
    return LArTPCConfig()  # 2560 wires x 9592 ticks, 100k depos


def smoke() -> LArTPCConfig:
    return LArTPCConfig(num_wires=128, num_ticks=512, num_depos=256,
                        response_wires=11, response_ticks=64)


register("lartpc-uboone", full, smoke)
