"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819].

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        family="dense",
        num_layers=32,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        mlp_kind="squared_relu",
        norm_kind="layernorm",
        rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        mlp_kind="squared_relu",
        norm_kind="layernorm",
    )


register("nemotron-4-15b", full, smoke)
