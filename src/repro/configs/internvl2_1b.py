"""internvl2-1b [vlm] — InternViT + Qwen2-0.5B backbone [arXiv:2404.16821; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The InternViT vision
frontend is a STUB: ``input_specs`` provides precomputed patch embeddings.
"""
from repro.config import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab_size=151655,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        frontend="vision",
        frontend_tokens=256,      # 448x448 / 14px patches, pixel-shuffled
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=128,
        tie_embeddings=True,
        frontend="vision",
        frontend_tokens=8,
    )


register("internvl2-1b", full, smoke)
