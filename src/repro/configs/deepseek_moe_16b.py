"""deepseek-moe-16b [moe] — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066; hf].

28L d_model=2048 16H (MHA kv=16) expert d_ff=1408 vocab=102400; first layer
is a dense FFN (d_ff=10944).
"""
from repro.config import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=102400,
        mlp_kind="swiglu",
        norm_kind="rmsnorm",
        moe=MoEConfig(num_experts=64, num_shared=2, top_k=6, expert_ff=1408,
                      first_moe_layer=1, dense_ff=10944),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=32,
        vocab_size=128,
        moe=MoEConfig(num_experts=8, num_shared=2, top_k=2, expert_ff=32,
                      first_moe_layer=1, dense_ff=128),
    )


register("deepseek-moe-16b", full, smoke)
