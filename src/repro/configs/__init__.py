"""Architecture registry: one module per assigned architecture.

Importing this package registers every arch under its ``--arch <id>``.
"""
from repro.configs import (  # noqa: F401
    deepseek_moe_16b,
    deepseek_v2_236b,
    gemma2_2b,
    internvl2_1b,
    lartpc_uboone,
    mamba2_780m,
    nemotron4_15b,
    qwen3_32b,
    recurrentgemma_2b,
    seamless_m4t_large_v2,
    stablelm_12b,
)

ARCH_IDS = [
    "mamba2-780m",
    "internvl2-1b",
    "qwen3-32b",
    "nemotron-4-15b",
    "gemma2-2b",
    "stablelm-12b",
    "deepseek-moe-16b",
    "deepseek-v2-236b",
    "recurrentgemma-2b",
    "seamless-m4t-large-v2",
]
