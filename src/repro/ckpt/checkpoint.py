"""Fault-tolerant sharded checkpointing (no orbax dependency).

* atomic: write to ``<dir>/tmp.<step>`` then ``os.rename`` to ``step_<N>``
  (a crashed save can never shadow a good checkpoint)
* keep-N rotation
* async: the device->host gather happens synchronously (cheap), the file
  write runs on a background thread
* elastic: leaves are stored as FULL logical arrays + a manifest; restore
  re-shards onto whatever mesh the new job has (different chip count OK)
* stores data-pipeline state + step so restarts are exactly-once
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    # jax.tree.flatten_with_path only exists from jax 0.4.34 onward and was
    # renamed from tree_util; go through tree_util for version portability
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        items.append((key, leaf))
    return items, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict[str, Any]] = None):
        """Snapshot `tree` (gathers to host now, writes in background)."""
        items, _ = _flatten(tree)
        host = [(k, np.asarray(jax.device_get(v))) for k, v in items]
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def _write(self, step: int, host, extra):
        tmp = os.path.join(self.directory, f"tmp.{step}")
        final = os.path.join(self.directory, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for i, (key, arr) in enumerate(host):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._rotate()

    def _rotate(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                # ignore manifests mid-write (no manifest.json yet)
                if os.path.exists(os.path.join(self.directory, name,
                                               "manifest.json")):
                    steps.append(int(name[5:]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree, shardings=None):
        """Restore into the structure of `target_tree`; device_put with
        `shardings` (same structure) if given — elastic re-shard."""
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {l["key"]: l for l in manifest["leaves"]}
        items, treedef = _flatten(target_tree)
        shard_items = (jax.tree.leaves(shardings) if shardings is not None
                       else [None] * len(items))
        leaves = []
        for (key, tgt), sh in zip(items, shard_items):
            entry = by_key[key]
            arr = np.load(os.path.join(d, entry["file"]))
            assert list(arr.shape) == list(tgt.shape), (key, arr.shape, tgt.shape)
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        return jax.tree.unflatten(treedef, leaves), manifest["extra"]
