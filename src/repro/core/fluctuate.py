"""Charge fluctuation — the paper's "Fluctuation" step (Table 2, col 4).

Physics: the patch value p_ij = q·w_ij is a *mean* electron count; the observed
count is Binomial(n=q, p=w_ij). Wire-Cell's serial CPU code draws
``std::binomial_distribution`` per pixel — the paper shows this dominates
runtime (3.42 s of 3.57 s) and serializes the loop. The ports factor the RNG
out into a pre-computed pool (Box–Muller from uniforms).

TPU adaptation: JAX RNG is counter-based (stateless, splittable), so the
paper's bottleneck *does not exist* — each pixel can derive its own stream in
parallel. We implement three strategies to reproduce the paper's comparison:

  counter : normal approximation N(p, sqrt(p(1−q/Q)·…)) with threefry counter
            RNG — the TPU-native way (paper's problem dissolved).
  pool    : paper-faithful pre-computed pool of standard normals (generated
            once, indexed by pixel id modulo pool size) — reproduces the
            ref-CUDA / Kokkos design.
  none    : no fluctuation (paper's ref-CPU-noRNG row).

The normal approximation to Binomial(n, p): mean np, var np(1−p). Here np is
the patch value and p = w_ij, so var = patch·(1−patch/q).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def binomial_normal_approx(patches: jax.Array, charge: jax.Array, normals: jax.Array):
    """Apply binomial fluctuation via normal approximation.

    patches: (N, pw, pt) mean counts; charge: (N,) totals; normals: std normals
    with patches' shape.
    """
    q = jnp.maximum(charge[:, None, None], 1.0)
    p = jnp.clip(patches / q, 0.0, 1.0)
    var = jnp.maximum(patches * (1.0 - p), 0.0)
    out = patches + jnp.sqrt(var) * normals
    return jnp.maximum(out, 0.0)


def fluctuate_counter(key: jax.Array, patches: jax.Array, charge: jax.Array):
    normals = jax.random.normal(key, patches.shape, patches.dtype)
    return binomial_normal_approx(patches, charge, normals)


def binomial_normal_relaxed(patches: jax.Array, charge: jax.Array,
                            normals: jax.Array):
    """The reparameterized (differentiable) form of the binomial draw.

    FORWARD-IDENTICAL to ``binomial_normal_approx`` — for var > 0 the same
    ``sqrt(var)`` is evaluated on the same values, and at var == 0 both
    yield exactly 0 — but the zero-variance branch is masked *before* the
    sqrt, so ``d sqrt(var)/d var = 1/(2 sqrt(var))`` never evaluates at 0
    and reverse-mode gradients through padding rows / empty pixels are 0
    instead of NaN. This is the pathwise (reparameterization) estimator:
    the standard normals are the fixed exogenous noise, and gradients flow
    through the mean (``patches``) and the std ``sqrt(p·q·(1-p))``.
    """
    q = jnp.maximum(charge[:, None, None], 1.0)
    p = jnp.clip(patches / q, 0.0, 1.0)
    var = jnp.maximum(patches * (1.0 - p), 0.0)
    safe = jnp.where(var > 0.0, var, 1.0)
    std = jnp.where(var > 0.0, jnp.sqrt(safe), 0.0)
    out = patches + std * normals
    return jnp.maximum(out, 0.0)


def fluctuate_counter_relaxed(key: jax.Array, patches: jax.Array,
                              charge: jax.Array):
    """``fluctuate_counter`` with finite gradients (``rng_strategy="relaxed"``).

    Draws the SAME threefry normals from the same key, so the sampled
    pipeline stays bit-identical to the default counter strategy; only the
    backward pass differs (no NaN at zero variance). The calibration loss
    (``repro.core.fit``) requires this strategy when ``cfg.fluctuate``.
    """
    normals = jax.random.normal(key, patches.shape, patches.dtype)
    return binomial_normal_relaxed(patches, charge, normals)


def make_pool(key: jax.Array, pool_size: int = 1 << 20) -> jax.Array:
    """Pre-computed standard-normal pool (paper's ref-CUDA/Kokkos strategy)."""
    return jax.random.normal(key, (pool_size,), jnp.float32)


def fluctuate_pool(pool: jax.Array, patches: jax.Array, charge: jax.Array,
                   offset: int = 0):
    """Index the pool by flat pixel id (mod pool size) — no RNG in the loop."""
    n = patches.size
    idx = (jnp.arange(n, dtype=jnp.uint32) + jnp.uint32(offset)) % pool.shape[0]
    normals = pool[idx].reshape(patches.shape)
    return binomial_normal_approx(patches, charge, normals)


def box_muller(u1: jax.Array, u2: jax.Array):
    """Box–Muller transform (paper §4.3.1) — two uniforms -> one std normal.

    Used inside the Pallas rasterize kernel where we hand it a uniform pool.
    """
    r = jnp.sqrt(-2.0 * jnp.log(jnp.maximum(u1, 1e-12)))
    return r * jnp.cos(2.0 * jnp.pi * u2)


# ---------------------------------------------------------------------------
# Counter-hash RNG primitives for the fused Pallas kernel
# ---------------------------------------------------------------------------
#
# The fused rasterize+scatter kernel draws its fluctuation randomness *inside*
# the kernel, seeded per (depo, tile) from the sim key. On compiled TPU it
# uses the hardware PRNG (pltpu.prng_seed / prng_random_bits); everywhere else
# (the Pallas interpreter has no TPU PRNG lowering) it falls back to this
# stateless counter hash: murmur3's 32-bit finalizer over
# (seed, depo, tile, pixel) counters. Both paths feed the same
# bits -> uniform -> Box–Muller chain, so they are statistically
# interchangeable (asserted against `fluctuate_counter` in the tests).


def hash_u32(x: jax.Array) -> jax.Array:
    """murmur3 fmix32: a full-avalanche 32-bit mixer (uint32 -> uint32).

    Pure jnp, so it runs identically under the Pallas interpreter, Mosaic,
    and plain XLA — the portable half of the in-kernel counter RNG.
    """
    x = x.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> jnp.uint32(16))
    return x


def uniform_from_bits(bits: jax.Array) -> jax.Array:
    """uint32 random bits -> float32 uniform in [0, 1) (top 24 bits)."""
    return (bits.astype(jnp.uint32) >> jnp.uint32(8)).astype(
        jnp.float32) * jnp.float32(1.0 / (1 << 24))


def counter_normals(seed0: jax.Array, seed1: jax.Array, stream: jax.Array,
                    counters: jax.Array) -> jax.Array:
    """Std normals from (seed, stream, counter) — the interpret-mode fallback.

    seed0/seed1 : uint32 scalars (the raw sim key data)
    stream      : uint32 scalar identifying the (depo, tile) pair
    counters    : uint32 array of per-pixel counters (any shape)
    Returns float32 std normals with ``counters``' shape. Fully deterministic:
    the same (key, depo, tile, pixel) always yields the same draw, on every
    backend.
    """
    base = hash_u32(seed1 ^ stream) + seed0.astype(jnp.uint32)
    two = jnp.uint32(2)
    # hash the counter BEFORE mixing with the stream base: adding a raw
    # counter to the base makes every stream a contiguous window of one
    # global 32-bit sequence, and at production scale (~2^37 draws/event)
    # windows collide birthday-style — whole pixel runs of unrelated depos
    # would repeat bit-identically. fmix(counter) ^ base has no window
    # structure: cross-stream coincidences drop to the generic per-value
    # birthday rate, and u1/u2 never collide together.
    b1 = hash_u32(base ^ hash_u32(two * counters))
    b2 = hash_u32(base ^ hash_u32(two * counters + jnp.uint32(1)))
    # 1 - u keeps the log argument in (0, 1]; box_muller clamps the rest
    return box_muller(1.0 - uniform_from_bits(b1), uniform_from_bits(b2))


def counter_normals_erfinv(seed0: jax.Array, seed1: jax.Array,
                           stream: jax.Array, counters: jax.Array) -> jax.Array:
    """``counter_normals`` with ONE hash per draw and the erfinv transform.

    Box–Muller burns two hash chains plus a log/sqrt/cos per normal; the
    inverse-CDF route needs one hash and one erfinv — the same
    ``sqrt(2)·erfinv(2u−1)`` transform ``jax.random.normal`` applies to its
    threefry uniforms, so the output distribution is identical to the
    library draw. ~2x cheaper per element on CPU; used by the
    plane-flattened XLA charge-grid strategy where the RNG is the hot loop.
    Same (seed, stream, counter) contract as ``counter_normals`` but a
    DIFFERENT bit stream — strategies using it pin their own goldens.
    """
    base = hash_u32(seed1 ^ stream) + seed0.astype(jnp.uint32)
    bits = hash_u32(base ^ hash_u32(counters))
    u = uniform_from_bits(bits)  # [0, 1)
    # clamp 2u-1 away from -1 exactly as jax.random.normal's minval does,
    # so u == 0 maps to a finite (extreme) draw instead of -inf
    import numpy as np
    lo = np.nextafter(np.float32(-1.0), np.float32(0.0))
    return jnp.float32(np.sqrt(2.0)) * jax.scipy.special.erfinv(
        jnp.maximum(2.0 * u - 1.0, lo))
