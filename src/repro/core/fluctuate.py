"""Charge fluctuation — the paper's "Fluctuation" step (Table 2, col 4).

Physics: the patch value p_ij = q·w_ij is a *mean* electron count; the observed
count is Binomial(n=q, p=w_ij). Wire-Cell's serial CPU code draws
``std::binomial_distribution`` per pixel — the paper shows this dominates
runtime (3.42 s of 3.57 s) and serializes the loop. The ports factor the RNG
out into a pre-computed pool (Box–Muller from uniforms).

TPU adaptation: JAX RNG is counter-based (stateless, splittable), so the
paper's bottleneck *does not exist* — each pixel can derive its own stream in
parallel. We implement three strategies to reproduce the paper's comparison:

  counter : normal approximation N(p, sqrt(p(1−q/Q)·…)) with threefry counter
            RNG — the TPU-native way (paper's problem dissolved).
  pool    : paper-faithful pre-computed pool of standard normals (generated
            once, indexed by pixel id modulo pool size) — reproduces the
            ref-CUDA / Kokkos design.
  none    : no fluctuation (paper's ref-CPU-noRNG row).

The normal approximation to Binomial(n, p): mean np, var np(1−p). Here np is
the patch value and p = w_ij, so var = patch·(1−patch/q).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def binomial_normal_approx(patches: jax.Array, charge: jax.Array, normals: jax.Array):
    """Apply binomial fluctuation via normal approximation.

    patches: (N, pw, pt) mean counts; charge: (N,) totals; normals: std normals
    with patches' shape.
    """
    q = jnp.maximum(charge[:, None, None], 1.0)
    p = jnp.clip(patches / q, 0.0, 1.0)
    var = jnp.maximum(patches * (1.0 - p), 0.0)
    out = patches + jnp.sqrt(var) * normals
    return jnp.maximum(out, 0.0)


def fluctuate_counter(key: jax.Array, patches: jax.Array, charge: jax.Array):
    normals = jax.random.normal(key, patches.shape, patches.dtype)
    return binomial_normal_approx(patches, charge, normals)


def make_pool(key: jax.Array, pool_size: int = 1 << 20) -> jax.Array:
    """Pre-computed standard-normal pool (paper's ref-CUDA/Kokkos strategy)."""
    return jax.random.normal(key, (pool_size,), jnp.float32)


def fluctuate_pool(pool: jax.Array, patches: jax.Array, charge: jax.Array,
                   offset: int = 0):
    """Index the pool by flat pixel id (mod pool size) — no RNG in the loop."""
    n = patches.size
    idx = (jnp.arange(n, dtype=jnp.uint32) + jnp.uint32(offset)) % pool.shape[0]
    normals = pool[idx].reshape(patches.shape)
    return binomial_normal_approx(patches, charge, normals)


def box_muller(u1: jax.Array, u2: jax.Array):
    """Box–Muller transform (paper §4.3.1) — two uniforms -> one std normal.

    Used inside the Pallas rasterize kernel where we hand it a uniform pool.
    """
    r = jnp.sqrt(-2.0 * jnp.log(jnp.maximum(u1, 1e-12)))
    return r * jnp.cos(2.0 * jnp.pi * u2)
