"""Drift/transport: physical depos -> detector-frame depos (the papers' stage 1).

The source paper's pipeline starts with a *drift* step the seed repo skipped:
Geant4 energy deposits live in detector space and must be transported to the
readout plane before rasterization — picking up drift-time diffusion widths,
electron-lifetime attenuation, and recombination-scaled charge on the way
(paper Fig. 2; larnd-sim ``drifting``/``quenching`` do the same on GPU).

Frames and units
----------------

``PhysicalDepoSet`` uses the **anode drift frame** — the parameterization
Wire-Cell's Drifter hands to the signal simulation:

  x : drift coordinate, measured as drift TIME to the readout plane [us]
      (metric distance / drift speed; transport physics evolves in time)
  y : transverse position across the wire plane, in wire-pitch units
      (the natural transverse metric of a wire readout)
  z : position along the wires [mm] — carried through, unused by the
      single-plane readout
  t : deposition time relative to the trigger [us]
  q : ionization electrons (mean, pre-recombination)

Metric-space tracks (e.g. larnd-sim HDF5 segments, mm) convert **once at
ingestion** via ``PhysicalDepoSet.from_mm``. Keeping unit conversion on the
ingestion boundary rather than inside the jit graph is what makes the
default generator path bit-for-bit with the seed repo: float32 round trips
through non-power-of-two unit constants (``wire -> mm -> wire``) perturb
~15% of values by 1 ulp, while the anode-frame fields need only exact ops
(identity, power-of-two scaling) to reach ``(wire, tick)``.

``drift_depos`` is the vectorized transport itself, registered as the
``drift`` hot op in the strategy registry so the stage graph dispatches it
like every other stage and the autotuner can time future candidates.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig
from repro.core.depo import DepoSet
from repro.tune.registry import register_strategy, set_default


class PhysicalDepoSet(NamedTuple):
    """Structure-of-arrays physical depo container (all float32, shape (N,)).

    See the module docstring for the anode-drift-frame conventions.
    """

    x: jax.Array
    y: jax.Array
    z: jax.Array
    t: jax.Array
    q: jax.Array

    @property
    def n(self) -> int:
        """Depo count — the last axis (an event axis may lead it)."""
        return self.x.shape[-1]

    def x_mm(self, cfg: LArTPCConfig) -> jax.Array:
        """Metric drift distance [mm] of each depo."""
        return self.x * cfg.drift_speed_mm_us

    def y_mm(self, cfg: LArTPCConfig) -> jax.Array:
        """Metric transverse position [mm] of each depo."""
        return self.y * cfg.wire_pitch_mm

    @classmethod
    def from_mm(cls, x_mm, y_mm, z_mm, t_us, q,
                cfg: LArTPCConfig) -> "PhysicalDepoSet":
        """Ingest metric-space depos (larnd-sim track convention: positions
        in mm, times in us, charge in electrons).

        The single lossy unit conversion of the pipeline happens here, on
        the ingestion boundary.
        """
        f = lambda a: jnp.asarray(a, jnp.float32)  # noqa: E731
        return cls(
            x=f(x_mm) / cfg.drift_speed_mm_us,
            y=f(y_mm) / cfg.wire_pitch_mm,
            z=f(z_mm),
            t=f(t_us),
            q=f(q),
        )


@register_strategy("drift", "jnp",
                   note="vectorized diffusion/attenuation/recombination")
def drift_depos(pdepos: PhysicalDepoSet, cfg: LArTPCConfig) -> DepoSet:
    """Transport physical depos to the readout plane.

    Per depo: arrival tick from deposition time + drift time; diffusion
    widths growing like sqrt(drift time) (paper Fig. 2), floored by
    ``cfg.sigma_w_floor`` / ``cfg.sigma_t_floor`` and clipped so the
    ``nsigma`` extent fits the rasterization patch; charge scaled by the
    recombination survival fraction and (when ``electron_lifetime_us`` > 0)
    attenuated by ``exp(-t_drift / lifetime)`` — larnd-sim's ``drifting``
    kernel, vectorized.

    At default physics (recombination 1.0, lifetime disabled) the charge
    and position paths are exact: ``generate_depos`` routed through this
    stage is bit-identical to the seed formulas (``tests/test_drift.py``).
    """
    t_drift = pdepos.x  # us — the frame is drift-time parameterized
    tick = (pdepos.t + t_drift) / cfg.tick_us
    wire = pdepos.y

    sigma_t = jnp.sqrt(2.0 * cfg.diffusion_long * t_drift) / (
        cfg.drift_speed_mm_us * cfg.tick_us
    ) * cfg.diffusion_scale + cfg.sigma_t_floor
    sigma_w = jnp.sqrt(2.0 * cfg.diffusion_tran * t_drift) / (
        cfg.wire_pitch_mm) * cfg.diffusion_scale + cfg.sigma_w_floor
    # clip so the nsigma extent fits inside the patch; the 0.3 numeric
    # guard yields to a smaller configured floor so sub-0.3 floors stay
    # effective (at the default floors this is exactly the seed clip)
    sigma_w = jnp.clip(sigma_w, min(0.3, cfg.sigma_w_floor),
                       (cfg.patch_wires / 2 - 1) / cfg.nsigma)
    sigma_t = jnp.clip(sigma_t, min(0.3, cfg.sigma_t_floor),
                       (cfg.patch_ticks / 2 - 1) / cfg.nsigma)

    q = pdepos.q * cfg.recombination
    lifetime = cfg.electron_lifetime_us
    if isinstance(lifetime, jax.Array):
        # traced lifetime (gradient-based calibration, repro.core.fit):
        # the enable/disable branch must be data-dependent. The guarded
        # denominator keeps the lifetime<=0 branch NaN-free under grad.
        atten = jnp.exp(-t_drift / jnp.maximum(lifetime, 1e-6))
        q = q * jnp.where(lifetime > 0.0, atten, 1.0)
    elif lifetime > 0.0:
        q = q * jnp.exp(-t_drift / lifetime)

    return DepoSet(
        wire=wire.astype(jnp.float32),
        tick=tick.astype(jnp.float32),
        sigma_w=sigma_w.astype(jnp.float32),
        sigma_t=sigma_t.astype(jnp.float32),
        charge=q.astype(jnp.float32),
    )


set_default("drift", "jnp")


def transport(pdepos: PhysicalDepoSet, cfg: LArTPCConfig) -> DepoSet:
    """Dispatch physical depos -> detector depos through the registry."""
    from repro.tune import autotune, registry

    strategy = cfg.drift_strategy
    if strategy == "auto":
        strategy = autotune.resolve("drift", cfg).strategy
    return registry.get_strategy("drift", strategy).fn(pdepos, cfg)


# ---------------------------------------------------------------------------
# Multi-plane transport (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------


def project_to_plane(pdepos: PhysicalDepoSet, spec, cfg: LArTPCConfig
                     ) -> PhysicalDepoSet:
    """Project the transverse position onto one plane's pitch direction.

    The anode frame carries the transverse position as ``(y, z)`` — ``y``
    across the reference plane in units of ``cfg.wire_pitch_mm``, ``z``
    along its wires in mm. A plane whose wires are rotated by
    ``spec.angle_deg`` from vertical indexes the perpendicular (pitch)
    direction, so its wire coordinate is

        wire_p = (y_mm * cos(angle) + z_mm * sin(angle)) / pitch_p + off_p
               = y * cw + z * cz + off_p

    with ``cw = cos(angle) * wire_pitch_mm / pitch_p`` and
    ``cz = sin(angle) / pitch_p`` precomputed as Python floats. ``off_p``
    centers the plane on the detector: wire (num_wires-1)/2 sits at the
    projected midpoint of the transverse box (y_mm in
    [0, (num_wires-1)*wire_pitch_mm], z in [0, num_wires*wire_pitch_mm] —
    the generator's volume), the convention a real readout uses for its
    wire numbering. Without it a rotated plane's coordinates run
    one-sided (e.g. -60 deg projects z negative-ward only) and a large
    fraction of the event would fall off the low-wire edge; centering
    loses only the symmetric corner overhangs a ±60 deg plane cannot
    cover with ``num_wires`` wires. The angle-0 reference-pitch plane has
    ``cw == 1.0, cz == 0.0, off == 0.0`` and skips the arithmetic
    entirely — bit-identical to the seed single-plane path (no lossy unit
    round trip; see the module docstring).
    """
    import math

    rad = math.radians(spec.angle_deg)
    cos_, sin_ = math.cos(rad), math.sin(rad)
    cw = cos_ * cfg.wire_pitch_mm / spec.pitch_mm
    cz = sin_ / spec.pitch_mm
    y_max = (cfg.num_wires - 1.0) * cfg.wire_pitch_mm
    z_max = cfg.num_wires * cfg.wire_pitch_mm
    lo = min(0.0, y_max * cos_) + min(0.0, z_max * sin_)
    hi = max(0.0, y_max * cos_) + max(0.0, z_max * sin_)
    off = (cfg.num_wires - 1.0) / 2.0 - (lo + hi) / (2.0 * spec.pitch_mm)
    if abs(off) < 1e-6:
        off = 0.0
    if cw == 1.0 and cz == 0.0 and off == 0.0:
        return pdepos
    y = pdepos.y * jnp.float32(cw)
    if cz != 0.0:
        y = y + pdepos.z * jnp.float32(cz)
    if off != 0.0:
        y = y + jnp.float32(off)
    return pdepos._replace(y=y)


def transport_planes(pdepos: PhysicalDepoSet, cfg: LArTPCConfig,
                     planes=None) -> DepoSet:
    """Transport physical depos onto every readout plane at once.

    Returns a ``DepoSet`` whose leaves carry a leading plane axis
    ``(P, N)``: per plane, the transverse position projects onto the
    plane's pitch direction (``project_to_plane``) and the registered
    drift strategy runs with that plane's pitch (transverse diffusion
    widths divide by the *plane's* wire pitch; arrival ticks, longitudinal
    widths, and charge physics are plane-independent). ``planes`` restricts
    to a subset of plane indices (the per-plane timing boards use this);
    None means all ``cfg.num_planes`` planes.
    """
    import dataclasses

    from repro.config import plane_specs

    specs = plane_specs(cfg)
    if planes is not None:
        specs = tuple(specs[p] for p in planes)
    per_plane = []
    for spec in specs:
        pcfg = dataclasses.replace(cfg, wire_pitch_mm=spec.pitch_mm)
        per_plane.append(transport(project_to_plane(pdepos, spec, cfg), pcfg))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_plane)
