"""Ingest validation + run-health accounting for the streaming executors.

The ROADMAP north star is a production service under sustained traffic — at
that scale a single poison event (NaN charge from a corrupt upstream file, a
million-depo "event" that blows the padded batch shape) must not kill a
million-event campaign. This module is the ingest gate of the fault-tolerance
layer (docs/robustness.md):

  check_depos      : per-event sanity rules for detector-frame ``DepoSet``s
                     and physical-frame ``PhysicalDepoSet``s — finiteness,
                     charge sign, frame bounds, plane-axis consistency, and
                     (when asked) the padded-capacity ceiling. Returns the
                     list of violated rules, empty when the event is clean.
  dead_letter      : the quarantine record for one rejected event — enough
                     context (event id, batch, reasons, depo count) to
                     re-ingest or debug it offline instead of crashing.
  RunHealth        : the per-run counters (events_ok / quarantined / retries
                     / resumed / ...) every fault path increments; flows into
                     ``stream_simulate``'s stats dict and the launcher
                     summary line.
  SimBatchError    : the structured failure surfaced when a batch exhausts
                     its retry budget (or hits a non-retryable error) —
                     carries the batch id, attempt count, and the degraded
                     batch size at failure time.
  is_oom_error     : classifies an exception as OOM-class (retryable with
                     degradation) vs everything else (fail fast).

Validation runs on the HOST over already-materialized event arrays — it
never enters the jit graph, so the default simulation program is untouched
(bit-identical ADCs; the jit-side sibling is the ``cfg.check_finite``
sentinel in ``repro.core.stages``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

#: out-of-frame margin, as a multiple of the readout extent: the rasterizer
#: clips patch origins to the grid, so mildly out-of-range coordinates (the
#: rotated-plane corner overhangs of a multi-plane projection) are harmless —
#: the bounds check only rejects values so far out they signal corruption
FRAME_MARGIN = 4.0


def _finite_reasons(name: str, arr: np.ndarray) -> List[str]:
    bad = np.size(arr) - int(np.isfinite(arr).sum())
    if bad:
        return [f"nonfinite {name} ({bad} of {np.size(arr)} values)"]
    return []


def _bounds_reason(name: str, arr: np.ndarray, lo: float, hi: float
                   ) -> List[str]:
    finite = arr[np.isfinite(arr)]
    if finite.size and (float(finite.min()) < lo or float(finite.max()) > hi):
        return [f"{name} outside [{lo:g}, {hi:g}] "
                f"(range [{float(finite.min()):g}, {float(finite.max()):g}])"]
    return []


def check_physical_depos(pdepos, cfg, max_depos: Optional[int] = None
                         ) -> List[str]:
    """Validate one physical-frame event (``PhysicalDepoSet``).

    Rules: every leaf finite; charge ``q >= 0``; drift time ``x >= 0`` (a
    negative drift time is unphysical — the depo would sit behind the
    anode); arrival tick ``(t + x) / tick_us`` within ``FRAME_MARGIN``
    readout windows; optional depo-count ceiling ``max_depos``.
    """
    leaves = {f: np.asarray(getattr(pdepos, f)) for f in pdepos._fields}
    reasons: List[str] = []
    reasons += _shape_reasons(leaves, num_planes=1)  # physical frame: no
    #                                                  plane axis yet
    for name, arr in leaves.items():
        reasons += _finite_reasons(name, arr)
    q, x = leaves["q"], leaves["x"]
    if np.any(np.isfinite(q) & (q < 0)):
        reasons.append(f"negative charge (min {float(np.nanmin(q)):g})")
    if np.any(np.isfinite(x) & (x < 0)):
        reasons.append(f"negative drift time (min {float(np.nanmin(x)):g})")
    window_us = cfg.num_ticks * cfg.tick_us
    arrival = leaves["t"] + x
    reasons += _bounds_reason("arrival time [us]", arrival,
                              -FRAME_MARGIN * window_us,
                              FRAME_MARGIN * window_us)
    if max_depos is not None and pdepos.n > max_depos:
        reasons.append(f"oversized: {pdepos.n} depos > capacity {max_depos}")
    return reasons


def check_detector_depos(depos, cfg, max_depos: Optional[int] = None
                         ) -> List[str]:
    """Validate one detector-frame event (``DepoSet``, drifted).

    Rules: every leaf finite; ``charge >= 0``; ``sigma_w``/``sigma_t`` > 0
    (a zero width divides the rasterizer's Gaussian edges); wire/tick within
    ``FRAME_MARGIN`` readout extents (generous on purpose — rotated-plane
    projections legitimately overhang the grid by a corner, and the
    rasterizer clips; only corruption-scale values reject); a leading plane
    axis exactly ``cfg.num_planes`` wide on multi-plane configs; optional
    depo-count ceiling ``max_depos`` (the padded batch capacity — an event
    bigger than the pad target would crash ``pack_events``).
    """
    leaves = {f: np.asarray(getattr(depos, f)) for f in depos._fields}
    reasons = _shape_reasons(leaves, num_planes=cfg.num_planes)
    for name, arr in leaves.items():
        reasons += _finite_reasons(name, arr)
    q = leaves["charge"]
    if np.any(np.isfinite(q) & (q < 0)):
        reasons.append(f"negative charge (min {float(np.nanmin(q)):g})")
    for name in ("sigma_w", "sigma_t"):
        s = leaves[name]
        if np.any(np.isfinite(s) & (s <= 0)):
            reasons.append(f"non-positive {name} "
                           f"(min {float(np.nanmin(s)):g})")
    reasons += _bounds_reason("wire", leaves["wire"],
                              -FRAME_MARGIN * cfg.num_wires,
                              FRAME_MARGIN * cfg.num_wires)
    reasons += _bounds_reason("tick", leaves["tick"],
                              -FRAME_MARGIN * cfg.num_ticks,
                              FRAME_MARGIN * cfg.num_ticks)
    if max_depos is not None and depos.n > max_depos:
        reasons.append(f"oversized: {depos.n} depos > capacity {max_depos}")
    return reasons


def _shape_reasons(leaves: Dict[str, np.ndarray], num_planes: int
                   ) -> List[str]:
    shapes = {a.shape for a in leaves.values()}
    if len(shapes) != 1:
        return [f"inconsistent leaf shapes {sorted(map(str, shapes))}"]
    (shape,) = shapes
    if num_planes > 1:
        if len(shape) != 2:
            return [f"multi-plane event needs (P, N) leaves, got {shape}"]
        if shape[0] != num_planes:
            return [f"plane axis {shape[0]} != num_planes {num_planes}"]
    elif len(shape) != 1:
        return [f"single-plane event needs (N,) leaves, got {shape}"]
    return []


def check_depos(depos, cfg, max_depos: Optional[int] = None) -> List[str]:
    """Validate one event, dispatching on its frame (detector vs physical).

    Returns the (possibly empty) list of violated rules — the caller
    quarantines the event when it is non-empty.
    """
    from repro.core.drift import PhysicalDepoSet

    if isinstance(depos, PhysicalDepoSet):
        return check_physical_depos(depos, cfg, max_depos=max_depos)
    return check_detector_depos(depos, cfg, max_depos=max_depos)


def dead_letter(event: int, batch: int, reasons: List[str], depos
                ) -> Dict[str, Any]:
    """The quarantine record for one rejected event (JSON-serializable)."""
    return {"event": int(event), "batch": int(batch),
            "reasons": list(reasons), "n_depos": int(depos.n)}


# ---------------------------------------------------------------------------
# Run health
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunHealth:
    """Per-run fault-tolerance counters (``stream_simulate``'s scoreboard).

    events_ok        : events simulated successfully this run
    quarantined      : events dead-lettered by ingest validation
    retries          : batch dispatch retry attempts (OOM-class failures)
    halvings         : times the retry policy halved the batch event count
    resumed          : events skipped because the journal says their batch
                       already completed (``--resume``)
    nonfinite_events : events whose ``cfg.check_finite`` sentinel tripped
    callback_errors  : ``on_batch`` callback exceptions swallowed as warnings
    dead_letters     : the quarantine records behind ``quarantined``
    """

    events_ok: int = 0
    quarantined: int = 0
    retries: int = 0
    halvings: int = 0
    resumed: int = 0
    nonfinite_events: int = 0
    callback_errors: int = 0
    dead_letters: List[dict] = dataclasses.field(default_factory=list)

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        parts = [f"ok={self.events_ok}", f"quarantined={self.quarantined}",
                 f"retries={self.retries}", f"resumed={self.resumed}"]
        for name in ("halvings", "nonfinite_events", "callback_errors"):
            if getattr(self, name):
                parts.append(f"{name}={getattr(self, name)}")
        return " ".join(parts)


# ---------------------------------------------------------------------------
# Failure classification
# ---------------------------------------------------------------------------


#: substrings that mark an exception as OOM-class (retryable by degrading
#: the batch size): XLA raises RESOURCE_EXHAUSTED from its allocators on
#: every backend; the others cover driver/runtime phrasing variants
OOM_MARKERS = ("RESOURCE_EXHAUSTED", "RESOURCE EXHAUSTED", "OUT_OF_MEMORY",
               "out of memory", "Out of memory", "OutOfMemory")


def is_oom_error(exc: BaseException) -> bool:
    """True when ``exc`` looks like a device allocation failure — the only
    failure class the retry policy degrades the batch for (everything else
    fails fast: retrying a shape error or a poison NaN cannot succeed)."""
    msg = f"{type(exc).__name__}: {exc}"
    return any(marker in msg for marker in OOM_MARKERS)


class SimBatchError(RuntimeError):
    """A batch failed permanently: retries exhausted or non-retryable cause.

    Carries the structured context the campaign driver needs — which batch,
    how many attempts, the degraded event count at failure time, and the
    underlying exception (also chained as ``__cause__``).
    """

    def __init__(self, batch: int, attempts: int, batch_events: int,
                 cause: BaseException):
        self.batch = batch
        self.attempts = attempts
        self.batch_events = batch_events
        self.cause = cause
        kind = "OOM-class" if is_oom_error(cause) else "non-retryable"
        super().__init__(
            f"batch {batch} failed permanently after {attempts} attempt(s) "
            f"at batch_events={batch_events} ({kind}): "
            f"{type(cause).__name__}: {cause}")
