"""Threshold-scan hit finding over deconvolved wires -> fixed-capacity HitSet.

The recon follow-ups to the source paper (arXiv:2107.00812 "Optimizing the
Hit Finding Algorithm...") make this the workload after deconvolution: walk
each wire's deconvolved waveform, and turn every run of consecutive
above-threshold ticks into one *hit* — summed charge, charge-weighted mean
tick, peak sample. The algorithm is sequential in time per wire but
embarrassingly parallel over wires, which is exactly the portability
trade-off the registry exists to measure:

  scan   : one ``lax.fori_loop`` run-scanner per wire, ``vmap``-ed over the
           wire axis — XLA vectorizes the per-tick step across wires.
  pallas : the same scanner as a Pallas kernel, one grid step per wire
           (``repro.kernels.hitfind``) — both call the SAME ``_wire_scan``
           body, so their outputs are bit-identical by construction.

Output contract (``HitSet``): a fixed-capacity (``cfg.max_hits``), mask-
padded pytree, so jit/vmap/shard_map see static shapes whatever the event
occupancy. Hits are compacted wire-major (ascending wire, then time);
``n_hits`` counts every candidate run found — ``n_hits > mask.sum()`` means
capacity truncation (per-wire ``max_hits_per_wire`` or global ``max_hits``),
detectable instead of silent.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig
from repro.tune.registry import register_strategy, set_default


class HitSet(NamedTuple):
    """Fixed-capacity, mask-padded hits of one readout plane.

    Leaves are (max_hits,); multi-plane outputs stack a leading plane axis,
    batched executors a leading event axis. Padding rows have mask False and
    zeroed values.
    """

    wire: jax.Array    # int32 global wire index of the hit's wire
    tick: jax.Array    # float32 charge-weighted mean tick of the run
    charge: jax.Array  # float32 summed deconvolved charge (electrons)
    peak: jax.Array    # float32 max deconvolved sample in the run
    mask: jax.Array    # bool — True for real hits, False for padding
    n_hits: jax.Array  # () int32 total candidate runs found; > mask.sum()
    #                    signals capacity truncation


# ---------------------------------------------------------------------------
# The shared per-wire run scanner (both strategies execute this exact body)
# ---------------------------------------------------------------------------


def _emit(fire, n, csum, tsum, pk, hq, ht, hp, cap: int):
    """Close a run: append (charge, mean tick, peak) at slot ``n`` if there
    is room. ``n`` counts every fired run, stored or not, so truncation at
    the per-wire capacity is visible to the caller."""
    ok = fire & (n < cap)
    idx = jnp.minimum(n, cap - 1)
    hq = hq.at[idx].set(jnp.where(ok, csum, hq[idx]))
    ht = ht.at[idx].set(jnp.where(ok, tsum / jnp.maximum(csum, 1e-30),
                                  ht[idx]))
    hp = hp.at[idx].set(jnp.where(ok, pk, hp[idx]))
    return n + fire.astype(jnp.int32), hq, ht, hp


def _wire_scan(vals: jax.Array, threshold, cap: int):
    """Scan one wire's (T,) waveform for runs of samples > threshold.

    Returns (count, charge, tick, peak): count is the TOTAL number of runs
    found (may exceed ``cap``); the (cap,) arrays hold the first ``cap``
    runs in time order. Pure jnp + ``fori_loop``, so it runs identically
    under vmap (the XLA strategy) and inside a Pallas kernel body.
    """
    t_len = vals.shape[0]

    def step(t, carry):
        n, active, csum, tsum, pk, hq, ht, hp = carry
        v = vals[t]
        above = v > threshold
        # a run ends when the previous tick was in-run and this one is not
        n, hq, ht, hp = _emit(active & ~above, n, csum, tsum, pk,
                              hq, ht, hp, cap)
        tf = t.astype(jnp.float32)
        csum = jnp.where(above, jnp.where(active, csum + v, v), 0.0)
        tsum = jnp.where(above, jnp.where(active, tsum + v * tf, v * tf), 0.0)
        pk = jnp.where(above, jnp.where(active, jnp.maximum(pk, v), v), 0.0)
        return n, above, csum, tsum, pk, hq, ht, hp

    zeros = jnp.zeros((cap,), jnp.float32)
    f0 = jnp.float32(0.0)
    carry = (jnp.int32(0), jnp.asarray(False), f0, f0, f0,
             zeros, zeros, zeros)
    n, active, csum, tsum, pk, hq, ht, hp = jax.lax.fori_loop(
        0, t_len, step, carry)
    # flush a run still open at the readout edge
    n, hq, ht, hp = _emit(active, n, csum, tsum, pk, hq, ht, hp, cap)
    return n, hq, ht, hp


# ---------------------------------------------------------------------------
# Strategies — the registry's ``hit_find`` op
# ---------------------------------------------------------------------------
#
# A strategy maps (decon (W, T), cfg) -> per-wire candidates:
#   counts (W,) int32, charge/tick/peak (W, max_hits_per_wire) float32
# ``find_hits`` compacts them into the global HitSet.


@register_strategy("hit_find", "scan",
                   note="per-wire fori_loop run scanner, vmap over wires",
                   differentiable=False)
def hit_find_scan(decon: jax.Array, cfg: LArTPCConfig):
    thr = jnp.float32(cfg.hit_threshold)
    cap = int(cfg.max_hits_per_wire)
    return jax.vmap(lambda row: _wire_scan(row, thr, cap))(decon)


def _pallas_viable(ctx) -> bool:
    # compiled on TPU; elsewhere the Pallas interpreter walks the wire grid
    # in Python, so cap it to smoke-scale grids (same bound as fused_pallas)
    if ctx.backend == "tpu":
        return True
    cells = ctx.shape.get("num_wires", 0) * ctx.shape.get("num_ticks", 0)
    return cells <= (1 << 21)


@register_strategy("hit_find", "pallas", available=_pallas_viable,
                   note="one Pallas grid step per wire (same scan body)",
                   differentiable=False)
def hit_find_pallas(decon: jax.Array, cfg: LArTPCConfig):
    from repro.kernels.hitfind.ops import find_wire_hits_pallas

    return find_wire_hits_pallas(decon, threshold=float(cfg.hit_threshold),
                                 cap=int(cfg.max_hits_per_wire))


set_default("hit_find", "scan")


# ---------------------------------------------------------------------------
# Compaction + dispatch
# ---------------------------------------------------------------------------


def compact_hits(counts: jax.Array, charge: jax.Array, tick: jax.Array,
                 peak: jax.Array, cfg: LArTPCConfig, *,
                 wire_offset=0, max_hits: Optional[int] = None) -> HitSet:
    """Flatten per-wire candidate arrays into one wire-major HitSet.

    Stored hits keep (wire, time) order; candidates past the global
    ``max_hits`` capacity fall into a dump slot that is dropped. ``n_hits``
    sums the *found* counts, so truncation (per-wire or global) shows as
    ``n_hits > mask.sum()``. ``wire_offset`` shifts the reported wire index
    (the distributed executor passes its shard's first global wire).
    """
    w, cap = charge.shape
    m = int(max_hits if max_hits is not None else cfg.max_hits)
    stored = jnp.minimum(counts, cap)                    # (W,)
    starts = jnp.cumsum(stored) - stored                 # exclusive prefix
    j = jnp.arange(cap, dtype=jnp.int32)[None, :]
    valid = j < stored[:, None]                          # (W, cap)
    # invalid and overflow candidates both target the dump slot m
    tgt = jnp.where(valid, jnp.minimum(starts[:, None] + j, m), m).reshape(-1)
    wires = jnp.broadcast_to(
        (jnp.arange(w, dtype=jnp.int32) + wire_offset)[:, None], (w, cap))

    def place(vals, dtype):
        out = jnp.zeros((m + 1,), dtype)
        return out.at[tgt].set(vals.reshape(-1).astype(dtype))[:m]

    nstored = jnp.zeros((m + 1,), jnp.int32).at[tgt].add(
        valid.reshape(-1).astype(jnp.int32))[:m]
    return HitSet(
        wire=place(wires, jnp.int32),
        tick=place(tick, jnp.float32),
        charge=place(charge, jnp.float32),
        peak=place(peak, jnp.float32),
        mask=nstored > 0,
        n_hits=jnp.sum(counts).astype(jnp.int32),
    )


def find_hits(decon: jax.Array, cfg: LArTPCConfig,
              strategy: Optional[str] = None, *, wire_offset=0,
              max_hits: Optional[int] = None) -> HitSet:
    """Threshold-scan one plane's deconvolved (W, T) grid into a HitSet.

    ``strategy`` may be None (registry default), ``"auto"`` (tuning cache /
    default, keyed by the grid shape and per-wire capacity), or a registered
    candidate name; unknown names fail here with the valid list.
    ``wire_offset``/``max_hits`` override the global wire numbering and the
    HitSet capacity (the distributed executor scans per-shard slices).
    """
    from repro.tune import autotune, registry

    if strategy is None:
        strategy = registry.default_strategy("hit_find")
    elif strategy == "auto":
        shape = {"num_wires": decon.shape[0], "num_ticks": decon.shape[1],
                 "max_hits_per_wire": cfg.max_hits_per_wire}
        strategy = autotune.resolve("hit_find", None, shape=shape).strategy
    try:
        strat = registry.get_strategy("hit_find", strategy)
    except KeyError:
        valid = sorted(registry.strategies("hit_find")) + ["auto"]
        raise ValueError(
            f"unknown hit_find strategy {strategy!r}; valid: {valid}"
        ) from None
    counts, charge, tick, peak = strat.fn(decon, cfg)
    return compact_hits(counts, charge, tick, peak, cfg,
                        wire_offset=wire_offset, max_hits=max_hits)


def hits_to_tuples(hits: HitSet) -> Tuple[Tuple[int, float, float], ...]:
    """Host-side view of the real hits as sorted (wire, tick, charge)
    tuples — the executor-equivalence tests compare hit SETS this way
    (compaction *positions* differ between the single-device and sharded
    layouts; the hits themselves must not)."""
    import numpy as np

    mask = np.asarray(hits.mask)
    rows = zip(np.asarray(hits.wire)[mask].tolist(),
               np.asarray(hits.tick)[mask].tolist(),
               np.asarray(hits.charge)[mask].tolist())
    return tuple(sorted(rows))
