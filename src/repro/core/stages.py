"""Stage-graph simulation core: one composable pipeline, many executors.

The paper (and its OpenMP/SYCL follow-ups, arXiv:2203.02479 / 2304.01841)
treats the LArTPC sim as a *chain of stages* — drift, rasterize/scatter
("charge grid"), convolve, noise, digitize — whose per-stage cost profile
drives every porting decision. This module makes that chain a first-class
object instead of code duplicated across entry points:

  Stage     : one named pipeline step — ``fn(SimState) -> SimState`` plus
              the strategy-registry op key it dispatches (if any).
  SimGraph  : an ordered tuple of stages with one executor (``run``), one
              instrumentation point per stage boundary (``timed``), and
              stage overrides (``replace``) for specialized executors.
  SimState  : the pytree flowing between stages (keys, depos, grid,
              signal, adc).

All four production entry points execute the same graph object:

  make_sim_fn           : jit(graph.run)                       (single event)
  make_batched_sim_fn   : jit(vmap(graph.run))                 (event batch)
  make_distributed_sim  : jit(shard_map(graph.run))            (multi-device,
                          with charge_grid/convolve/noise stage overrides)
  stream_simulate       : the double-buffered driver over make_batched_sim_fn

so adding a stage (signal processing / deconvolution is next) or a strategy
is a one-file change, and the per-stage timing boards the papers use to find
the next bottleneck come for free (``benchmarks/stages.py``).

RNG contract (bit-for-bit with the pre-graph code): the executor splits the
event key once — ``kf, kn = split(key)`` — exactly as ``simulate_fig4``
always did; stages draw from their assigned subkey. ``SimState.key`` keeps
the *unsplit* event key for executors with their own derivation schedule
(the distributed pipeline folds in a per-device index).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig, plane_specs
from repro.core.depo import DepoSet
from repro.core.fft_conv import digitize, fft_convolve
from repro.core.noise import simulate_noise
from repro.core.response import DetectorResponse

#: canonical stage order of the simulation chain
STAGE_ORDER = ("drift", "charge_grid", "convolve", "noise", "digitize")
#: the recon stages ``build_sim_graph(..., recon=True)`` appends
RECON_STAGE_ORDER = ("deconvolve", "hit_find")
#: the full sim -> recon chain
FULL_STAGE_ORDER = STAGE_ORDER + RECON_STAGE_ORDER


class SimOutput(NamedTuple):
    """Simulation result. Single-plane configs (``num_planes == 1``) keep
    the seed 2-D layout; multi-plane configs carry a leading plane axis on
    every leaf: adc (P, num_wires, num_ticks), etc.

    ``decon``/``hits`` are populated only by recon graphs
    (``build_sim_graph(..., recon=True)``) and stay None — an empty pytree
    node, invisible to jit/vmap — on the default sim-only graph."""

    adc: jax.Array        # (num_wires, num_ticks) int16
    signal: jax.Array     # (num_wires, num_ticks) float32 pre-digitization
    charge_grid: jax.Array  # S(t,x) after scatter-add
    decon: Optional[jax.Array] = None  # deconvolved charge estimate Ŝ(t,x)
    hits: Optional[Any] = None         # HitSet (repro.core.hitfind)
    #: () bool — True when every float stage output was finite; populated
    #: only when ``cfg.check_finite`` (None otherwise: an empty pytree
    #: node, so the default graph's structure/output is untouched)
    finite_ok: Optional[jax.Array] = None


class SimState(NamedTuple):
    """The pytree a SimGraph threads through its stages.

    ``depos`` may be a ``PhysicalDepoSet`` (drift transports it) or an
    already-drifted ``DepoSet`` (drift passes it through) — the branch is
    on pytree *structure*, resolved at trace time.
    """

    key: jax.Array                     # unsplit event key
    kf: jax.Array                      # charge-grid subkey (fig4 schedule)
    kn: jax.Array                      # noise subkey (fig4 schedule)
    depos: Any                         # PhysicalDepoSet | DepoSet
    grid: Optional[jax.Array] = None   # S(t,x) after charge_grid
    signal: Optional[jax.Array] = None  # M(t,x) after convolve (+ noise)
    adc: Optional[jax.Array] = None    # int16 after digitize
    decon: Optional[jax.Array] = None  # Ŝ(t,x) after deconvolve (recon)
    hits: Optional[Any] = None         # HitSet after hit_find (recon)
    finite_ok: Optional[jax.Array] = None  # check_finite sentinel accumulator


@dataclasses.dataclass(frozen=True)
class Stage:
    """One named pipeline step.

    name : instrumentation-point name (timing boards key on it)
    fn   : ``SimState -> SimState`` — reads its inputs from the state,
           writes its outputs back
    op   : strategy-registry hot-op key this stage dispatches through
           (``repro.tune``), or None for fixed-function stages
    """

    name: str
    fn: Callable[[SimState], SimState]
    op: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class SimGraph:
    """An ordered stage chain with one executor for every launch mode."""

    stages: Tuple[Stage, ...]

    @property
    def stage_names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.stages)

    def stage(self, name: str) -> Stage:
        for s in self.stages:
            if s.name == name:
                return s
        raise KeyError(f"no stage {name!r}; graph has {self.stage_names}")

    def replace(self, **overrides: Callable[[SimState], SimState] | Stage
                ) -> "SimGraph":
        """A new graph with named stages overridden (the specialization
        hook: the distributed executor swaps in collective-aware
        charge_grid/convolve/noise implementations, scenario configs can
        swap any stage without touching the executor)."""
        unknown = set(overrides) - set(self.stage_names)
        if unknown:
            raise KeyError(f"unknown stages {sorted(unknown)}; "
                           f"graph has {self.stage_names}")
        stages = tuple(
            (overrides[s.name] if isinstance(overrides.get(s.name), Stage)
             else dataclasses.replace(s, fn=overrides[s.name]))
            if s.name in overrides else s
            for s in self.stages)
        return SimGraph(stages=stages)

    # -- execution ----------------------------------------------------------

    def init_state(self, key: jax.Array, depos) -> SimState:
        kf, kn = jax.random.split(key)
        return SimState(key=key, kf=kf, kn=kn, depos=depos)

    def output(self, state: SimState) -> SimOutput:
        return SimOutput(adc=state.adc, signal=state.signal,
                         charge_grid=state.grid, decon=state.decon,
                         hits=state.hits, finite_ok=state.finite_ok)

    def run_state(self, state: SimState) -> SimState:
        for stage in self.stages:
            state = stage.fn(state)
        return state

    def run(self, key: jax.Array, depos) -> SimOutput:
        """Execute the full chain for one event. jit/vmap/shard_map-able."""
        return self.output(self.run_state(self.init_state(key, depos)))

    # -- instrumentation ----------------------------------------------------

    def timed(self, key: jax.Array, depos, *, warmup: int = 1,
              iters: int = 3, batched: bool = False,
              ) -> Tuple[SimOutput, Dict[str, float]]:
        """Run stage-by-stage, timing each stage boundary on device.

        Each stage jits separately and blocks between stages, so the state
        materializes at every boundary — per-stage cost the way the papers'
        stage tables report it (the fused end-to-end program can be faster;
        time ``jit(graph.run)`` for that number). ``batched=True`` vmaps
        every stage over a leading event axis of ``key``/``depos``.

        Returns (final SimOutput, {stage name: median seconds}).
        """
        init = jax.vmap(self.init_state) if batched else self.init_state
        state = jax.jit(init)(key, depos)
        jax.block_until_ready(state)
        timings: Dict[str, float] = {}
        for stage in self.stages:
            fn = jax.jit(jax.vmap(stage.fn) if batched else stage.fn)
            out = fn(state)
            jax.block_until_ready(out)  # compile + warm
            for _ in range(max(warmup - 1, 0)):
                jax.block_until_ready(fn(state))
            times = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(state))
                times.append(time.perf_counter() - t0)
            times.sort()
            timings[stage.name] = times[len(times) // 2]
            state = out
        return self.output(state), timings


# ---------------------------------------------------------------------------
# Stage factories — the default (single-device fig4) implementations
#
# Multi-plane configs (``cfg.num_planes > 1``) run every readout stage once
# per plane inside ONE stage fn — a static Python loop over ``plane_specs``
# with stacked (P, ...) state leaves — so the graph shape, the executors,
# and the timing boards stay plane-count agnostic. ``planes`` restricts a
# multi-plane graph to a subset of plane indices (the per-plane cost boards
# build one-plane graphs this way); it has no effect on single-plane
# configs, whose stages are byte-for-byte the seed implementations.
# ---------------------------------------------------------------------------


#: charge_grid strategies that rasterize ALL planes in one kernel launch —
#: they receive the unsplit charge-grid subkey plus the full (P, N) depos
#: and fold the per-plane ``fold_in(kf, index)`` subkeys internally, so
#: their output is bit-identical to the per-plane loop
MULTIPLANE_CHARGE_GRID = ("fused_pallas_multiplane",
                          "fused_pallas_multiplane_compact",
                          "multiplane_xla")
#: charge_grid strategies safe to vmap over the plane axis (pure-XLA
#: rasterize/fluctuate/scatter chains). The single-plane Pallas kernels are
#: excluded — their multi-plane form is the dedicated strategies above —
#: so anything else falls back to the per-plane loop.
PLANE_VMAP_CHARGE_GRID = ("unfused", "unfused_bf16")


def resolve_plane_batching(cfg: LArTPCConfig) -> str:
    """Resolve ``cfg.plane_batching`` to a concrete "loop" | "stacked"."""
    mode = cfg.plane_batching
    if mode not in ("auto", "loop", "stacked"):
        raise ValueError(f"unknown plane_batching {mode!r}; expected 'auto', "
                         "'loop' or 'stacked'")
    if mode == "auto":
        return "stacked" if cfg.num_planes > 1 else "loop"
    return mode


def plane_fold_keys(key: jax.Array, specs) -> jax.Array:
    """Stacked per-plane subkeys ``fold_in(key, spec.index)``.

    The vmapped form of the loop's per-plane fold — bit-identical per row
    (same derivation as ``batch.event_keys`` uses for the event axis)."""
    idx = jnp.asarray([s.index for s in specs], dtype=jnp.uint32)
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, idx)


def _selected_specs(cfg: LArTPCConfig, planes: Optional[Tuple[int, ...]]):
    specs = plane_specs(cfg)
    if planes is None:
        return specs
    return tuple(specs[p] for p in planes)


def _as_plane_responses(cfg: LArTPCConfig, resp,
                        planes: Optional[Tuple[int, ...]]):
    """Normalize ``resp`` to one response per selected plane.

    None builds the defaults (``make_response`` per plane type); a single
    ``DetectorResponse`` is accepted only for single-plane configs — a lone
    transform cannot cover induction *and* collection planes, so passing
    one to a multi-plane graph is an error, not a silent broadcast.
    """
    from repro.core.response import make_response

    specs = _selected_specs(cfg, planes)
    if resp is None:
        return tuple(make_response(cfg, plane=s.kind) for s in specs)
    if isinstance(resp, DetectorResponse):
        if len(specs) != 1:
            raise ValueError(
                f"config has {len(specs)} selected planes but got a single "
                "DetectorResponse; pass make_plane_responses(cfg) (or None "
                "to build the per-plane defaults)")
        return (resp,)
    resps = tuple(resp)
    if len(resps) != len(specs):
        raise ValueError(f"got {len(resps)} responses for {len(specs)} "
                         "selected planes")
    return resps


def drift_stage(cfg: LArTPCConfig,
                planes: Optional[Tuple[int, ...]] = None) -> Stage:
    """Transport physical depos to the readout plane(s); pass through depos
    that already arrived (an input DepoSet), so every executor accepts both
    physical- and detector-frame input. Multi-plane configs project each
    physical depo onto every selected plane (leading plane axis on the
    output DepoSet); pre-drifted input must already carry that axis."""
    from repro.core.drift import PhysicalDepoSet, transport, transport_planes

    multi = cfg.num_planes > 1

    def fn(state: SimState) -> SimState:
        if isinstance(state.depos, PhysicalDepoSet):
            depos = (transport_planes(state.depos, cfg, planes=planes)
                     if multi else transport(state.depos, cfg))
            return state._replace(depos=depos)
        if multi:
            if state.depos.wire.ndim < 2:
                raise ValueError(
                    "multi-plane config fed a planeless DepoSet; pass a "
                    "PhysicalDepoSet (the drift stage projects it onto "
                    "every plane) or a DepoSet with a leading plane axis "
                    "(e.g. generate_plane_depos)")
            n_in = state.depos.wire.shape[-2]
            if n_in != cfg.num_planes:
                raise ValueError(
                    f"pre-drifted depos carry {n_in} planes but the config "
                    f"has num_planes={cfg.num_planes}; pre-drifted input "
                    "always carries the FULL plane axis (a plane-restricted "
                    "graph selects from it here)")
            if planes is not None:
                # select the restricted planes so downstream stages'
                # positional plane loop lines up with the selected specs
                sel = jnp.asarray(planes)
                return state._replace(depos=jax.tree.map(
                    lambda x: x[..., sel, :], state.depos))
        return state

    return Stage("drift", fn, op="drift")


def compute_charge_grid(key: jax.Array, depos: DepoSet, cfg: LArTPCConfig,
                        pool: Optional[jax.Array] = None) -> jax.Array:
    """Dispatch depos -> S(t,x) through the registered strategy."""
    from repro.tune import autotune, registry

    strategy = cfg.charge_grid_strategy
    if strategy == "auto":
        strategy = autotune.resolve("charge_grid", cfg).strategy
    return registry.get_strategy("charge_grid", strategy).fn(
        key, depos, cfg, pool)


def charge_grid_stage(cfg: LArTPCConfig,
                      pool: Optional[jax.Array] = None,
                      planes: Optional[Tuple[int, ...]] = None) -> Stage:
    """depos -> S(t,x): rasterize + fluctuate + scatter-add (or the fused
    kernel), dispatched through the ``charge_grid`` strategy registry.

    Multi-plane: each plane draws from a plane-folded subkey
    (``fold_in(kf, plane_index)``) so electron fluctuations are independent
    per plane; grids stack to (P, W, T). ``plane_batching="loop"`` runs one
    dispatch per plane (the original static Python loop); "stacked" runs the
    plane axis as ONE batched dispatch — a dedicated multi-plane kernel
    strategy when resolved, otherwise a plane vmap of the XLA chain — with
    bit-identical output (same per-plane subkeys, same per-plane math).
    (The paper-faithful ``pool`` stream reuses the one pool per plane,
    matching its fixed-pool design across events.)"""
    specs = _selected_specs(cfg, planes)
    multi = cfg.num_planes > 1
    stacked = multi and resolve_plane_batching(cfg) == "stacked"

    def loop_fn(state: SimState) -> SimState:
        grids = []
        for i, spec in enumerate(specs):
            kf = jax.random.fold_in(state.kf, spec.index)
            depos_p = jax.tree.map(lambda x, i=i: x[i], state.depos)
            grids.append(compute_charge_grid(kf, depos_p, cfg, pool=pool))
        return state._replace(grid=jnp.stack(grids))

    def fn(state: SimState) -> SimState:
        if not multi:
            return state._replace(grid=compute_charge_grid(
                state.kf, state.depos, cfg, pool=pool))
        if not stacked:
            return loop_fn(state)
        from repro.tune import autotune, registry

        strategy = cfg.charge_grid_strategy
        if strategy == "auto":
            strategy = autotune.resolve("charge_grid", cfg).strategy
        if (strategy in MULTIPLANE_CHARGE_GRID
                and len(specs) == cfg.num_planes):
            # one kernel launch rasterizes every plane; it folds the
            # per-plane subkeys from the unsplit kf internally
            return state._replace(grid=registry.get_strategy(
                "charge_grid", strategy).fn(state.kf, state.depos, cfg, pool))
        if strategy in PLANE_VMAP_CHARGE_GRID:
            f = registry.get_strategy("charge_grid", strategy).fn
            grid = jax.vmap(lambda k, d: f(k, d, cfg, pool))(
                plane_fold_keys(state.kf, specs), state.depos)
            return state._replace(grid=grid)
        return loop_fn(state)

    return Stage("charge_grid", fn, op="charge_grid")


def convolve_stage(cfg: LArTPCConfig, resp,
                   planes: Optional[Tuple[int, ...]] = None) -> Stage:
    """S(t,x) -> M(t,x): frequency-domain convolution with the detector
    response, dispatched through the ``fft_convolve`` strategy registry.

    Multi-plane: ``resp`` is a per-plane sequence (bipolar induction /
    unipolar collection transforms). ``plane_batching="loop"`` runs one
    convolution per plane; "stacked" runs ONE batched rfft2 over the
    (P, W, T) grid with the per-plane response spectra stacked to
    (P, wp, tf) — bit-identical (batched FFTs compute each plane with the
    same per-plane program) — falling back to the loop when the per-plane
    resolved strategies are not uniformly "rfft2" or the responses disagree
    on padded shape."""
    multi = cfg.num_planes > 1
    resps = _as_plane_responses(cfg, resp, planes)
    stacked = (multi and resolve_plane_batching(cfg) == "stacked"
               and len({r.pad_shape for r in resps}) == 1)

    def resolved_names(grid_shape):
        """Per-plane strategy names, mirroring ``fft_convolve`` dispatch."""
        from repro.tune import autotune, registry

        names = []
        for r in resps:
            s = cfg.fft_strategy
            if s is None:
                s = registry.default_strategy("fft_convolve")
            elif s == "auto":
                shape = {"num_wires": grid_shape[0],
                         "num_ticks": grid_shape[1],
                         "response_wires": r.kernel.shape[0],
                         "response_ticks": r.kernel.shape[1],
                         "plane": r.plane}
                s = autotune.resolve("fft_convolve", None,
                                     shape=shape).strategy
            names.append(s)
        return names

    def loop_fn(state: SimState) -> SimState:
        signal = jnp.stack([
            fft_convolve(state.grid[i], r, cfg.fft_strategy)
            for i, r in enumerate(resps)])
        return state._replace(signal=signal)

    def fn(state: SimState) -> SimState:
        if not multi:
            return state._replace(
                signal=fft_convolve(state.grid, resps[0], cfg.fft_strategy))
        if not stacked:
            return loop_fn(state)
        w, t = state.grid.shape[-2:]
        if any(n != "rfft2" for n in resolved_names((w, t))):
            return loop_fn(state)
        from repro.core.fft_conv import _pad_grid

        wp, tp = resps[0].pad_shape
        padded = jnp.stack([_pad_grid(state.grid[i], r)
                            for i, r in enumerate(resps)])
        rfreq = jnp.stack([r.freq for r in resps])
        out = jnp.fft.irfft2(jnp.fft.rfft2(padded) * rfreq, s=(wp, tp))
        return state._replace(signal=out[:, :w, :t])

    return Stage("convolve", fn, op="fft_convolve")


def noise_stage(cfg: LArTPCConfig,
                planes: Optional[Tuple[int, ...]] = None) -> Stage:
    """Add frequency-shaped electronics noise to the signal (multi-plane:
    an independent realization per plane via plane-folded subkeys —
    ``plane_batching="stacked"`` draws every plane's spectrum in ONE
    batched dispatch over the stacked subkeys, bit-identical to the
    per-plane loop)."""
    specs = _selected_specs(cfg, planes)
    multi = cfg.num_planes > 1
    stacked = multi and resolve_plane_batching(cfg) == "stacked"

    def fn(state: SimState) -> SimState:
        denom = jnp.maximum(cfg.adc_per_electron, 1e-30)
        if not multi:
            return state._replace(
                signal=state.signal + simulate_noise(state.kn, cfg) / denom)
        if stacked:
            noise = jax.vmap(lambda k: simulate_noise(k, cfg))(
                plane_fold_keys(state.kn, specs))
        else:
            noise = jnp.stack([
                simulate_noise(jax.random.fold_in(state.kn, spec.index), cfg)
                for spec in specs])
        return state._replace(signal=state.signal + noise / denom)

    return Stage("noise", fn)


def digitize_stage(cfg: LArTPCConfig) -> Stage:
    """M(t,x) -> int16 ADC counts."""

    def fn(state: SimState) -> SimState:
        return state._replace(adc=digitize(state.signal, cfg))

    return Stage("digitize", fn)


def deconvolve_stage(cfg: LArTPCConfig, resp=None,
                     planes: Optional[Tuple[int, ...]] = None) -> Stage:
    """ADC -> Ŝ(t,x): invert the response with the config's regularized
    filter, dispatched through the ``deconvolve`` strategy registry.

    The per-plane inverse filters are precomputed here from the SAME
    responses the convolve stage applied (bipolar induction planes get the
    bipolar inverse, unipolar collection the unipolar one)."""
    from repro.core.deconvolve import (deconvolve, make_deconv_filter,
                                       measured_signal)

    multi = cfg.num_planes > 1
    filts = tuple(make_deconv_filter(r, cfg)
                  for r in _as_plane_responses(cfg, resp, planes))

    def fn(state: SimState) -> SimState:
        meas = measured_signal(state.adc, cfg)
        if not multi:
            return state._replace(
                decon=deconvolve(meas, filts[0], cfg.deconv_strategy))
        decon = jnp.stack([
            deconvolve(meas[i], f, cfg.deconv_strategy)
            for i, f in enumerate(filts)])
        return state._replace(decon=decon)

    return Stage("deconvolve", fn, op="deconvolve")


def hit_find_stage(cfg: LArTPCConfig,
                   planes: Optional[Tuple[int, ...]] = None) -> Stage:
    """Ŝ(t,x) -> HitSet: threshold-scan runs on every deconvolved wire,
    dispatched through the ``hit_find`` strategy registry. Multi-plane:
    one scan per plane, HitSet leaves stacked to (P, max_hits)."""
    from repro.core.hitfind import find_hits

    specs = _selected_specs(cfg, planes)
    multi = cfg.num_planes > 1

    def fn(state: SimState) -> SimState:
        if not multi:
            return state._replace(
                hits=find_hits(state.decon, cfg, cfg.hitfind_strategy))
        per_plane = [find_hits(state.decon[i], cfg, cfg.hitfind_strategy)
                     for i in range(len(specs))]
        hits = jax.tree.map(lambda *xs: jnp.stack(xs), *per_plane)
        return state._replace(hits=hits)

    return Stage("hit_find", fn, op="hit_find")


#: which SimState field each stage's finite sentinel inspects (stages that
#: only produce integers — digitize — have nothing to check; hit_find's
#: float leaves derive from decon, checked one stage earlier, but its
#: charge/tick can still overflow so it is checked too)
_FINITE_CHECK_FIELDS = {
    "drift": "depos",
    "charge_grid": "grid",
    "convolve": "signal",
    "noise": "signal",
    "deconvolve": "decon",
    "hit_find": "hits",
}


def _finite_checked(stage: Stage) -> Stage:
    """Wrap a stage with the ``cfg.check_finite`` sentinel: after the stage
    runs, AND ``all(isfinite(...))`` over the float leaves it wrote into the
    state's ``finite_ok`` flag. One fused reduction per stage — jit-cheap —
    and never a branch, so vmap/shard_map see the same program shape."""
    field = _FINITE_CHECK_FIELDS.get(stage.name)
    if field is None:
        return stage

    def fn(state: SimState) -> SimState:
        state = stage.fn(state)
        ok = (state.finite_ok if state.finite_ok is not None
              else jnp.asarray(True))
        for leaf in jax.tree.leaves(getattr(state, field)):
            if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
                ok = ok & jnp.all(jnp.isfinite(leaf))
        return state._replace(finite_ok=ok)

    return dataclasses.replace(stage, fn=fn)


def build_sim_graph(cfg: LArTPCConfig, resp=None,
                    pool: Optional[jax.Array] = None, add_noise: bool = True,
                    overrides: Optional[Dict[str, Callable | Stage]] = None,
                    planes: Optional[Tuple[int, ...]] = None,
                    recon: bool = False) -> SimGraph:
    """Assemble the canonical ``drift -> charge_grid -> convolve -> noise ->
    digitize`` chain. This is the ONLY place the stage order is written down;
    every executor (single / batched / distributed / streaming) runs the
    graph this returns.

    ``recon=True`` appends the reconstruction stages ``deconvolve ->
    hit_find`` after digitize (closing the sim -> recon loop); the default
    graph stays bit-identical to the sim-only chain — no recon stage, no
    ``decon``/``hits`` output leaves.

    ``resp`` is the detector response: a single ``DetectorResponse`` for
    single-plane configs, a per-plane sequence for multi-plane configs, or
    None to build the per-plane-type defaults. Multi-plane configs
    (``cfg.num_planes > 1``) run each readout stage per plane and stack a
    leading plane axis onto every ``SimOutput`` leaf; ``planes`` restricts
    the graph to a subset of plane indices (per-plane cost boards).

    ``add_noise=False`` drops the noise stage (rather than running it as an
    identity), so timing boards and traced programs only contain real work.
    ``overrides`` maps stage names to replacement fns/Stages (see
    ``SimGraph.replace``).

    When the config asks for the paper-faithful ``pool`` fluctuation stream
    and no pool is passed, the standard pre-computed pool is built here —
    every executor (and the timing boards) gets it without its own wiring.
    (Skipped when ``overrides`` replaces the charge_grid stage: the
    replacement owns its fluctuation scheme, e.g. the distributed
    executor's counter RNG.)
    """
    if (pool is None and cfg.fluctuate and cfg.rng_strategy == "pool"
            and not (overrides and "charge_grid" in overrides)):
        from repro.core import fluctuate as fl

        pool = fl.make_pool(jax.random.key(1234))
    stages = [
        drift_stage(cfg, planes=planes),
        charge_grid_stage(cfg, pool=pool, planes=planes),
        convolve_stage(cfg, resp, planes=planes),
    ]
    if add_noise:
        stages.append(noise_stage(cfg, planes=planes))
    stages.append(digitize_stage(cfg))
    if recon:
        stages.append(deconvolve_stage(cfg, resp, planes=planes))
        stages.append(hit_find_stage(cfg, planes=planes))
    if cfg.check_finite:
        # the numeric sentinel wraps the standard stages only; ``overrides``
        # below replace whole (wrapped) stages, so a specialized executor
        # owns its own checking if it wants any
        stages = [_finite_checked(s) for s in stages]
    graph = SimGraph(stages=tuple(stages))
    if overrides:
        graph = graph.replace(**overrides)
    return graph
