"""Electronics noise N(t,x): frequency-shaped Gaussian noise per wire.

Wire-Cell generates noise in the frequency domain from a measured amplitude
spectrum with random phases, then inverse-FFTs per channel. We reproduce that
structure with a synthetic 1/f-plus-plateau spectrum shaped by the electronics
response.

Normalization (Parseval). For a real length-``n`` signal built from its
rfft half-spectrum ``X`` (``irfft``), Parseval reads

    sum_t x_t^2 = (1/n) * sum_k w_k |X_k|^2

with ``w_k = 2`` for interior bins (each appears twice in the full
spectrum) and ``w_k = 1`` for the self-conjugate DC and (even ``n``)
Nyquist bins. We draw ``X_k = (re + i*im) * amp_k / sqrt(2)`` so
``E|X_k|^2 = amp_k^2`` on interior bins; DC/Nyquist carry no imaginary
part (a Hermitian spectrum requires them real), so there
``E|X_k|^2 = amp_k^2 / 2``. ``noise_spectrum`` scales ``amp`` so the
expected time-domain RMS equals ``cfg.noise_rms_adc`` exactly
(realized RMS is within a fraction of a percent at production sizes —
pinned to 5% in ``tests/test_core_sim.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig


def _parseval_weights(num_ticks: int) -> jax.Array:
    """Effective per-rfft-bin weight w_k with E[sum_t x_t^2] =
    (1/n) * sum_k w_k amp_k^2 for the spectrum draw in the module doc.

    Interior bins: full-spectrum multiplicity 2 and E|X_k|^2 = amp_k^2,
    so w_k = 2. The self-conjugate DC bin — and the Nyquist bin when
    ``num_ticks`` is even — appears once and carries half the variance
    (imaginary part zeroed), so w_k = 1 * 1/2 = 0.5.
    """
    nfreq = num_ticks // 2 + 1
    w = jnp.full((nfreq,), 2.0, jnp.float32)
    w = w.at[0].set(0.5)
    if num_ticks % 2 == 0:
        w = w.at[-1].set(0.5)
    return w


def noise_spectrum(cfg: LArTPCConfig) -> jax.Array:
    """Amplitude spectrum (num_ticks//2+1,) — 1/f + white, shaped.

    Scaled so a ``simulate_noise`` realization has expected time-domain RMS
    ``cfg.noise_rms_adc``: Parseval gives ``E[mean_t x^2] =
    sum_k w_k amp_k^2 / n^2`` for the spectrum draw described in the
    module docstring, so ``amp`` is scaled by
    ``rms * n / sqrt(sum(w * amp^2))``.
    """
    n = cfg.num_ticks
    nfreq = n // 2 + 1
    f = jnp.arange(nfreq, dtype=jnp.float32) + 1.0
    amp = 1.0 / jnp.sqrt(f) + 0.3
    # suppress very high frequency (anti-aliasing of the shaper)
    amp = amp * jnp.exp(-((f / nfreq) ** 2) * 2.0)
    w = _parseval_weights(n)
    norm = cfg.noise_rms_adc * n / jnp.sqrt(jnp.sum(w * amp**2) + 1e-30)
    return amp * norm


def sample_noise_rows(key: jax.Array, n_rows: int, amp: jax.Array,
                      num_ticks: int) -> jax.Array:
    """(n_rows, num_ticks) realizations of the given amplitude spectrum —
    the ONE place the frequency-domain draw and its Parseval-critical
    details live (shared by ``simulate_noise`` and the distributed
    executor's per-shard noise stage)."""
    nfreq = num_ticks // 2 + 1
    k1, k2 = jax.random.split(key)
    re = jax.random.normal(k1, (n_rows, nfreq))
    im = jax.random.normal(k2, (n_rows, nfreq))
    # a Hermitian spectrum has real DC (and, for even n, Nyquist) bins;
    # imaginary parts there would be silently discarded by irfft, skewing
    # the Parseval accounting
    im = im.at[:, 0].set(0.0)
    if num_ticks % 2 == 0:
        im = im.at[:, -1].set(0.0)
    spec = (re + 1j * im) * amp[None, :] * 0.7071067811865476
    return jnp.fft.irfft(spec, n=num_ticks, axis=-1).astype(jnp.float32)


def simulate_noise(key: jax.Array, cfg: LArTPCConfig) -> jax.Array:
    """(num_wires, num_ticks) correlated noise realization."""
    return sample_noise_rows(key, cfg.num_wires, noise_spectrum(cfg),
                             cfg.num_ticks)
