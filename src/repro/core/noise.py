"""Electronics noise N(t,x): frequency-shaped Gaussian noise per wire.

Wire-Cell generates noise in the frequency domain from a measured amplitude
spectrum with random phases, then inverse-FFTs per channel. We reproduce that
structure with a synthetic 1/f-plus-plateau spectrum shaped by the electronics
response.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig


def noise_spectrum(cfg: LArTPCConfig) -> jax.Array:
    """Amplitude spectrum (num_ticks//2+1,) — 1/f + white, shaped."""
    nfreq = cfg.num_ticks // 2 + 1
    f = jnp.arange(nfreq, dtype=jnp.float32) + 1.0
    amp = 1.0 / jnp.sqrt(f) + 0.3
    # suppress very high frequency (anti-aliasing of the shaper)
    amp = amp * jnp.exp(-((f / nfreq) ** 2) * 2.0)
    # normalize so time-domain RMS == cfg.noise_rms_adc
    rms = jnp.sqrt(jnp.sum(amp**2) / cfg.num_ticks) / jnp.sqrt(cfg.num_ticks)
    return amp * (cfg.noise_rms_adc / (rms * cfg.num_ticks + 1e-30)) * cfg.num_ticks


def simulate_noise(key: jax.Array, cfg: LArTPCConfig) -> jax.Array:
    """(num_wires, num_ticks) correlated noise realization."""
    nfreq = cfg.num_ticks // 2 + 1
    amp = noise_spectrum(cfg)
    k1, k2 = jax.random.split(key)
    re = jax.random.normal(k1, (cfg.num_wires, nfreq))
    im = jax.random.normal(k2, (cfg.num_wires, nfreq))
    spec = (re + 1j * im) * amp[None, :] * 0.7071067811865476
    return jnp.fft.irfft(spec, n=cfg.num_ticks, axis=-1).astype(jnp.float32)
