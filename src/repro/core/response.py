"""Detector response R(t, x): field response × electronics shaping.

The paper uses the pre-computed MicroBooNE 2-D response (refs [9,10]): bipolar
for induction planes, unipolar for collection. We synthesize a response with the
same structure: a wire-direction induction profile spanning ±(response_wires//2)
wires convolved with a time-direction shaping (semi-Gaussian electronics) and a
plane-dependent field-response time shape.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig


class DetectorResponse(NamedTuple):
    """A frequency-domain transfer function over the padded readout grid.

    The FORWARD response (``make_response``) is the canonical instance, but
    the container is direction-agnostic: the recon chain's inverse filters
    (``repro.core.deconvolve.make_deconv_filter``) are DetectorResponses
    too — same ``pad_shape``/``plane``, ``freq`` holding the regularized
    inverse — so every ``fft_convolve`` layout and the plane-keyed tuning
    bucket apply to deconvolution unchanged.
    """

    kernel: jax.Array       # (response_wires, response_ticks) real-space response
    freq: jax.Array         # rfft2 of the kernel at padded grid shape (complex64)
    pad_shape: tuple        # (W_pad, T_pad) padded grid shape for linear conv
    plane: str = "induction"  # field-response type this transform encodes
    #                          ("induction" | "collection") — part of the
    #                          fft_convolve tuning key (repro.tune)


def _semigaussian(t_us: jax.Array, shaping_us: float = 2.0, order: int = 4) -> jax.Array:
    """CR-(RC)^n semi-Gaussian electronics shaping response."""
    x = jnp.clip(t_us / shaping_us, 0.0, None)
    h = (x ** order) * jnp.exp(-order * x)
    return h / (jnp.max(h) + 1e-30)


def _field_time(t_us: jax.Array, plane: str) -> jax.Array:
    """Field-response time shape: bipolar (induction) or unipolar (collection)."""
    if plane == "collection":
        return jnp.exp(-0.5 * ((t_us - 1.0) / 0.5) ** 2)
    # induction: derivative-of-Gaussian -> bipolar
    return -(t_us - 1.5) * jnp.exp(-0.5 * ((t_us - 1.5) / 0.6) ** 2)


def next_fast_len(n: int) -> int:
    """Smallest 2^a * 3^b * 5^c >= n (FFT-friendly size)."""
    if n <= 1:
        return 1
    best = 1 << (n - 1).bit_length()
    m5 = 1
    while m5 < best:
        m53 = m5
        while m53 < best:
            m = m53
            while m < n:
                m *= 2
            best = min(best, m)
            m53 *= 3
        m5 *= 5
    return best


def make_response(cfg: LArTPCConfig, plane: str = "induction") -> DetectorResponse:
    rw, rt = cfg.response_wires, cfg.response_ticks
    t_us = jnp.arange(rt, dtype=jnp.float32) * cfg.tick_us
    time_resp = _field_time(t_us, plane)
    elec = _semigaussian(t_us, shaping_us=cfg.response_shaping_us)
    # time response = field (x) electronics, linear convolution cropped to rt
    tr = jnp.convolve(time_resp, elec, mode="full")[:rt]
    tr = tr / (jnp.max(jnp.abs(tr)) + 1e-30)

    # wire-direction induction profile: falls off with wire distance
    dw = jnp.arange(rw, dtype=jnp.float32) - (rw - 1) / 2.0
    wire_prof = jnp.exp(-0.5 * (dw / (rw / 6.0)) ** 2)
    wire_prof = wire_prof / jnp.sum(wire_prof)

    kernel = wire_prof[:, None] * tr[None, :]
    # overall amplitude: a calibration degree of freedom. A *python* 1.0
    # skips the multiply so the default traced program is unchanged; a
    # traced gain (repro.core.fit differentiating the response) always
    # applies (multiplying by exactly 1.0 is value-exact anyway).
    gain = cfg.response_gain
    if isinstance(gain, jax.Array) or gain != 1.0:
        kernel = kernel * gain

    w_pad = next_fast_len(cfg.num_wires + rw - 1)
    t_pad = next_fast_len(cfg.num_ticks + rt - 1)
    kpad = jnp.zeros((w_pad, t_pad), jnp.float32)
    kpad = kpad.at[:rw, :rt].set(kernel)
    # center the wire axis so output is aligned (roll by half the wire span)
    kpad = jnp.roll(kpad, shift=-(rw // 2), axis=0)
    freq = jnp.fft.rfft2(kpad)
    return DetectorResponse(kernel=kernel, freq=freq, pad_shape=(w_pad, t_pad),
                            plane=plane)


def make_plane_responses(cfg: LArTPCConfig):
    """One ``DetectorResponse`` per readout plane of ``cfg``, in plane order
    (bipolar for induction planes, unipolar for the collection plane)."""
    from repro.config import plane_specs

    return tuple(make_response(cfg, plane=s.kind) for s in plane_specs(cfg))


def make_distributed_response(cfg: LArTPCConfig, w_pad: int,
                              plane: str = "induction") -> DetectorResponse:
    """Response transform at the *distributed* grid shape (w_pad, num_ticks).

    The distributed pipeline uses cyclic convolution at the readout size
    (Wire-Cell's own convention — the response support (~200 ticks) is tiny
    compared to the readout window, and wrap-around lands in the pre-trigger
    padding), so freq is evaluated at exactly (w_pad, num_ticks).
    """
    base = make_response(cfg, plane)
    rw, rt = base.kernel.shape
    kpad = jnp.zeros((w_pad, cfg.num_ticks), jnp.float32)
    kpad = kpad.at[:rw, :rt].set(base.kernel)
    kpad = jnp.roll(kpad, shift=-(rw // 2), axis=0)
    freq = jnp.fft.rfft2(kpad)  # (w_pad, num_ticks//2+1)
    return DetectorResponse(kernel=base.kernel, freq=freq,
                            pad_shape=(w_pad, cfg.num_ticks), plane=plane)


def make_distributed_plane_responses(cfg: LArTPCConfig, w_pad: int):
    """Per-plane responses at the distributed grid shape, in plane order."""
    from repro.config import plane_specs

    return tuple(make_distributed_response(cfg, w_pad, plane=s.kind)
                 for s in plane_specs(cfg))
