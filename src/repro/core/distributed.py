"""Multi-device LArTPC simulation: shard_map pipeline + pencil-decomposed FFT.

Production layout (mesh axes combined into one logical "shard" group):

  depos         : sharded over all devices (pure DP — rasterization is
                  embarrassingly parallel).
  scatter-add   : each device accumulates a *partial* grid from its depo
                  shard, then one ``psum_scatter`` along the wire axis leaves
                  the summed grid wire-sharded. (TPU analogue of the paper's
                  cross-GPU atomic-add: a single reduce-scatter collective.)
  FFT           : pencil decomposition — tick-axis rFFT is wire-local;
                  an ``all_to_all`` transposes to frequency-sharding so the
                  wire-axis FFT is local; multiply by R(ω); inverse the chain.
  output        : ADC grid wire-sharded (stays distributed for downstream
                  consumers, e.g. signal processing).

Two scatter-reduction strategies for §Perf:
  psum_scatter : partial full-size grids + one reduce-scatter (simple; moves
                 W_pad*T bytes per device through ICI).
  halo         : depos are pre-binned to their owner wire-shard on the host
                 (data pipeline does this for free); each device scatter-adds
                 only its own wire range + a halo margin, then exchanges halo
                 strips with neighbours via ``ppermute``. Moves only
                 O(halo*T) bytes — collective-bytes drop by ~W_shard/halo.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import LArTPCConfig
from repro.core import fluctuate as fl
from repro.core.depo import DepoSet
from repro.core.noise import noise_spectrum, sample_noise_rows
from repro.core.rasterize import rasterize
from repro.core.scatter import scatter_add
from repro.core.stages import (SimState, build_sim_graph,
                               resolve_plane_batching)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def padded_grid_shape(cfg: LArTPCConfig, nshards: int):
    """(W_pad, T, F_pad): wire axis divisible by nshards, freq axis too."""
    w_pad = _round_up(cfg.num_wires, nshards)
    nfreq = cfg.num_ticks // 2 + 1
    f_pad = _round_up(nfreq, nshards)
    return w_pad, cfg.num_ticks, f_pad


def make_distributed_sim(mesh: Mesh, cfg: LArTPCConfig, resp,
                         axes: Sequence[str] = ("data", "model"),
                         scatter_reduction: str = "psum_scatter",
                         add_noise: bool = True, recon: bool = False):
    """Build the jit'd distributed sim: (key, depos sharded over `axes`) -> ADC.

    `resp` is the response at the *distributed* (W_pad, T) grid shape —
    build it with ``make_distributed_response`` (single plane) or
    ``make_distributed_plane_responses`` (one per plane, multi-plane
    configs). Multi-plane configs take *physical* depos (the stock drift
    stage projects them onto every plane in-graph) and return a
    (num_planes, W_pad, T) ADC grid, plane axis replicated, wire axis
    sharded.

    ``recon=True`` appends the deconvolve/hit_find stages with
    collective-aware overrides and returns ``(adc, decon, hits)`` instead
    of the bare ADC grid: deconvolve rides the SAME pencil-FFT path as the
    forward convolve (the inverse filter is just another frequency-domain
    multiply, at the distributed cyclic shape); hit finding is wire-local
    per shard — each shard scans its own wires with a per-shard HitSet
    capacity of ceil(max_hits / nshards) and its global wire offset, and
    the shards' hits concatenate along the capacity axis (hit *positions*
    therefore differ from the single-device compaction; the masked hit set
    is what matches). ``hits.n_hits`` is summed over shards to the global
    candidate count, () single-plane / (P,) multi-plane.

    scatter_reduction:
      psum_scatter : each device scatter-adds its depos into a full-size
                     partial grid; one reduce-scatter leaves it wire-sharded
                     over ALL axes. Moves O(W_pad*T) bytes per device.
      halo         : depos must arrive pre-binned by wire strip over the LAST
                     axis (the data pipeline sorts by wire — free); each
                     device accumulates only its strip + halo margins and
                     exchanges the margins with ring neighbours, partials
                     psum'd over the other axes. Moves O(W_pad*T/nshards)
                     bytes — the paper's atomic-add turned into a
                     neighbour exchange. Single-plane only: each plane has
                     its own wire coordinate, so one host-side wire binning
                     cannot serve them all.
    """
    from repro.config import plane_specs

    axes = tuple(axes)
    specs = plane_specs(cfg)
    multi = cfg.num_planes > 1
    n_planes = len(specs)
    # "stacked" folds the plane axis into the shard_map body as a real
    # array axis: ONE reduce-scatter chain, ONE pencil-FFT all_to_all
    # chain, and one halo ppermute pair per step regardless of P (the
    # "loop" mode preserves the per-plane collectives)
    stacked = multi and resolve_plane_batching(cfg) == "stacked"
    if multi and scatter_reduction == "halo" and not stacked:
        raise ValueError(
            "multi-plane scatter_reduction='halo' requires "
            "plane_batching='stacked': the loop path pre-bins depos by ONE "
            "wire coordinate, but every plane projects its own; the "
            "stacked path takes a (num_planes, N) DepoSet pre-binned per "
            "plane-projected wire (bin_depos_by_wire)")
    if multi:
        resps = tuple(resp)
        if len(resps) != len(specs):
            raise ValueError(f"got {len(resps)} responses for "
                             f"{len(specs)} planes")
        rfreqs = [r.freq for r in resps]
    else:
        rfreqs = [resp.freq]  # (w_pad, nfreq) complex64, precomputed
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    # strips live on the FIRST axis so strip-major wire ownership matches the
    # flat (axes-major) ownership the pencil FFT uses
    halo_axis = axes[0]
    n_halo = mesh.shape[halo_axis]
    if scatter_reduction == "halo":
        w_pad, t_len, f_pad = padded_grid_shape(cfg, max(nshards, n_halo))
        w_strip = w_pad // n_halo
        halo = cfg.patch_wires
        assert w_strip >= halo, (
            f"halo strategy needs strip {w_strip} >= patch {halo}")
    else:
        w_pad, t_len, f_pad = padded_grid_shape(cfg, nshards)
    nfreq = t_len // 2 + 1
    w_shard = w_pad // nshards
    f_shard = f_pad // nshards

    namp = noise_spectrum(cfg)  # (nfreq,)

    # The distributed executor runs the SAME SimGraph as the single-event
    # and batched paths; only the collective-aware stages are overridden
    # (charge_grid reduces across devices, convolve is the pencil FFT,
    # noise draws per-device wire-local realizations). Drift and digitize
    # are the stock stages — drift is elementwise over the (sharded) depo
    # axis and digitize over the grid, so both shard freely, including the
    # multi-plane per-plane projection.

    def _rasterize_fluct(depos, base_key):
        """One plane's depo shard -> fluctuated patches (no collectives)."""
        patches, w0, t0 = rasterize(depos, cfg)
        if cfg.fluctuate and cfg.rng_strategy != "none":
            kf = jax.random.fold_in(base_key, _flat_index(axes, mesh))
            patches = fl.fluctuate_counter(kf, patches, depos.charge)
        return patches, w0, t0

    def _local_strip(patches, w0, t0):
        """Local halo-margined strip for one plane (no collectives)."""
        me = jax.lax.axis_index(halo_axis)
        lo = me * w_strip
        # local strip with halo margin on both sides (depos pre-binned
        # so every patch lands within [lo-halo, lo+w_strip+halo))
        return _scatter_local_strip(patches, w0, t0, lo, w_strip, halo,
                                    t_len, cfg)

    def _reduce_strips(strip):
        """Halo collectives for (..., strip_w, T) strips: one psum over the
        non-halo axes, one ppermute ring exchange, one sub-shard slice —
        the SAME collective count whether a plane axis leads or not."""
        for a in axes[1:]:
            strip = jax.lax.psum(strip, a)
        strip_own = _halo_exchange(strip, w_strip, halo, halo_axis)
        if w_shard == w_strip:
            return strip_own
        # slice my (finer) w_shard piece out of the strip for the FFT
        sub = _flat_index(axes[1:], mesh)
        start = (0,) * (strip_own.ndim - 2) + (sub * w_shard, 0)
        sizes = strip_own.shape[:-2] + (w_shard, t_len)
        return jax.lax.dynamic_slice(strip_own, start, sizes)

    def _reduce_partials(partial):
        """Reduce-scatter the wire axis (axis -2) of (..., W_pad, T)
        partials across every shard: one psum_scatter per mesh axis, the
        SAME collective count whether a plane axis leads or not."""
        lead = partial.shape[:-2]
        for a in axes:
            na = mesh.shape[a]
            partial = jnp.moveaxis(
                partial.reshape(*lead, na, partial.shape[-2] // na, t_len),
                -3, 0)
            partial = jax.lax.psum_scatter(
                partial, a, scatter_dimension=0, tiled=False)
        return partial

    def _charge_grid_one(depos, base_key):
        """One plane's depo shard -> its wire-sharded grid piece."""
        patches, w0, t0 = _rasterize_fluct(depos, base_key)
        if scatter_reduction == "halo":
            return _reduce_strips(_local_strip(patches, w0, t0))
        return _reduce_partials(
            _scatter_partial_full(patches, w0, t0, w_pad, t_len, cfg))

    def dist_charge_grid(state: SimState) -> SimState:
        if not multi:
            return state._replace(
                grid=_charge_grid_one(state.depos, state.key))
        # per-plane rasterize + fluctuate (local work, plane-folded keys,
        # bit-identical to the loop); ONLY the collectives batch over P
        locals_ = []
        for i, spec in enumerate(specs):
            depos_p = jax.tree.map(lambda x, i=i: x[i], state.depos)
            base = jax.random.fold_in(state.key, spec.index)
            locals_.append(_rasterize_fluct(depos_p, base))
        if not stacked:
            return state._replace(grid=jnp.stack([
                (_reduce_strips(_local_strip(p, w0, t0))
                 if scatter_reduction == "halo" else
                 _reduce_partials(_scatter_partial_full(p, w0, t0, w_pad,
                                                        t_len, cfg)))
                for p, w0, t0 in locals_]))
        if scatter_reduction == "halo":
            strip = jnp.stack([_local_strip(p, w0, t0)
                               for p, w0, t0 in locals_])
            return state._replace(grid=_reduce_strips(strip))
        partial = jnp.stack([
            _scatter_partial_full(p, w0, t0, w_pad, t_len, cfg)
            for p, w0, t0 in locals_])
        return state._replace(grid=_reduce_partials(partial))

    def _convolve_one(grid_local, rfreq):
        # ---- pencil FFT: tick rFFT local -> transpose -> wire FFT ----
        freq_t = jnp.fft.rfft(grid_local, axis=-1)          # (w_shard, nfreq)
        freq_t = jnp.pad(freq_t, ((0, 0), (0, f_pad - nfreq)))
        # transpose: (w_shard, f_pad) -> gather wires / scatter freq
        blk = freq_t.reshape(w_shard, nshards, f_shard)
        blk = jnp.swapaxes(blk, 0, 1)                        # (nshards, w_shard, f_shard)
        blk = _all_to_all_chain(blk, axes, mesh)             # (nshards, w_shard, f_shard)
        cols = blk.reshape(w_pad, f_shard)                   # all wires, my freqs
        freq_wt = jnp.fft.fft(cols, axis=0)                  # wire-axis FFT

        # ---- multiply by response in frequency domain ----
        me = _flat_index(axes, mesh)
        rcols = jax.lax.dynamic_slice(
            jnp.pad(rfreq, ((0, 0), (0, f_pad - nfreq))),
            (0, me * f_shard), (w_pad, f_shard))
        out_wt = freq_wt * rcols

        # ---- inverse chain ----
        cols = jnp.fft.ifft(out_wt, axis=0)                  # (w_pad, f_shard)
        blk = cols.reshape(nshards, w_shard, f_shard)
        blk = _all_to_all_chain(blk, axes, mesh)
        freq_t = jnp.swapaxes(blk, 0, 1).reshape(w_shard, f_pad)[:, :nfreq]
        return jnp.fft.irfft(freq_t, n=t_len, axis=-1).real.astype(jnp.float32)

    def _convolve_planes(grid_local, rfreq_pad):
        """All P planes through ONE pencil-FFT all_to_all chain.

        grid_local (P, w_shard, t_len); rfreq_pad (P, w_pad, f_pad) —
        plane p's output bit-identical to ``_convolve_one`` on plane p
        (the all_to_all is pure data movement, the FFTs batch per row).
        """
        freq_t = jnp.fft.rfft(grid_local, axis=-1)      # (P, w_shard, nfreq)
        freq_t = jnp.pad(freq_t, ((0, 0), (0, 0), (0, f_pad - nfreq)))
        blk = freq_t.reshape(n_planes, w_shard, nshards, f_shard)
        blk = jnp.moveaxis(blk, 2, 0)            # (nshards, P, w_shard, f_sh)
        blk = _all_to_all_chain(blk, axes, mesh)
        cols = jnp.swapaxes(blk, 0, 1).reshape(n_planes, w_pad, f_shard)
        freq_wt = jnp.fft.fft(cols, axis=-2)             # wire-axis FFT

        me = _flat_index(axes, mesh)
        rcols = jax.lax.dynamic_slice(
            rfreq_pad, (0, 0, me * f_shard), (n_planes, w_pad, f_shard))
        out_wt = freq_wt * rcols

        cols = jnp.fft.ifft(out_wt, axis=-2)             # (P, w_pad, f_shard)
        blk = jnp.swapaxes(cols.reshape(n_planes, nshards, w_shard, f_shard),
                           0, 1)                 # (nshards, P, w_shard, f_sh)
        blk = _all_to_all_chain(blk, axes, mesh)
        freq_t = jnp.moveaxis(blk, 0, 2).reshape(
            n_planes, w_shard, f_pad)[..., :nfreq]
        return jnp.fft.irfft(freq_t, n=t_len, axis=-1).real.astype(
            jnp.float32)

    if multi and stacked:
        rfreq_pad = jnp.stack([
            jnp.pad(rf, ((0, 0), (0, f_pad - nfreq))) for rf in rfreqs])

    def dist_convolve(state: SimState) -> SimState:
        if not multi:
            return state._replace(signal=_convolve_one(state.grid, rfreqs[0]))
        if stacked:
            return state._replace(
                signal=_convolve_planes(state.grid, rfreq_pad))
        return state._replace(signal=jnp.stack([
            _convolve_one(state.grid[i], rfreqs[i])
            for i in range(len(rfreqs))]))

    def _noise_one(kn):
        # wire-local noise realization for one plane: the shared draw, so
        # the Parseval normalization lives in exactly one place
        return sample_noise_rows(kn, w_shard, namp, t_len)

    def dist_noise(state: SimState) -> SimState:
        # per-device key schedule
        kn = jax.random.fold_in(state.key, 77 + _flat_index(axes, mesh))
        if not multi:
            noise = _noise_one(kn)
        elif stacked:
            # ONE batched spectrum draw over the stacked per-plane subkeys
            # (same fold_in derivation as the loop, vmapped)
            idx = jnp.asarray([s.index for s in specs], jnp.uint32)
            kns = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(kn, idx)
            noise = jax.vmap(_noise_one)(kns)
        else:
            noise = jnp.stack([
                _noise_one(jax.random.fold_in(kn, spec.index))
                for spec in specs])
        return state._replace(
            signal=state.signal + noise / max(cfg.adc_per_electron, 1e-30))

    overrides = {"charge_grid": dist_charge_grid, "convolve": dist_convolve}
    if add_noise:
        overrides["noise"] = dist_noise

    if recon:
        from repro.core.deconvolve import make_deconv_filter, measured_signal
        from repro.core.hitfind import find_hits

        # per-plane inverse filters at the distributed cyclic shape: the
        # resp(s) passed in ARE that shape, so the filters inherit it
        gfreqs = [make_deconv_filter(r, cfg).freq
                  for r in (resps if multi else [resp])]
        cap_shard = -(-cfg.max_hits // nshards)

        def _deconv_one(adc_local, gfreq):
            # the inverse filter is just another frequency-domain multiply:
            # reuse the forward pencil-FFT chain verbatim
            return _convolve_one(measured_signal(adc_local, cfg), gfreq)

        if multi and stacked:
            gfreq_pad = jnp.stack([
                jnp.pad(g, ((0, 0), (0, f_pad - nfreq))) for g in gfreqs])

        def dist_deconvolve(state: SimState) -> SimState:
            if not multi:
                return state._replace(
                    decon=_deconv_one(state.adc, gfreqs[0]))
            if stacked:
                # the inverse filter rides the same single-shot pencil chain
                return state._replace(decon=_convolve_planes(
                    measured_signal(state.adc, cfg), gfreq_pad))
            return state._replace(decon=jnp.stack([
                _deconv_one(state.adc[i], gfreqs[i])
                for i in range(len(gfreqs))]))

        def _hits_one(decon_local):
            me = _flat_index(axes, mesh)
            off = me * w_shard
            gw = off + jnp.arange(w_shard)
            # the wire axis is padded to w_pad: zero the padding wires so
            # their (noise-only) waveforms cannot fire hits
            masked = jnp.where((gw < cfg.num_wires)[:, None],
                               decon_local, 0.0)
            return find_hits(masked, cfg, cfg.hitfind_strategy,
                             wire_offset=off, max_hits=cap_shard)

        def dist_hit_find(state: SimState) -> SimState:
            if not multi:
                h = _hits_one(state.decon)
                # n_hits -> (1,) so every HitSet leaf concatenates over the
                # shard axis under one out_spec; the wrapper sums it back
                return state._replace(hits=h._replace(n_hits=h.n_hits[None]))
            per = [_hits_one(state.decon[i]) for i in range(len(specs))]
            h = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
            return state._replace(hits=h._replace(n_hits=h.n_hits[:, None]))

        overrides["deconvolve"] = dist_deconvolve
        overrides["hit_find"] = dist_hit_find

    graph = build_sim_graph(cfg, resp, add_noise=add_noise,
                            overrides=overrides, recon=recon)
    grid_spec = P(None, axes, None) if multi else P(axes, None)

    def local_run(key, depos):
        out = graph.run(key, depos)
        if not recon:
            return out.adc
        return out.adc, out.decon, out.hits

    # multi-plane halo takes a pre-drifted, per-plane-binned (P, N) DepoSet:
    # shard the depo axis, replicate the plane axis. Everything else takes
    # 1-D depo leaves (physical depos for multi-plane psum_scatter; the
    # in-graph drift stage projects them per plane).
    depo_spec = (P(None, axes) if multi and scatter_reduction == "halo"
                 else P(axes))
    fn = shard_map(
        local_run, mesh=mesh,
        # the depo spec is a pytree prefix: every leaf of the depos arg
        # (DepoSet or PhysicalDepoSet) shards its depo axis over `axes`
        in_specs=(P(), depo_spec),
        # the HitSet spec is a prefix too: every hit leaf concatenates its
        # leading (capacity / plane) axis over the shard group
        out_specs=(grid_spec if not recon else
                   (grid_spec, grid_spec,
                    P(None, axes) if multi else P(axes))),
        check_rep=False,
    )
    if not recon:
        return jax.jit(fn)

    def run(key, depos):
        adc, decon, hits = fn(key, depos)
        # per-shard candidate counts -> one global count per plane
        n = jnp.sum(hits.n_hits, axis=-1).astype(jnp.int32)
        return adc, decon, hits._replace(n_hits=n)

    return jax.jit(run)


def _flat_index(axes, mesh):
    """Flattened linear index of this device within the `axes` group."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _all_to_all_chain(blk, axes, mesh):
    """all_to_all over possibly-multiple mesh axes treated as one group.

    blk: (nshards, ...) — the leading axis is split/concat across the group.
    """
    if len(axes) == 1:
        return jax.lax.all_to_all(blk, axes[0], split_axis=0, concat_axis=0,
                                  tiled=True)
    # factor the group: reshape leading dim (A*B) -> A blocks of B
    a, b = axes[0], axes[1]
    na, nb = mesh.shape[a], mesh.shape[b]
    n = blk.shape[0]
    assert n == na * nb
    x = blk.reshape(na, nb, *blk.shape[1:])
    x = jax.lax.all_to_all(x, a, split_axis=0, concat_axis=0, tiled=False)
    # now (na, nb, ...): leading na is local block from each a-peer
    x = jnp.swapaxes(x, 0, 1).reshape(nb, na, *blk.shape[1:])
    x = jax.lax.all_to_all(x, b, split_axis=0, concat_axis=0, tiled=False)
    x = jnp.swapaxes(x, 0, 1)  # (na, nb, ...)
    return x.reshape(n, *blk.shape[1:])


def _scatter_partial_full(patches, w0, t0, w_pad, t_len, cfg: LArTPCConfig):
    """Local scatter-add into a full-size (padded) grid."""
    import dataclasses

    cfg2 = dataclasses.replace(cfg, num_wires=w_pad, num_ticks=t_len)
    return scatter_add(patches, w0, t0, cfg2, strategy="xla")


def _scatter_local_strip(patches, w0, t0, lo, w_shard, halo, t_len,
                         cfg: LArTPCConfig):
    """Scatter-add into my wire strip [lo-halo, lo+w_shard+halo)."""
    import dataclasses

    strip_w = w_shard + 2 * halo
    # shift into strip coordinates; out-of-range pixels get dropped by the
    # scatter's bounds mode.
    w0s = w0 - (lo - halo)
    n, pw, pt = patches.shape
    dw = jnp.arange(pw, dtype=jnp.int32)[None, :, None]
    dt = jnp.arange(pt, dtype=jnp.int32)[None, None, :]
    wi = w0s[:, None, None] + dw
    ti = t0[:, None, None] + dt
    inb = (wi >= 0) & (wi < strip_w)
    flat = jnp.where(inb, wi, 0) * t_len + ti
    grid = jnp.zeros((strip_w * t_len,), patches.dtype)
    grid = grid.at[flat.reshape(-1)].add(
        jnp.where(inb, patches, 0.0).reshape(-1), mode="drop")
    return grid.reshape(strip_w, t_len)


def _halo_exchange(strip, w_shard, halo, axis: str):
    """Add my halo overhangs into my neighbours' strips (ring ppermute).

    strip: (..., w_shard + 2*halo, T) — the wire axis is axis -2, so a
    stacked plane axis rides along through ONE ppermute pair; returns the
    owned (..., w_shard, T) region.
    """
    lo_halo = strip[..., :halo, :]    # belongs to left neighbour
    hi_halo = strip[..., -halo:, :]   # belongs to right neighbour
    n = jax.lax.psum(1, axis)
    right = [(i, (i + 1) % n) for i in range(n)]
    left = [(i, (i - 1) % n) for i in range(n)]
    from_left = jax.lax.ppermute(hi_halo, axis, right)   # left nbr's overhang
    from_right = jax.lax.ppermute(lo_halo, axis, left)   # right nbr's overhang
    own = strip[..., halo:halo + w_shard, :]
    own = own.at[..., :halo, :].add(from_left)
    own = own.at[..., -halo:, :].add(from_right)
    return own


def bin_depos_by_wire(depos: DepoSet, n_strips: int, w_pad: int) -> DepoSet:
    """Host-side pre-binning for the halo strategy: sort depos by wire and
    pad each strip's bucket to equal count (zero-charge filler), so strip i
    of the first mesh axis receives exactly the depos that touch it.

    Also accepts a multi-plane ``DepoSet`` with (P, N) leaves: each plane's
    row is binned by ITS OWN projected wire coordinate, and every plane
    shares one bucket capacity (the max over plane x strip) so strip s
    occupies the same column range in every plane — a single depo-axis
    shard then carries strip s of ALL planes.
    """
    import numpy as np

    wires = np.asarray(depos.wire)
    multi = wires.ndim == 2
    wires = np.atleast_2d(wires)
    strip_w = w_pad // n_strips
    plane_buckets = []
    cap = 1
    for wrow in wires:
        strip = np.clip((wrow // strip_w).astype(np.int64), 0, n_strips - 1)
        buckets = [np.nonzero(strip == s)[0] for s in range(n_strips)]
        cap = max(cap, max(len(b) for b in buckets))
        plane_buckets.append(buckets)
    n_out = cap * n_strips
    rows = []
    for buckets in plane_buckets:
        idx = np.zeros(n_out, np.int64)
        valid = np.zeros(n_out, bool)
        for s, b in enumerate(buckets):
            idx[s * cap:s * cap + len(b)] = b
            valid[s * cap:s * cap + len(b)] = True
        rows.append((idx, valid))
    center = np.array([(s * strip_w + strip_w // 2)
                       for s in range(n_strips)], np.float32)
    fill_wire = np.repeat(center, cap)

    def take(x, fill):
        arr = np.atleast_2d(np.asarray(x))
        out = np.stack([np.where(valid, arr[p][idx], fill).astype(np.float32)
                        for p, (idx, valid) in enumerate(rows)])
        return jnp.asarray(out if multi else out[0])

    return DepoSet(
        wire=take(depos.wire, fill_wire),
        tick=take(depos.tick, 100.0),
        sigma_w=take(depos.sigma_w, 1.0),
        sigma_t=take(depos.sigma_t, 1.0),
        charge=take(depos.charge, 0.0),
    )


def shard_depos(depos, mesh: Mesh, axes=("data", "model")):
    """Pad depo count to shard evenly and device_put with the DP sharding.

    Accepts a detector-frame ``DepoSet`` or a physical ``PhysicalDepoSet``
    (the input of multi-plane distributed runs — the in-graph drift stage
    projects it per plane). Padding depos carry zero charge, so they
    contribute nothing to any plane.
    """
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    # multi-plane halo inputs carry (P, N) leaves: pad/shard the LAST
    # (depo) axis only and replicate the plane axis
    planed = isinstance(depos, DepoSet) and depos.wire.ndim == 2
    n = depos.wire.shape[-1] if planed else depos.n
    n_pad = _round_up(n, nshards)
    pad = n_pad - n

    def padf(x):
        if planed:
            return jnp.pad(x, ((0, 0), (0, pad)))
        return jnp.pad(x, (0, pad))

    padded = type(depos)(*(padf(x) for x in depos))
    if isinstance(depos, DepoSet):
        # zero-charge padding; positive sigmas avoid 0/0 in Gaussian edges
        padded = padded._replace(charge=padded.charge.at[..., n:].set(0.0),
                                 sigma_w=padded.sigma_w.at[..., n:].set(1.0),
                                 sigma_t=padded.sigma_t.at[..., n:].set(1.0))
    # physical depos pad with zeros: q=0 is inert, and the drift stage's
    # sigma floors keep zero-drift-time widths positive
    sh = NamedSharding(mesh, P(None, tuple(axes)) if planed
                       else P(tuple(axes)))
    return type(depos)(*(jax.device_put(x, sh) for x in padded))
