"""End-to-end LArTPC signal simulation pipelines.

Two strategies, mirroring the paper's Fig. 3 vs Fig. 4:

  fig3 : per-depo dispatch. A host loop rasterizes ONE depo per jit call and
         accumulates on the host. This reproduces the paper's initial port:
         tiny kernels, per-item host round-trips, concurrency ~ patch size.
         Kept as the faithful *bad* baseline (paper F1).

  fig4 : batched device-resident. One jit'd program: rasterize ALL depos,
         fluctuate, scatter-add, FFT-convolve, add noise, digitize. One H2D
         (the depo arrays), one D2H (the ADC grid). The paper's proposed fix,
         implemented fully.

The stage chain itself — ``drift -> charge_grid -> convolve -> noise ->
digitize`` — lives in ``repro.core.stages`` as a ``SimGraph``; this module
contributes the fig4 *executor* (``make_sim_fn`` = jit over the graph), the
registered ``charge_grid`` strategy candidates, and the deliberately naive
fig3 host loop. ``make_sim_fn`` resolves any ``"auto"`` strategy fields
*before* jit so the traced program is fixed.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LArTPCConfig
from repro.core import fluctuate as fl
from repro.core.depo import DepoSet, depo_patch_origin
from repro.core.fft_conv import digitize, fft_convolve
from repro.core.noise import simulate_noise
from repro.core.rasterize import rasterize, rasterize_one
from repro.core.response import DetectorResponse, make_response
from repro.core.scatter import scatter_add
from repro.core.stages import (SimOutput, build_sim_graph,
                               compute_charge_grid)
from repro.tune.registry import register_strategy, set_default

__all__ = [
    "SimOutput", "compute_charge_grid", "simulate_fig3", "simulate_fig4",
    "make_sim_fn", "simulate", "charge_grid_unfused",
]


def _fluctuate(key, patches, charge, cfg: LArTPCConfig, pool=None):
    if not cfg.fluctuate or cfg.rng_strategy == "none":
        return patches
    if cfg.rng_strategy == "pool":
        assert pool is not None, "pool strategy requires a pre-computed pool"
        return fl.fluctuate_pool(pool, patches, charge)
    if cfg.rng_strategy == "relaxed":
        return fl.fluctuate_counter_relaxed(key, patches, charge)
    return fl.fluctuate_counter(key, patches, charge)


# ---------------------------------------------------------------------------
# Charge-grid strategies (depos -> S(t,x)) — the registry's second hot op
# ---------------------------------------------------------------------------


@register_strategy("charge_grid", "unfused",
                   note="rasterize -> fluctuate -> scatter_add")
def charge_grid_unfused(key: jax.Array, depos: DepoSet, cfg: LArTPCConfig,
                        pool: Optional[jax.Array] = None) -> jax.Array:
    patches, w0, t0 = rasterize(depos, cfg)
    patches = _fluctuate(key, patches, depos.charge, cfg, pool)
    return scatter_add(patches, w0, t0, cfg)


@register_strategy("charge_grid", "unfused_bf16",
                   note="unfused chain with bfloat16 patches (f32 accumulate)")
def charge_grid_unfused_bf16(key: jax.Array, depos: DepoSet,
                             cfg: LArTPCConfig,
                             pool: Optional[jax.Array] = None) -> jax.Array:
    import dataclasses

    return charge_grid_unfused(
        key, depos, dataclasses.replace(cfg, patch_dtype="bfloat16"), pool)


def _fused_viable(ctx) -> bool:
    # the fused kernel draws counter-style fluctuation randomness in kernel,
    # so it competes in the physics-default config; the paper-faithful
    # pre-computed "pool" stream cannot be reproduced in kernel, and off-TPU
    # the Pallas interpreter makes production grids prohibitive
    cfg = ctx.cfg
    if cfg is None or (cfg.fluctuate and cfg.rng_strategy in ("pool", "relaxed")):
        return False
    if ctx.backend == "tpu":
        return True
    cells = ctx.shape.get("num_wires", 0) * ctx.shape.get("num_ticks", 0)
    return cells <= (1 << 21)


def _fused_key(key: jax.Array, cfg: LArTPCConfig) -> Optional[jax.Array]:
    """The in-kernel RNG key, or None when the config wants no fluctuation."""
    if cfg.fluctuate and cfg.rng_strategy == "counter":
        return key
    if cfg.fluctuate and cfg.rng_strategy in ("pool", "relaxed"):
        raise ValueError(
            "fused charge-grid strategies draw in-kernel counter randomness "
            "and cannot reproduce the pre-computed pool/relaxed streams; use "
            "rng_strategy='counter'/'none' or charge_grid_strategy='unfused'")
    return None


@register_strategy("charge_grid", "fused_pallas", available=_fused_viable,
                   note="fused rasterize+fluctuate+scatter Pallas kernel",
                   differentiable=False)
def charge_grid_fused(key: jax.Array, depos: DepoSet, cfg: LArTPCConfig,
                      pool: Optional[jax.Array] = None) -> jax.Array:
    from repro.kernels.fused_sim.ops import simulate_charge_grid

    del pool  # in-kernel counter RNG; the pool strategy is rejected above
    return simulate_charge_grid(depos, cfg, key=_fused_key(key, cfg))


@register_strategy("charge_grid", "fused_pallas_compact",
                   available=_fused_viable,
                   note="fused kernel over occupied tiles only",
                   differentiable=False)
def charge_grid_fused_compact(key: jax.Array, depos: DepoSet,
                              cfg: LArTPCConfig,
                              pool: Optional[jax.Array] = None) -> jax.Array:
    from repro.kernels.fused_sim.ops import simulate_charge_grid_compact

    del pool
    return simulate_charge_grid_compact(depos, cfg,
                                        key=_fused_key(key, cfg))


def _fused_mp_viable(ctx) -> bool:
    # the multi-plane fused kernels only make sense with a plane axis to
    # batch over; fluctuation constraints match the single-plane fused
    # kernels (in-kernel counter RNG), and off-TPU the interpreter budget
    # scales with the number of planes it rasterizes per launch
    cfg = ctx.cfg
    if cfg is None or cfg.num_planes < 2:
        return False
    if cfg.fluctuate and cfg.rng_strategy in ("pool", "relaxed"):
        return False
    if ctx.backend == "tpu":
        return True
    cells = (ctx.shape.get("num_wires", 0) * ctx.shape.get("num_ticks", 0)
             * cfg.num_planes)
    return cells <= (1 << 21)


def _plane_grid_keys(key: jax.Array, cfg: LArTPCConfig):
    """Stacked per-plane in-kernel RNG subkeys ``fold_in(key, p)``, or None
    when the config wants no fluctuation (pool/relaxed streams rejected by
    ``_fused_key``, same as the single-plane fused strategies)."""
    from repro.config import plane_specs

    if _fused_key(key, cfg) is None:
        return None
    idx = jnp.asarray([s.index for s in plane_specs(cfg)], jnp.uint32)
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, idx)


def _require_plane_axis(depos: DepoSet, cfg: LArTPCConfig) -> None:
    if depos.wire.ndim < 2 or depos.wire.shape[0] != cfg.num_planes:
        raise ValueError(
            "multi-plane charge_grid strategies take the FULL stacked "
            f"(num_planes={cfg.num_planes}, N) depos of one event (got "
            f"shape {depos.wire.shape}); they are dispatched by the "
            "stacked plane-batching path, not per plane")


@register_strategy("charge_grid", "fused_pallas_multiplane",
                   available=_fused_mp_viable,
                   note="one fused kernel rasterizes ALL planes per launch",
                   differentiable=False)
def charge_grid_fused_multiplane(key: jax.Array, depos: DepoSet,
                                 cfg: LArTPCConfig,
                                 pool: Optional[jax.Array] = None
                                 ) -> jax.Array:
    from repro.kernels.fused_sim.ops import simulate_charge_grid_multiplane

    del pool
    _require_plane_axis(depos, cfg)
    return simulate_charge_grid_multiplane(depos, cfg,
                                           keys=_plane_grid_keys(key, cfg))


@register_strategy("charge_grid", "fused_pallas_multiplane_compact",
                   available=_fused_mp_viable,
                   note="multi-plane fused kernel over occupied tiles only",
                   differentiable=False)
def charge_grid_fused_multiplane_compact(key: jax.Array, depos: DepoSet,
                                         cfg: LArTPCConfig,
                                         pool: Optional[jax.Array] = None
                                         ) -> jax.Array:
    from repro.kernels.fused_sim.ops import (
        simulate_charge_grid_multiplane_compact)

    del pool
    _require_plane_axis(depos, cfg)
    return simulate_charge_grid_multiplane_compact(
        depos, cfg, keys=_plane_grid_keys(key, cfg))


def _mp_xla_viable(ctx) -> bool:
    # plane-flattened XLA chain: needs a plane axis to amortize, and its
    # fluctuation randomness is the fused kernels' counter hash, which (like
    # them) cannot reproduce the pre-computed pool/relaxed streams. No cell
    # cap — plain XLA scales to production grids on every backend.
    cfg = ctx.cfg
    if cfg is None or cfg.num_planes < 2:
        return False
    return not (cfg.fluctuate and cfg.rng_strategy in ("pool", "relaxed"))


@register_strategy("charge_grid", "multiplane_xla", available=_mp_xla_viable,
                   note="plane-flattened XLA chain; counter-hash fluctuation",
                   differentiable=False)
def charge_grid_multiplane_xla(key: jax.Array, depos: DepoSet,
                               cfg: LArTPCConfig,
                               pool: Optional[jax.Array] = None) -> jax.Array:
    """All planes as ONE flat depo batch: rasterize (P*N) patches, draw
    counter-hash fluctuations, and land them with a single window scatter
    into a plane-major (P*W, T) grid.

    The plane axis never becomes a Python loop or a vmap: every stage sees
    one batch, so per-dispatch overhead and the RNG cost are paid once. The
    fluctuation draws use the fused kernels' stateless counter hash (seeded
    per plane from ``fold_in(key, plane)``, streamed per depo, countered per
    patch pixel) instead of threefry — statistically interchangeable, but a
    different bit stream than ``unfused``, so it carries its own pinned
    goldens.
    """
    import dataclasses

    del pool  # counter-hash RNG; the pool strategy is rejected above
    _require_plane_axis(depos, cfg)
    n_planes, n = depos.wire.shape[0], depos.wire.shape[-1]
    flat = jax.tree.map(
        lambda x: x.reshape((n_planes * n,) + x.shape[2:]), depos)
    patches, w0, t0 = rasterize(flat, cfg)
    keys = _plane_grid_keys(key, cfg)
    if keys is not None:
        seeds = jax.random.key_data(keys).astype(jnp.uint32)  # (P, 2)
        s0 = jnp.repeat(seeds[:, 0], n)[:, None, None]
        s1 = jnp.repeat(seeds[:, 1], n)[:, None, None]
        # per-depo stream (same odd constant as the fused kernel's depo
        # stream), per-patch-pixel counter
        d_id = jnp.tile(jnp.arange(n, dtype=jnp.uint32), n_planes)
        stream = (d_id * jnp.uint32(0x9E3779B9))[:, None, None]
        pw, pt = patches.shape[1], patches.shape[2]
        pix = (jnp.arange(pw, dtype=jnp.uint32)[:, None] * jnp.uint32(pt)
               + jnp.arange(pt, dtype=jnp.uint32)[None, :])[None]
        normals = fl.counter_normals_erfinv(s0, s1, stream, pix)
        patches = fl.binomial_normal_approx(
            patches, flat.charge, normals.astype(patches.dtype))
    # plane-major wire offsets turn P scatters into ONE window scatter over
    # a (P*W, T) grid
    off = jnp.repeat(
        jnp.arange(n_planes, dtype=w0.dtype) * cfg.num_wires, n)
    tall = dataclasses.replace(cfg, num_wires=n_planes * cfg.num_wires)
    grid = scatter_add(patches, w0 + off, t0, tall, strategy="xla")
    return grid.reshape(n_planes, cfg.num_wires, cfg.num_ticks)


set_default("charge_grid", "unfused")


# ---------------------------------------------------------------------------
# Pipelines
# ---------------------------------------------------------------------------


def simulate_fig4(key: jax.Array, depos, resp=None,
                  cfg: Optional[LArTPCConfig] = None,
                  pool: Optional[jax.Array] = None,
                  add_noise: bool = True, recon: bool = False) -> SimOutput:
    """The batched device-resident pipeline (paper Fig. 4). jit-able end to end.

    One ``SimGraph.run`` of the canonical stage chain; ``depos`` may be a
    detector-frame ``DepoSet`` or a physical ``PhysicalDepoSet`` (the drift
    stage transports the latter). ``resp`` is a single ``DetectorResponse``
    (single-plane), a per-plane sequence (multi-plane), or None for the
    config defaults; multi-plane outputs carry a leading plane axis.
    ``recon=True`` appends the deconvolve/hit_find stages and populates
    ``SimOutput.decon``/``hits``.
    """
    if cfg is None:
        # cfg defaults to None only so resp can be omitted positionally
        raise TypeError("simulate_fig4() missing required argument: 'cfg'")
    graph = build_sim_graph(cfg, resp, pool=pool, add_noise=add_noise,
                            recon=recon)
    return graph.run(key, depos)


def simulate_fig3(key: jax.Array, depos: DepoSet, resp: DetectorResponse,
                  cfg: LArTPCConfig, pool: Optional[jax.Array] = None,
                  add_noise: bool = True, max_depos: Optional[int] = None) -> SimOutput:
    """Per-depo host-loop pipeline (paper Fig. 3) — deliberately naive.

    One jit dispatch per depo; the patch returns to the host each iteration
    (``np.asarray`` forces the D2H transfer the paper's Fig. 3 shows), and the
    host accumulates into a numpy grid. Conv/noise still run on device at the
    end (the paper's port also left "scatter add" and "FT" serial).
    """
    pw, pt = cfg.patch_wires, cfg.patch_ticks

    @jax.jit
    def one(wire, tick, sw, st, q, w0, t0, normals):
        patch = rasterize_one(wire, tick, sw, st, q, w0.astype(jnp.float32),
                              t0.astype(jnp.float32), pw, pt)
        if cfg.fluctuate and cfg.rng_strategy != "none":
            qq = jnp.maximum(q, 1.0)
            p = jnp.clip(patch / qq, 0.0, 1.0)
            patch = jnp.maximum(
                patch + jnp.sqrt(jnp.maximum(patch * (1 - p), 0.0)) * normals, 0.0)
        return patch

    w0s, t0s = depo_patch_origin(depos, cfg)
    n = depos.n if max_depos is None else min(depos.n, max_depos)
    host_grid = np.zeros((cfg.num_wires, cfg.num_ticks), np.float32)
    wire, tick = np.asarray(depos.wire), np.asarray(depos.tick)
    sw, st = np.asarray(depos.sigma_w), np.asarray(depos.sigma_t)
    q = np.asarray(depos.charge)
    w0s_h, t0s_h = np.asarray(w0s), np.asarray(t0s)
    if pool is None:
        pool = fl.make_pool(jax.random.fold_in(key, 7), 1 << 16)
    pool_h = np.asarray(pool)
    for i in range(n):
        normals = jnp.asarray(
            pool_h[(i * pw * pt) % pool_h.shape[0]:][: pw * pt].reshape(pw, pt)
            if (i * pw * pt) % pool_h.shape[0] + pw * pt <= pool_h.shape[0]
            else np.resize(pool_h, (pw, pt)))
        patch = np.asarray(one(wire[i], tick[i], sw[i], st[i], q[i],
                               w0s_h[i], t0s_h[i], normals))  # D2H per depo
        host_grid[w0s_h[i]:w0s_h[i] + pw, t0s_h[i]:t0s_h[i] + pt] += patch
    grid = jnp.asarray(host_grid)  # final H2D
    signal = fft_convolve(grid, resp, cfg.fft_strategy)
    if add_noise:
        signal = signal + simulate_noise(jax.random.fold_in(key, 1), cfg) / max(
            cfg.adc_per_electron, 1e-30)
    return SimOutput(adc=digitize(signal, cfg), signal=signal, charge_grid=grid)


def make_sim_fn(cfg: LArTPCConfig, resp: Optional[DetectorResponse] = None,
                add_noise: bool = True, donate: bool = False,
                recon: bool = False):
    """Return a jit'd simulate(key, depos) closure (the production path):
    the single-event executor of the canonical ``SimGraph``.

    ``recon=True`` runs the full sim -> recon chain (deconvolve + hit_find
    appended; see ``build_sim_graph``).

    Any ``"auto"`` strategy fields resolve (tuning cache / backend default)
    here, before jit, so the traced program is fixed.

    ``donate=True`` donates the (key, depos) input buffers to the call
    (``jax.jit`` ``donate_argnums``): XLA reuses their device memory for
    outputs instead of allocating fresh buffers — the right choice for
    streaming drivers that stage fresh inputs every launch. Callers that
    re-invoke with the *same* arrays (benchmark loops) must keep the
    default.
    """
    from repro.tune import resolve_config

    cfg = resolve_config(cfg)
    # build_sim_graph supplies the standard RNG pool when cfg asks for it,
    # and the per-plane default responses when resp is None
    graph = build_sim_graph(cfg, resp, add_noise=add_noise, recon=recon)
    return jax.jit(graph.run, donate_argnums=(0, 1) if donate else ())


def simulate(key: jax.Array, depos: DepoSet, cfg: LArTPCConfig,
             resp=None, add_noise: bool = True, **kw) -> SimOutput:
    from repro.tune import resolve_config

    cfg = resolve_config(cfg)
    if cfg.pipeline == "fig3":
        if cfg.num_planes > 1:
            raise ValueError(
                "the fig3 per-depo host-loop baseline is single-plane only; "
                "use pipeline='fig4' for multi-plane configs")
        resp = resp if resp is not None else make_response(cfg)
        return simulate_fig3(key, depos, resp, cfg, add_noise=add_noise, **kw)
    return simulate_fig4(key, depos, resp, cfg, add_noise=add_noise, **kw)
