"""Multi-event batching: pack E ragged events into one device-resident launch.

The paper's Fig. 3 -> Fig. 4 lesson is that throughput comes from batching
work into few large kernels instead of many small dispatches. The seed repo
applied that *within* one event but still looped events on the host — the
same serialization one level up. This module closes the loop at the event
level:

  pack_events      : E ragged DepoSets -> one padded (E, N_max) EventBatch
                     (structure of arrays; padding rows carry zero charge so
                     they rasterize to zero and scatter-add is a no-op).
  simulate_events  : the full fig4 pipeline under ``jax.vmap`` over the event
                     axis, one jit'd program for all E events, with per-event
                     RNG keys so events remain statistically independent
                     under the default ``counter`` strategy. Caveat: with
                     ``rng_strategy="pool"`` every event reuses the same
                     normal pool from offset 0 — fluctuations are then
                     identical across events, exactly as they are between
                     per-event calls of ``simulate_fig4`` (the paper's fixed
                     pre-computed pool design; only the additive noise stage
                     differs per event).
  shard_events     : place the event axis across devices via the mesh rules
                     in ``repro.parallel.sharding`` (logical axis "events").

Per-event results are bit-identical to calling ``simulate_fig4`` on the same
padded row (asserted in ``tests/test_event_batch.py``): vmap changes the
batching, not the math, and zero-charge padding contributes exactly 0.0 to
every accumulation.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig
from repro.core.depo import DepoSet
from repro.core.response import DetectorResponse
from repro.core.stages import SimGraph, SimOutput, build_sim_graph
from repro.parallel.sharding import current_mesh, logical, named_sharding


class EventBatch(NamedTuple):
    """Padded structure-of-arrays container for E events of <= N_max depos.

    wire/tick/sigma_w/sigma_t/charge : (E, N_max) float32, rows past
    ``n_depos[e]`` are padding (charge 0, sigma 1) that contributes nothing.
    Multi-plane events (``generate_plane_depos``) carry a plane axis
    between the event and depo axes: (E, P, N_max).
    n_depos : (E,) int32 — valid depo count per event (per plane).
    """

    wire: jax.Array
    tick: jax.Array
    sigma_w: jax.Array
    sigma_t: jax.Array
    charge: jax.Array
    n_depos: jax.Array

    @property
    def num_events(self) -> int:
        return self.wire.shape[0]

    @property
    def max_depos(self) -> int:
        return self.wire.shape[-1]

    @property
    def total_depos(self) -> int:
        """Total number of *valid* (non-padding) depos across events."""
        return int(jax.device_get(self.n_depos).sum())

    def depo_set(self) -> DepoSet:
        """View as a DepoSet of (E, N_max) leaves — the vmap operand."""
        return DepoSet(wire=self.wire, tick=self.tick, sigma_w=self.sigma_w,
                       sigma_t=self.sigma_t, charge=self.charge)

    def event(self, e: int) -> DepoSet:
        """The padded per-event slice (keeps the (N_max,) padded length, so
        ``simulate_fig4`` on it reproduces the batched row bit-for-bit)."""
        return DepoSet(wire=self.wire[e], tick=self.tick[e],
                       sigma_w=self.sigma_w[e], sigma_t=self.sigma_t[e],
                       charge=self.charge[e])


class PhysicalEventBatch(NamedTuple):
    """Padded structure-of-arrays container for E *physical* events.

    The calibration path (``repro.core.fit``) batches events upstream of the
    drift stage — gradients must flow through transport — so it packs
    ``PhysicalDepoSet``s rather than drifted ``DepoSet``s. Leaves are
    (E, N_max) float32; padding rows carry q = 0 (a zero-charge depo drifts
    to a zero-charge depo and rasterizes to nothing).
    """

    x: jax.Array
    y: jax.Array
    z: jax.Array
    t: jax.Array
    q: jax.Array
    n_depos: jax.Array

    @property
    def num_events(self) -> int:
        return self.x.shape[0]

    @property
    def max_depos(self) -> int:
        return self.x.shape[-1]

    def physical_set(self):
        """View as a PhysicalDepoSet of (E, N_max) leaves — the vmap operand."""
        from repro.core.drift import PhysicalDepoSet

        return PhysicalDepoSet(x=self.x, y=self.y, z=self.z, t=self.t,
                               q=self.q)

    def event(self, e: int):
        """The padded per-event slice (keeps the (N_max,) padded length)."""
        from repro.core.drift import PhysicalDepoSet

        return PhysicalDepoSet(x=self.x[e], y=self.y[e], z=self.z[e],
                               t=self.t[e], q=self.q[e])


def pack_physical_events(events, pad_to: Optional[int] = None,
                         pad_multiple: int = 1) -> PhysicalEventBatch:
    """Pack E ragged PhysicalDepoSets into one padded (E, N_max) batch.

    The physical-frame sibling of ``pack_events``: all leaves pad with 0 —
    a q = 0 depo at the frame origin is inert through drift (charge 0 after
    recombination/lifetime scaling) and through rasterization (all-zero
    patch, fluctuation variance 0). Caveat: the *RNG realization* of the
    sampling strategies still depends on the padded length (threefry draws
    pair counter i with i + n/2 over the flattened patch block), so runs are
    bit-comparable only at equal ``N_max`` — which is why fit targets and
    the fit loss share one batch (``repro.core.fit``).
    """
    if not events:
        raise ValueError("pack_physical_events needs at least one event")
    n_max = max(max(ev.n for ev in events), 1)
    if pad_to is not None:
        n_max = max(n_max, pad_to)
    n_max = -(-n_max // pad_multiple) * pad_multiple

    def padf(x):
        return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, n_max - x.shape[-1])])

    stacked = {f: jnp.stack([padf(getattr(ev, f)) for ev in events])
               for f in ("x", "y", "z", "t", "q")}
    n_depos = jnp.asarray([ev.n for ev in events], jnp.int32)
    return PhysicalEventBatch(n_depos=n_depos, **stacked)


def empty_event(planes: int = 1) -> DepoSet:
    """A zero-depo event (used to pad the *event* axis of a short batch).

    ``planes > 1`` shapes the leaves (planes, 0) so the empty event stacks
    with multi-plane events from ``generate_plane_depos``.
    """
    shape = (0,) if planes == 1 else (planes, 0)
    z = jnp.zeros(shape, jnp.float32)
    return DepoSet(wire=z, tick=z, sigma_w=z, sigma_t=z, charge=z)


def pad_depos(depos: DepoSet, n_max: int) -> DepoSet:
    """Pad one event's depo axis (the LAST leaf axis — a plane axis may
    lead it) to ``n_max`` with inert depos.

    Padding rows have charge 0 (rasterizes to an all-zero patch, fluctuation
    variance 0, scatter-add of zeros) and sigma 1 (any positive value —
    avoids 0/0 in the Gaussian edges).
    """
    n = depos.n
    if n > n_max:
        raise ValueError(f"event has {n} depos > pad target {n_max}")
    pad = n_max - n

    def padf(x, fill=0.0):
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        return jnp.pad(x, widths, constant_values=fill)

    return DepoSet(
        wire=padf(depos.wire), tick=padf(depos.tick),
        sigma_w=padf(depos.sigma_w, 1.0), sigma_t=padf(depos.sigma_t, 1.0),
        charge=padf(depos.charge),
    )


def pack_events(events: Sequence[DepoSet], pad_to: Optional[int] = None,
                pad_multiple: int = 1) -> EventBatch:
    """Pack E ragged DepoSets into one padded (E, N_max) EventBatch.

    N_max = max event size, rounded up to ``pad_multiple`` (pick a fixed
    ``pad_to`` across batches to avoid re-jitting per batch shape).
    """
    if not events:
        raise ValueError("pack_events needs at least one event")
    n_max = max(max(ev.n for ev in events), 1)
    if pad_to is not None:
        n_max = max(n_max, pad_to)
    n_max = -(-n_max // pad_multiple) * pad_multiple
    padded = [pad_depos(ev, n_max) for ev in events]
    stacked = {f: jnp.stack([getattr(p, f) for p in padded])
               for f in DepoSet._fields}
    n_depos = jnp.asarray([ev.n for ev in events], jnp.int32)
    return EventBatch(n_depos=n_depos, **stacked)


def screen_events(events, ids: Sequence[int], cfg: LArTPCConfig, *,
                  pad_to: Optional[int] = None, batch: int = 0,
                  health=None):
    """Ingest validation gate: keep clean events, quarantine the rest.

    Runs ``repro.core.validate.check_depos`` on every (event, id) pair and
    returns ``(kept_events, kept_ids, dead_letters)`` — kept events preserve
    their ids (and hence their ``fold_in`` keys), so their simulated ADCs
    are bit-identical to a run that never saw the quarantined events.
    ``pad_to`` enforces the padded-batch capacity (an event larger than the
    pad target would crash ``pack_events`` mid-stream); ``health`` (a
    ``RunHealth``) collects the counters when given.
    """
    from repro.core.validate import check_depos, dead_letter

    kept_events, kept_ids, letters = [], [], []
    for ev, depos in zip(ids, events):
        reasons = check_depos(depos, cfg, max_depos=pad_to)
        if reasons:
            letters.append(dead_letter(ev, batch, reasons, depos))
        else:
            kept_events.append(depos)
            kept_ids.append(ev)
    if health is not None and letters:
        health.quarantined += len(letters)
        health.dead_letters.extend(letters)
    return kept_events, kept_ids, letters


def event_keys(key: jax.Array, event_ids: Sequence[int]) -> jax.Array:
    """Stacked per-event keys, (E,) — fold_in(key, ev) for each event id.

    Matches the per-event key derivation of the single-event launcher, so a
    batched run reproduces a serial run of the same event ids exactly.
    """
    ids = jnp.asarray(list(event_ids), jnp.uint32)
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(key, ids)


# ---------------------------------------------------------------------------
# Batched pipeline
# ---------------------------------------------------------------------------


def simulate_events(keys: jax.Array, batch: EventBatch, resp: DetectorResponse,
                    cfg: LArTPCConfig, pool: Optional[jax.Array] = None,
                    add_noise: bool = True, recon: bool = False,
                    graph: Optional[SimGraph] = None) -> SimOutput:
    """The canonical SimGraph for all E events in one program: vmap over the
    event axis (the batched executor of ``repro.core.stages``).

    keys : (E,) PRNG keys (one per event — events stay independent).
    Returns a SimOutput whose leaves carry a leading event axis:
    adc (E, num_wires, num_ticks), etc. With ``recon=True`` the graph ends
    in deconvolve + hit_find and ``decon``/``hits`` gain the event axis too
    (HitSet leaves become (E, max_hits), n_hits (E,)).
    """
    if graph is None:
        graph = build_sim_graph(cfg, resp, pool=pool, add_noise=add_noise,
                                recon=recon)
    depos = batch.depo_set()

    def ev_names(x):
        return ("events",) + (None,) * (x.ndim - 1)

    depos = jax.tree.map(lambda x: logical(x, ev_names(x)), depos)
    keys = logical(keys, ("events",))
    out = jax.vmap(graph.run)(keys, depos)
    # tree.map (not per-field) so nested recon leaves (the HitSet) get the
    # event-axis constraint too and absent (None) fields pass through
    return jax.tree.map(lambda x: logical(x, ev_names(x)), out)


def make_batched_sim_fn(cfg: LArTPCConfig,
                        resp: Optional[DetectorResponse] = None,
                        add_noise: bool = True, donate: bool = False,
                        recon: bool = False):
    """jit'd ``sim(keys, batch) -> SimOutput`` closure (batched production
    path — the vmap executor over the same ``SimGraph`` ``make_sim_fn``
    runs single-event). ``recon=True`` appends deconvolve + hit_find.

    ``"auto"`` strategy fields resolve here, before jit, so one fixed traced
    program serves the whole stream (see ``repro.tune``).

    ``donate=True`` donates the (keys, batch) buffers (``donate_argnums``):
    the streaming launcher stages a fresh batch every launch, so its input
    memory can be recycled for outputs instead of growing the footprint by
    a full (E, N_max) batch. Keep the default when re-invoking with the
    same arrays (e.g. benchmark sweeps)."""
    from repro.tune import resolve_config

    cfg = resolve_config(cfg)
    # build_sim_graph supplies the standard RNG pool when cfg asks for it,
    # and the per-plane default responses when resp is None
    graph = build_sim_graph(cfg, resp, add_noise=add_noise, recon=recon)

    @functools.partial(jax.jit, donate_argnums=(0, 1) if donate else ())
    def sim(keys, batch: EventBatch) -> SimOutput:
        return simulate_events(keys, batch, resp, cfg, graph=graph)

    return sim


def shard_events(batch: EventBatch, mesh=None) -> EventBatch:
    """Stage an EventBatch onto devices, event axis sharded per mesh rules.

    This is the explicit H2D step of the streaming launcher: with a mesh
    active the event axis spreads over the data axes; without one it is a
    plain (async) device_put.
    """
    mesh = mesh or current_mesh()

    def put(x, names):
        s = named_sharding(x.shape, names, mesh=mesh)
        return jax.device_put(x, s) if s is not None else jax.device_put(x)

    arrs = {f: put(getattr(batch, f),
                   ("events",) + (None,) * (getattr(batch, f).ndim - 1))
            for f in DepoSet._fields}
    return EventBatch(n_depos=put(batch.n_depos, ("events",)), **arrs)
