"""Frequency-domain convolution — the paper's "FT" step (Eq. 2).

    S(t,x) --rfft2--> S(ω) ; M(ω) = R(ω)·S(ω) ; M(ω) --irfft2--> M(t,x)

Zero-padding to the response's linear-convolution size avoids circular wrap
(``make_response`` picks FFT-friendly padded sizes). On TPU the whole chain
(pad → rfft2 → complex multiply → irfft2 → crop) fuses into one program —
the paper's §5 "hand-write vendor FFT wrappers" problem is XLA's job here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig
from repro.core.response import DetectorResponse


def fft_convolve(grid: jax.Array, resp: DetectorResponse) -> jax.Array:
    """Linear 2-D convolution of the charge grid with the detector response."""
    w, t = grid.shape
    wp, tp = resp.pad_shape
    padded = jnp.zeros((wp, tp), grid.dtype).at[:w, :t].set(grid)
    freq = jnp.fft.rfft2(padded)
    out = jnp.fft.irfft2(freq * resp.freq, s=(wp, tp))
    return out[:w, :t]


def digitize(signal: jax.Array, cfg: LArTPCConfig) -> jax.Array:
    """Voltage -> ADC counts (12-bit), paper's M(t,x) measurement."""
    adc = cfg.adc_baseline + cfg.adc_per_electron * signal
    return jnp.clip(jnp.round(adc), 0, 4095).astype(jnp.int16)
