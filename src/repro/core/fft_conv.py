"""Frequency-domain convolution — the paper's "FT" step (Eq. 2).

    S(t,x) --rfft2--> S(ω) ; M(ω) = R(ω)·S(ω) ; M(ω) --irfft2--> M(t,x)

Zero-padding to the response's linear-convolution size avoids circular wrap
(``make_response`` picks FFT-friendly padded sizes). On TPU the whole chain
(pad → rfft2 → complex multiply → irfft2 → crop) fuses into one program —
the paper's §5 "hand-write vendor FFT wrappers" problem is XLA's job here.

Two layout strategies register as ``fft_convolve`` candidates in the
kernel-strategy registry (``repro.tune``):

  rfft2 : real-input FFT over the half-spectrum — half the frequency-domain
          memory traffic; the natural choice when the backend's rfft is native.
  fft2  : full complex FFT — same math (the full spectrum is reconstructed
          from the stored half-spectrum via Hermitian symmetry); some
          backends lower complex FFTs better than real ones.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig
from repro.core.response import DetectorResponse
from repro.tune.registry import register_strategy, set_default


def _pad_grid(grid: jax.Array, resp: DetectorResponse) -> jax.Array:
    """Zero-pad the grid to the response's linear-convolution size.

    The single upcast of the convolve stage happens here: FFT kernels only
    accept f32/f64 inputs (``rfft2`` rejects bfloat16 outright), so narrow
    grids (``cfg.patch_dtype="bfloat16"`` paths) widen to float32 before
    the transform and BOTH strategies return the widened dtype — identical
    math, identical output dtype, whatever the input precision.
    """
    w, t = grid.shape
    wp, tp = resp.pad_shape
    if grid.dtype not in (jnp.float32, jnp.float64):
        grid = grid.astype(jnp.float32)
    return jnp.zeros((wp, tp), grid.dtype).at[:w, :t].set(grid)


@register_strategy("fft_convolve", "rfft2",
                   note="real-input half-spectrum FFT")
def fft_convolve_rfft2(grid: jax.Array, resp: DetectorResponse) -> jax.Array:
    w, t = grid.shape
    wp, tp = resp.pad_shape
    freq = jnp.fft.rfft2(_pad_grid(grid, resp))
    out = jnp.fft.irfft2(freq * resp.freq, s=(wp, tp))
    return out[:w, :t]


def _full_spectrum(half: jax.Array, tp: int) -> jax.Array:
    """Full complex spectrum of a real signal from its rfft2 half-spectrum.

    Hermitian symmetry: F[k1, k2] = conj(F[-k1 mod W, tp - k2]).
    """
    wp = half.shape[0]
    ncopy = tp - half.shape[1]
    rows = (-jnp.arange(wp)) % wp
    cols = ncopy - jnp.arange(ncopy)
    tail = jnp.conj(half[rows][:, cols])
    return jnp.concatenate([half, tail], axis=1)


@register_strategy("fft_convolve", "fft2",
                   note="full complex FFT; identical math, different layout")
def fft_convolve_fft2(grid: jax.Array, resp: DetectorResponse) -> jax.Array:
    w, t = grid.shape
    wp, tp = resp.pad_shape
    padded = _pad_grid(grid, resp)  # upcasts narrow grids, same as rfft2
    freq = jnp.fft.fft2(padded.astype(jnp.complex64))
    rfreq = _full_spectrum(resp.freq, tp)
    out = jnp.real(jnp.fft.ifft2(freq * rfreq))
    # return the PADDED dtype (f32 for narrow inputs), matching rfft2 —
    # downcasting back to e.g. bfloat16 here made the two strategies
    # disagree on output dtype for the same input
    return out[:w, :t].astype(padded.dtype)


set_default("fft_convolve", "rfft2")


def fft_convolve(grid: jax.Array, resp: DetectorResponse,
                 strategy: str | None = None) -> jax.Array:
    """Linear 2-D convolution of the charge grid with the detector response.

    ``strategy`` may be None (the registry's backend default), ``"auto"``
    (tuning cache / default), or any registered candidate name. EVERY
    concrete name dispatches through the registry — a strategy registered by
    an extension is honored even if it shadows a built-in — and an unknown
    name fails here with the valid candidates, not deep inside the registry.
    """
    from repro.tune import autotune, registry

    if strategy is None:
        strategy = registry.default_strategy("fft_convolve")
    elif strategy == "auto":
        shape = {"num_wires": grid.shape[0], "num_ticks": grid.shape[1],
                 "response_wires": resp.kernel.shape[0],
                 "response_ticks": resp.kernel.shape[1],
                 # plane kind keys the decision: induction and collection
                 # transforms are different problems to the tuner
                 "plane": resp.plane}
        strategy = autotune.resolve("fft_convolve", None,
                                    shape=shape).strategy
    try:
        strat = registry.get_strategy("fft_convolve", strategy)
    except KeyError:
        valid = sorted(registry.strategies("fft_convolve")) + ["auto"]
        raise ValueError(
            f"unknown fft_convolve strategy {strategy!r}; valid: {valid}"
        ) from None
    return strat.fn(grid, resp)


def digitize(signal: jax.Array, cfg: LArTPCConfig) -> jax.Array:
    """Voltage -> ADC counts (12-bit), paper's M(t,x) measurement.

    ``cfg.digitize_ste`` selects a straight-through estimator for the
    round/clip quantization: the forward VALUES are identical (round and
    clip commute when the rails are integers, so ``round(clip(x)) ==
    clip(round(x))``) but the result stays float32 and the backward pass
    treats rounding as identity while the clip still zeroes gradients
    outside the ADC rails — the standard STE for quantizers. The default
    (``False``) is the bit-identical int16 seed path.
    """
    adc = cfg.adc_baseline + cfg.adc_per_electron * signal
    if cfg.digitize_ste:
        clipped = jnp.clip(adc, 0.0, 4095.0)
        return clipped + jax.lax.stop_gradient(jnp.round(clipped) - clipped)
    return jnp.clip(jnp.round(adc), 0, 4095).astype(jnp.int16)
