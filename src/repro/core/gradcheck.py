"""Finite-difference gradient verification for the differentiable sim.

Library code shared by ``tests/test_gradcheck.py`` and the CI gate
(``launch/fit.py --gradcheck``): central-difference numerical gradients
checked against ``jax.grad`` for scalar losses routed through each stage of
the simulation chain, at smoke size.

Tolerances are float32-grade by design. A central difference carries
O(h^2) truncation error plus O(ulp/h) roundoff from the f32 forward, so the
checks use per-case step sizes and a relative tolerance of a few percent —
tight enough to catch a wrong/zero/NaN gradient path (the failure modes that
matter), loose enough not to flake on accumulation-order noise. Stages with
quantized forwards (digitize) are checked end-to-end through an MSE loss
whose averaging over the readout grid smooths the staircase; the exact STE
pass-through property is asserted analytically in the tests instead.

Every gradcheck case intentionally routes traced theta elements into the
config via ``dataclasses.replace`` — that is the *calibration contract*
under test, and the consumers (``transport``, ``make_response``, ...) carry
the ``isinstance(jax.Array)`` guards. The scope-level lint heuristic can't
see cross-module guards, so the rule is disabled file-wide here:
"""
# repro-lint: disable-file=config-replace-guard
from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig


class GradcheckResult(NamedTuple):
    """Outcome of one analytic-vs-numeric gradient comparison."""

    name: str
    fields: tuple          # parameter names, theta order
    analytic: tuple        # jax.grad, per parameter
    numeric: tuple         # central differences, per parameter
    max_abs_err: float
    max_rel_err: float     # |a - n| / max(|a|, |n|, atol) per element, maxed
    ok: bool

    def __str__(self) -> str:  # the table row --gradcheck prints
        mark = "ok " if self.ok else "FAIL"
        return (f"[{mark}] {self.name:<44s} rel_err={self.max_rel_err:.3e} "
                f"abs_err={self.max_abs_err:.3e}")


def finite_difference_grad(f: Callable, theta: jax.Array,
                           eps: float = 1e-3) -> jax.Array:
    """Central-difference gradient of scalar ``f`` at ``theta``.

    Per-element step ``h_i = eps * max(|theta_i|, 1)`` — relative for O(1)+
    parameters, absolute ``eps`` for small ones; the difference quotient is
    accumulated in float64 on the host.
    """
    theta = jnp.asarray(theta, jnp.float32)
    grads = []
    for i in range(theta.shape[0]):
        h = eps * max(abs(float(theta[i])), 1.0)
        fp = float(f(theta.at[i].add(h)))
        fm = float(f(theta.at[i].add(-h)))
        grads.append((fp - fm) / (2.0 * h))
    return jnp.asarray(grads, jnp.float32)


def gradcheck(f: Callable, theta, *, name: str = "",
              fields: Sequence[str] = (), eps: float = 1e-3,
              rtol: float = 5e-2, atol: float = 1e-4) -> GradcheckResult:
    """Compare ``jax.grad(f)`` to central differences at ``theta``.

    Passes when every element satisfies
    ``|analytic - numeric| <= atol + rtol * max(|analytic|, |numeric|)``.
    ``f`` is jit-compiled here (one trace serves the 1 + 2n evaluations).
    """
    theta = jnp.asarray(theta, jnp.float32)
    fj = jax.jit(f)
    analytic = jax.jit(jax.grad(f))(theta)
    if not bool(jnp.all(jnp.isfinite(analytic))):
        return GradcheckResult(name=name, fields=tuple(fields),
                               analytic=tuple(map(float, analytic)),
                               numeric=(float("nan"),) * theta.shape[0],
                               max_abs_err=float("inf"),
                               max_rel_err=float("inf"), ok=False)
    numeric = finite_difference_grad(fj, theta, eps)
    abs_err = jnp.abs(analytic - numeric)
    scale = jnp.maximum(jnp.maximum(jnp.abs(analytic), jnp.abs(numeric)),
                        atol)
    rel_err = abs_err / scale
    ok = bool(jnp.all(abs_err <= atol + rtol * jnp.maximum(
        jnp.abs(analytic), jnp.abs(numeric))))
    return GradcheckResult(
        name=name, fields=tuple(fields),
        analytic=tuple(float(x) for x in analytic),
        numeric=tuple(float(x) for x in numeric),
        max_abs_err=float(jnp.max(abs_err)),
        max_rel_err=float(jnp.max(rel_err)), ok=ok)


# ---------------------------------------------------------------------------
# The per-stage suite
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GradcheckCase:
    """One named scalar-loss gradient check.

    ``build(cfg, key)`` returns ``(f, theta0)``: the scalar loss over the
    raw (identity-transform) parameter vector and the point to check at.
    """

    name: str
    fields: tuple
    build: Callable
    eps: float = 1e-3
    rtol: float = 5e-2
    atol: float = 1e-4


def _base_cfg(cfg: Optional[LArTPCConfig]) -> LArTPCConfig:
    from repro.core.fit import fit_config

    if cfg is None:
        from repro.config import get_config

        cfg = get_config("lartpc-uboone", smoke=True)
    return fit_config(cfg)


def _weights(key: jax.Array, shape) -> jax.Array:
    """A fixed random projection: ``sum(x * w)`` probes the full Jacobian
    instead of the row-sum (which charge conservation can make trivially
    flat, e.g. d(sum grid)/d(diffusion) ~ 0 away from edges)."""
    return jax.random.normal(key, shape, jnp.float32)


def _drift_case(cfg: LArTPCConfig, key: jax.Array):
    from repro.core.depo import generate_physical_depos
    from repro.core.drift import transport

    pdepos = generate_physical_depos(key, cfg)
    w = _weights(jax.random.fold_in(key, 1), (pdepos.n,))

    def f(theta):
        tcfg = dataclasses.replace(cfg, electron_lifetime_us=theta[0],
                                   recombination=theta[1])
        return jnp.sum(transport(pdepos, tcfg).charge * w) / pdepos.n

    return f, jnp.asarray([50.0, 0.7], jnp.float32)


def _charge_grid_case(cfg: LArTPCConfig, key: jax.Array):
    from repro.core.depo import generate_physical_depos
    from repro.core.drift import transport
    from repro.core.stages import compute_charge_grid

    pdepos = generate_physical_depos(key, cfg)
    kf = jax.random.fold_in(key, 2)
    w = _weights(jax.random.fold_in(key, 1),
                 (cfg.num_wires, cfg.num_ticks))

    def f(theta):
        tcfg = dataclasses.replace(cfg, diffusion_scale=theta[0])
        grid = compute_charge_grid(kf, transport(pdepos, tcfg), tcfg)
        return jnp.sum(grid * w) / grid.size

    return f, jnp.asarray([cfg.diffusion_scale], jnp.float32)


def _response_case(cfg: LArTPCConfig, key: jax.Array):
    from repro.core.depo import generate_depos
    from repro.core.fft_conv import fft_convolve
    from repro.core.response import make_response
    from repro.core.stages import compute_charge_grid

    depos = generate_depos(key, cfg)
    grid = compute_charge_grid(jax.random.fold_in(key, 2), depos, cfg)
    w = _weights(jax.random.fold_in(key, 1), grid.shape)

    def f(theta):
        tcfg = dataclasses.replace(cfg, response_gain=theta[0],
                                   response_shaping_us=theta[1])
        resp = make_response(tcfg)
        return jnp.sum(fft_convolve(grid, resp, tcfg.fft_strategy) * w
                       ) / grid.size

    return f, jnp.asarray([1.3, 1.7], jnp.float32)


def _noise_case(cfg: LArTPCConfig, key: jax.Array):
    from repro.core.noise import simulate_noise

    kn = jax.random.fold_in(key, 3)
    w = _weights(jax.random.fold_in(key, 1),
                 (cfg.num_wires, cfg.num_ticks))

    def f(theta):
        tcfg = dataclasses.replace(cfg, noise_rms_adc=theta[0])
        noise = simulate_noise(kn, tcfg)
        return jnp.sum(noise * w) / noise.size

    return f, jnp.asarray([cfg.noise_rms_adc], jnp.float32)


def _deconvolve_case(cfg: LArTPCConfig, key: jax.Array):
    from repro.core.deconvolve import (deconvolve, make_deconv_filter,
                                       measured_signal)
    from repro.core.response import make_response
    from repro.core.stages import build_sim_graph

    graph = build_sim_graph(cfg, None)
    adc = graph.run(key, _physical_event(cfg, key)).adc
    w = _weights(jax.random.fold_in(key, 1), adc.shape)

    def f(theta):
        tcfg = dataclasses.replace(cfg, adc_per_electron=theta[0],
                                   adc_baseline=theta[1])
        filt = make_deconv_filter(make_response(tcfg), tcfg)
        decon = deconvolve(measured_signal(adc, tcfg), filt,
                           tcfg.deconv_strategy)
        return jnp.sum(decon * w) / (decon.size * 1e3)

    return f, jnp.asarray([cfg.adc_per_electron, cfg.adc_baseline],
                          jnp.float32)


def _physical_event(cfg: LArTPCConfig, key: jax.Array):
    from repro.core.depo import generate_physical_depos

    return generate_physical_depos(jax.random.fold_in(key, 7), cfg)


def _end_to_end_case(cfg: LArTPCConfig, key: jax.Array):
    """The full chain, digitize STE included, through the fit loss itself —
    the gradient the calibration driver actually descends. FD over a
    quantized forward leans on MSE averaging to smooth the staircase, hence
    the larger step and looser tolerance on this case."""
    from repro.core.fit import (FitParam, FitSpec, make_fit_loss,
                                make_fit_targets)

    # boost the deposit size so a few-percent parameter change moves the
    # waveform by many ADC counts: at the smoke default (~64 counts above
    # baseline) the loss is quantization-dominated and a finite difference
    # measures staircase-crossing density, not the smooth derivative the
    # STE provides
    cfg = dataclasses.replace(cfg,
                              electrons_per_depo=30 * cfg.electrons_per_depo)
    spec = FitSpec(params=(FitParam("recombination"),
                           FitParam("adc_per_electron")))
    targets = make_fit_targets(cfg, key, num_events=1)
    loss = make_fit_loss(cfg, spec, targets)
    truth = jnp.asarray([cfg.recombination, cfg.adc_per_electron],
                        jnp.float32)

    def f(mult):
        # multiplier coordinates: theta_i = mult_i * truth_i keeps every
        # component O(1), so the FD step is a uniform ~2% relative
        # perturbation (an absolute step on adc_per_electron ~ 0.01 would
        # dwarf the parameter)
        return loss(mult * truth)

    # check away from the truth (at truth the loss floor is exactly 0 and
    # both gradients vanish — nothing to compare)
    return f, jnp.asarray([0.9, 1.1], jnp.float32)


def _recon_loss_case(cfg: LArTPCConfig, key: jax.Array):
    """The fit loss with the deconvolved-charge term: gradients must flow
    through digitize -> measured_signal -> deconvolve as well."""
    from repro.core.fit import (FitParam, FitSpec, make_fit_loss,
                                make_fit_targets)

    spec = FitSpec(params=(FitParam("response_gain"),))
    targets = make_fit_targets(cfg, key, num_events=1, recon=True)
    loss = make_fit_loss(cfg, spec, targets, decon_weight=1e-4)

    def f(theta):
        return loss(theta)

    return f, jnp.asarray([1.15], jnp.float32)


def stage_gradcheck_cases() -> List[GradcheckCase]:
    """The per-stage check matrix (see module docstring for tolerances)."""
    return [
        GradcheckCase("drift/lifetime+recombination",
                      ("electron_lifetime_us", "recombination"),
                      _drift_case, eps=1e-3, rtol=2e-2),
        GradcheckCase("charge_grid/diffusion_scale",
                      ("diffusion_scale",),
                      _charge_grid_case, eps=1e-4, rtol=5e-2),
        GradcheckCase("convolve/response_gain+shaping",
                      ("response_gain", "response_shaping_us"),
                      _response_case, eps=1e-3, rtol=3e-2),
        GradcheckCase("noise/noise_rms_adc",
                      ("noise_rms_adc",),
                      _noise_case, eps=1e-3, rtol=2e-2),
        GradcheckCase("deconvolve/adc_gain+baseline",
                      ("adc_per_electron", "adc_baseline"),
                      _deconvolve_case, eps=1e-4, rtol=5e-2),
        GradcheckCase("e2e/fit_loss (STE digitize)",
                      ("recombination", "adc_per_electron"),
                      _end_to_end_case, eps=2e-2, rtol=2e-1, atol=1e-3),
        GradcheckCase("e2e/fit_loss+decon term",
                      ("response_gain",),
                      _recon_loss_case, eps=2e-2, rtol=2e-1, atol=1e-3),
    ]


def stage_gradcheck_suite(cfg: Optional[LArTPCConfig] = None, *,
                          seed: int = 0,
                          cases: Optional[Sequence[GradcheckCase]] = None,
                          ) -> List[GradcheckResult]:
    """Run the (or a) case matrix at smoke size; returns one result per case.

    ``cfg`` defaults to the smoke config pushed through ``fit_config`` —
    pass a multi-plane or bf16 variant to re-run the matrix under it (the
    tests do). All-green is the CI gate: ``all(r.ok for r in results)``.
    """
    base = _base_cfg(cfg)
    key = jax.random.key(seed)
    results = []
    for i, case in enumerate(stage_gradcheck_cases() if cases is None
                             else cases):
        f, theta0 = case.build(base, jax.random.fold_in(key, i))
        results.append(gradcheck(f, theta0, name=case.name,
                                 fields=case.fields, eps=case.eps,
                                 rtol=case.rtol, atol=case.atol))
    return results
