"""Signal-processing deconvolution — the recon half of the sim->recon loop.

The follow-up papers to the source paper (arXiv:2002.06291, 2107.00812) make
per-plane deconvolution the first reconstruction workload on the same
detectors: invert the field+electronics response the convolve stage applied,
recovering charge-vs-wire-vs-time from the ADC waveforms.

    M(ω) = R(ω)·S(ω)  +  N(ω)          (convolve stage + noise stage)
    Ŝ(ω) = G(ω)·M(ω)                    (this module)

A bare inverse 1/R blows up where |R| -> 0 (the induction transform has a
near-zero DC line: a bipolar response integrates to ~0, so per-wire total
charge is unrecoverable — Wire-Cell's own signal processing has the same
hole). Both filters here regularize that inversion:

  wiener   : G = conj(R) / (|R|² + λ·max|R|²) — the Wiener form with a flat
             noise-to-signal prior λ (relative to the response peak power),
             gain bounded by 1/(2·sqrt(λ·max|R|²)) however small |R| gets.
  gaussian : the same bounded inversion times a Gaussian low-pass along the
             time-frequency axis (Wire-Cell's default filter family); the
             window's DC gain is exactly 1.

A filter is *represented as* a ``DetectorResponse`` (freq = G at the same
``pad_shape``), so applying it is literally the convolve stage's math and
both ``fft_convolve`` layout strategies work on it unchanged. Two candidates
register under the ``deconvolve`` op:

  rfft2     : direct half-spectrum multiply (the rfft2 convolve layout).
  fft_reuse : dispatch through ``fft_convolve``'s own tuned strategy table —
              whatever layout won the convolve tuning wins here too.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig
from repro.core.fft_conv import fft_convolve, fft_convolve_rfft2
from repro.core.response import DetectorResponse
from repro.tune.registry import register_strategy, set_default

#: filter families ``make_deconv_filter`` accepts
DECONV_FILTERS = ("wiener", "gaussian")


def measured_signal(adc: jax.Array, cfg: LArTPCConfig) -> jax.Array:
    """ADC counts -> measured signal in electron units.

    Inverts the affine map of ``digitize`` (baseline shift + gain); the
    round/clip quantization is irrecoverable — at the default gain one ADC
    count is 1/adc_per_electron = 100 electrons, which is why hit thresholds
    sit well above a single count.
    """
    gain = cfg.adc_per_electron
    if isinstance(gain, jax.Array):
        # traced gain (gradient-based calibration, repro.core.fit)
        denom = jnp.maximum(gain, 1e-30)
    else:
        denom = max(float(gain), 1e-30)
    return (adc.astype(jnp.float32) - cfg.adc_baseline) / denom


def _bounded_inverse(freq: jax.Array, lam: float) -> jax.Array:
    """conj(R)/(|R|² + λ·max|R|²): the regularized inverse both filters share.

    λ is *relative* to the response peak power, so the gain bound
    1/(2·sqrt(λ·max|R|²)) holds whatever the response normalization, and
    |R| = 0 maps to gain 0 instead of a 1/ε blow-up.
    """
    power = jnp.real(freq * jnp.conj(freq))
    floor = lam * jnp.max(power)
    return jnp.conj(freq) / (power + floor)


def make_deconv_filter(resp: DetectorResponse, cfg: LArTPCConfig,
                       kind: Optional[str] = None,
                       wiener_lambda: Optional[float] = None,
                       gauss_cut: Optional[float] = None,
                       ) -> DetectorResponse:
    """Build the inverse filter G for ``resp`` as a ``DetectorResponse``.

    The returned transform has ``freq = G`` at ``resp.pad_shape`` and keeps
    ``resp``'s kernel and plane kind, so it drops into the same dispatch
    (and the same plane-keyed tuning bucket) as the forward convolve.
    ``kind``/``wiener_lambda``/``gauss_cut`` default to the config fields.
    """
    kind = kind if kind is not None else cfg.deconv_filter
    lam = (wiener_lambda if wiener_lambda is not None
           else cfg.deconv_wiener_lambda)
    if kind not in DECONV_FILTERS:
        raise ValueError(
            f"unknown deconv filter {kind!r}; valid: {list(DECONV_FILTERS)}")
    g = _bounded_inverse(resp.freq, lam)
    if kind == "gaussian":
        cut = gauss_cut if gauss_cut is not None else cfg.deconv_gauss_cut
        # rfft half-spectrum: column k is time-frequency index k in
        # [0, T_pad//2]; the window is exp(-½ (k/(cut·Nyquist))²) — real,
        # wire-independent, and exactly 1 at k = 0 (DC gain preserved)
        nyq = max(resp.pad_shape[1] // 2, 1)
        k = jnp.arange(g.shape[1], dtype=jnp.float32)
        window = jnp.exp(-0.5 * (k / (cut * nyq)) ** 2)
        g = g * window[None, :]
    return DetectorResponse(kernel=resp.kernel, freq=g.astype(jnp.complex64),
                            pad_shape=resp.pad_shape, plane=resp.plane)


def make_plane_deconv_filters(cfg: LArTPCConfig, resps=None):
    """One inverse filter per readout plane, in plane order.

    ``resps`` is the per-plane forward responses (defaults to
    ``make_plane_responses(cfg)``); filters inherit each plane's transform
    shape, so they work at the distributed grid shape too when built from
    ``make_distributed_plane_responses``.
    """
    from repro.core.response import make_plane_responses

    if resps is None:
        resps = make_plane_responses(cfg)
    return tuple(make_deconv_filter(r, cfg) for r in resps)


# ---------------------------------------------------------------------------
# Strategies — the registry's ``deconvolve`` op
# ---------------------------------------------------------------------------


@register_strategy("deconvolve", "rfft2",
                   note="direct half-spectrum inverse-filter multiply")
def deconvolve_rfft2(meas: jax.Array, filt: DetectorResponse) -> jax.Array:
    # the filter is a DetectorResponse, so the rfft2 convolve layout IS the
    # deconvolution: pad -> rfft2 -> multiply G -> irfft2 -> crop
    return fft_convolve_rfft2(meas, filt)


@register_strategy("deconvolve", "fft_reuse",
                   note="reuse the tuned fft_convolve layout for the "
                        "inverse multiply")
def deconvolve_fft_reuse(meas: jax.Array, filt: DetectorResponse) -> jax.Array:
    # "auto" resolves from the fft_convolve tuning cache (plane-keyed) at
    # trace time — the layout that won the forward convolve wins here too
    return fft_convolve(meas, filt, strategy="auto")


set_default("deconvolve", "rfft2")


def deconvolve(meas: jax.Array, filt: DetectorResponse,
               strategy: Optional[str] = None) -> jax.Array:
    """Apply the inverse filter: measured signal (electrons) -> charge
    estimate Ŝ(t,x), same (num_wires, num_ticks) layout as the charge grid.

    ``meas`` is the measured signal in electron units — ``SimOutput.signal``
    directly, or ``measured_signal(adc, cfg)`` for the full ADC chain.
    ``strategy`` may be None (registry default), ``"auto"`` (tuning cache,
    keyed by shape AND plane kind like the forward convolve), or any
    registered candidate name; unknown names fail here with the valid list.
    """
    from repro.tune import autotune, registry

    if strategy is None:
        strategy = registry.default_strategy("deconvolve")
    elif strategy == "auto":
        shape = {"num_wires": meas.shape[0], "num_ticks": meas.shape[1],
                 "response_wires": filt.kernel.shape[0],
                 "response_ticks": filt.kernel.shape[1],
                 "plane": filt.plane}
        strategy = autotune.resolve("deconvolve", None, shape=shape).strategy
    try:
        strat = registry.get_strategy("deconvolve", strategy)
    except KeyError:
        valid = sorted(registry.strategies("deconvolve")) + ["auto"]
        raise ValueError(
            f"unknown deconvolve strategy {strategy!r}; valid: {valid}"
        ) from None
    return strat.fn(meas, filt)
