"""Gradient-based detector calibration: fit physics fields of ``LArTPCConfig``
to target ADC waveforms by differentiating THROUGH the simulation chain.

The paper's portability argument is about running the forward sim fast on
many architectures; the differentiable-programming follow-ups to its
workload (larnd-sim's gradient calibration, arXiv:2309.04639) show the same
pipelines pay off twice when ``jax.grad`` flows through them: detector
parameters — electron lifetime, recombination, diffusion, noise level,
electronics gain/shaping — can be *recovered* from data by gradient descent
on a waveform loss instead of hand-tuned scans.

Three things make the stage graph differentiable without touching the
default bit-exact path (see docs/calibration.md):

  * ``rng_strategy="relaxed"`` — the counter fluctuation draw with the
    zero-variance sqrt reparameterized (``repro.core.fluctuate``); forward
    values are bit-for-bit with ``"counter"``.
  * ``cfg.digitize_ste=True`` — straight-through estimator around the ADC
    round/clip; forward values equal the quantized ones (round and clip
    commute on integer rails) but stay float32 with pass-through gradients
    inside the rails.
  * traced config rebuild — the loss closes over a *frozen* config and
    rebuilds it inside the traced function via ``dataclasses.replace`` with
    tracer-valued physics fields, so the response, noise spectrum, drift
    attenuation, and digitizer gain all become functions of theta.

Self-calibration contract: a loss built by ``make_fit_loss`` against targets
from ``make_fit_targets`` uses the SAME per-event keys as the target run, so
the noise and fluctuation realizations match and the loss is exactly zero at
the true parameters — gradient descent recovers them rather than fitting the
noise (``launch/fit.py --smoke`` gates this in CI).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig
from repro.core.batch import (PhysicalEventBatch, event_keys,
                              pack_physical_events)
from repro.core.stages import SimGraph, SimOutput, build_sim_graph

#: config fields the differentiable graph supports as free fit parameters —
#: each one's consumers are audited for trace-safety (no Python branching on
#: the value) and covered by ``tests/test_gradcheck.py``
FITTABLE_FIELDS = (
    "electron_lifetime_us",
    "recombination",
    "diffusion_scale",
    "noise_rms_adc",
    "adc_per_electron",
    "adc_baseline",
    "response_gain",
    "response_shaping_us",
)

#: (registry op, config strategy field, differentiable fallback) — the
#: strategy choices ``fit_config`` audits against the registry's
#: ``differentiable`` flags
_STRATEGY_FIELDS = (
    ("drift", "drift_strategy", "jnp"),
    ("charge_grid", "charge_grid_strategy", "unfused"),
    ("scatter_add", "scatter_strategy", "xla"),
    ("fft_convolve", "fft_strategy", "rfft2"),
    ("deconvolve", "deconv_strategy", "rfft2"),
)


# ---------------------------------------------------------------------------
# FitSpec: which fields are free, with bounds/transforms
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FitParam:
    """One free parameter of a fit.

    field     : ``LArTPCConfig`` field name (must be in ``FITTABLE_FIELDS``).
    init      : starting value (None -> the config's current value).
    lo / hi   : optional bounds, enforced by the transform (not by clipping).
    transform : how the unconstrained optimizer coordinate theta maps to the
                physical value:
                  identity : value = theta
                  log      : value = lo + exp(theta)       (positivity)
                  sigmoid  : value = lo + (hi-lo)*sigmoid(theta)  (box)
                None picks automatically: both bounds -> sigmoid, a lower
                bound alone -> log, unbounded -> identity.
    """

    field: str
    init: Optional[float] = None
    lo: Optional[float] = None
    hi: Optional[float] = None
    transform: Optional[str] = None

    def __post_init__(self):
        if self.field not in FITTABLE_FIELDS:
            raise ValueError(
                f"{self.field!r} is not a fittable config field; supported: "
                f"{list(FITTABLE_FIELDS)} (see docs/calibration.md to add one)")
        kind = self.resolved_transform
        if kind not in ("identity", "log", "sigmoid"):
            raise ValueError(f"unknown transform {kind!r} for {self.field!r}; "
                             "valid: identity | log | sigmoid")
        if kind == "sigmoid" and (self.lo is None or self.hi is None
                                  or not self.hi > self.lo):
            raise ValueError(f"sigmoid transform for {self.field!r} needs "
                             "bounds with hi > lo")

    @property
    def resolved_transform(self) -> str:
        if self.transform is not None:
            return self.transform
        if self.lo is not None and self.hi is not None:
            return "sigmoid"
        if self.lo is not None:
            return "log"
        return "identity"

    # -- theta <-> value ----------------------------------------------------

    def to_value(self, theta):
        kind = self.resolved_transform
        if kind == "log":
            return (self.lo or 0.0) + jnp.exp(theta)
        if kind == "sigmoid":
            return self.lo + (self.hi - self.lo) * jax.nn.sigmoid(theta)
        return theta

    def to_theta(self, value: float) -> float:
        kind = self.resolved_transform
        if kind == "log":
            return math.log(max(value - (self.lo or 0.0), 1e-12))
        if kind == "sigmoid":
            u = (value - self.lo) / (self.hi - self.lo)
            u = min(max(u, 1e-6), 1.0 - 1e-6)
            return math.log(u / (1.0 - u))
        return float(value)


@dataclasses.dataclass(frozen=True)
class FitSpec:
    """The free-parameter set of a calibration fit.

    Maps between the optimizer's unconstrained theta vector (one float32
    entry per param, in declaration order) and config field values; ``apply``
    rebuilds a (traced) config from theta inside the loss.
    """

    params: Tuple[FitParam, ...]

    def __post_init__(self):
        names = [p.field for p in self.params]
        if not names:
            raise ValueError("FitSpec needs at least one FitParam")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate fit fields: {names}")

    @property
    def fields(self) -> Tuple[str, ...]:
        return tuple(p.field for p in self.params)

    @property
    def n(self) -> int:
        return len(self.params)

    def init_theta(self, cfg: LArTPCConfig) -> jax.Array:
        """Starting theta: each param's ``init`` (or the config's value)
        pushed through its inverse transform."""
        vals = [p.init if p.init is not None else getattr(cfg, p.field)
                for p in self.params]
        return jnp.asarray([p.to_theta(v) for p, v in zip(self.params, vals)],
                           jnp.float32)

    def true_theta(self, cfg: LArTPCConfig) -> jax.Array:
        """Theta at the config's CURRENT values (ignores ``init``) — the
        ground truth of a self-calibration test."""
        return jnp.asarray(
            [p.to_theta(getattr(cfg, p.field)) for p in self.params],
            jnp.float32)

    def unpack(self, theta: jax.Array) -> Dict[str, jax.Array]:
        """theta vector -> {field: scalar value} (traced-safe)."""
        return {p.field: p.to_value(theta[i])
                for i, p in enumerate(self.params)}

    def values(self, theta) -> Dict[str, float]:
        """Concrete {field: float} view of theta (host-side logging)."""
        return {k: float(v) for k, v in
                self.unpack(jnp.asarray(theta, jnp.float32)).items()}

    def apply(self, cfg: LArTPCConfig, theta: jax.Array) -> LArTPCConfig:
        """Rebuild ``cfg`` with the theta-valued fields (inside a trace the
        replaced fields become tracers — the frozen dataclass carries them
        fine; it just stops being hashable, which the loss never needs)."""
        return dataclasses.replace(cfg, **self.unpack(theta))


def spec_from_names(names: Sequence[str], cfg: LArTPCConfig,
                    rel_bounds: float = 4.0) -> FitSpec:
    """Convenience FitSpec: box-bound each named field to
    [value/rel_bounds, value*rel_bounds] around the config's current value
    (positive fields), identity for fields currently at zero."""
    params = []
    for name in names:
        v = float(getattr(cfg, name))
        if v > 0:
            params.append(FitParam(name, lo=v / rel_bounds,
                                   hi=v * rel_bounds))
        else:
            params.append(FitParam(name))
    return FitSpec(params=tuple(params))


# ---------------------------------------------------------------------------
# Differentiable-config plumbing
# ---------------------------------------------------------------------------


def fit_config(cfg: LArTPCConfig) -> LArTPCConfig:
    """The differentiable variant of ``cfg``.

    Forward values are IDENTICAL to the default graph (as float32): the
    relaxed fluctuation draw is bit-for-bit with ``counter``, and the STE
    digitizer's forward equals the quantized ADC. Strategy fields whose
    registered candidate is not differentiable (Pallas kernels without a
    VJP, ``auto`` picks that could resolve to one) fall back to the audited
    XLA implementations.
    """
    from repro.tune import registry

    if cfg.fluctuate and cfg.rng_strategy == "pool":
        raise ValueError(
            "the paper-faithful 'pool' fluctuation stream has no "
            "reparameterized form — its normals are consumed by data-"
            "dependent offsets; calibrate with rng_strategy='counter' "
            "(mapped to 'relaxed') or 'none'")
    updates: Dict[str, object] = {"digitize_ste": True}
    if cfg.fluctuate and cfg.rng_strategy in ("counter", "relaxed"):
        updates["rng_strategy"] = "relaxed"
    for op, field, fallback in _STRATEGY_FIELDS:
        cur = getattr(cfg, field)
        if cur == "auto" or not registry.is_differentiable(op, cur):
            updates[field] = fallback
    return dataclasses.replace(cfg, **updates)


def assert_differentiable_config(cfg: LArTPCConfig) -> None:
    """Raise unless every strategy/flag choice of ``cfg`` supports
    reverse-mode autodiff (the precondition of ``make_fit_loss``)."""
    from repro.tune import registry

    problems = []
    if cfg.fluctuate and cfg.rng_strategy not in ("relaxed", "none"):
        problems.append(
            f"rng_strategy={cfg.rng_strategy!r} (need 'relaxed' or 'none')")
    if not cfg.digitize_ste:
        problems.append("digitize_ste=False (the quantizer has zero "
                        "gradient almost everywhere)")
    for op, field, _ in _STRATEGY_FIELDS:
        cur = getattr(cfg, field)
        if cur == "auto" or not registry.is_differentiable(op, cur):
            problems.append(f"{field}={cur!r} (non-differentiable candidate "
                            f"of op {op!r})")
    if problems:
        raise ValueError("config is not differentiable: "
                         + "; ".join(problems)
                         + " — pass it through repro.core.fit.fit_config")


def _drop_stage(graph: SimGraph, name: str) -> SimGraph:
    return SimGraph(stages=tuple(s for s in graph.stages if s.name != name))


# ---------------------------------------------------------------------------
# Targets and loss
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FitTargets:
    """Self-generated calibration targets: the inputs and reference outputs
    of a fit, produced by the DEFAULT (bit-exact, int16) graph at the true
    config."""

    batch: PhysicalEventBatch
    keys: jax.Array            # (E,) per-event PRNG keys
    adc: jax.Array             # (E, W, T) int16 reference waveforms
    decon: Optional[jax.Array] = None  # (E, W, T) reference deconvolved charge


def make_fit_targets(cfg: LArTPCConfig, key: jax.Array, num_events: int = 2,
                     num_depos: Optional[int] = None, add_noise: bool = True,
                     recon: bool = False) -> FitTargets:
    """Generate events and run the default graph at ``cfg``'s (true) physics.

    The returned per-event keys are the fit's too: reusing them makes the
    loss's noise/fluctuation realizations match the target's exactly, so the
    loss is zero at the true parameters (the self-calibration contract).
    """
    from repro.core.depo import generate_physical_depos

    kgen, krun = jax.random.split(key)
    events = [generate_physical_depos(jax.random.fold_in(kgen, e), cfg,
                                      n=num_depos)
              for e in range(num_events)]
    batch = pack_physical_events(events)
    keys = event_keys(krun, range(num_events))
    graph = build_sim_graph(cfg, None, add_noise=add_noise, recon=recon)
    if recon:
        graph = _drop_stage(graph, "hit_find")
    out: SimOutput = jax.jit(jax.vmap(graph.run))(keys, batch.physical_set())
    return FitTargets(batch=batch, keys=keys, adc=out.adc, decon=out.decon)


def make_fit_loss(cfg: LArTPCConfig, spec: FitSpec, targets: FitTargets,
                  add_noise: bool = True, decon_weight: float = 0.0,
                  ) -> Callable[[jax.Array], jax.Array]:
    """Build the batched scalar loss ``theta -> mean squared ADC error``.

    The loss rebuilds the config — and therefore the detector response, the
    noise spectrum, and every stage closure — inside the traced function
    from ``spec.apply(fit_config(cfg), theta)``, runs the differentiable
    graph under ``vmap`` over the target events (same per-event keys as the
    target run), and returns

        mean((adc - target_adc)^2)
          [+ decon_weight * mean((decon - target_decon)^2)]

    The deconvolved-charge term (``decon_weight > 0``, requires targets
    built with ``recon=True``) adds the recon chain's view of the same
    waveforms — useful when fitting response parameters, whose imprint the
    inverse filter amplifies. jit the result (it is trace-stable: the theta
    vector is its only traced input).
    """
    fcfg = fit_config(cfg)
    assert_differentiable_config(fcfg)
    use_decon = decon_weight > 0.0
    if use_decon and targets.decon is None:
        raise ValueError("decon_weight > 0 needs targets built with "
                         "make_fit_targets(..., recon=True)")
    depos = targets.batch.physical_set()
    target_adc = targets.adc.astype(jnp.float32)

    def loss(theta: jax.Array) -> jax.Array:
        tcfg = spec.apply(fcfg, theta)
        graph = build_sim_graph(tcfg, None, add_noise=add_noise,
                                recon=use_decon)
        if use_decon:
            graph = _drop_stage(graph, "hit_find")
        out = jax.vmap(graph.run)(targets.keys, depos)
        val = jnp.mean((out.adc - target_adc) ** 2)
        if use_decon:
            val = val + decon_weight * jnp.mean((out.decon - targets.decon) ** 2)
        return val

    return loss


# ---------------------------------------------------------------------------
# Optimizer drivers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FitResult:
    """Outcome of a fit run."""

    theta: jax.Array                 # final unconstrained coordinates
    values: Dict[str, float]         # final physical parameter values
    loss: float                      # final loss
    history: List[Tuple[int, float]]  # (step, loss) log
    steps: int

    def relative_errors(self, truth: Dict[str, float]) -> Dict[str, float]:
        """|fit - truth| / max(|truth|, eps) per field."""
        return {k: abs(self.values[k] - v) / max(abs(v), 1e-12)
                for k, v in truth.items()}


def run_fit(loss_fn: Callable, spec: FitSpec, theta0: jax.Array, *,
            steps: int = 200, lr: float = 0.05, optimizer: str = "adam",
            b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
            log_every: int = 0,
            callback: Optional[Callable[[int, float, Dict[str, float]], None]]
            = None) -> FitResult:
    """Minimize ``loss_fn`` over theta.

    optimizer="adam"  : Adam on the unconstrained theta vector, ``steps``
                        jit-compiled value_and_grad evaluations with
                        per-step (step, loss) history.
    optimizer="bfgs"  : ``jax.scipy.optimize.minimize(method="BFGS")`` —
                        quasi-Newton, usually far fewer evaluations on these
                        few-parameter smooth losses; history holds the start
                        and end points only.

    ``callback(step, loss, values)`` fires every ``log_every`` steps (and on
    the last) when set — the launch driver's per-step logging hook.
    """
    theta = jnp.asarray(theta0, jnp.float32)
    history: List[Tuple[int, float]] = []

    if optimizer == "bfgs":
        from jax.scipy.optimize import minimize

        l0 = float(loss_fn(theta))
        history.append((0, l0))
        if callback:
            callback(0, l0, spec.values(theta))
        res = minimize(loss_fn, theta, method="BFGS",
                       options={"maxiter": steps})
        theta = res.x.astype(jnp.float32)
        lf = float(res.fun)
        n_steps = int(res.nit)
        history.append((n_steps, lf))
        if callback:
            callback(n_steps, lf, spec.values(theta))
        return FitResult(theta=theta, values=spec.values(theta), loss=lf,
                         history=history, steps=n_steps)
    if optimizer != "adam":
        raise ValueError(f"unknown optimizer {optimizer!r}; "
                         "valid: adam | bfgs")

    vg = jax.jit(jax.value_and_grad(loss_fn))
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    val = float("nan")
    for step in range(1, steps + 1):
        val_arr, g = vg(theta)
        val = float(val_arr)
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mhat = m / (1.0 - b1 ** step)
        vhat = v / (1.0 - b2 ** step)
        theta = theta - lr * mhat / (jnp.sqrt(vhat) + eps)
        history.append((step, val))
        if callback and (step == steps
                         or (log_every and step % log_every == 0)):
            callback(step, val, spec.values(theta))
    return FitResult(theta=theta, values=spec.values(theta), loss=val,
                     history=history, steps=steps)


def calibrate(cfg: LArTPCConfig, spec: FitSpec, targets: FitTargets, *,
              steps: int = 200, lr: float = 0.05, optimizer: str = "adam",
              add_noise: bool = True, decon_weight: float = 0.0,
              log_every: int = 0, callback=None) -> FitResult:
    """End-to-end convenience: build the loss for ``targets`` and fit from
    ``spec``'s init values. ``cfg`` supplies the truth for the target run
    ONLY through ``targets``; the fit starts from each param's ``init``."""
    loss_fn = make_fit_loss(cfg, spec, targets, add_noise=add_noise,
                            decon_weight=decon_weight)
    return run_fit(loss_fn, spec, spec.init_theta(cfg), steps=steps, lr=lr,
                   optimizer=optimizer, log_every=log_every,
                   callback=callback)
