"""Scatter-add: accumulate all depo patches into the readout grid S(t, x).

The paper's Kokkos port uses ``Kokkos::atomic_add`` (Fig. 5). TPUs/XLA expose
no device atomics; we implement three deterministic TPU-native strategies:

  xla          : one big ``scatter-add`` HLO (grid.at[flat_idx].add(vals)).
                 XLA serializes colliding updates; simplest, good baseline.
  sort_segment : radix-sort pixel contributions by destination index, then
                 scatter with ``indices_are_sorted=True`` — the sorted stream
                 turns random-access HBM traffic into sequential traffic, the
                 TPU analogue of coalesced atomics.
  pallas       : owner-computes tile binning (``repro.kernels.scatter_add``):
                 the output grid is cut into VMEM tiles; depos are binned to
                 the tiles they touch; each tile *gathers* its contributions.
                 Scatter inverted into gather = canonical TPU formulation,
                 bitwise deterministic (atomics are not).

All strategies produce identical results (up to float addition order for
`xla`), asserted in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig


def _flat_pixel_indices(w0: jax.Array, t0: jax.Array, pw: int, pt: int, num_ticks: int):
    """Flat destination index for every patch pixel: (N, pw, pt) int32."""
    dw = jnp.arange(pw, dtype=jnp.int32)[None, :, None]
    dt = jnp.arange(pt, dtype=jnp.int32)[None, None, :]
    return (w0[:, None, None] + dw) * num_ticks + (t0[:, None, None] + dt)


def scatter_xla(patches: jax.Array, w0: jax.Array, t0: jax.Array, cfg: LArTPCConfig):
    n, pw, pt = patches.shape
    idx = _flat_pixel_indices(w0, t0, pw, pt, cfg.num_ticks).reshape(-1)
    grid = jnp.zeros((cfg.num_wires * cfg.num_ticks,), patches.dtype)
    grid = grid.at[idx].add(patches.reshape(-1), mode="drop")
    return grid.reshape(cfg.num_wires, cfg.num_ticks)


def scatter_sort_segment(patches: jax.Array, w0: jax.Array, t0: jax.Array,
                         cfg: LArTPCConfig):
    n, pw, pt = patches.shape
    idx = _flat_pixel_indices(w0, t0, pw, pt, cfg.num_ticks).reshape(-1)
    vals = patches.reshape(-1)
    order = jnp.argsort(idx)
    idx_s = idx[order]
    vals_s = vals[order]
    # collapse runs of equal destination before the scatter: after sorting,
    # segment-sum by run id, then one sorted scatter of the run totals.
    new_run = jnp.concatenate(
        [jnp.array([0], jnp.int32), (idx_s[1:] != idx_s[:-1]).astype(jnp.int32)])
    seg_id = jnp.cumsum(new_run)
    nseg = vals_s.shape[0]  # static upper bound on number of runs
    totals = jax.ops.segment_sum(vals_s, seg_id, num_segments=nseg)
    first_of_seg = new_run.astype(bool).at[0].set(True)
    first_pos = jnp.nonzero(first_of_seg, size=nseg, fill_value=0)[0]
    seg_dest = idx_s[first_pos]
    valid = jnp.arange(nseg) <= seg_id[-1]
    grid = jnp.zeros((cfg.num_wires * cfg.num_ticks,), patches.dtype)
    grid = grid.at[jnp.where(valid, seg_dest, cfg.num_wires * cfg.num_ticks)].add(
        jnp.where(valid, totals, 0.0), mode="drop", indices_are_sorted=True,
        unique_indices=False)
    return grid.reshape(cfg.num_wires, cfg.num_ticks)


def scatter_pallas(patches: jax.Array, w0: jax.Array, t0: jax.Array,
                   cfg: LArTPCConfig, interpret: bool = True):
    from repro.kernels.scatter_add.ops import scatter_add_tiles

    return scatter_add_tiles(
        patches, w0, t0,
        num_wires=cfg.num_wires, num_ticks=cfg.num_ticks, interpret=interpret,
    )


STRATEGIES = {
    "xla": scatter_xla,
    "sort_segment": scatter_sort_segment,
    "pallas": scatter_pallas,
}


def scatter_add(patches, w0, t0, cfg: LArTPCConfig, strategy: str | None = None):
    strategy = strategy or cfg.scatter_strategy
    return STRATEGIES[strategy](patches, w0, t0, cfg)
