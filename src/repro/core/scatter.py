"""Scatter-add: accumulate all depo patches into the readout grid S(t, x).

The paper's Kokkos port uses ``Kokkos::atomic_add`` (Fig. 5). TPUs/XLA expose
no device atomics; we implement four deterministic TPU-native strategies:

  xla           : one big ``scatter-add`` HLO (grid.at[flat_idx].add(vals)).
                  XLA serializes colliding updates; simplest, good baseline.
  sort_segment  : sort pixel contributions by destination index with one
                  fused ``lax.sort_key_val``, segment-reduce the equal-
                  destination runs, then scatter the run totals with
                  ``indices_are_sorted=True`` — the sorted stream turns
                  random-access HBM traffic into sequential traffic, the TPU
                  analogue of coalesced atomics.
  pallas        : owner-computes tile binning (``repro.kernels.scatter_add``):
                  the output grid is cut into VMEM tiles; depos are binned to
                  the tiles they touch; each tile *gathers* its contributions.
                  Scatter inverted into gather = canonical TPU formulation,
                  bitwise deterministic (atomics are not).
  pallas_compact: the same owner-computes kernel launched over OCCUPIED
                  tiles only — kernel work scales with occupied readout
                  area instead of detector area (track-like depo sets leave
                  most tiles empty).

All strategies accumulate in float32 (patches may arrive narrower, see
``cfg.patch_dtype``) and produce identical results (up to float addition
order for `xla`), asserted in tests. Each registers itself as a
``scatter_add`` candidate in the kernel-strategy registry (``repro.tune``);
set ``cfg.scatter_strategy="auto"`` to pick per backend from the tuning
cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig
from repro.kernels import default_interpret
from repro.tune.registry import register_strategy, set_default


def _flat_pixel_indices(w0: jax.Array, t0: jax.Array, pw: int, pt: int, num_ticks: int):
    """Flat destination index for every patch pixel: (N, pw, pt) int32."""
    dw = jnp.arange(pw, dtype=jnp.int32)[None, :, None]
    dt = jnp.arange(pt, dtype=jnp.int32)[None, None, :]
    return (w0[:, None, None] + dw) * num_ticks + (t0[:, None, None] + dt)


def flat_pixel_contribs(patches: jax.Array, w0: jax.Array, t0: jax.Array,
                        num_ticks: int):
    """Flattened (idx, vals) contribution stream, built ONCE and shared by
    the HLO-scatter strategies.

    idx  : (N*pw*pt,) int32 flat destination pixel of every patch pixel
    vals : (N*pw*pt,) float32 values (upcast from ``cfg.patch_dtype`` —
           narrow patches halve the HBM read; accumulation stays f32)
    """
    n, pw, pt = patches.shape
    idx = _flat_pixel_indices(w0, t0, pw, pt, num_ticks).reshape(-1)
    vals = patches.reshape(-1).astype(jnp.float32)
    return idx, vals


@register_strategy("scatter_add", "xla", note="one scatter-add HLO")
def scatter_xla(patches: jax.Array, w0: jax.Array, t0: jax.Array, cfg: LArTPCConfig):
    n, pw, pt = patches.shape
    if pw > cfg.num_wires or pt > cfg.num_ticks:
        # degenerate grids (patch larger than the readout): per-pixel
        # updates keep the in-range pixels a clipped window start cannot
        # express — correctness path only, never hit at detector shapes
        idx, vals = flat_pixel_contribs(patches, w0, t0, cfg.num_ticks)
        grid = jnp.zeros((cfg.num_wires * cfg.num_ticks,), jnp.float32)
        grid = grid.at[idx].add(vals, mode="drop")
        return grid.reshape(cfg.num_wires, cfg.num_ticks)
    # ONE update per PATCH (a (pw, pt) window at (w0, t0)) instead of one
    # per pixel: N window adds replace N*pw*pt scalar adds, so the scatter
    # stops paying per-element index arithmetic. ``depo_patch_origin``
    # clips every origin to [0, dim - patch], so no window is ever out of
    # bounds and the update stream visits pixels in the same (n, dw, dt)
    # order as the per-pixel form — bit-identical output, ~50x faster on
    # CPU at smoke shapes.
    dnums = jax.lax.ScatterDimensionNumbers(
        update_window_dims=(1, 2), inserted_window_dims=(),
        scatter_dims_to_operand_dims=(0, 1))
    starts = jnp.stack([w0, t0], axis=-1)
    return jax.lax.scatter_add(
        jnp.zeros((cfg.num_wires, cfg.num_ticks), jnp.float32), starts,
        patches.astype(jnp.float32), dnums,
        indices_are_sorted=False, unique_indices=False)


@register_strategy("scatter_add", "sort_segment",
                   note="fused sort by destination, segment-sum, sorted scatter")
def scatter_sort_segment(patches: jax.Array, w0: jax.Array, t0: jax.Array,
                         cfg: LArTPCConfig):
    idx, vals = flat_pixel_contribs(patches, w0, t0, cfg.num_ticks)
    # ONE fused sort carries the values with the keys (no argsort + two
    # gathers: half the sort-stage memory traffic)
    idx_s, vals_s = jax.lax.sort_key_val(idx, vals)
    # collapse runs of equal destination before the scatter: after sorting,
    # segment-reduce by run id, then one sorted scatter of the run totals.
    new_run = jnp.concatenate(
        [jnp.array([0], jnp.int32), (idx_s[1:] != idx_s[:-1]).astype(jnp.int32)])
    seg_id = jnp.cumsum(new_run)
    nseg = vals_s.shape[0]  # static upper bound on number of runs
    totals = jax.ops.segment_sum(vals_s, seg_id, num_segments=nseg,
                                 indices_are_sorted=True)
    # each run's destination: a segment-max of the (constant-per-run) sorted
    # indices — replaces the old jnp.nonzero first-position pass + gather
    seg_dest = jax.ops.segment_max(idx_s, seg_id, num_segments=nseg,
                                   indices_are_sorted=True)
    valid = jnp.arange(nseg) <= seg_id[-1]
    grid = jnp.zeros((cfg.num_wires * cfg.num_ticks,), jnp.float32)
    grid = grid.at[jnp.where(valid, seg_dest, cfg.num_wires * cfg.num_ticks)].add(
        jnp.where(valid, totals, 0.0), mode="drop", indices_are_sorted=True,
        unique_indices=False)
    return grid.reshape(cfg.num_wires, cfg.num_ticks)


def _pallas_viable(ctx) -> bool:
    # Compiled on TPU; anywhere else the kernel runs in the Pallas
    # interpreter, which is a correctness tool — keep it out of the tuner's
    # candidate set once the grid is big enough that interpret-mode tile
    # loops dominate (it would never win, only slow tuning down).
    if ctx.backend == "tpu":
        return True
    cells = ctx.shape.get("num_wires", 0) * ctx.shape.get("num_ticks", 0)
    return cells <= (1 << 21)


@register_strategy("scatter_add", "pallas", available=_pallas_viable,
                   note="owner-computes tile kernel; interpret off-TPU",
                   differentiable=False)
def scatter_pallas(patches: jax.Array, w0: jax.Array, t0: jax.Array,
                   cfg: LArTPCConfig, interpret: bool | None = None):
    from repro.kernels.scatter_add.ops import scatter_add_tiles

    return scatter_add_tiles(
        patches, w0, t0,
        num_wires=cfg.num_wires, num_ticks=cfg.num_ticks,
        interpret=default_interpret() if interpret is None else interpret,
    )


@register_strategy("scatter_add", "pallas_compact", available=_pallas_viable,
                   note="owner-computes kernel over occupied tiles only",
                   differentiable=False)
def scatter_pallas_compact(patches: jax.Array, w0: jax.Array, t0: jax.Array,
                           cfg: LArTPCConfig, interpret: bool | None = None):
    from repro.kernels.scatter_add.ops import scatter_add_tiles_compact

    return scatter_add_tiles_compact(
        patches, w0, t0,
        num_wires=cfg.num_wires, num_ticks=cfg.num_ticks,
        interpret=default_interpret() if interpret is None else interpret,
    )


set_default("scatter_add", "xla")

#: name -> fn view of the registered candidates (back-compat surface)
STRATEGIES = {
    "xla": scatter_xla,
    "sort_segment": scatter_sort_segment,
    "pallas": scatter_pallas,
    "pallas_compact": scatter_pallas_compact,
}


def scatter_add(patches, w0, t0, cfg: LArTPCConfig, strategy: str | None = None):
    """Dispatch to a scatter strategy.

    ``strategy`` (or ``cfg.scatter_strategy``) may be a concrete name or
    ``"auto"``: auto resolves through the tuning cache / backend default at
    trace time, so the traced program is fixed (see ``repro.tune``).
    """
    from repro.tune import autotune, registry

    strategy = strategy or cfg.scatter_strategy
    if strategy == "auto":
        shape = autotune.op_shape("scatter_add", cfg)
        shape["num_depos"] = int(patches.shape[0])
        strategy = autotune.resolve("scatter_add", cfg, shape=shape).strategy
    return registry.get_strategy("scatter_add", strategy).fn(
        patches, w0, t0, cfg)
