"""Core: the paper's contribution — LArTPC signal simulation, TPU-native.

Stage chain (paper Eq. 1/2, composed as a ``SimGraph`` in ``stages.py``):
    physical depos --drift--> depos --charge_grid--> S(t,x)
        --convolve--> M(t,x) --noise--> + N(t,x) --digitize--> ADC(t,x)

Multi-plane configs (``cfg.num_planes > 1``) run the readout stages once
per wire plane (U/V/W) and stack a leading plane axis on every output.

Recon chain (``build_sim_graph(..., recon=True)`` — the signal-processing
follow-up workload, arXiv:2002.06291 / 2107.00812):
    ADC(t,x) --deconvolve--> Ŝ(t,x) --hit_find--> HitSet
"""
from repro.core.depo import (DepoSet, generate_depos, generate_physical_depos,
                             generate_plane_depos)
from repro.core.drift import (PhysicalDepoSet, drift_depos, transport,
                              transport_planes)
from repro.core.response import (DetectorResponse, make_plane_responses,
                                 make_response)
from repro.core.stages import SimGraph, SimOutput, SimState, Stage, build_sim_graph
from repro.core.pipeline import simulate, make_sim_fn
from repro.core.batch import (EventBatch, PhysicalEventBatch, event_keys,
                              make_batched_sim_fn, pack_events,
                              pack_physical_events, shard_events,
                              simulate_events)
from repro.core.fit import (FitParam, FitResult, FitSpec, FitTargets,
                            assert_differentiable_config, calibrate,
                            fit_config, make_fit_loss, make_fit_targets,
                            run_fit, spec_from_names)
from repro.core.gradcheck import (GradcheckResult, finite_difference_grad,
                                  gradcheck, stage_gradcheck_suite)
from repro.core.deconvolve import (deconvolve, make_deconv_filter,
                                   make_plane_deconv_filters, measured_signal)
from repro.core.hitfind import HitSet, compact_hits, find_hits, hits_to_tuples

__all__ = [
    "DepoSet",
    "PhysicalDepoSet",
    "generate_depos",
    "generate_physical_depos",
    "generate_plane_depos",
    "drift_depos",
    "transport",
    "transport_planes",
    "DetectorResponse",
    "make_response",
    "make_plane_responses",
    "SimGraph",
    "SimOutput",
    "SimState",
    "Stage",
    "build_sim_graph",
    "simulate",
    "make_sim_fn",
    "EventBatch",
    "PhysicalEventBatch",
    "event_keys",
    "pack_events",
    "pack_physical_events",
    "shard_events",
    "simulate_events",
    "make_batched_sim_fn",
    "FitParam",
    "FitResult",
    "FitSpec",
    "FitTargets",
    "assert_differentiable_config",
    "calibrate",
    "fit_config",
    "make_fit_loss",
    "make_fit_targets",
    "run_fit",
    "spec_from_names",
    "GradcheckResult",
    "finite_difference_grad",
    "gradcheck",
    "stage_gradcheck_suite",
    "deconvolve",
    "make_deconv_filter",
    "make_plane_deconv_filters",
    "measured_signal",
    "HitSet",
    "compact_hits",
    "find_hits",
    "hits_to_tuples",
]
