"""Core: the paper's contribution — LArTPC signal simulation, TPU-native.

Pipeline (paper Eq. 1/2):
    depos --rasterize--> patches --scatter-add--> S(t,x) --FFT conv--> M(t,x)
    (+ shaped electronics noise, digitization)
"""
from repro.core.depo import DepoSet, generate_depos
from repro.core.response import DetectorResponse, make_response
from repro.core.pipeline import simulate, make_sim_fn

__all__ = [
    "DepoSet",
    "generate_depos",
    "DetectorResponse",
    "make_response",
    "simulate",
    "make_sim_fn",
]
