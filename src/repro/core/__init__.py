"""Core: the paper's contribution — LArTPC signal simulation, TPU-native.

Pipeline (paper Eq. 1/2):
    depos --rasterize--> patches --scatter-add--> S(t,x) --FFT conv--> M(t,x)
    (+ shaped electronics noise, digitization)
"""
from repro.core.depo import DepoSet, generate_depos
from repro.core.response import DetectorResponse, make_response
from repro.core.pipeline import simulate, make_sim_fn
from repro.core.batch import (EventBatch, event_keys, make_batched_sim_fn,
                              pack_events, shard_events, simulate_events)

__all__ = [
    "DepoSet",
    "generate_depos",
    "DetectorResponse",
    "make_response",
    "simulate",
    "make_sim_fn",
    "EventBatch",
    "event_keys",
    "pack_events",
    "shard_events",
    "simulate_events",
    "make_batched_sim_fn",
]
