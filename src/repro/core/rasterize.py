"""Batched rasterization: depo -> (patch_wires x patch_ticks) charge patch.

This is the paper's "2D sampling" step (Table 2, col 3). Each depo is a 2-D
Gaussian; the patch pixel (i, j) receives the bin-integrated Gaussian mass

    q * [Φ((i+1-μ_w)/σ_w) − Φ((i−μ_w)/σ_w)] * [Φ((j+1-μ_t)/σ_t) − Φ((j−μ_t)/σ_t)]

computed as an outer product of per-axis erf differences — O(pw+pt) erfs per
depo instead of O(pw·pt), the same separability trick Wire-Cell uses.

The pure-jnp batched implementation here is the `fig4` building block (one
fused launch for all depos) and the oracle for the Pallas kernel in
``repro.kernels.rasterize``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig
from repro.core.depo import DepoSet, depo_patch_origin

_SQRT2 = 1.4142135623730951


def _axis_weights(center: jax.Array, sigma: jax.Array, origin: jax.Array, npix: int):
    """Bin-integrated Gaussian weights along one axis.

    center/sigma/origin: (N,) ; returns (N, npix).
    """
    edges = origin[:, None].astype(jnp.float32) + jnp.arange(npix + 1, dtype=jnp.float32)[None, :]
    z = (edges - center[:, None]) / (sigma[:, None] * _SQRT2)
    cdf = jax.lax.erf(z)  # 2Φ−1, the 0.5 factors cancel in the difference
    # clamp: float32 erf differences in the far tail can go ~-1e-8
    return jnp.maximum(0.5 * (cdf[:, 1:] - cdf[:, :-1]), 0.0)


def rasterize(depos: DepoSet, cfg: LArTPCConfig):
    """All-depo batched rasterization.

    Returns (patches, w0, t0): patches (N, pw, pt) in ``cfg.patch_dtype``
    (weights are always computed in float32; a narrower patch dtype only
    changes what is materialized between stages), origins (N,) int32.
    """
    w0, t0 = depo_patch_origin(depos, cfg)
    ww = _axis_weights(depos.wire, depos.sigma_w, w0, cfg.patch_wires)   # (N, pw)
    wt = _axis_weights(depos.tick, depos.sigma_t, t0, cfg.patch_ticks)   # (N, pt)
    patches = depos.charge[:, None, None] * ww[:, :, None] * wt[:, None, :]
    return patches.astype(jnp.dtype(cfg.patch_dtype)), w0, t0


def rasterize_one(wire, tick, sigma_w, sigma_t, charge, w0, t0, pw: int, pt: int):
    """Single-depo rasterization (the fig3 per-depo dispatch unit)."""
    edges_w = w0 + jnp.arange(pw + 1, dtype=jnp.float32)
    edges_t = t0 + jnp.arange(pt + 1, dtype=jnp.float32)
    cw = jax.lax.erf((edges_w - wire) / (sigma_w * _SQRT2))
    ct = jax.lax.erf((edges_t - tick) / (sigma_t * _SQRT2))
    ww = jnp.maximum(0.5 * (cw[1:] - cw[:-1]), 0.0)
    wt = jnp.maximum(0.5 * (ct[1:] - ct[:-1]), 0.0)
    return charge * ww[:, None] * wt[None, :]
