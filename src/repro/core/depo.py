"""Energy depositions ("depos") — the input to the LArTPC signal simulation.

A depo is a point charge deposit from a Geant4-tracked particle. During drift to
the readout plane it becomes a 2-D Gaussian cloud (transverse × longitudinal
diffusion, Fig. 2 of the paper). The real experiment feeds CORSIKA+Geant4 output
through LArSoft; here ``generate_depos`` is the stand-in generator producing the
same statistical shape: tracks of correlated depos with diffusion growing with
drift distance.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig


class DepoSet(NamedTuple):
    """Structure-of-arrays depo container (all float32, shape (N,)).

    wire    : transverse center, in wire-pitch units (fractional)
    tick    : longitudinal (drift-time) center, in tick units (fractional)
    sigma_w : transverse Gaussian width, wire units
    sigma_t : longitudinal Gaussian width, tick units
    charge  : number of ionization electrons (mean, pre-fluctuation)
    """

    wire: jax.Array
    tick: jax.Array
    sigma_w: jax.Array
    sigma_t: jax.Array
    charge: jax.Array

    @property
    def n(self) -> int:
        return self.wire.shape[0]


def generate_depos(key: jax.Array, cfg: LArTPCConfig, n: int | None = None) -> DepoSet:
    """Synthetic cosmic-ray-like depos: straight tracks through the volume.

    Matches the paper's benchmark input statistically: ~100k depos from cosmic
    tracks, diffusion widths set by drift distance.
    """
    n = n or cfg.num_depos
    n_tracks = max(1, n // 512)  # ~512 depos per track segment
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)

    # track entry points and direction (in wire/tick coordinates)
    entry_w = jax.random.uniform(k1, (n_tracks,), minval=0.0, maxval=cfg.num_wires - 1.0)
    entry_t = jax.random.uniform(k2, (n_tracks,), minval=0.0, maxval=cfg.num_ticks - 1.0)
    theta = jax.random.uniform(k3, (n_tracks,), minval=-1.2, maxval=1.2)

    per = n // n_tracks + 1
    s = jnp.arange(per, dtype=jnp.float32)[None, :]  # arc-length steps along the track
    wires = entry_w[:, None] + jnp.sin(theta)[:, None] * s * 0.5
    ticks = entry_t[:, None] + jnp.cos(theta)[:, None] * s * 2.0
    wires = wires.reshape(-1)[:n]
    ticks = ticks.reshape(-1)[:n]
    # keep everything inside the active volume (reflect)
    wires = jnp.clip(jnp.abs(wires), 0, cfg.num_wires - 1)
    ticks = jnp.clip(jnp.abs(ticks), 0, cfg.num_ticks - 1)

    # diffusion grows like sqrt(drift distance); drift distance ~ tick
    drift_us = ticks * cfg.tick_us
    sigma_t = jnp.sqrt(2.0 * cfg.diffusion_long * drift_us) / (
        cfg.drift_speed_mm_us * cfg.tick_us
    ) * 1e-2 + 0.8
    sigma_w = jnp.sqrt(2.0 * cfg.diffusion_tran * drift_us) / cfg.wire_pitch_mm * 1e-2 + 0.6
    # clip so the nsigma extent fits inside the patch
    sigma_w = jnp.clip(sigma_w, 0.3, (cfg.patch_wires / 2 - 1) / cfg.nsigma)
    sigma_t = jnp.clip(sigma_t, 0.3, (cfg.patch_ticks / 2 - 1) / cfg.nsigma)

    # Landau-ish long-tailed charge per depo (lognormal)
    charge = cfg.electrons_per_depo * jnp.exp(
        0.3 * jax.random.normal(k4, (n,))
    )
    return DepoSet(
        wire=wires.astype(jnp.float32),
        tick=ticks.astype(jnp.float32),
        sigma_w=sigma_w.astype(jnp.float32),
        sigma_t=sigma_t.astype(jnp.float32),
        charge=charge.astype(jnp.float32),
    )


def depo_patch_origin(depos: DepoSet, cfg: LArTPCConfig):
    """Integer (wire, tick) origin of each depo's patch, clipped to the grid."""
    w0 = jnp.round(depos.wire).astype(jnp.int32) - cfg.patch_wires // 2
    t0 = jnp.round(depos.tick).astype(jnp.int32) - cfg.patch_ticks // 2
    w0 = jnp.clip(w0, 0, cfg.num_wires - cfg.patch_wires)
    t0 = jnp.clip(t0, 0, cfg.num_ticks - cfg.patch_ticks)
    return w0, t0
