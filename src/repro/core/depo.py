"""Energy depositions ("depos") — the input to the LArTPC signal simulation.

A depo is a point charge deposit from a Geant4-tracked particle. During drift
to the readout plane it becomes a 2-D Gaussian cloud (transverse ×
longitudinal diffusion, Fig. 2 of the paper). The real experiment feeds
CORSIKA+Geant4 output through LArSoft; here ``generate_physical_depos`` is
the stand-in generator producing the same statistical shape — tracks of
correlated *physical* depos — and ``generate_depos`` is that generator plus
the drift stage (``repro.core.drift``), which owns diffusion, lifetime
attenuation, and recombination.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig


class DepoSet(NamedTuple):
    """Structure-of-arrays depo container (all float32, shape (N,)).

    wire    : transverse center, in wire-pitch units (fractional)
    tick    : longitudinal (drift-time) center, in tick units (fractional)
    sigma_w : transverse Gaussian width, wire units
    sigma_t : longitudinal Gaussian width, tick units
    charge  : number of ionization electrons (mean, pre-fluctuation)
    """

    wire: jax.Array
    tick: jax.Array
    sigma_w: jax.Array
    sigma_t: jax.Array
    charge: jax.Array

    @property
    def n(self) -> int:
        """Depos per plane — the last axis (leaves may carry leading plane
        and/or event axes: (N,), (P, N), (E, P, N))."""
        return self.wire.shape[-1]


def generate_physical_depos(key: jax.Array, cfg: LArTPCConfig,
                            n: int | None = None):
    """Synthetic cosmic-ray-like *physical* depos: straight tracks through
    the volume, in the anode drift frame (``repro.core.drift``).

    Matches the paper's benchmark input statistically: ~100k depos from
    cosmic tracks, deposited at trigger time (t=0) with drift times spanning
    the readout window. Transport to ``(wire, tick)`` detector coordinates —
    diffusion, lifetime attenuation, recombination — is the drift stage's
    job, not the generator's.
    """
    from repro.core.drift import PhysicalDepoSet

    n = n or cfg.num_depos
    n_tracks = max(1, n // 512)  # ~512 depos per track segment
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)

    # track entry points and direction (in wire/tick coordinates)
    entry_w = jax.random.uniform(k1, (n_tracks,), minval=0.0, maxval=cfg.num_wires - 1.0)
    entry_t = jax.random.uniform(k2, (n_tracks,), minval=0.0, maxval=cfg.num_ticks - 1.0)
    theta = jax.random.uniform(k3, (n_tracks,), minval=-1.2, maxval=1.2)

    per = n // n_tracks + 1
    s = jnp.arange(per, dtype=jnp.float32)[None, :]  # arc-length steps along the track
    wires = entry_w[:, None] + jnp.sin(theta)[:, None] * s * 0.5
    ticks = entry_t[:, None] + jnp.cos(theta)[:, None] * s * 2.0
    wires = wires.reshape(-1)[:n]
    ticks = ticks.reshape(-1)[:n]
    # keep everything inside the active volume (reflect)
    wires = jnp.clip(jnp.abs(wires), 0, cfg.num_wires - 1)
    ticks = jnp.clip(jnp.abs(ticks), 0, cfg.num_ticks - 1)

    # along-wire position z [mm]: tracks slope through a square-ish
    # transverse volume. Unused by the identity single-plane readout (its
    # projection never reads z, so these draws don't perturb it) but gives
    # the rotated U/V planes of a multi-plane config real geometry.
    k5a, k5b = jax.random.split(k5)
    z_extent = cfg.num_wires * cfg.wire_pitch_mm
    entry_z = jax.random.uniform(k5a, (n_tracks,), minval=0.0,
                                 maxval=z_extent)
    dz = jax.random.uniform(k5b, (n_tracks,), minval=-2.0, maxval=2.0)
    zs = (entry_z[:, None] + dz[:, None] * s).reshape(-1)[:n]
    zs = jnp.clip(jnp.abs(zs), 0, z_extent)

    # Landau-ish long-tailed charge per depo (lognormal)
    charge = cfg.electrons_per_depo * jnp.exp(
        0.3 * jax.random.normal(k4, (n,))
    )
    return PhysicalDepoSet(
        x=(ticks * cfg.tick_us).astype(jnp.float32),  # drift time [us]
        y=wires.astype(jnp.float32),                  # wire-pitch units
        z=zs.astype(jnp.float32),
        t=jnp.zeros((n,), jnp.float32),               # deposited at trigger
        q=charge.astype(jnp.float32),
    )


def generate_depos(key: jax.Array, cfg: LArTPCConfig, n: int | None = None) -> DepoSet:
    """Physical depo generation + drift transport, as one detector DepoSet.

    Thin wrapper: ``generate_physical_depos`` samples tracks, the drift
    stage transports them to the readout plane. Bit-for-bit with the seed
    repo's direct detector-frame generator at default physics
    (``tests/test_drift.py`` pins this).
    """
    from repro.core.drift import transport

    return transport(generate_physical_depos(key, cfg, n), cfg)


def generate_plane_depos(key: jax.Array, cfg: LArTPCConfig,
                         n: int | None = None) -> DepoSet:
    """Physical depo generation + multi-plane transport: one DepoSet with
    a leading plane axis ``(num_planes, N)`` — the pre-drifted input of the
    streaming launcher in multi-plane configs."""
    from repro.core.drift import transport_planes

    return transport_planes(generate_physical_depos(key, cfg, n), cfg)


def depo_patch_origin(depos: DepoSet, cfg: LArTPCConfig):
    """Integer (wire, tick) origin of each depo's patch, clipped to the grid."""
    w0 = jnp.round(depos.wire).astype(jnp.int32) - cfg.patch_wires // 2
    t0 = jnp.round(depos.tick).astype(jnp.int32) - cfg.patch_ticks // 2
    w0 = jnp.clip(w0, 0, cfg.num_wires - cfg.patch_wires)
    t0 = jnp.clip(t0, 0, cfg.num_ticks - cfg.patch_ticks)
    return w0, t0
