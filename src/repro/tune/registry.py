"""Kernel-strategy registry: one table of candidate implementations per hot op.

The source paper's central portability lesson is that the *best* implementation
of a hot spot differs by backend — its Kokkos port had to choose between
atomic, sort-segment, and tiled scatter-add strategies per architecture, and
the follow-up OpenMP/SYCL ports flip the winner again. The seed repo carried
that choice as scattered per-op ``if/else`` on config strings. This module
replaces it with a single registry:

  * each hot op (``scatter_add``, ``charge_grid``, ``fft_convolve``) registers
    its candidate implementations under a name, with a declared availability
    predicate (some candidates only make sense on some backends / shapes);
  * per-op, per-backend *heuristic* defaults live in one table instead of
    being implied by call sites;
  * the empirical autotuner (``repro.tune.autotune``) walks the same table to
    time candidates on the live backend and cache the winner.

The registry is deliberately dependency-light (jax only for backend
introspection, no config import): implementations register themselves from
the modules that own them, and ``ensure_registered`` imports those modules
lazily to avoid cycles.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class TuneContext:
    """Everything an availability predicate may inspect.

    cfg        : the workload config (``LArTPCConfig`` for the sim ops).
    backend    : jax platform name ("cpu" | "gpu" | "tpu").
    device_kind: e.g. "TPU v4", "cpu" — part of the tuning-cache key.
    shape      : problem dims the op cares about (num_depos, grid dims, ...).
    """

    cfg: Any
    backend: str
    device_kind: str
    shape: Mapping[str, int]


@dataclasses.dataclass(frozen=True)
class Strategy:
    """One registered candidate implementation of a hot op.

    ``differentiable`` declares whether ``jax.grad`` can flow through this
    candidate: plain-XLA implementations are (True, the default); Pallas
    kernels without a custom VJP and discrete-output ops (hit finding) are
    not. The calibration path (``repro.core.fit``) restricts strategy
    resolution to differentiable candidates via this predicate.

    ``collectives`` declares which collective kinds ("all-reduce",
    "reduce-scatter", ...) the candidate may emit when compiled. Every
    current single-device strategy declares none — which is exactly the
    invariant the contract auditor (``repro.analysis.audit``) enforces: a
    collective appearing in a local executor's compiled program whose
    strategies declare no collectives is a policy failure, not a baseline
    diff. A future distributed-aware candidate opts out by declaring its
    kinds here.
    """

    op: str
    name: str
    fn: Callable
    available: Optional[Callable[[TuneContext], bool]] = None
    note: str = ""
    differentiable: bool = True
    collectives: Tuple[str, ...] = ()

    def is_available(self, ctx: TuneContext) -> bool:
        return self.available is None or bool(self.available(ctx))


_OPS: Dict[str, Dict[str, Strategy]] = {}
_DEFAULTS: Dict[str, Dict[str, str]] = {}  # op -> {backend or "*": name}
_ENSURED = False


def register_strategy(
    op: str,
    name: str,
    *,
    available: Optional[Callable[[TuneContext], bool]] = None,
    note: str = "",
    differentiable: bool = True,
    collectives: Tuple[str, ...] = (),
):
    """Decorator: register ``fn`` as candidate ``name`` of hot op ``op``."""

    def deco(fn):
        _OPS.setdefault(op, {})[name] = Strategy(op, name, fn, available,
                                                 note, differentiable,
                                                 tuple(collectives))
        return fn

    return deco


def set_default(op: str, name: str, backend: str = "*") -> None:
    """Declare the heuristic default strategy for ``op`` on ``backend``
    ("*" = any backend without a more specific entry)."""
    _DEFAULTS.setdefault(op, {})[backend] = name


def ensure_registered() -> None:
    """Import every module that registers strategies (idempotent).

    Mirrors ``repro.config.get_config`` importing ``repro.configs``: the
    registry stays dependency-free and the owning modules self-register.
    """
    global _ENSURED
    if _ENSURED:
        return
    _ENSURED = True
    import repro.core.deconvolve  # noqa: F401  registers deconvolve/*
    import repro.core.drift  # noqa: F401  registers drift/*
    import repro.core.fft_conv  # noqa: F401  registers fft_convolve/*
    import repro.core.hitfind  # noqa: F401  registers hit_find/*
    import repro.core.pipeline  # noqa: F401  registers charge_grid/*
    import repro.core.scatter  # noqa: F401  registers scatter_add/*


def list_ops() -> list:
    ensure_registered()
    return sorted(_OPS)


def strategies(op: str) -> Dict[str, Strategy]:
    """All registered candidates of ``op`` (name -> Strategy)."""
    ensure_registered()
    if op not in _OPS:
        raise KeyError(f"unknown hot op {op!r}; known: {sorted(_OPS)}")
    return dict(_OPS[op])


def get_strategy(op: str, name: str) -> Strategy:
    table = strategies(op)
    if name not in table:
        raise KeyError(
            f"unknown strategy {name!r} for op {op!r}; known: {sorted(table)}"
        )
    return table[name]


def available_strategies(op: str, ctx: TuneContext) -> Dict[str, Strategy]:
    """Candidates of ``op`` whose availability predicate passes for ``ctx``."""
    return {n: s for n, s in strategies(op).items() if s.is_available(ctx)}


def differentiable_strategies(op: str) -> Dict[str, Strategy]:
    """Candidates of ``op`` that reverse-mode autodiff can flow through —
    the availability predicate of the calibration/fit path."""
    return {n: s for n, s in strategies(op).items() if s.differentiable}


def is_differentiable(op: str, name: str) -> bool:
    """Whether candidate ``name`` of ``op`` supports ``jax.grad``."""
    return get_strategy(op, name).differentiable


def declared_collectives(op: Optional[str] = None) -> Tuple[str, ...]:
    """Union of collective kinds declared by registered strategies — of one
    op, or of every op (``op=None``). The contract auditor's allowance for
    single-device programs."""
    ops = [op] if op is not None else list_ops()
    kinds: set = set()
    for o in ops:
        for strat in strategies(o).values():
            kinds.update(strat.collectives)
    return tuple(sorted(kinds))


def default_strategy(op: str, backend: Optional[str] = None) -> str:
    """The heuristic (non-tuned) default for ``op`` on ``backend``."""
    ensure_registered()
    backend = backend or current_backend()
    table = _DEFAULTS.get(op, {})
    if backend in table:
        return table[backend]
    if "*" in table:
        return table["*"]
    raise KeyError(f"no default strategy declared for op {op!r}")


def current_backend() -> str:
    return jax.default_backend()


def current_device_kind() -> str:
    kind = jax.devices()[0].device_kind
    return kind.replace(" ", "_")


def make_context(
    cfg,
    shape: Mapping[str, int],
    backend: Optional[str] = None,
) -> TuneContext:
    return TuneContext(
        cfg=cfg,
        backend=backend or current_backend(),
        device_kind=current_device_kind(),
        shape=dict(shape),
    )
