"""Kernel-strategy registry + empirical autotuner (see docs/tuning.md).

Public surface:

  registry   : register_strategy / strategies / get_strategy /
               available_strategies / default_strategy / TuneContext
  autotuner  : tune_op / resolve / resolve_config / TuneCache / TuneDecision
"""

from repro.tune.autotune import (
    OP_FIELDS,
    TUNABLE_OPS,
    TuneCache,
    TuneDecision,
    cache_key,
    candidate_thunks,
    median_timer,
    op_shape,
    resolve,
    resolve_config,
    resolve_config_with_decisions,
    shape_bucket,
    tune_op,
)
from repro.tune.registry import (
    Strategy,
    TuneContext,
    available_strategies,
    default_strategy,
    differentiable_strategies,
    ensure_registered,
    get_strategy,
    is_differentiable,
    list_ops,
    make_context,
    register_strategy,
    set_default,
    strategies,
)

__all__ = [
    "OP_FIELDS",
    "TUNABLE_OPS",
    "Strategy",
    "TuneCache",
    "TuneContext",
    "TuneDecision",
    "available_strategies",
    "cache_key",
    "candidate_thunks",
    "default_strategy",
    "differentiable_strategies",
    "ensure_registered",
    "get_strategy",
    "is_differentiable",
    "list_ops",
    "make_context",
    "median_timer",
    "op_shape",
    "register_strategy",
    "resolve",
    "resolve_config",
    "resolve_config_with_decisions",
    "set_default",
    "shape_bucket",
    "strategies",
    "tune_op",
]
