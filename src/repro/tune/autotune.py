"""Empirical autotuner: time registered candidates, cache the winner to disk.

The decision the paper's authors made by hand — "which scatter-add strategy
for this architecture?" — is made here by measurement on the *live* backend
at the *actual* problem shape, then cached so later runs skip re-tuning:

  key   = (op, backend, device_kind, shape-bucket)
  value = {strategy, timings_us, tuned_at, jax_version, shape}

Shape dims are bucketed to the next power of two, so e.g. 100_000 and
120_000 depos share one decision but 1_000 does not. The cache is a single
JSON file (default ``~/.cache/repro-tune/tune_cache.json``, override with
``$REPRO_TUNE_CACHE``) — human-readable, diffable, safe to delete.

Resolution order for a strategy-valued config field:

  explicit name  >  disk cache  >  (tune now, if asked)  >  backend default

``resolve_config`` must run *before* ``jax.jit`` traces the pipeline: the
chosen strategy is baked into the traced program, exactly like the paper's
per-architecture builds — but chosen by data, not by hand.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import os
import time
import uuid
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax

from repro.tune import registry
from repro.tune.registry import TuneContext

CACHE_ENV = "REPRO_TUNE_CACHE"

#: cache record schema version. Bump on incompatible record changes: entries
#: with a different (or missing) ``schema`` field are ignored per-entry —
#: a stale or foreign record degrades to a cache miss, never a crash.
SCHEMA_VERSION = 1

#: op -> the config field that names its strategy
OP_FIELDS: Dict[str, str] = {
    "drift": "drift_strategy",
    "scatter_add": "scatter_strategy",
    "charge_grid": "charge_grid_strategy",
    "fft_convolve": "fft_strategy",
    "deconvolve": "deconv_strategy",
    "hit_find": "hitfind_strategy",
}

#: ops whose tuning decision is keyed by the plane KIND (their transforms
#: differ between bipolar induction and unipolar collection planes) — on
#: multi-plane "auto" configs the field stays "auto" and every dispatch
#: resolves with its own plane key (see resolve_config_with_decisions)
PLANE_KEYED_OPS = ("fft_convolve", "deconvolve")


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


def default_cache_path() -> str:
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    home = os.path.expanduser("~")
    return os.path.join(home, ".cache", "repro-tune", "tune_cache.json")


class TuneCache:
    """A {cache_key: decision-record} JSON file, loaded lazily, written on put.

    Robust to the failure modes a shared cache file actually sees
    (docs/robustness.md):

    * **Concurrent writers** — each ``put`` writes to a per-process temp name
      (pid + random suffix) and atomically ``os.replace``s it in, so two
      processes can never interleave bytes; and it *merges on write* (re-read
      disk, overlay this process's own entries) so the last writer keeps the
      other's decisions instead of clobbering them.
    * **Corrupt files** — torn writes / garbage bytes / non-dict JSON degrade
      to an empty cache (a re-tune), never a crash.
    * **Foreign entries** — records without ``schema == SCHEMA_VERSION`` (or
      that are not dicts at all) are dropped per-entry on read.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self._data: Optional[Dict[str, dict]] = None
        #: entries written by THIS process — re-overlaid on every merge
        self._local: Dict[str, dict] = {}

    @staticmethod
    def _valid(entry: object) -> bool:
        return isinstance(entry, dict) and entry.get("schema") == SCHEMA_VERSION

    def _read_disk(self) -> Dict[str, dict]:
        try:
            with open(self.path) as f:
                raw = json.load(f)
        except (OSError, ValueError, UnicodeDecodeError):
            return {}
        if not isinstance(raw, dict):
            return {}
        return {k: v for k, v in raw.items() if self._valid(v)}

    def _load(self) -> Dict[str, dict]:
        if self._data is None:
            self._data = self._read_disk()
        return self._data

    def get(self, key: str) -> Optional[dict]:
        return self._load().get(key)

    def put(self, key: str, record: dict) -> None:
        record = dict(record, schema=SCHEMA_VERSION)
        self._local[key] = record
        # merge-on-write: a concurrent tuner may have landed entries since we
        # loaded — keep theirs, overlay ours
        data = self._read_disk()
        data.update(self._local)
        self._data = data
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        tmp = f"{self.path}.{os.getpid()}.{uuid.uuid4().hex[:8]}.tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(data, f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        finally:
            with contextlib.suppress(OSError):
                os.unlink(tmp)


# ---------------------------------------------------------------------------
# Shape buckets and cache keys
# ---------------------------------------------------------------------------


def _bucket(n: int) -> int:
    """Next power of two >= n (0 stays 0): 100_000 -> 131_072."""
    return 0 if n <= 0 else 1 << (int(n) - 1).bit_length()


def shape_bucket(shape: Mapping[str, object]) -> str:
    """Numeric dims bucket to the next power of two; categorical dims
    (e.g. the fft_convolve ``plane`` kind) pass through verbatim."""
    return ";".join(
        f"{k}={v}" if isinstance(v, str) else f"{k}={_bucket(v)}"
        for k, v in sorted(shape.items())
    )


def cache_key(
    op: str,
    backend: str,
    device_kind: str,
    shape: Mapping[str, int],
) -> str:
    return f"{op}|{backend}|{device_kind}|{shape_bucket(shape)}"


def op_shape(op: str, cfg) -> Dict[str, int]:
    """The problem dims op's tuning decision depends on."""
    if op == "drift":
        return {"num_depos": cfg.num_depos}
    if op in ("scatter_add", "charge_grid"):
        shape = {
            "num_depos": cfg.num_depos,
            "num_wires": cfg.num_wires,
            "num_ticks": cfg.num_ticks,
            "patch_wires": cfg.patch_wires,
            "patch_ticks": cfg.patch_ticks,
        }
        if op == "charge_grid":
            # the plane count changes the PROBLEM, not just its size: a
            # 3-plane dispatch compares single-plane candidates (paying the
            # per-plane loop) against the fused multi-plane kernels, so a
            # single-plane winner must not key multi-plane dispatches
            shape["num_planes"] = getattr(cfg, "num_planes", 1)
        return shape
    if op in ("fft_convolve", "deconvolve"):
        from repro.config import plane_specs

        return {
            "num_wires": cfg.num_wires,
            "num_ticks": cfg.num_ticks,
            "response_wires": cfg.response_wires,
            "response_ticks": cfg.response_ticks,
            # the response TYPE is part of the problem: a decision timed
            # against the bipolar induction transform must not key
            # collection-plane dispatches (and likewise for the inverse
            # filters). This default is the first plane's kind (the readout
            # plane of a single-plane config); multi-plane "auto" configs
            # never bake one answer into the field — resolve_config leaves
            # "auto" so every dispatch resolves with plane=resp.plane, and
            # tuning runs once per distinct kind (``_resolve_per_plane``)
            "plane": plane_specs(cfg)[0].kind,
        }
    if op == "hit_find":
        return {
            "num_wires": cfg.num_wires,
            "num_ticks": cfg.num_ticks,
            "max_hits_per_wire": cfg.max_hits_per_wire,
        }
    raise KeyError(f"no shape extractor for op {op!r}")


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------

#: a timer maps (candidate name, zero-arg thunk) -> median seconds; tests
#: inject fakes here to make the winner deterministic without a clock
Timer = Callable[[str, Callable[[], object]], float]


def median_timer(
    name: str,
    thunk: Callable[[], object],
    *,
    warmup: int = 1,
    iters: int = 3,
) -> float:
    """Default wall-clock timer (median of ``iters``, after ``warmup``)."""
    del name
    for _ in range(warmup):
        jax.block_until_ready(thunk())
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(thunk())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


# ---------------------------------------------------------------------------
# Per-op problem builders: representative inputs + one thunk per candidate
# ---------------------------------------------------------------------------


def _problem_depos(cfg, sample_depos: Optional[int]):
    from repro.core.depo import generate_depos

    n = sample_depos or cfg.num_depos
    return generate_depos(jax.random.key(0), cfg, n)


def _drift_problem(cfg, ctx: TuneContext, sample_depos: Optional[int]):
    from repro.core.depo import generate_physical_depos

    n = sample_depos or cfg.num_depos
    pdepos = generate_physical_depos(jax.random.key(0), cfg, n)
    jax.block_until_ready(pdepos)

    def make(strat):
        f = jax.jit(functools.partial(strat.fn, cfg=cfg))
        return lambda: f(pdepos)

    avail = registry.available_strategies("drift", ctx)
    return {name: make(s) for name, s in avail.items()}


def _scatter_problem(cfg, ctx: TuneContext, sample_depos: Optional[int]):
    from repro.core.rasterize import rasterize

    depos = _problem_depos(cfg, sample_depos)
    patches, w0, t0 = jax.jit(lambda d: rasterize(d, cfg))(depos)
    jax.block_until_ready(patches)

    def make(strat):
        f = jax.jit(functools.partial(strat.fn, cfg=cfg))
        return lambda: f(patches, w0, t0)

    avail = registry.available_strategies("scatter_add", ctx)
    return {name: make(s) for name, s in avail.items()}


def _charge_grid_problem(cfg, ctx: TuneContext, sample_depos: Optional[int]):
    key = jax.random.key(1)
    avail = registry.available_strategies("charge_grid", ctx)
    if getattr(cfg, "num_planes", 1) > 1:
        from repro.config import plane_specs
        from repro.core.depo import generate_plane_depos
        from repro.core.stages import MULTIPLANE_CHARGE_GRID

        n = sample_depos or cfg.num_depos
        depos = generate_plane_depos(jax.random.key(0), cfg, n)
        jax.block_until_ready(depos)
        specs = plane_specs(cfg)

        def make_mp(name, strat):
            if name in MULTIPLANE_CHARGE_GRID:
                # fused multi-plane kernels take the (P, N) depos whole
                f = jax.jit(lambda k, d: strat.fn(k, d, cfg, None))
                return lambda: f(key, depos)

            # single-plane candidates pay the FULL per-plane loop (the
            # same fold_in schedule the executor runs), so the board
            # compares like against like: all P planes either way
            def loop(k, d):
                return jax.numpy.stack([
                    strat.fn(jax.random.fold_in(k, s.index),
                             jax.tree.map(lambda x, i=i: x[i], d), cfg, None)
                    for i, s in enumerate(specs)])

            f = jax.jit(loop)
            return lambda: f(key, depos)

        return {name: make_mp(name, s) for name, s in avail.items()}

    depos = _problem_depos(cfg, sample_depos)

    def make(strat):
        f = jax.jit(lambda k, d: strat.fn(k, d, cfg, None))
        return lambda: f(key, depos)

    return {name: make(s) for name, s in avail.items()}


def _fft_problem(cfg, ctx: TuneContext, sample_depos: Optional[int]):
    from repro.core.response import make_response

    del sample_depos
    # time against the response the decision is keyed to: the tuning shape
    # carries the plane kind, so collection-plane tunings measure the
    # collection transform instead of silently reusing induction
    resp = make_response(cfg, plane=ctx.shape.get("plane", "induction"))
    shape = (cfg.num_wires, cfg.num_ticks)
    grid = jax.random.uniform(jax.random.key(2), shape)

    def make(strat):
        f = jax.jit(lambda g: strat.fn(g, resp))
        return lambda: f(grid)

    avail = registry.available_strategies("fft_convolve", ctx)
    return {name: make(s) for name, s in avail.items()}


def _deconv_problem(cfg, ctx: TuneContext, sample_depos: Optional[int]):
    from repro.core.deconvolve import make_deconv_filter
    from repro.core.response import make_response

    del sample_depos
    # like _fft_problem: the inverse filter of the plane kind the decision
    # is keyed to, applied to a measured-signal-sized grid
    resp = make_response(cfg, plane=ctx.shape.get("plane", "induction"))
    filt = make_deconv_filter(resp, cfg)
    shape = (cfg.num_wires, cfg.num_ticks)
    meas = jax.random.normal(jax.random.key(3), shape)

    def make(strat):
        f = jax.jit(lambda m: strat.fn(m, filt))
        return lambda: f(meas)

    avail = registry.available_strategies("deconvolve", ctx)
    return {name: make(s) for name, s in avail.items()}


def _hitfind_problem(cfg, ctx: TuneContext, sample_depos: Optional[int]):
    del sample_depos
    # noise-scale deconvolved grid: candidate runs appear at a realistic
    # (sparse) rate relative to the threshold
    shape = (cfg.num_wires, cfg.num_ticks)
    decon = jax.random.normal(jax.random.key(4), shape) * cfg.hit_threshold

    def make(strat):
        f = jax.jit(lambda d: strat.fn(d, cfg))
        return lambda: f(decon)

    avail = registry.available_strategies("hit_find", ctx)
    return {name: make(s) for name, s in avail.items()}


_PROBLEMS = {
    "drift": _drift_problem,
    "scatter_add": _scatter_problem,
    "charge_grid": _charge_grid_problem,
    "fft_convolve": _fft_problem,
    "deconvolve": _deconv_problem,
    "hit_find": _hitfind_problem,
}

TUNABLE_OPS = tuple(_PROBLEMS)


def _usable_hit(op: str, hit: Optional[dict], ctx: TuneContext) -> bool:
    """A cached decision is only usable if its strategy still exists AND its
    availability predicate passes for the *current* context: the cache key
    carries (backend, device_kind, shape) but not config predicates like
    ``fluctuate``, so e.g. a ``fused_pallas`` winner tuned under a
    no-fluctuation config must not leak into a run that needs fluctuation."""
    if not isinstance(hit, dict):  # None, or a foreign non-record entry
        return False
    return hit.get("strategy") in registry.available_strategies(op, ctx)


def candidate_thunks(
    op: str,
    cfg,
    *,
    sample_depos: Optional[int] = None,
    shape: Optional[Mapping[str, int]] = None,
) -> Dict[str, Callable[[], object]]:
    """Zero-arg jit'd thunks for every *available* candidate of ``op``,
    built on representative inputs for ``cfg`` (shared by the tuner and the
    ``benchmarks/tune.py`` sweep)."""
    registry.ensure_registered()
    shape = dict(shape) if shape is not None else op_shape(op, cfg)
    ctx = registry.make_context(cfg, shape)
    return _PROBLEMS[op](cfg, ctx, sample_depos)


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TuneDecision:
    """How a strategy name was arrived at for one op."""

    op: str
    strategy: str
    source: str  # explicit | cache | tuned | default
    cache_key: str = ""
    timings_us: Tuple[Tuple[str, float], ...] = ()

    @property
    def cache_hit(self) -> bool:
        return self.source == "cache"

    def describe(self) -> str:
        if self.source == "tuned":
            ordered = sorted(self.timings_us, key=lambda it: it[1])
            board = ", ".join(f"{n}={t:.0f}us" for n, t in ordered)
            return (
                f"tune[{self.op}]: selected {self.strategy!r} "
                f"(tuned: {board}) -> cached as {self.cache_key}"
            )
        if self.source == "cache":
            return (
                f"tune[{self.op}]: selected {self.strategy!r} "
                f"(cache hit: {self.cache_key})"
            )
        return f"tune[{self.op}]: selected {self.strategy!r} ({self.source})"


def tune_op(
    op: str,
    cfg,
    *,
    cache: Optional[TuneCache] = None,
    timer: Optional[Timer] = None,
    force: bool = False,
    sample_depos: Optional[int] = None,
    shape: Optional[Mapping[str, int]] = None,
) -> TuneDecision:
    """Pick the fastest available candidate of ``op`` for this config/backend.

    Consults the disk cache first (unless ``force``); on a miss, times every
    available candidate with ``timer`` and persists the winner.
    """
    registry.ensure_registered()
    cache = cache or TuneCache()
    timer = timer or median_timer
    shape = dict(shape) if shape is not None else op_shape(op, cfg)
    ctx = registry.make_context(cfg, shape)
    key = cache_key(op, ctx.backend, ctx.device_kind, shape)

    if not force:
        hit = cache.get(key)
        if _usable_hit(op, hit, ctx):
            return TuneDecision(
                op=op, strategy=hit["strategy"], source="cache", cache_key=key
            )

    candidates = candidate_thunks(op, cfg, sample_depos=sample_depos, shape=shape)
    if not candidates:
        return TuneDecision(
            op=op,
            strategy=registry.default_strategy(op),
            source="default",
            cache_key=key,
        )
    timings = {name: timer(name, thunk) for name, thunk in candidates.items()}
    winner = min(timings, key=timings.get)
    timings_us = {n: t * 1e6 for n, t in timings.items()}
    record = {
        "strategy": winner,
        "timings_us": timings_us,
        "shape": dict(shape),
        "backend": ctx.backend,
        "device_kind": ctx.device_kind,
        "jax_version": jax.__version__,
        "tuned_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    cache.put(key, record)
    return TuneDecision(
        op=op,
        strategy=winner,
        source="tuned",
        cache_key=key,
        timings_us=tuple(sorted(timings_us.items())),
    )


def resolve(
    op: str,
    cfg,
    *,
    tune: bool = False,
    cache: Optional[TuneCache] = None,
    timer: Optional[Timer] = None,
    force: bool = False,
    sample_depos: Optional[int] = None,
    shape: Optional[Mapping[str, int]] = None,
) -> TuneDecision:
    """Resolve ``op``'s strategy for ``cfg``: explicit > cache > tune > default.

    Safe to call at trace time (pure Python + file read; never times unless
    ``tune=True``, which callers must only do *outside* jit). ``cfg`` may be
    None for a cache/default-only lookup when ``shape`` is given.
    """
    if cfg is not None:
        explicit = getattr(cfg, OP_FIELDS[op], "auto")
        if explicit != "auto":
            return TuneDecision(op=op, strategy=explicit, source="explicit")
    registry.ensure_registered()
    cache = cache or TuneCache()
    shape = dict(shape) if shape is not None else op_shape(op, cfg)
    ctx = registry.make_context(cfg, shape)
    key = cache_key(op, ctx.backend, ctx.device_kind, shape)
    if not force:
        hit = cache.get(key)
        if _usable_hit(op, hit, ctx):
            return TuneDecision(
                op=op, strategy=hit["strategy"], source="cache", cache_key=key
            )
    if tune and cfg is not None:
        return tune_op(
            op,
            cfg,
            cache=cache,
            timer=timer,
            force=force,
            sample_depos=sample_depos,
            shape=shape,
        )
    name = registry.default_strategy(op, ctx.backend)
    return TuneDecision(op=op, strategy=name, source="default", cache_key=key)


def resolve_config(
    cfg,
    *,
    tune: bool = False,
    cache: Optional[TuneCache] = None,
    timer: Optional[Timer] = None,
    force: bool = False,
    sample_depos: Optional[int] = None,
):
    """Replace every ``"auto"`` strategy field of ``cfg`` with a concrete name.

    Call this *before* jit so the traced program is fixed. Returns the
    resolved config (non-auto fields pass through untouched).
    """
    cfg, _ = resolve_config_with_decisions(
        cfg,
        tune=tune,
        cache=cache,
        timer=timer,
        force=force,
        sample_depos=sample_depos,
    )
    return cfg


def resolve_config_with_decisions(
    cfg,
    *,
    tune: bool = False,
    cache: Optional[TuneCache] = None,
    timer: Optional[Timer] = None,
    force: bool = False,
    sample_depos: Optional[int] = None,
    tune_explicit: bool = False,
):
    """Like ``resolve_config`` but also returns the per-op decisions.

    ``tune_explicit=True`` re-tunes ops even when their config field already
    names a concrete strategy (the ``--tune`` launcher flag: measure and
    override, don't trust the hand-picked value).
    """
    cache = cache or TuneCache()
    decisions = []
    for op, fld in OP_FIELDS.items():
        if tune and tune_explicit and getattr(cfg, fld) != "auto":
            cfg = dataclasses.replace(cfg, **{fld: "auto"})
        if (
            op in PLANE_KEYED_OPS
            and getattr(cfg, "num_planes", 1) > 1
            and getattr(cfg, fld) == "auto"
        ):
            # Multi-plane: ONE config field cannot name a per-plane winner,
            # so "auto" stays in the config and each dispatch resolves from
            # the cache with its own plane key at trace time (the ops' auto
            # paths only read cache/defaults — they never time). Tuning
            # here measures every distinct plane kind so those per-plane
            # cache entries exist before jit.
            decisions.extend(
                _resolve_per_plane(
                    op,
                    cfg,
                    tune=tune,
                    cache=cache,
                    timer=timer,
                    force=force,
                    sample_depos=sample_depos,
                )
            )
            continue
        d = resolve(
            op,
            cfg,
            tune=tune,
            cache=cache,
            timer=timer,
            force=force,
            sample_depos=sample_depos,
        )
        decisions.append(d)
        if getattr(cfg, fld) != d.strategy:
            cfg = dataclasses.replace(cfg, **{fld: d.strategy})
    return cfg, decisions


def _resolve_per_plane(
    op: str,
    cfg,
    *,
    tune: bool,
    cache: TuneCache,
    timer: Optional[Timer],
    force: bool,
    sample_depos: Optional[int],
):
    """One decision of a plane-keyed op per distinct plane kind of a
    multi-plane config (the field itself stays "auto"; see the caller)."""
    from repro.config import plane_specs

    decisions = []
    for kind in sorted({s.kind for s in plane_specs(cfg)}):
        shape = dict(op_shape(op, cfg), plane=kind)
        if tune:
            d = tune_op(
                op,
                cfg,
                cache=cache,
                timer=timer,
                force=force,
                sample_depos=sample_depos,
                shape=shape,
            )
        else:
            # cache/default lookup only — cfg=None skips the explicit-name
            # branch (the field is "auto" by construction here)
            d = resolve(op, None, cache=cache, shape=shape)
        decisions.append(d)
    return decisions
