"""Reference implementation the kernel is checked against: the shared
``_wire_scan`` body vmapped over wires (identical to the registry's XLA
``scan`` strategy — the kernel must be bit-equal to this)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hitfind import _wire_scan


def find_wire_hits_ref(decon: jax.Array, *, threshold: float, cap: int):
    thr = jnp.float32(threshold)
    return jax.vmap(lambda row: _wire_scan(row, thr, cap))(decon)
