"""jit'd wrapper for the per-wire Pallas hit scanner."""
from __future__ import annotations

import jax

from repro.kernels import default_interpret
from repro.kernels.hitfind.kernel import hitfind_pallas


def find_wire_hits_pallas(decon: jax.Array, *, threshold: float, cap: int,
                          interpret: bool | None = None):
    """(W, T) deconvolved grid -> per-wire candidates, kernel-scanned.

    Returns (counts (W,) int32, charge/tick/peak (W, cap) float32) — the
    same layout (and, by shared scan body, the same bits) as the XLA
    ``scan`` strategy.
    """
    if interpret is None:
        interpret = default_interpret()
    counts, hq, ht, hp = hitfind_pallas(decon, threshold=threshold, cap=cap,
                                        interpret=interpret)
    return counts[:, 0], hq, ht, hp
