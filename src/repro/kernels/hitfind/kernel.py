"""Pallas kernel: per-wire threshold-run hit scanner.

One grid step per wire: the step DMAs that wire's (1, T) waveform block into
VMEM, runs the SAME ``_wire_scan`` body the XLA strategy vmaps (a
``fori_loop`` over ticks — sequential in time, parallel over wires, the
natural decomposition the hit-finding paper (arXiv:2107.00812) settles on),
and writes the wire's (1, cap) candidate rows plus its (1, 1) run count.

The candidate arrays ride the loop carry in registers/VMEM and store once at
the end — no scatter into the output ref from inside the loop. The threshold
and per-wire capacity are baked in as Python statics (they come from the
config, which is static under jit anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hitfind import _wire_scan


def _hitfind_kernel(q_ref, counts_ref, hq_ref, ht_ref, hp_ref, *,
                    threshold: float, cap: int):
    """Grid step w: scan wire w's waveform block for above-threshold runs.

    q_ref: (1, T) VMEM block of the deconvolved grid's wire w.
    counts_ref: (1, 1) int32; hq/ht/hp_ref: (1, cap) float32 outputs.
    """
    vals = q_ref[0, :].astype(jnp.float32)
    n, hq, ht, hp = _wire_scan(vals, jnp.float32(threshold), cap)
    counts_ref[0, 0] = n
    hq_ref[0, :] = hq
    ht_ref[0, :] = ht
    hp_ref[0, :] = hp


def hitfind_pallas(decon: jax.Array, *, threshold: float, cap: int,
                   interpret: bool = True):
    """Run the per-wire scanner over a (W, T) deconvolved grid.

    Returns (counts (W, 1) int32, charge (W, cap), tick (W, cap),
    peak (W, cap)) — the per-wire candidate layout ``compact_hits`` takes
    (the caller squeezes counts).
    """
    w, t_len = decon.shape
    kernel = functools.partial(_hitfind_kernel, threshold=threshold, cap=cap)
    return pl.pallas_call(
        kernel,
        grid=(w,),
        in_specs=[pl.BlockSpec((1, t_len), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, cap), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((w, 1), jnp.int32),
            jax.ShapeDtypeStruct((w, cap), jnp.float32),
            jax.ShapeDtypeStruct((w, cap), jnp.float32),
            jax.ShapeDtypeStruct((w, cap), jnp.float32),
        ),
        interpret=interpret,
    )(decon)
