"""Pure-jnp oracle for the owner-computes scatter-add kernel."""
from __future__ import annotations

import jax.numpy as jnp


def scatter_add_ref(patches, w0, t0, *, num_wires: int, num_ticks: int):
    """Dense scatter-add of zero-padded patches into the grid.

    patches: (N, PW_pad, PT_pad); padding pixels must already be zero, and
    padded extents may hang off the grid edge (dropped, like the kernel's
    tile clamp — callers guarantee true patch pixels stay in bounds).
    """
    n, pw, pt = patches.shape
    dw = jnp.arange(pw, dtype=jnp.int32)[None, :, None]
    dt = jnp.arange(pt, dtype=jnp.int32)[None, None, :]
    wi = w0[:, None, None] + dw
    ti = t0[:, None, None] + dt
    inb = (wi < num_wires) & (ti < num_ticks)
    flat = jnp.where(inb, wi * num_ticks + ti, num_wires * num_ticks)
    grid = jnp.zeros((num_wires * num_ticks + 1,), patches.dtype)
    grid = grid.at[flat.reshape(-1)].add(
        jnp.where(inb, patches, 0.0).reshape(-1), mode="drop")
    return grid[:-1].reshape(num_wires, num_ticks)
