"""Pallas TPU kernel: owner-computes tile-binned scatter-add.

TPU adaptation of ``Kokkos::atomic_add`` (paper §5, Fig. 5). TPUs have no
usable device atomics, so the scatter is inverted into a gather:

  * the output grid is cut into (TW, TT) VMEM tiles;
  * depos are pre-binned (ops.py) into per-tile lists — a depo appears in the
    list of every tile its patch overlaps (≤4 tiles when tile ≥ patch);
  * the kernel grid is (n_tiles, K): tile i accumulates its k-th depo's
    patch into a VMEM-resident accumulator. The patch block is fetched by a
    *scalar-prefetch-driven* BlockSpec index_map (the depo id list lives in
    SMEM), so each grid step DMAs exactly one patch into VMEM.

The accumulation is bitwise deterministic (fixed order per tile), unlike
atomics — a correctness upgrade over the paper's approach, for free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scatter_kernel(ids_ref, w0_ref, t0_ref, patch_ref, out_ref, *,
                    k_max: int, tw: int, tt: int, pw_pad: int, pt_pad: int,
                    tiles_t: int):
    """Grid step (i, k): accumulate depo ids[i*K+k]'s patch into tile i.

    ids/w0/t0 are scalar-prefetch refs (SMEM): ids (n_tiles*K,), w0/t0 (N,).
    patch_ref: (1, PW, PT) VMEM block of the selected depo's patch.
    out_ref: (TW, TT) VMEM accumulator for tile i (revisited across k).
    """
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d = ids_ref[i * k_max + k]

    @pl.when(d >= 0)
    def _accum():
        tile_w0 = (i // tiles_t) * tw
        tile_t0 = (i % tiles_t) * tt
        off_w = w0_ref[jnp.maximum(d, 0)] - tile_w0   # may be negative
        off_t = t0_ref[jnp.maximum(d, 0)] - tile_t0
        # patches may arrive in a narrow dtype (cfg.patch_dtype="bfloat16"):
        # the DMA moves the narrow bits, the VMEM accumulation stays f32
        patch = patch_ref[0].astype(jnp.float32)      # (PW, PT)
        # place the patch into a zero-padded staging buffer at a dynamic
        # offset, then add the tile window — static shapes, dynamic offsets.
        buf = jnp.zeros((tw + 2 * pw_pad, tt + 2 * pt_pad), patch.dtype)
        buf = jax.lax.dynamic_update_slice(
            buf, patch, (off_w + pw_pad, off_t + pt_pad))
        out_ref[...] += jax.lax.dynamic_slice(
            buf, (pw_pad, pt_pad), (tw, tt))


def _scatter_kernel_compact(tiles_ref, ids_ref, w0_ref, t0_ref, patch_ref,
                            out_ref, *, k_max: int, tw: int, tt: int,
                            pw_pad: int, pt_pad: int, tiles_t: int):
    """Grid step (i, k): accumulate depo ids[i*K+k] into ACTIVE tile i.

    Identical to ``_scatter_kernel`` except the tile coordinate comes from
    the scalar-prefetched active-tile list (``tiles_ref[i]`` is a global tile
    id, -1 padded) and the output is one (1, TW, TT) block per active slot —
    kernel work scales with occupied tiles, not detector tiles.
    """
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    t_id = tiles_ref[i]
    d = ids_ref[i * k_max + k]

    @pl.when((t_id >= 0) & (d >= 0))
    def _accum():
        tile_w0 = (jnp.maximum(t_id, 0) // tiles_t) * tw
        tile_t0 = (jnp.maximum(t_id, 0) % tiles_t) * tt
        off_w = w0_ref[jnp.maximum(d, 0)] - tile_w0   # may be negative
        off_t = t0_ref[jnp.maximum(d, 0)] - tile_t0
        patch = patch_ref[0].astype(jnp.float32)      # (PW, PT)
        buf = jnp.zeros((tw + 2 * pw_pad, tt + 2 * pt_pad), jnp.float32)
        buf = jax.lax.dynamic_update_slice(
            buf, patch, (off_w + pw_pad, off_t + pt_pad))
        out_ref[0] += jax.lax.dynamic_slice(
            buf, (pw_pad, pt_pad), (tw, tt))


def scatter_add_pallas(patches, w0, t0, tile_ids, *, num_wires: int,
                       num_ticks: int, tw: int, tt: int, k_max: int,
                       interpret: bool = True):
    """Owner-computes scatter-add.

    patches  : (N, PW_pad, PT_pad) f32 (zero-padded beyond the true patch)
    w0, t0   : (N,) int32 patch origins
    tile_ids : (n_tiles * k_max,) int32 depo ids per tile, -1 padded
    Returns the (num_wires_padded, num_ticks_padded) grid (tile-aligned).
    """
    n, pw_pad, pt_pad = patches.shape
    tiles_w = (num_wires + tw - 1) // tw
    tiles_t = (num_ticks + tt - 1) // tt
    n_tiles = tiles_w * tiles_t
    assert tw >= pw_pad and tt >= pt_pad, "tile must cover a padded patch"

    kernel = functools.partial(
        _scatter_kernel, k_max=k_max, tw=tw, tt=tt, pw_pad=pw_pad,
        pt_pad=pt_pad, tiles_t=tiles_t)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_tiles, k_max),
        in_specs=[
            pl.BlockSpec(
                (1, pw_pad, pt_pad),
                # fetch the patch of the depo this (tile, k) step handles
                lambda i, k, ids, w0s, t0s: (
                    jnp.maximum(ids[i * k_max + k], 0), 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (tw, tt), lambda i, k, ids, w0s, t0s: (i // tiles_t, i % tiles_t)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((tiles_w * tw, tiles_t * tt),
                                       jnp.float32),
        interpret=interpret,
    )(tile_ids, w0, t0, patches)


def scatter_add_pallas_compact(patches, w0, t0, active_tiles, tile_ids, *,
                               num_wires: int, num_ticks: int, tw: int,
                               tt: int, k_max: int, interpret: bool = True):
    """Active-tile owner-computes scatter-add.

    active_tiles : (n_active,) int32 global tile ids of occupied tiles, -1
                   padded to the occupancy bucket
    tile_ids     : (n_active * k_max,) int32 depo ids per active tile
    Returns (n_active, tw, tt) f32 tile blocks — the caller scatters them
    back into the full grid (see ``fused_sim.kernel.scatter_tiles_to_grid``).
    """
    n, pw_pad, pt_pad = patches.shape
    tiles_t = (num_ticks + tt - 1) // tt
    n_active = active_tiles.shape[0]
    assert tw >= pw_pad and tt >= pt_pad, "tile must cover a padded patch"

    kernel = functools.partial(
        _scatter_kernel_compact, k_max=k_max, tw=tw, tt=tt, pw_pad=pw_pad,
        pt_pad=pt_pad, tiles_t=tiles_t)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n_active, k_max),
        in_specs=[
            pl.BlockSpec(
                (1, pw_pad, pt_pad),
                lambda i, k, tiles, ids, w0s, t0s: (
                    jnp.maximum(ids[i * k_max + k], 0), 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, tw, tt),
                               lambda i, k, tiles, ids, w0s, t0s: (i, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_active, tw, tt), jnp.float32),
        interpret=interpret,
    )(active_tiles, tile_ids, w0, t0, patches)
