"""Binning preprocessor + jit'd wrappers for the owner-computes scatter kernel.

Two launch layouts:

  dense   : the Pallas grid covers every (tile, k) pair — simple, but work
            scales with *detector* area even when track-like depos leave most
            readout tiles empty.
  compact : depos are binned, empty tiles dropped, and the grid runs over the
            compacted (n_active, k_max) list with the global tile coordinate
            scalar-prefetched. Occupancy is measured on the host when inputs
            are concrete (bucketed to a power of two so retrace count stays
            logarithmic); under a trace it falls back to the static bound
            min(n_tiles, next_pow2(4N)) — each depo's patch overlaps at most
            4 tiles, so the bound is exact for sparse events and degrades to
            the dense layout only when the detector is saturated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.scatter_add.kernel import (scatter_add_pallas,
                                              scatter_add_pallas_compact)


def next_pow2(n: int, lo: int = 8) -> int:
    """Smallest power of two >= max(n, lo) — the retrace-bounding bucket."""
    n = max(int(n), lo)
    return 1 << (n - 1).bit_length()


def _candidate_tiles(w0, t0, pw_pad: int, pt_pad: int, tiles_t: int,
                     tw: int, tt: int, n_tiles: int):
    """Per-depo candidate tile ids (N, 4) + first-occurrence mask (N, 4).

    A padded patch at (w0, t0) spans [w0, w0+pw_pad) x [t0, t0+pt_pad) and
    overlaps at most 4 tiles when tile >= padded patch: the tiles containing
    its 4 corners. Corners sharing a tile are deduped via ``first``.
    """
    n = w0.shape[0]
    tiles_w = n_tiles // tiles_t
    cw0 = w0 // tw
    ct0 = t0 // tt
    # clamp the far corner to the last tile row/col: a PADDED patch may spill
    # past the tiled extent even though its in-grid pixels do not, and an
    # unclamped tick overflow would alias tile (w, tiles_t) onto the valid
    # tile (w+1, 0) — burning a k_max slot there (worst case evicting a
    # genuine depo) and falsely marking it active for the compact layout
    cw1 = jnp.minimum((w0 + pw_pad - 1) // tw, tiles_w - 1)
    ct1 = jnp.minimum((t0 + pt_pad - 1) // tt, tiles_t - 1)
    cand_w = jnp.stack([cw0, cw0, cw1, cw1], 1)          # (N, 4)
    cand_t = jnp.stack([ct0, ct1, ct0, ct1], 1)
    tile = cand_w * tiles_t + cand_t                     # (N, 4)
    first = jnp.ones_like(tile, dtype=bool)
    for a in range(1, 4):
        dup = jnp.zeros((n,), bool)
        for b in range(a):
            dup = dup | (tile[:, a] == tile[:, b])
        first = first.at[:, a].set(~dup)
    return tile, first


def _sorted_tile_runs(w0, t0, pw_pad: int, pt_pad: int, num_wires: int,
                      num_ticks: int, tw: int, tt: int):
    """Sort (tile, depo) pairs by tile and annotate the equal-tile runs.

    Returns (tile_s, depo_s, is_first, rank, seg_id, n_tiles): entries sorted
    by tile id (invalid entries pushed past ``n_tiles``), each entry's rank
    within its run, and the 0-based run index ``seg_id`` (valid runs first,
    since the sort is ascending).
    """
    n = w0.shape[0]
    tiles_w = (num_wires + tw - 1) // tw
    tiles_t = (num_ticks + tt - 1) // tt
    n_tiles = tiles_w * tiles_t

    tile, first = _candidate_tiles(w0, t0, pw_pad, pt_pad, tiles_t, tw, tt,
                                   n_tiles)
    depo_id = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None],
                               (n, 4))
    tile_flat = jnp.where(first, tile, n_tiles).reshape(-1)  # invalid -> n_tiles
    depo_flat = depo_id.reshape(-1)
    tile_s, depo_s = jax.lax.sort_key_val(tile_flat, depo_flat)
    # rank within equal-tile run = position - first position of the run
    idx = jnp.arange(tile_s.shape[0], dtype=jnp.int32)
    is_first = jnp.concatenate([jnp.array([True]),
                                tile_s[1:] != tile_s[:-1]])
    run_start = jnp.where(is_first, idx, 0)
    run_start = jax.lax.associative_scan(jnp.maximum, run_start)
    rank = idx - run_start
    seg_id = jnp.cumsum(is_first.astype(jnp.int32)) - 1
    return tile_s, depo_s, is_first, rank, seg_id, n_tiles


def bin_depos_to_tiles(w0, t0, pw_pad: int, pt_pad: int, num_wires: int,
                       num_ticks: int, tw: int, tt: int, k_max: int):
    """Build per-tile depo id lists (n_tiles*k_max,), -1 padded.

    A padded patch at (w0, t0) spans [w0, w0+pw_pad) x [t0, t0+pt_pad) and can
    overlap at most 4 tiles when tile >= padded patch. Each depo is appended
    to every overlapping tile's list. Overflow beyond k_max is dropped
    (choose k_max generously; tests assert no drops).
    """
    tile_s, depo_s, _, rank, _, n_tiles = _sorted_tile_runs(
        w0, t0, pw_pad, pt_pad, num_wires, num_ticks, tw, tt)
    valid = (tile_s < n_tiles) & (rank < k_max)
    slot = jnp.where(valid, tile_s * k_max + rank, n_tiles * k_max)
    ids = jnp.full((n_tiles * k_max + 1,), -1, jnp.int32)
    ids = ids.at[slot].set(jnp.where(valid, depo_s, -1), mode="drop")
    return ids[:-1], n_tiles


def bin_depos_to_tiles_compact(w0, t0, pw_pad: int, pt_pad: int,
                               num_wires: int, num_ticks: int, tw: int,
                               tt: int, k_max: int, n_cap: int):
    """Compacted binning: active tile list + per-active-tile depo lists.

    Returns (active_tiles, ids): active_tiles (n_cap,) int32 global tile ids
    (-1 padded), ids (n_cap * k_max,) int32 depo ids (-1 padded). ``n_cap``
    must be >= the true number of occupied tiles (min(n_tiles, 4*N) always
    is); overflowing tiles would be silently dropped.
    """
    tile_s, depo_s, is_first, rank, seg_id, n_tiles = _sorted_tile_runs(
        w0, t0, pw_pad, pt_pad, num_wires, num_ticks, tw, tt)
    valid = (tile_s < n_tiles) & (rank < k_max) & (seg_id < n_cap)
    slot = jnp.where(valid, seg_id * k_max + rank, n_cap * k_max)
    ids = jnp.full((n_cap * k_max + 1,), -1, jnp.int32)
    ids = ids.at[slot].set(jnp.where(valid, depo_s, -1), mode="drop")

    head = is_first & (tile_s < n_tiles) & (seg_id < n_cap)
    tiles = jnp.full((n_cap + 1,), -1, jnp.int32)
    tiles = tiles.at[jnp.where(head, seg_id, n_cap)].set(
        jnp.where(head, tile_s, -1), mode="drop")
    return tiles[:n_cap], ids[:-1]


@functools.partial(jax.jit, static_argnames=("pw_pad", "pt_pad", "num_wires",
                                             "num_ticks", "tw", "tt"))
def count_active_tiles(w0, t0, *, pw_pad: int, pt_pad: int, num_wires: int,
                       num_ticks: int, tw: int, tt: int):
    """Number of readout tiles touched by at least one depo patch (0-d int)."""
    tile_s, _, is_first, _, _, n_tiles = _sorted_tile_runs(
        w0, t0, pw_pad, pt_pad, num_wires, num_ticks, tw, tt)
    return jnp.sum(is_first & (tile_s < n_tiles)).astype(jnp.int32)


def active_tile_cap(w0, pw_pad: int, pt_pad: int, num_wires: int,
                    num_ticks: int, tw: int, tt: int, t0=None) -> int:
    """Static-or-measured occupancy bucket for the compact launch layout.

    With concrete inputs (eager call): count the truly occupied tiles on the
    host and round up to a power of two — retraces are bounded at
    log2(n_tiles) distinct caps. Under a trace (inside a jit'd pipeline) the
    count is unavailable, so fall back to the static bound
    min(n_tiles, next_pow2(4N)).

    Known trade-off: the eager path sorts the 4N candidate entries twice
    (once here for the count, once inside the cap-shaped jit for the actual
    binning) plus one host sync. Reusing the sorted runs would mean passing
    them through the jit boundary as operands; at current scales the kernel
    dominates and the simpler API wins.
    """
    n = w0.shape[0]
    tiles_w = (num_wires + tw - 1) // tw
    tiles_t = (num_ticks + tt - 1) // tt
    n_tiles = tiles_w * tiles_t
    if isinstance(w0, jax.core.Tracer) or t0 is None or isinstance(
            t0, jax.core.Tracer):
        return min(n_tiles, next_pow2(4 * n))
    n_act = int(count_active_tiles(
        w0, t0, pw_pad=pw_pad, pt_pad=pt_pad, num_wires=num_wires,
        num_ticks=num_ticks, tw=tw, tt=tt))
    return min(n_tiles, next_pow2(n_act))


def default_k_max(n: int, num_wires: int, num_ticks: int, tw: int,
                  tt: int) -> int:
    """Heuristic per-tile list length: expected uniform occupancy x8 safety,
    bucketed to a power of two so the jit cache stays small. Shared by the
    dense/compact scatter kernels and the fused rasterize+scatter wrappers,
    so every kernel family buckets identically."""
    tiles = ((num_wires + tw - 1) // tw) * ((num_ticks + tt - 1) // tt)
    return next_pow2(int(4 * n / tiles * 8))


@functools.partial(jax.jit, static_argnames=("num_wires", "num_ticks", "tw",
                                             "tt", "k_max", "interpret"))
def scatter_add_tiles(patches, w0, t0, *, num_wires: int, num_ticks: int,
                      tw: int = 64, tt: int = 256, k_max: int = 0,
                      interpret: bool | None = None):
    """Full owner-computes scatter-add: bin then accumulate (dense layout).

    ``interpret=None`` auto-selects by backend (compiled on TPU, interpreter
    elsewhere). Returns (num_wires, num_ticks) f32 grid.
    """
    from repro.kernels import default_interpret

    interpret = default_interpret() if interpret is None else interpret
    n, pw_pad, pt_pad = patches.shape
    tw = max(tw, pw_pad)
    tt = max(tt, pt_pad)
    if k_max == 0:
        k_max = default_k_max(n, num_wires, num_ticks, tw, tt)
    ids, _ = bin_depos_to_tiles(w0, t0, pw_pad, pt_pad, num_wires, num_ticks,
                                tw, tt, k_max)
    grid = scatter_add_pallas(
        patches, w0.astype(jnp.int32), t0.astype(jnp.int32), ids,
        num_wires=num_wires, num_ticks=num_ticks, tw=tw, tt=tt, k_max=k_max,
        interpret=interpret)
    return grid[:num_wires, :num_ticks]


@functools.partial(jax.jit, static_argnames=("num_wires", "num_ticks", "tw",
                                             "tt", "k_max", "n_cap",
                                             "interpret"))
def _scatter_add_tiles_compact_jit(patches, w0, t0, *, num_wires: int,
                                   num_ticks: int, tw: int, tt: int,
                                   k_max: int, n_cap: int, interpret: bool):
    from repro.kernels.fused_sim.kernel import scatter_tiles_to_grid

    n, pw_pad, pt_pad = patches.shape
    tiles_w = (num_wires + tw - 1) // tw
    tiles_t = (num_ticks + tt - 1) // tt
    active, ids = bin_depos_to_tiles_compact(
        w0, t0, pw_pad, pt_pad, num_wires, num_ticks, tw, tt, k_max, n_cap)
    blocks = scatter_add_pallas_compact(
        patches, w0.astype(jnp.int32), t0.astype(jnp.int32), active, ids,
        num_wires=num_wires, num_ticks=num_ticks, tw=tw, tt=tt, k_max=k_max,
        interpret=interpret)
    grid = scatter_tiles_to_grid(blocks, active, tiles_w, tiles_t, tw, tt)
    return grid[:num_wires, :num_ticks]


def scatter_add_tiles_compact(patches, w0, t0, *, num_wires: int,
                              num_ticks: int, tw: int = 64, tt: int = 256,
                              k_max: int = 0, n_active: int | None = None,
                              interpret: bool | None = None):
    """Active-tile owner-computes scatter-add (compact layout).

    Kernel work is (n_active_bucket x k_max) instead of (n_tiles x k_max):
    proportional to occupied readout area. ``n_active`` overrides the
    occupancy measurement (it is bucketed, and must be >= the true count).
    """
    from repro.kernels import default_interpret

    interpret = default_interpret() if interpret is None else interpret
    n, pw_pad, pt_pad = patches.shape
    tw = max(tw, pw_pad)
    tt = max(tt, pt_pad)
    if k_max == 0:
        k_max = default_k_max(n, num_wires, num_ticks, tw, tt)
    tiles_w = (num_wires + tw - 1) // tw
    tiles_t = (num_ticks + tt - 1) // tt
    if n_active is not None:
        n_cap = min(tiles_w * tiles_t, next_pow2(n_active))
    else:
        n_cap = active_tile_cap(w0, pw_pad, pt_pad, num_wires, num_ticks,
                                tw, tt, t0=t0)
    return _scatter_add_tiles_compact_jit(
        patches, w0, t0, num_wires=num_wires, num_ticks=num_ticks, tw=tw,
        tt=tt, k_max=k_max, n_cap=n_cap, interpret=interpret)
