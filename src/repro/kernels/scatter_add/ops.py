"""Binning preprocessor + jit'd wrapper for the owner-computes scatter kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.scatter_add.kernel import scatter_add_pallas


def bin_depos_to_tiles(w0, t0, pw_pad: int, pt_pad: int, num_wires: int,
                       num_ticks: int, tw: int, tt: int, k_max: int):
    """Build per-tile depo id lists (n_tiles*k_max,), -1 padded.

    A padded patch at (w0, t0) spans [w0, w0+pw_pad) x [t0, t0+pt_pad) and can
    overlap at most 4 tiles when tile >= padded patch. Each depo is appended
    to every overlapping tile's list. Overflow beyond k_max is dropped
    (choose k_max generously; tests assert no drops).
    """
    n = w0.shape[0]
    tiles_w = (num_wires + tw - 1) // tw
    tiles_t = (num_ticks + tt - 1) // tt
    n_tiles = tiles_w * tiles_t

    # candidate tiles: the tiles containing the 4 patch corners
    cw0 = w0 // tw
    cw1 = (w0 + pw_pad - 1) // tw
    ct0 = t0 // tt
    ct1 = (t0 + pt_pad - 1) // tt
    cand_w = jnp.stack([cw0, cw0, cw1, cw1], 1)          # (N, 4)
    cand_t = jnp.stack([ct0, ct1, ct0, ct1], 1)
    tile = cand_w * tiles_t + cand_t                     # (N, 4)
    # dedup within the 4 candidates (corners may share a tile)
    first = jnp.ones_like(tile, dtype=bool)
    for a in range(1, 4):
        dup = jnp.zeros((n,), bool)
        for b in range(a):
            dup = dup | (tile[:, a] == tile[:, b])
        first = first.at[:, a].set(~dup)
    depo_id = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, 4))

    tile_flat = jnp.where(first, tile, n_tiles).reshape(-1)   # invalid -> n_tiles
    depo_flat = depo_id.reshape(-1)
    order = jnp.argsort(tile_flat, stable=True)
    tile_s = tile_flat[order]
    depo_s = depo_flat[order]
    # rank within equal-tile run = position - first position of the run
    idx = jnp.arange(tile_s.shape[0], dtype=jnp.int32)
    is_first = jnp.concatenate([jnp.array([True]), tile_s[1:] != tile_s[:-1]])
    run_start = jnp.where(is_first, idx, 0)
    run_start = jax.lax.associative_scan(jnp.maximum, run_start)
    rank = idx - run_start
    valid = (tile_s < n_tiles) & (rank < k_max)
    slot = jnp.where(valid, tile_s * k_max + rank, n_tiles * k_max)
    ids = jnp.full((n_tiles * k_max + 1,), -1, jnp.int32)
    ids = ids.at[slot].set(jnp.where(valid, depo_s, -1), mode="drop")
    return ids[:-1], n_tiles


@functools.partial(jax.jit, static_argnames=("num_wires", "num_ticks", "tw",
                                             "tt", "k_max", "interpret"))
def scatter_add_tiles(patches, w0, t0, *, num_wires: int, num_ticks: int,
                      tw: int = 64, tt: int = 256, k_max: int = 0,
                      interpret: bool | None = None):
    """Full owner-computes scatter-add: bin then accumulate.

    ``interpret=None`` auto-selects by backend (compiled on TPU, interpreter
    elsewhere). Returns (num_wires, num_ticks) f32 grid.
    """
    from repro.kernels import default_interpret

    interpret = default_interpret() if interpret is None else interpret
    n, pw_pad, pt_pad = patches.shape
    tw = max(tw, pw_pad)
    tt = max(tt, pt_pad)
    if k_max == 0:
        # expected depos/tile if uniform, x8 safety, at least 8
        tiles = ((num_wires + tw - 1) // tw) * ((num_ticks + tt - 1) // tt)
        k_max = max(8, int(4 * n / tiles * 8))
    ids, _ = bin_depos_to_tiles(w0, t0, pw_pad, pt_pad, num_wires, num_ticks,
                                tw, tt, k_max)
    grid = scatter_add_pallas(
        patches, w0.astype(jnp.int32), t0.astype(jnp.int32), ids,
        num_wires=num_wires, num_ticks=num_ticks, tw=tw, tt=tt, k_max=k_max,
        interpret=interpret)
    return grid[:num_wires, :num_ticks]
