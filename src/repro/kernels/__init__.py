# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def default_interpret(backend: str | None = None) -> bool:
    """Pallas ``interpret`` default for the current (or given) backend.

    Compiled with Mosaic on TPU; the portable interpreter everywhere else —
    the registry's strategy fns use this so a Pallas candidate is runnable on
    any backend without per-call-site flags.
    """
    import jax

    return (backend or jax.default_backend()) != "tpu"
