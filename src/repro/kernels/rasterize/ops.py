"""jit'd public wrapper for the rasterize kernel: DepoSet -> padded patches."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.config import LArTPCConfig
from repro.core.depo import DepoSet, depo_patch_origin
from repro.kernels.rasterize.kernel import rasterize_pallas


def _pad_depos(depos: DepoSet, block: int):
    n = depos.n
    n_pad = (n + block - 1) // block * block
    if n_pad == n:
        return depos, n
    pad = n_pad - n

    def padf(x, fill=0.0):
        return jnp.pad(x, (0, pad), constant_values=fill)

    return DepoSet(
        wire=padf(depos.wire), tick=padf(depos.tick),
        sigma_w=padf(depos.sigma_w, 1.0), sigma_t=padf(depos.sigma_t, 1.0),
        charge=padf(depos.charge),
    ), n


@functools.partial(jax.jit, static_argnames=("cfg", "depo_block", "fluctuate",
                                             "interpret"))
def rasterize_depos(key: jax.Array, depos: DepoSet, cfg: LArTPCConfig,
                    depo_block: int = 256, fluctuate: bool = True,
                    interpret: bool = True):
    """Rasterize (+fluctuate) every depo with the Pallas kernel.

    Returns (patches (N, PW_pad, PT_pad), w0, t0) — N is the original count.
    """
    padded, n = _pad_depos(depos, depo_block)
    w0, t0 = depo_patch_origin(padded, cfg)
    pw_pad = (cfg.patch_wires + 7) // 8 * 8
    pt_pad = cfg.pad_ticks
    if fluctuate:
        k1, k2 = jax.random.split(key)
        shape = (padded.n, pw_pad, pt_pad)
        u1 = jax.random.uniform(k1, shape, jnp.float32)
        u2 = jax.random.uniform(k2, shape, jnp.float32)
    else:
        u1 = u2 = jnp.zeros((padded.n, pw_pad, pt_pad), jnp.float32)
    patches = rasterize_pallas(
        padded.wire, padded.tick, padded.sigma_w, padded.sigma_t,
        padded.charge, w0, t0, u1, u2,
        pw=cfg.patch_wires, pt=cfg.patch_ticks, pw_pad=pw_pad, pt_pad=pt_pad,
        depo_block=depo_block, fluctuate=fluctuate, interpret=interpret)
    return patches[:n], w0[:n], t0[:n]
