"""Pure-jnp oracle for the rasterize Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_SQRT2 = 1.4142135623730951


def rasterize_ref(wire, tick, sigma_w, sigma_t, charge, w0, t0, u1, u2, *,
                  pw: int, pt: int, pw_pad: int = 0, pt_pad: int = 128,
                  fluctuate: bool = True):
    """Reference implementation, bit-matching the kernel's math.

    Shapes mirror ``rasterize_pallas``; returns (N, PW_pad, PT_pad) f32.
    """
    n = wire.shape[0]
    pw_pad = pw_pad or ((pw + 7) // 8 * 8)
    w0f = w0.astype(jnp.float32)[:, None]
    t0f = t0.astype(jnp.float32)[:, None]

    iw = jnp.arange(pw_pad, dtype=jnp.float32)[None, :]
    lo_w = jax.lax.erf((w0f + iw - wire[:, None]) / (sigma_w[:, None] * _SQRT2))
    hi_w = jax.lax.erf((w0f + iw + 1.0 - wire[:, None]) / (sigma_w[:, None] * _SQRT2))
    ww = jnp.where(iw < pw, jnp.maximum(0.5 * (hi_w - lo_w), 0.0), 0.0)

    it = jnp.arange(pt_pad, dtype=jnp.float32)[None, :]
    lo_t = jax.lax.erf((t0f + it - tick[:, None]) / (sigma_t[:, None] * _SQRT2))
    hi_t = jax.lax.erf((t0f + it + 1.0 - tick[:, None]) / (sigma_t[:, None] * _SQRT2))
    wt = jnp.where(it < pt, jnp.maximum(0.5 * (hi_t - lo_t), 0.0), 0.0)

    q = charge[:, None, None]
    patch = q * ww[:, :, None] * wt[:, None, :]

    if fluctuate:
        u1c = jnp.maximum(u1, 1e-12)
        normal = jnp.sqrt(-2.0 * jnp.log(u1c)) * jnp.cos(2.0 * jnp.pi * u2)
        p = jnp.clip(patch / jnp.maximum(q, 1.0), 0.0, 1.0)
        var = jnp.maximum(patch * (1.0 - p), 0.0)
        patch = jnp.maximum(patch + jnp.sqrt(var) * normal, 0.0)
    return patch
