"""Pallas TPU kernel: batched depo rasterization + fused Box–Muller fluctuation.

TPU adaptation of the paper's rasterization CUDA kernel (§3):

* GPU version: one thread block per depo, 20×20 threads, one launch per depo
  (concurrency < 1000 — the paper's identified flaw).
* TPU version: ONE ``pallas_call`` for all N depos. Grid = N / DEPO_BLOCK;
  each grid step rasterizes DEPO_BLOCK depos into a VMEM-resident
  (DEPO_BLOCK, PW, PT) patch block. The per-axis erf weights are computed as
  (B, PW) / (B, PT) VPU ops and combined by a broadcasted outer product —
  O(pw+pt) transcendentals per depo, vectorized across the depo block.
* Fluctuation is FUSED into the same kernel (the paper's separate
  "Fluctuation" step): Box–Muller (paper §4.3.1) over a pre-computed uniform
  pool (the paper's "random number pool"), applied to the binomial
  normal-approximation with no extra HBM round-trip.

Patch dims are padded to TPU tiles: PT (ticks, lane axis) -> 128, PW
(wires, sublane axis) -> multiple of 8. Padding pixels are masked to zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SQRT2 = 1.4142135623730951


def _rasterize_kernel(wire_ref, tick_ref, sw_ref, st_ref, q_ref,
                      w0_ref, t0_ref, u1_ref, u2_ref, out_ref,
                      *, pw: int, pt: int, fluctuate: bool):
    """One grid step: rasterize a block of B depos.

    Refs:
      wire/tick/sw/st/q/w0/t0 : (B, 1) f32 depo parameters (VMEM)
      u1, u2                  : (B, PW, PT) f32 uniforms for Box–Muller
      out                     : (B, PW, PT) f32 patches
    """
    b, pw_pad, pt_pad = out_ref.shape

    wire = wire_ref[:, 0][:, None]            # (B, 1)
    tick = tick_ref[:, 0][:, None]
    sw = sw_ref[:, 0][:, None]
    st = st_ref[:, 0][:, None]
    q = q_ref[:, 0][:, None, None]            # (B, 1, 1)
    w0 = w0_ref[:, 0][:, None]
    t0 = t0_ref[:, 0][:, None]

    # per-axis bin-integrated Gaussian weights (VPU transcendentals)
    iw = jax.lax.broadcasted_iota(jnp.float32, (b, pw_pad), 1)
    lo_w = jax.lax.erf((w0 + iw - wire) / (sw * _SQRT2))
    hi_w = jax.lax.erf((w0 + iw + 1.0 - wire) / (sw * _SQRT2))
    ww = jnp.maximum(0.5 * (hi_w - lo_w), 0.0)   # (B, PW); clamp f32 tails
    ww = jnp.where(iw < pw, ww, 0.0)          # mask wire padding

    it = jax.lax.broadcasted_iota(jnp.float32, (b, pt_pad), 1)
    lo_t = jax.lax.erf((t0 + it - tick) / (st * _SQRT2))
    hi_t = jax.lax.erf((t0 + it + 1.0 - tick) / (st * _SQRT2))
    wt = jnp.maximum(0.5 * (hi_t - lo_t), 0.0)   # (B, PT)
    wt = jnp.where(it < pt, wt, 0.0)          # mask tick padding

    patch = q * ww[:, :, None] * wt[:, None, :]   # (B, PW, PT) outer product

    if fluctuate:
        # binomial -> normal approximation, noise via Box–Muller of the pool
        u1 = jnp.maximum(u1_ref[...], 1e-12)
        u2 = u2_ref[...]
        normal = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
        p = jnp.clip(patch / jnp.maximum(q, 1.0), 0.0, 1.0)
        var = jnp.maximum(patch * (1.0 - p), 0.0)
        patch = jnp.maximum(patch + jnp.sqrt(var) * normal, 0.0)

    out_ref[...] = patch


def rasterize_pallas(wire, tick, sigma_w, sigma_t, charge, w0, t0, u1, u2, *,
                     pw: int, pt: int, pw_pad: int = 0, pt_pad: int = 128,
                     depo_block: int = 256, fluctuate: bool = True,
                     interpret: bool = True):
    """Rasterize all depos in one pallas_call.

    Args: depo params (N,) f32 (w0/t0 pre-cast to f32); u1/u2 (N, PW, PT)
    uniform pools. Returns (N, PW_pad, PT_pad) f32 patches (padding zeroed).
    """
    n = wire.shape[0]
    pw_pad = pw_pad or ((pw + 7) // 8 * 8)
    assert pt <= pt_pad and pw <= pw_pad
    assert n % depo_block == 0, f"pad depo count {n} to a multiple of {depo_block}"
    grid = (n // depo_block,)

    def col(x):
        return x.astype(jnp.float32).reshape(n, 1)

    scalar_spec = pl.BlockSpec((depo_block, 1), lambda i: (i, 0))
    pool_spec = pl.BlockSpec((depo_block, pw_pad, pt_pad), lambda i: (i, 0, 0))

    kernel = functools.partial(_rasterize_kernel, pw=pw, pt=pt,
                               fluctuate=fluctuate)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[scalar_spec] * 7 + [pool_spec, pool_spec],
        out_specs=pl.BlockSpec((depo_block, pw_pad, pt_pad),
                               lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, pw_pad, pt_pad), jnp.float32),
        interpret=interpret,
    )(col(wire), col(tick), col(sigma_w), col(sigma_t), col(charge),
      col(w0), col(t0), u1, u2)
