"""Pallas TPU kernel: FUSED rasterize + scatter-add (beyond-paper Fig. 4++).

The paper's Fig. 4 keeps data on-device between stages; this kernel goes one
step further: the (N, 24, 128) patch array never exists in HBM at all. Each
output tile evaluates its depos' bin-integrated Gaussians directly at tile
coordinates and accumulates in VMEM — at MicroBooNE scale (100k depos) this
removes ~1.2 GB of HBM write+read traffic, trading it for ~2x more VPU
transcendentals (erf over tile extents instead of patch extents): a good
trade at 819 GB/s vs ~100+ Gexp/s.

Grid/binning layout matches ``kernels/scatter_add`` (owner-computes tiles,
scalar-prefetched per-tile depo lists).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SQRT2 = 1.4142135623730951


def _fused_kernel(ids_ref, wire_ref, tick_ref, sw_ref, st_ref, q_ref,
                  w0_ref, t0_ref, out_ref, *, k_max: int, tw: int, tt: int,
                  pw: int, pt: int, tiles_t: int):
    """Grid step (i, k): rasterize depo ids[i*K+k] straight into tile i."""
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d = ids_ref[i * k_max + k]

    @pl.when(d >= 0)
    def _accum():
        dd = jnp.maximum(d, 0)
        wire = wire_ref[dd]
        tick = tick_ref[dd]
        sw = sw_ref[dd]
        st = st_ref[dd]
        q = q_ref[dd]
        w0 = w0_ref[dd].astype(jnp.float32)   # patch origin (absolute)
        t0 = t0_ref[dd].astype(jnp.float32)
        tile_w0 = ((i // tiles_t) * tw).astype(jnp.float32)
        tile_t0 = ((i % tiles_t) * tt).astype(jnp.float32)

        # absolute wire/tick coordinates of this tile's rows/cols
        aw = tile_w0 + jax.lax.broadcasted_iota(jnp.float32, (tw, 1), 0)
        at = tile_t0 + jax.lax.broadcasted_iota(jnp.float32, (1, tt), 1)

        lo_w = jax.lax.erf((aw - wire) / (sw * _SQRT2))
        hi_w = jax.lax.erf((aw + 1.0 - wire) / (sw * _SQRT2))
        ww = jnp.maximum(0.5 * (hi_w - lo_w), 0.0)        # (TW, 1)
        in_w = (aw >= w0) & (aw < w0 + pw)                # patch support
        ww = jnp.where(in_w, ww, 0.0)

        lo_t = jax.lax.erf((at - tick) / (st * _SQRT2))
        hi_t = jax.lax.erf((at + 1.0 - tick) / (st * _SQRT2))
        wt = jnp.maximum(0.5 * (hi_t - lo_t), 0.0)        # (1, TT)
        in_t = (at >= t0) & (at < t0 + pt)
        wt = jnp.where(in_t, wt, 0.0)

        out_ref[...] += q * ww * wt


def fused_rasterize_scatter(wire, tick, sigma_w, sigma_t, charge, w0, t0,
                            tile_ids, *, num_wires: int, num_ticks: int,
                            tw: int, tt: int, k_max: int, pw: int, pt: int,
                            interpret: bool = True):
    """Depos -> charge grid in ONE kernel (no patch array in HBM).

    Scalar-prefetch operands: tile_ids (n_tiles*k_max,) int32 (-1 padded),
    depo params (N,) f32 / int32.
    """
    tiles_w = (num_wires + tw - 1) // tw
    tiles_t = (num_ticks + tt - 1) // tt
    n_tiles = tiles_w * tiles_t

    kernel = functools.partial(_fused_kernel, k_max=k_max, tw=tw, tt=tt,
                               pw=pw, pt=pt, tiles_t=tiles_t)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=8,
        grid=(n_tiles, k_max),
        in_specs=[],
        out_specs=pl.BlockSpec(
            (tw, tt), lambda i, k, *refs: (i // tiles_t, i % tiles_t)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((tiles_w * tw, tiles_t * tt),
                                       jnp.float32),
        interpret=interpret,
    )(tile_ids, wire.astype(jnp.float32), tick.astype(jnp.float32),
      sigma_w.astype(jnp.float32), sigma_t.astype(jnp.float32),
      charge.astype(jnp.float32), w0.astype(jnp.int32), t0.astype(jnp.int32))
    return out[:num_wires, :num_ticks]
