"""Pallas TPU kernel: FUSED rasterize + fluctuate + scatter-add (Fig. 4++).

The paper's Fig. 4 keeps data on-device between stages; this kernel goes one
step further: the (N, 24, 128) patch array never exists in HBM at all. Each
output tile evaluates its depos' bin-integrated Gaussians directly at tile
coordinates and accumulates in VMEM — at MicroBooNE scale (100k depos) this
removes ~1.2 GB of HBM write+read traffic, trading it for ~2x more VPU
transcendentals (erf over tile extents instead of patch extents): a good
trade at 819 GB/s vs ~100+ Gexp/s.

Two additions over the original fused kernel:

  * in-kernel counter RNG — binomial-approximation charge fluctuation is
    applied to each (depo, tile) contribution *inside* the kernel, seeded per
    (depo, tile) from the sim key: ``pltpu.prng_seed``/``prng_random_bits``
    when Mosaic-compiled on TPU, and the portable counter hash from
    ``repro.core.fluctuate`` under the interpreter (which has no TPU PRNG
    lowering). This lifts the old ``fluctuate=False`` restriction: the fused
    strategy now competes in the physics-default configuration.
  * an active-tile variant (``fused_rasterize_scatter_compact``) whose grid
    runs over a *compacted* list of occupied tiles (scalar-prefetched tile
    coordinates) instead of the dense ``(n_tiles, k_max)`` product — kernel
    work scales with occupied readout area, not detector area. Track-like
    depo sets leave most tiles empty; see ``ops.py`` for the binning and the
    occupancy bucketing that bounds retraces.

Grid/binning layout matches ``kernels/scatter_add`` (owner-computes tiles,
scalar-prefetched per-tile depo lists).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fluctuate import box_muller, counter_normals, uniform_from_bits

_SQRT2 = 1.4142135623730951
#: stream-id mixing constants (distinct odd 32-bit constants so the
#: (depo, tile) -> stream map is injective enough for statistics)
_C_DEPO = 0x9E3779B9
_C_TILE = 0x7FEB352D


def _tile_normals(s0, s1, d, t_id, *, tw: int, tt: int, tpu_prng: bool):
    """(TW, TT) std normals for one (depo, tile) grid step.

    Seeded from the sim key (``s0``/``s1``, two int32 scalar-prefetch words)
    plus the (depo id, PLANE-LOCAL global tile id) pair, so the dense and
    compacted kernels — and each plane of the multi-plane kernels, which
    pass their plane's own seed words — draw identical streams and their
    fluctuated grids agree bit for bit.
    """
    if tpu_prng:
        # compiled TPU path: hardware PRNG, seeded per (depo, tile)
        pltpu.prng_seed(s0, s1, d, t_id)
        b1 = pltpu.bitcast(pltpu.prng_random_bits((tw, tt)), jnp.uint32)
        b2 = pltpu.bitcast(pltpu.prng_random_bits((tw, tt)), jnp.uint32)
        return box_muller(1.0 - uniform_from_bits(b1), uniform_from_bits(b2))
    # portable path (interpreter / any backend): stateless counter hash
    row = jax.lax.broadcasted_iota(jnp.uint32, (tw, tt), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (tw, tt), 1)
    pix = row * jnp.uint32(tt) + col
    stream = (d.astype(jnp.uint32) * jnp.uint32(_C_DEPO)
              ^ t_id.astype(jnp.uint32) * jnp.uint32(_C_TILE))
    return counter_normals(s0.astype(jnp.uint32), s1.astype(jnp.uint32),
                           stream, pix)


def _depo_tile_contrib(d, dp, t_id, wire_ref, tick_ref, sw_ref, st_ref, q_ref,
                       w0_ref, t0_ref, s0, s1, *, tw: int, tt: int,
                       pw: int, pt: int, tiles_t: int, fluctuate: bool,
                       tpu_prng: bool):
    """(TW, TT) charge contribution of depo ``d`` to global tile ``t_id``.

    Rasterizes the depo's bin-integrated Gaussian at the tile's absolute
    coordinates (masked to the patch support) and, when ``fluctuate``,
    applies the per-pixel binomial normal approximation with in-kernel
    randomness. Pixels outside the patch support have zero mean and zero
    variance, so they stay exactly 0.0 with or without fluctuation.

    ``d`` seeds the RNG stream (plane-LOCAL depo id); ``dp`` indexes the
    parameter refs — the multi-plane kernels flatten their (P, N) operands,
    so ``dp = d + plane * N`` there, while the single-plane kernels pass
    ``dp = d``. ``t_id`` is likewise the plane-local global tile id.
    """
    wire = wire_ref[dp]
    tick = tick_ref[dp]
    sw = sw_ref[dp]
    st = st_ref[dp]
    q = q_ref[dp]
    w0 = w0_ref[dp].astype(jnp.float32)  # patch origin (absolute)
    t0 = t0_ref[dp].astype(jnp.float32)
    tile_w0 = ((t_id // tiles_t) * tw).astype(jnp.float32)
    tile_t0 = ((t_id % tiles_t) * tt).astype(jnp.float32)

    # absolute wire/tick coordinates of this tile's rows/cols
    aw = tile_w0 + jax.lax.broadcasted_iota(jnp.float32, (tw, 1), 0)
    at = tile_t0 + jax.lax.broadcasted_iota(jnp.float32, (1, tt), 1)

    lo_w = jax.lax.erf((aw - wire) / (sw * _SQRT2))
    hi_w = jax.lax.erf((aw + 1.0 - wire) / (sw * _SQRT2))
    ww = jnp.maximum(0.5 * (hi_w - lo_w), 0.0)        # (TW, 1)
    in_w = (aw >= w0) & (aw < w0 + pw)                # patch support
    ww = jnp.where(in_w, ww, 0.0)

    lo_t = jax.lax.erf((at - tick) / (st * _SQRT2))
    hi_t = jax.lax.erf((at + 1.0 - tick) / (st * _SQRT2))
    wt = jnp.maximum(0.5 * (hi_t - lo_t), 0.0)        # (1, TT)
    in_t = (at >= t0) & (at < t0 + pt)
    wt = jnp.where(in_t, wt, 0.0)

    vals = q * ww * wt
    if fluctuate:
        # binomial normal approximation, matching core.fluctuate:
        # mean = vals, var = vals * (1 - vals / q), clamped at zero
        normals = _tile_normals(s0, s1, d, t_id, tw=tw, tt=tt,
                                tpu_prng=tpu_prng)
        qq = jnp.maximum(q, 1.0)
        p = jnp.clip(vals / qq, 0.0, 1.0)
        var = jnp.maximum(vals * (1.0 - p), 0.0)
        vals = jnp.maximum(vals + jnp.sqrt(var) * normals, 0.0)
    return vals


def _fused_kernel(ids_ref, wire_ref, tick_ref, sw_ref, st_ref, q_ref,
                  w0_ref, t0_ref, seed_ref, out_ref, *, k_max: int, tw: int,
                  tt: int, pw: int, pt: int, tiles_t: int, fluctuate: bool,
                  tpu_prng: bool):
    """Grid step (i, k): rasterize depo ids[i*K+k] straight into tile i."""
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    d = ids_ref[i * k_max + k]

    @pl.when(d >= 0)
    def _accum():
        dd = jnp.maximum(d, 0)
        out_ref[...] += _depo_tile_contrib(
            dd, dd, i, wire_ref, tick_ref, sw_ref, st_ref, q_ref,
            w0_ref, t0_ref, seed_ref[0], seed_ref[1], tw=tw, tt=tt, pw=pw,
            pt=pt, tiles_t=tiles_t, fluctuate=fluctuate, tpu_prng=tpu_prng)


def _fused_kernel_compact(tiles_ref, ids_ref, wire_ref, tick_ref, sw_ref,
                          st_ref, q_ref, w0_ref, t0_ref, seed_ref, out_ref, *,
                          k_max: int, tw: int, tt: int, pw: int, pt: int,
                          tiles_t: int, fluctuate: bool, tpu_prng: bool):
    """Grid step (i, k): rasterize depo ids[i*K+k] into ACTIVE tile i.

    ``tiles_ref[i]`` holds the global tile id of the i-th occupied tile
    (scalar-prefetched; -1 pads the bucketed active list). Inactive grid
    steps only zero their output block.
    """
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    t_id = tiles_ref[i]
    d = ids_ref[i * k_max + k]

    @pl.when((t_id >= 0) & (d >= 0))
    def _accum():
        dd = jnp.maximum(d, 0)
        out_ref[0] += _depo_tile_contrib(
            dd, dd, jnp.maximum(t_id, 0), wire_ref, tick_ref,
            sw_ref, st_ref, q_ref, w0_ref, t0_ref, seed_ref[0], seed_ref[1],
            tw=tw, tt=tt, pw=pw, pt=pt, tiles_t=tiles_t, fluctuate=fluctuate,
            tpu_prng=tpu_prng)


def _fused_kernel_multiplane(ids_ref, wire_ref, tick_ref, sw_ref, st_ref,
                             q_ref, w0_ref, t0_ref, seed_ref, out_ref, *,
                             k_max: int, tw: int, tt: int, pw: int, pt: int,
                             tiles_t: int, n_tiles: int, n_depos: int,
                             fluctuate: bool, tpu_prng: bool):
    """Grid step (i, k) over the PLANE-MAJOR flat tile axis i = p*T + t.

    Every depo's parameters are loaded once per overlapped tile across ALL
    planes of one launch: the params are the per-plane projections stacked
    (and flattened plane-major), the depo ids are each plane's binned lists
    concatenated, and the RNG seed words are the per-plane folded subkeys —
    so plane p's output block is bit-identical to the single-plane kernel
    run with ``fold_in(kf, p)``.
    """
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = i // n_tiles
    t_local = i - p * n_tiles
    d = ids_ref[i * k_max + k]

    @pl.when(d >= 0)
    def _accum():
        dd = jnp.maximum(d, 0)
        out_ref[...] += _depo_tile_contrib(
            dd, dd + p * n_depos, t_local, wire_ref, tick_ref, sw_ref,
            st_ref, q_ref, w0_ref, t0_ref, seed_ref[2 * p],
            seed_ref[2 * p + 1], tw=tw, tt=tt, pw=pw, pt=pt, tiles_t=tiles_t,
            fluctuate=fluctuate, tpu_prng=tpu_prng)


def _fused_kernel_multiplane_compact(tiles_ref, ids_ref, wire_ref, tick_ref,
                                     sw_ref, st_ref, q_ref, w0_ref, t0_ref,
                                     seed_ref, out_ref, *, k_max: int,
                                     tw: int, tt: int, pw: int, pt: int,
                                     tiles_t: int, n_cap: int, n_depos: int,
                                     fluctuate: bool, tpu_prng: bool):
    """Active-tile multi-plane kernel: i runs over the plane-major
    concatenation of each plane's compacted tile list (``n_cap`` slots per
    plane); ``tiles_ref[i]`` is the PLANE-LOCAL global tile id."""
    i = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = i // n_cap
    t_id = tiles_ref[i]
    d = ids_ref[i * k_max + k]

    @pl.when((t_id >= 0) & (d >= 0))
    def _accum():
        dd = jnp.maximum(d, 0)
        out_ref[0] += _depo_tile_contrib(
            dd, dd + p * n_depos, jnp.maximum(t_id, 0), wire_ref, tick_ref,
            sw_ref, st_ref, q_ref, w0_ref, t0_ref, seed_ref[2 * p],
            seed_ref[2 * p + 1], tw=tw, tt=tt, pw=pw, pt=pt, tiles_t=tiles_t,
            fluctuate=fluctuate, tpu_prng=tpu_prng)


def _seed_operand(seed):
    """(2,) int32 scalar-prefetch operand from raw PRNG key data (or None)."""
    if seed is None:
        return jnp.zeros((2,), jnp.int32)
    return jnp.asarray(seed).astype(jnp.uint32).reshape(-1)[:2].view(jnp.int32)


def _seed_operand_planes(seeds, num_planes: int):
    """(2P,) int32 scalar-prefetch operand from stacked (P, ...) key data."""
    if seeds is None:
        return jnp.zeros((2 * num_planes,), jnp.int32)
    seeds = jnp.asarray(seeds).astype(jnp.uint32).reshape(num_planes, -1)
    return seeds[:, :2].reshape(-1).view(jnp.int32)


def fused_rasterize_scatter(wire, tick, sigma_w, sigma_t, charge, w0, t0,
                            tile_ids, *, num_wires: int, num_ticks: int,
                            tw: int, tt: int, k_max: int, pw: int, pt: int,
                            interpret: bool = True, seed=None,
                            fluctuate: bool = False):
    """Depos -> charge grid in ONE kernel (no patch array in HBM).

    Scalar-prefetch operands: tile_ids (n_tiles*k_max,) int32 (-1 padded),
    depo params (N,) f32 / int32, seed (2,) int32 raw key data (only read
    when ``fluctuate``).
    """
    tiles_w = (num_wires + tw - 1) // tw
    tiles_t = (num_ticks + tt - 1) // tt
    n_tiles = tiles_w * tiles_t

    kernel = functools.partial(_fused_kernel, k_max=k_max, tw=tw, tt=tt,
                               pw=pw, pt=pt, tiles_t=tiles_t,
                               fluctuate=fluctuate, tpu_prng=not interpret)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=9,
        grid=(n_tiles, k_max),
        in_specs=[],
        out_specs=pl.BlockSpec(
            (tw, tt), lambda i, k, *refs: (i // tiles_t, i % tiles_t)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((tiles_w * tw, tiles_t * tt),
                                       jnp.float32),
        interpret=interpret,
    )(tile_ids, wire.astype(jnp.float32), tick.astype(jnp.float32),
      sigma_w.astype(jnp.float32), sigma_t.astype(jnp.float32),
      charge.astype(jnp.float32), w0.astype(jnp.int32), t0.astype(jnp.int32),
      _seed_operand(seed))
    return out[:num_wires, :num_ticks]


def fused_rasterize_scatter_compact(wire, tick, sigma_w, sigma_t, charge,
                                    w0, t0, active_tiles, tile_ids, *,
                                    num_wires: int, num_ticks: int, tw: int,
                                    tt: int, k_max: int, pw: int, pt: int,
                                    interpret: bool = True, seed=None,
                                    fluctuate: bool = False):
    """Active-tile fused kernel: grid (n_active, k_max), not (n_tiles, k_max).

    active_tiles : (n_active,) int32 global tile ids, -1 padded
    tile_ids     : (n_active * k_max,) int32 depo ids per active tile
    The kernel emits one (tw, tt) block per active slot; the blocks are then
    scattered back into the full grid (an O(occupied area) write).
    """
    tiles_w = (num_wires + tw - 1) // tw
    tiles_t = (num_ticks + tt - 1) // tt
    n_tiles = tiles_w * tiles_t
    n_active = active_tiles.shape[0]

    kernel = functools.partial(_fused_kernel_compact, k_max=k_max, tw=tw,
                               tt=tt, pw=pw, pt=pt, tiles_t=tiles_t,
                               fluctuate=fluctuate, tpu_prng=not interpret)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=10,
        grid=(n_active, k_max),
        in_specs=[],
        out_specs=pl.BlockSpec((1, tw, tt), lambda i, k, *refs: (i, 0, 0)),
    )
    blocks = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_active, tw, tt), jnp.float32),
        interpret=interpret,
    )(active_tiles, tile_ids, wire.astype(jnp.float32),
      tick.astype(jnp.float32), sigma_w.astype(jnp.float32),
      sigma_t.astype(jnp.float32), charge.astype(jnp.float32),
      w0.astype(jnp.int32), t0.astype(jnp.int32), _seed_operand(seed))
    return scatter_tiles_to_grid(blocks, active_tiles, tiles_w, tiles_t,
                                 tw, tt)[:num_wires, :num_ticks]


def fused_rasterize_scatter_multiplane(wire, tick, sigma_w, sigma_t, charge,
                                       w0, t0, tile_ids, *, num_planes: int,
                                       num_wires: int, num_ticks: int,
                                       tw: int, tt: int, k_max: int, pw: int,
                                       pt: int, interpret: bool = True,
                                       seeds=None, fluctuate: bool = False):
    """All P planes' charge grids in ONE kernel launch (dense tile layout).

    Depo params are the per-plane projections, shape (P, N) each (flattened
    plane-major for the scalar-prefetch refs); ``tile_ids`` is the
    concatenation of each plane's dense (n_tiles*k_max,) binned depo lists
    (plane-LOCAL depo ids); ``seeds`` is (P, 2) raw key data of the
    per-plane folded subkeys. Returns (P, num_wires, num_ticks) f32 —
    plane p bit-identical to ``fused_rasterize_scatter`` with plane p's
    params and seed.
    """
    n = wire.shape[-1]
    tiles_w = (num_wires + tw - 1) // tw
    tiles_t = (num_ticks + tt - 1) // tt
    n_tiles = tiles_w * tiles_t

    kernel = functools.partial(
        _fused_kernel_multiplane, k_max=k_max, tw=tw, tt=tt, pw=pw, pt=pt,
        tiles_t=tiles_t, n_tiles=n_tiles, n_depos=n, fluctuate=fluctuate,
        tpu_prng=not interpret)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=9,
        grid=(num_planes * n_tiles, k_max),
        in_specs=[],
        # i = p*n_tiles + t_local, so i // tiles_t = p*tiles_w + block row
        # and i % tiles_t = block col: the single-plane index map extends
        # unchanged to the plane-major stacked output
        out_specs=pl.BlockSpec(
            (tw, tt), lambda i, k, *refs: (i // tiles_t, i % tiles_t)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (num_planes * tiles_w * tw, tiles_t * tt), jnp.float32),
        interpret=interpret,
    )(tile_ids, wire.astype(jnp.float32).reshape(-1),
      tick.astype(jnp.float32).reshape(-1),
      sigma_w.astype(jnp.float32).reshape(-1),
      sigma_t.astype(jnp.float32).reshape(-1),
      charge.astype(jnp.float32).reshape(-1),
      w0.astype(jnp.int32).reshape(-1), t0.astype(jnp.int32).reshape(-1),
      _seed_operand_planes(seeds, num_planes))
    out = out.reshape(num_planes, tiles_w * tw, tiles_t * tt)
    return out[:, :num_wires, :num_ticks]


def fused_rasterize_scatter_multiplane_compact(
        wire, tick, sigma_w, sigma_t, charge, w0, t0, active_tiles, tile_ids,
        *, num_planes: int, num_wires: int, num_ticks: int, tw: int, tt: int,
        k_max: int, pw: int, pt: int, interpret: bool = True, seeds=None,
        fluctuate: bool = False):
    """Active-tile multi-plane fused kernel: grid (P*n_cap, k_max).

    active_tiles : (P*n_cap,) int32 plane-LOCAL global tile ids, -1 padded
                   (each plane's compacted list occupies n_cap slots)
    tile_ids     : (P*n_cap*k_max,) int32 plane-local depo ids
    Returns (P, num_wires, num_ticks) f32, bit-identical per plane to the
    dense multi-plane kernel (RNG streams key on plane-local tile ids,
    which compaction preserves).
    """
    n = wire.shape[-1]
    tiles_w = (num_wires + tw - 1) // tw
    tiles_t = (num_ticks + tt - 1) // tt
    n_tiles = tiles_w * tiles_t
    n_cap = active_tiles.shape[0] // num_planes

    kernel = functools.partial(
        _fused_kernel_multiplane_compact, k_max=k_max, tw=tw, tt=tt, pw=pw,
        pt=pt, tiles_t=tiles_t, n_cap=n_cap, n_depos=n, fluctuate=fluctuate,
        tpu_prng=not interpret)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=10,
        grid=(num_planes * n_cap, k_max),
        in_specs=[],
        out_specs=pl.BlockSpec((1, tw, tt), lambda i, k, *refs: (i, 0, 0)),
    )
    blocks = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_planes * n_cap, tw, tt),
                                       jnp.float32),
        interpret=interpret,
    )(active_tiles, tile_ids, wire.astype(jnp.float32).reshape(-1),
      tick.astype(jnp.float32).reshape(-1),
      sigma_w.astype(jnp.float32).reshape(-1),
      sigma_t.astype(jnp.float32).reshape(-1),
      charge.astype(jnp.float32).reshape(-1),
      w0.astype(jnp.int32).reshape(-1), t0.astype(jnp.int32).reshape(-1),
      _seed_operand_planes(seeds, num_planes))
    grids = scatter_tiles_to_grid_planes(blocks, active_tiles, num_planes,
                                         tiles_w, tiles_t, tw, tt)
    return grids[:, :num_wires, :num_ticks]


def scatter_tiles_to_grid(blocks, active_tiles, tiles_w: int, tiles_t: int,
                          tw: int, tt: int):
    """Place (n_active, tw, tt) tile blocks into the full padded grid.

    Padding slots (active_tiles == -1) are dropped; unoccupied tiles stay
    zero. The write is proportional to the occupied area.
    """
    n_tiles = tiles_w * tiles_t
    dest = jnp.where(active_tiles >= 0, active_tiles, n_tiles)
    full = jnp.zeros((n_tiles, tw, tt), blocks.dtype)
    full = full.at[dest].set(blocks, mode="drop")
    return full.reshape(tiles_w, tiles_t, tw, tt).swapaxes(1, 2).reshape(
        tiles_w * tw, tiles_t * tt)


def scatter_tiles_to_grid_planes(blocks, active_tiles, num_planes: int,
                                 tiles_w: int, tiles_t: int, tw: int,
                                 tt: int):
    """Place (P*n_cap, tw, tt) tile blocks into (P, W_pad, T_pad) grids.

    ``active_tiles`` holds plane-LOCAL tile ids in plane-major n_cap-slot
    runs; each plane's blocks scatter into its own grid (padding slots
    dropped, unoccupied tiles stay zero)."""
    n_tiles = tiles_w * tiles_t
    n_cap = active_tiles.shape[0] // num_planes
    offs = jnp.repeat(
        jnp.arange(num_planes, dtype=jnp.int32) * n_tiles, n_cap)
    dest = jnp.where(active_tiles >= 0, active_tiles + offs,
                     num_planes * n_tiles)
    full = jnp.zeros((num_planes * n_tiles, tw, tt), blocks.dtype)
    full = full.at[dest].set(blocks, mode="drop")
    return full.reshape(num_planes, tiles_w, tiles_t, tw, tt).swapaxes(
        2, 3).reshape(num_planes, tiles_w * tw, tiles_t * tt)
