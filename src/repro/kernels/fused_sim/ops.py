"""jit'd wrappers for the fused rasterize+fluctuate+scatter kernel.

``simulate_charge_grid``        — dense tile grid (one step per detector tile)
``simulate_charge_grid_compact``— active-tile grid (one step per OCCUPIED
                                  tile; see ``kernels.scatter_add.ops`` for
                                  the occupancy bucketing)

Both accept an optional PRNG ``key``: when given (and only then) the kernel
applies binomial-approximation charge fluctuation *in kernel*, seeded per
(depo, tile) from the key — no patch array and no normals array ever exist
in HBM. ``key=None`` keeps the original deterministic behavior.
"""
from __future__ import annotations

import functools

import jax

from repro.config import LArTPCConfig
from repro.core.depo import DepoSet, depo_patch_origin
from repro.kernels import default_interpret
from repro.kernels.fused_sim.kernel import (fused_rasterize_scatter,
                                            fused_rasterize_scatter_compact)
from repro.kernels.scatter_add.ops import (active_tile_cap,
                                           bin_depos_to_tiles,
                                           bin_depos_to_tiles_compact,
                                           default_k_max, next_pow2)


def _grid_dims(cfg: LArTPCConfig, tw: int, tt: int):
    tiles_w = (cfg.num_wires + tw - 1) // tw
    tiles_t = (cfg.num_ticks + tt - 1) // tt
    return tiles_w, tiles_t, tiles_w * tiles_t


def _resolve_k_max(k_max: int, n: int, cfg: LArTPCConfig, tw: int,
                   tt: int) -> int:
    """Explicit k_max, or the bucketed heuristic shared with scatter_add."""
    return k_max or default_k_max(n, cfg.num_wires, cfg.num_ticks, tw, tt)


def _seed_from(key):
    return None if key is None else jax.random.key_data(key)


@functools.partial(jax.jit, static_argnames=("cfg", "tw", "tt", "k_max",
                                             "interpret"))
def simulate_charge_grid(depos: DepoSet, cfg: LArTPCConfig, tw: int = 64,
                         tt: int = 256, k_max: int = 0,
                         interpret: bool | None = None, key=None):
    """Fused depos -> S(t, x) charge grid (dense tile layout).

    ``key`` enables in-kernel charge fluctuation (see module docstring);
    ``interpret=None`` auto-selects by backend: Mosaic-compiled on TPU, the
    portable Pallas interpreter elsewhere (``repro.kernels.default_interpret``).
    """
    interpret = default_interpret() if interpret is None else interpret
    w0, t0 = depo_patch_origin(depos, cfg)
    k_max = _resolve_k_max(k_max, depos.n, cfg, tw, tt)
    # bin by the TRUE patch extent (the kernel masks to [w0, w0+pw))
    ids, _ = bin_depos_to_tiles(w0, t0, cfg.patch_wires, cfg.patch_ticks,
                                cfg.num_wires, cfg.num_ticks, tw, tt, k_max)
    return fused_rasterize_scatter(
        depos.wire, depos.tick, depos.sigma_w, depos.sigma_t, depos.charge,
        w0, t0, ids, num_wires=cfg.num_wires, num_ticks=cfg.num_ticks,
        tw=tw, tt=tt, k_max=k_max, pw=cfg.patch_wires, pt=cfg.patch_ticks,
        interpret=interpret, seed=_seed_from(key), fluctuate=key is not None)


@functools.partial(jax.jit, static_argnames=("cfg", "tw", "tt", "k_max",
                                             "n_cap", "interpret"))
def _simulate_compact_jit(depos: DepoSet, cfg: LArTPCConfig, tw: int, tt: int,
                          k_max: int, n_cap: int, interpret: bool, key):
    w0, t0 = depo_patch_origin(depos, cfg)
    active, ids = bin_depos_to_tiles_compact(
        w0, t0, cfg.patch_wires, cfg.patch_ticks, cfg.num_wires,
        cfg.num_ticks, tw, tt, k_max, n_cap)
    return fused_rasterize_scatter_compact(
        depos.wire, depos.tick, depos.sigma_w, depos.sigma_t, depos.charge,
        w0, t0, active, ids, num_wires=cfg.num_wires, num_ticks=cfg.num_ticks,
        tw=tw, tt=tt, k_max=k_max, pw=cfg.patch_wires, pt=cfg.patch_ticks,
        interpret=interpret, seed=_seed_from(key), fluctuate=key is not None)


def simulate_charge_grid_compact(depos: DepoSet, cfg: LArTPCConfig,
                                 tw: int = 64, tt: int = 256, k_max: int = 0,
                                 interpret: bool | None = None, key=None,
                                 n_active: int | None = None):
    """Fused depos -> S(t, x) over OCCUPIED tiles only.

    Kernel work is (n_active_bucket x k_max): with concrete (eager) inputs
    the occupancy is measured on the host and bucketed to a power of two;
    under an outer jit it falls back to the static min(n_tiles, 4N) bound.
    Bit-identical to ``simulate_charge_grid`` for the same key: RNG streams
    are seeded by the *global* tile id, which compaction preserves.
    """
    interpret = default_interpret() if interpret is None else interpret
    _, _, n_tiles = _grid_dims(cfg, tw, tt)
    k_max = _resolve_k_max(k_max, depos.n, cfg, tw, tt)
    if n_active is not None:
        n_cap = min(n_tiles, next_pow2(n_active))
    else:
        w0, t0 = depo_patch_origin(depos, cfg)
        n_cap = active_tile_cap(w0, cfg.patch_wires, cfg.patch_ticks,
                                cfg.num_wires, cfg.num_ticks, tw, tt, t0=t0)
    return _simulate_compact_jit(depos, cfg, tw, tt, k_max, n_cap, interpret,
                                 key)
