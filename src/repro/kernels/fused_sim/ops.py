"""jit'd wrapper for the fused rasterize+scatter kernel: DepoSet -> grid."""
from __future__ import annotations

import functools

import jax

from repro.config import LArTPCConfig
from repro.core.depo import DepoSet, depo_patch_origin
from repro.kernels import default_interpret
from repro.kernels.fused_sim.kernel import fused_rasterize_scatter
from repro.kernels.scatter_add.ops import bin_depos_to_tiles


@functools.partial(jax.jit, static_argnames=("cfg", "tw", "tt", "k_max",
                                             "interpret"))
def simulate_charge_grid(depos: DepoSet, cfg: LArTPCConfig, tw: int = 64,
                         tt: int = 256, k_max: int = 0,
                         interpret: bool | None = None):
    """Fused depos -> S(t, x) charge grid (no fluctuation; see kernel doc).

    ``interpret=None`` auto-selects by backend: Mosaic-compiled on TPU, the
    portable Pallas interpreter elsewhere (``repro.kernels.default_interpret``).
    """
    interpret = default_interpret() if interpret is None else interpret
    w0, t0 = depo_patch_origin(depos, cfg)
    n = depos.n
    if k_max == 0:
        tiles = (((cfg.num_wires + tw - 1) // tw)
                 * ((cfg.num_ticks + tt - 1) // tt))
        k_max = max(8, int(4 * n / tiles * 8))
    # bin by the TRUE patch extent (the kernel masks to [w0, w0+pw))
    ids, _ = bin_depos_to_tiles(w0, t0, cfg.patch_wires, cfg.patch_ticks,
                                cfg.num_wires, cfg.num_ticks, tw, tt, k_max)
    return fused_rasterize_scatter(
        depos.wire, depos.tick, depos.sigma_w, depos.sigma_t, depos.charge,
        w0, t0, ids, num_wires=cfg.num_wires, num_ticks=cfg.num_ticks,
        tw=tw, tt=tt, k_max=k_max, pw=cfg.patch_wires, pt=cfg.patch_ticks,
        interpret=interpret)
