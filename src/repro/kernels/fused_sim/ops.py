"""jit'd wrappers for the fused rasterize+fluctuate+scatter kernel.

``simulate_charge_grid``        — dense tile grid (one step per detector tile)
``simulate_charge_grid_compact``— active-tile grid (one step per OCCUPIED
                                  tile; see ``kernels.scatter_add.ops`` for
                                  the occupancy bucketing)

Both accept an optional PRNG ``key``: when given (and only then) the kernel
applies binomial-approximation charge fluctuation *in kernel*, seeded per
(depo, tile) from the key — no patch array and no normals array ever exist
in HBM. ``key=None`` keeps the original deterministic behavior.
"""
from __future__ import annotations

import functools

import jax

import jax.numpy as jnp

from repro.config import LArTPCConfig
from repro.core.depo import DepoSet, depo_patch_origin
from repro.kernels import default_interpret
from repro.kernels.fused_sim.kernel import (
    fused_rasterize_scatter, fused_rasterize_scatter_compact,
    fused_rasterize_scatter_multiplane,
    fused_rasterize_scatter_multiplane_compact)
from repro.kernels.scatter_add.ops import (active_tile_cap,
                                           bin_depos_to_tiles,
                                           bin_depos_to_tiles_compact,
                                           default_k_max, next_pow2)


def _grid_dims(cfg: LArTPCConfig, tw: int, tt: int):
    tiles_w = (cfg.num_wires + tw - 1) // tw
    tiles_t = (cfg.num_ticks + tt - 1) // tt
    return tiles_w, tiles_t, tiles_w * tiles_t


def _resolve_k_max(k_max: int, n: int, cfg: LArTPCConfig, tw: int,
                   tt: int) -> int:
    """Explicit k_max, or the bucketed heuristic shared with scatter_add."""
    return k_max or default_k_max(n, cfg.num_wires, cfg.num_ticks, tw, tt)


def _seed_from(key):
    return None if key is None else jax.random.key_data(key)


def _seeds_from(keys):
    """Stacked (P, 2) raw key data from stacked per-plane keys (or None)."""
    return None if keys is None else jax.random.key_data(keys)


@functools.partial(jax.jit, static_argnames=("cfg", "tw", "tt", "k_max",
                                             "interpret"))
def simulate_charge_grid(depos: DepoSet, cfg: LArTPCConfig, tw: int = 64,
                         tt: int = 256, k_max: int = 0,
                         interpret: bool | None = None, key=None):
    """Fused depos -> S(t, x) charge grid (dense tile layout).

    ``key`` enables in-kernel charge fluctuation (see module docstring);
    ``interpret=None`` auto-selects by backend: Mosaic-compiled on TPU, the
    portable Pallas interpreter elsewhere (``repro.kernels.default_interpret``).
    """
    interpret = default_interpret() if interpret is None else interpret
    w0, t0 = depo_patch_origin(depos, cfg)
    k_max = _resolve_k_max(k_max, depos.n, cfg, tw, tt)
    # bin by the TRUE patch extent (the kernel masks to [w0, w0+pw))
    ids, _ = bin_depos_to_tiles(w0, t0, cfg.patch_wires, cfg.patch_ticks,
                                cfg.num_wires, cfg.num_ticks, tw, tt, k_max)
    return fused_rasterize_scatter(
        depos.wire, depos.tick, depos.sigma_w, depos.sigma_t, depos.charge,
        w0, t0, ids, num_wires=cfg.num_wires, num_ticks=cfg.num_ticks,
        tw=tw, tt=tt, k_max=k_max, pw=cfg.patch_wires, pt=cfg.patch_ticks,
        interpret=interpret, seed=_seed_from(key), fluctuate=key is not None)


@functools.partial(jax.jit, static_argnames=("cfg", "tw", "tt", "k_max",
                                             "n_cap", "interpret"))
def _simulate_compact_jit(depos: DepoSet, cfg: LArTPCConfig, tw: int, tt: int,
                          k_max: int, n_cap: int, interpret: bool, key):
    w0, t0 = depo_patch_origin(depos, cfg)
    active, ids = bin_depos_to_tiles_compact(
        w0, t0, cfg.patch_wires, cfg.patch_ticks, cfg.num_wires,
        cfg.num_ticks, tw, tt, k_max, n_cap)
    return fused_rasterize_scatter_compact(
        depos.wire, depos.tick, depos.sigma_w, depos.sigma_t, depos.charge,
        w0, t0, active, ids, num_wires=cfg.num_wires, num_ticks=cfg.num_ticks,
        tw=tw, tt=tt, k_max=k_max, pw=cfg.patch_wires, pt=cfg.patch_ticks,
        interpret=interpret, seed=_seed_from(key), fluctuate=key is not None)


def simulate_charge_grid_compact(depos: DepoSet, cfg: LArTPCConfig,
                                 tw: int = 64, tt: int = 256, k_max: int = 0,
                                 interpret: bool | None = None, key=None,
                                 n_active: int | None = None):
    """Fused depos -> S(t, x) over OCCUPIED tiles only.

    Kernel work is (n_active_bucket x k_max): with concrete (eager) inputs
    the occupancy is measured on the host and bucketed to a power of two;
    under an outer jit it falls back to the static min(n_tiles, 4N) bound.
    Bit-identical to ``simulate_charge_grid`` for the same key: RNG streams
    are seeded by the *global* tile id, which compaction preserves.
    """
    interpret = default_interpret() if interpret is None else interpret
    _, _, n_tiles = _grid_dims(cfg, tw, tt)
    k_max = _resolve_k_max(k_max, depos.n, cfg, tw, tt)
    if n_active is not None:
        n_cap = min(n_tiles, next_pow2(n_active))
    else:
        w0, t0 = depo_patch_origin(depos, cfg)
        n_cap = active_tile_cap(w0, cfg.patch_wires, cfg.patch_ticks,
                                cfg.num_wires, cfg.num_ticks, tw, tt, t0=t0)
    return _simulate_compact_jit(depos, cfg, tw, tt, k_max, n_cap, interpret,
                                 key)


@functools.partial(jax.jit, static_argnames=("cfg", "tw", "tt", "k_max",
                                             "interpret"))
def simulate_charge_grid_multiplane(depos: DepoSet, cfg: LArTPCConfig,
                                    tw: int = 64, tt: int = 256,
                                    k_max: int = 0,
                                    interpret: bool | None = None,
                                    keys=None):
    """Fused depos -> (P, W, T) charge grids, ONE launch for all planes.

    ``depos`` carries a leading plane axis (P, N) — the per-plane
    projections of one event's physical depos. ``keys`` is the stacked
    per-plane subkey array (``fold_in(kf, p)`` per plane) enabling
    in-kernel fluctuation; plane p's grid is bit-identical to
    ``simulate_charge_grid`` run on plane p's depos with plane p's key.
    """
    interpret = default_interpret() if interpret is None else interpret
    num_planes, n = depos.wire.shape
    w0, t0 = depo_patch_origin(depos, cfg)
    k_max = _resolve_k_max(k_max, n, cfg, tw, tt)
    # per-plane dense binned lists (plane-LOCAL depo ids), concatenated
    # plane-major — matching the kernel's flat i = p*n_tiles + t layout
    ids = jnp.concatenate([
        bin_depos_to_tiles(w0[p], t0[p], cfg.patch_wires, cfg.patch_ticks,
                           cfg.num_wires, cfg.num_ticks, tw, tt, k_max)[0]
        for p in range(num_planes)])
    return fused_rasterize_scatter_multiplane(
        depos.wire, depos.tick, depos.sigma_w, depos.sigma_t, depos.charge,
        w0, t0, ids, num_planes=num_planes, num_wires=cfg.num_wires,
        num_ticks=cfg.num_ticks, tw=tw, tt=tt, k_max=k_max,
        pw=cfg.patch_wires, pt=cfg.patch_ticks, interpret=interpret,
        seeds=_seeds_from(keys), fluctuate=keys is not None)


@functools.partial(jax.jit, static_argnames=("cfg", "tw", "tt", "k_max",
                                             "n_cap", "interpret"))
def _simulate_multiplane_compact_jit(depos: DepoSet, cfg: LArTPCConfig,
                                     tw: int, tt: int, k_max: int,
                                     n_cap: int, interpret: bool, keys):
    num_planes, _ = depos.wire.shape
    w0, t0 = depo_patch_origin(depos, cfg)
    actives, ids = [], []
    for p in range(num_planes):
        a, i = bin_depos_to_tiles_compact(
            w0[p], t0[p], cfg.patch_wires, cfg.patch_ticks, cfg.num_wires,
            cfg.num_ticks, tw, tt, k_max, n_cap)
        actives.append(a)
        ids.append(i)
    return fused_rasterize_scatter_multiplane_compact(
        depos.wire, depos.tick, depos.sigma_w, depos.sigma_t, depos.charge,
        w0, t0, jnp.concatenate(actives), jnp.concatenate(ids),
        num_planes=num_planes, num_wires=cfg.num_wires,
        num_ticks=cfg.num_ticks, tw=tw, tt=tt, k_max=k_max,
        pw=cfg.patch_wires, pt=cfg.patch_ticks, interpret=interpret,
        seeds=_seeds_from(keys), fluctuate=keys is not None)


def simulate_charge_grid_multiplane_compact(depos: DepoSet,
                                            cfg: LArTPCConfig, tw: int = 64,
                                            tt: int = 256, k_max: int = 0,
                                            interpret: bool | None = None,
                                            keys=None,
                                            n_active: int | None = None):
    """Fused multi-plane charge grids over OCCUPIED tiles only.

    Every plane's compacted tile list gets the SAME bucketed capacity
    ``n_cap`` (the max over planes of the measured occupancy, or the
    static min(n_tiles, 4N) bound under a trace) so the concatenated
    launch stays rectangular. Bit-identical to
    ``simulate_charge_grid_multiplane`` for the same keys.
    """
    interpret = default_interpret() if interpret is None else interpret
    _, _, n_tiles = _grid_dims(cfg, tw, tt)
    num_planes = depos.wire.shape[0]
    k_max = _resolve_k_max(k_max, depos.n, cfg, tw, tt)
    if n_active is not None:
        n_cap = min(n_tiles, next_pow2(n_active))
    else:
        w0, t0 = depo_patch_origin(depos, cfg)
        n_cap = max(
            active_tile_cap(w0[p], cfg.patch_wires, cfg.patch_ticks,
                            cfg.num_wires, cfg.num_ticks, tw, tt, t0=t0[p])
            for p in range(num_planes))
    return _simulate_multiplane_compact_jit(depos, cfg, tw, tt, k_max, n_cap,
                                            interpret, keys)
