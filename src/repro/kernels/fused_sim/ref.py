"""Oracle for the fused kernel: unfused rasterize + dense scatter-add."""
from __future__ import annotations

from repro.config import LArTPCConfig
from repro.core.depo import DepoSet
from repro.core.rasterize import rasterize
from repro.core.scatter import scatter_xla


def simulate_charge_grid_ref(depos: DepoSet, cfg: LArTPCConfig):
    patches, w0, t0 = rasterize(depos, cfg)
    return scatter_xla(patches, w0, t0, cfg)
