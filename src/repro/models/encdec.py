"""Encoder-decoder backbone (SeamlessM4T-v2 style): speech-embedding encoder
(bidirectional) + causal text decoder with cross-attention.

The speech frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, D); the encoder is the transformer
stack on top of them.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.transformer import (Stack, apply_block, build_params,
                                      make_block, stacks_for)


def _enc_stack(cfg: ModelConfig) -> Stack:
    return Stack("enc_layers", cfg.num_encoder_layers, "gqa", "mlp", cfg.d_ff)


def build_encdec_params(make, cfg: ModelConfig):
    p: Dict[str, Any] = {}
    # encoder: its own stack (bidirectional attention)
    enc = _enc_stack(cfg)

    def enc_make(path, shape, names, *a, **kw):
        return make(path, (enc.n,) + tuple(shape), ("layers",) + tuple(names),
                    *a, **kw)

    p["encoder"] = make_block(enc_make, "encoder", cfg, enc)
    p["enc_final_norm"] = L.make_norm(make, "enc_final_norm", cfg.d_model,
                                      cfg.norm_kind)
    # decoder: standard stacks + cross attention
    dec = build_params(make, cfg, cross_attn=True, with_embed=True)
    p.update(dec)
    return p


def encode(params, enc_embeds, cfg: ModelConfig):
    """enc_embeds: (B, S_enc, D) frontend stub output -> encoder states."""
    b, s, _ = enc_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    enc = _enc_stack(cfg)
    x = enc_embeds.astype(cfg.dtype)

    def body(carry, lp):
        xx, _ = apply_block_bidir(lp, carry, positions, cfg, enc)
        return xx, None

    if cfg.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.save_only_these_names(
                "mix_out", "ffn_out"))
    x, _ = jax.lax.scan(body, x, params["encoder"])
    x = L.apply_norm(params["enc_final_norm"], x, cfg.norm_kind)
    return x, positions


def apply_block_bidir(p, x, positions, cfg, stack):
    """Encoder block: non-causal self-attention + MLP."""
    h = L.apply_norm(p["ln_mix"], x, cfg.norm_kind)
    out, _ = attn.gqa_attention(p["mix"], h, positions, cfg, causal=False)
    x = x + out
    h = L.apply_norm(p["ln_ffn"], x, cfg.norm_kind)
    x = x + L.apply_mlp(p["ffn"], h, cfg.mlp_kind)
    return x, None


def encdec_forward(params, tokens, enc_embeds, cfg: ModelConfig, *,
                   caches=None, enc_out=None, start_index=None,
                   features_only=False):
    """Full enc-dec forward.

    tokens: decoder input (B, S_dec). enc_embeds: (B, S_enc, D) stub frames.
    enc_out: optionally precomputed encoder output (decode steps reuse it).
    Returns (logits, new_caches, aux, enc_out).
    """
    if enc_out is None:
        enc_states, enc_positions = encode(params, enc_embeds, cfg)
    else:
        enc_states, enc_positions = enc_out

    # cross-attention kv computed per decoder layer inside the scan from the
    # (replicated) encoder states; decoder stacks handle the rest.
    x = L.embed(params["embed"], tokens, cfg)
    b, s, _ = x.shape
    if start_index is not None:
        positions = jnp.broadcast_to(
            start_index + jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))

    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    for stack in stacks_for(cfg):
        sp = params[stack.name]
        windows = jnp.zeros((stack.n,), jnp.int32)
        cache = caches.get(stack.name) if caches is not None else None

        def body(carry, per_layer):
            xx = carry
            lp, win, csl = per_layer
            kv = attn.encode_cross_kv(lp["cross"], enc_states, cfg)
            xx, new_c, aux = apply_block(lp, xx, positions, cfg, stack, win,
                                         csl, cross_kv=kv,
                                         enc_positions=enc_positions)
            return xx, (new_c, aux)

        if cfg.remat != "none":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_saveable)
        if cache is None:
            x, (new_c, auxs) = jax.lax.scan(
                lambda c, pl: body(c, (pl[0], pl[1], None)), x, (sp, windows))
        else:
            x, (new_c, auxs) = jax.lax.scan(body, x, (sp, windows, cache))
            new_caches[stack.name] = new_c
        aux_total = aux_total + jnp.sum(auxs)

    x = L.apply_norm(params["final_norm"], x, cfg.norm_kind)
    if features_only:
        return x, new_caches, aux_total, (enc_states, enc_positions)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["unembed"]["table"])
    logits = L.unembed({"table": table}, x, cfg)
    return logits, new_caches, aux_total, (enc_states, enc_positions)
