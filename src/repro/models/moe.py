"""Fine-grained Mixture-of-Experts (DeepSeekMoE-style: shared + routed top-k).

Dispatch is sort-based (MaxText/Megablocks-style), not mask-einsum — the
one-hot dispatch tensor for 160 experts x 32k tokens would be terabytes.

  1. router logits -> top-k expert ids + weights per token
  2. (token, expert) pairs sorted by expert id -> contiguous per-expert runs
  3. every expert gathers up to CAPACITY tokens from its run (static shapes;
     overflow tokens are dropped, standard capacity-factor semantics)
  4. batched expert FFN: einsum over the expert dim (sharded over `model` —
     expert parallelism); GSPMD inserts the token all-to-all
  5. weighted scatter back to token order + shared-expert contribution

Aux load-balance loss (switch-style) is returned for the trainer.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig
from repro.models.layers import apply_mlp, make_mlp
from repro.parallel.sharding import logical


def make_moe(make, path: str, cfg: ModelConfig):
    m: MoEConfig = cfg.moe
    d, e, f = cfg.d_model, m.num_experts, m.expert_ff
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "router": make(f"{path}.router", (d, e), ("embed", "experts"), s_in,
                       dtype_=jnp.float32),
        "w_gate": make(f"{path}.w_gate", (e, d, f),
                       ("experts", "embed", "expert_mlp"), s_in),
        "w_up": make(f"{path}.w_up", (e, d, f),
                     ("experts", "embed", "expert_mlp"), s_in),
        "w_down": make(f"{path}.w_down", (e, f, d),
                       ("experts", "expert_mlp", "embed"), s_out),
    }
    if m.num_shared:
        p["shared"] = make_mlp(make, f"{path}.shared", d,
                               m.expert_ff * m.num_shared, cfg.mlp_kind)
    return p


def _capacity(tokens: int, num_experts: int, top_k: int,
              factor: float = 1.25) -> int:
    cap = int(tokens * top_k / num_experts * factor) + 1
    return max(8, (cap + 7) // 8 * 8)


def apply_moe(params, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    xf = x.reshape(t, d)

    # --- route ---
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)                       # (T,k)
    weights = weights / jnp.sum(weights, -1, keepdims=True)

    # --- aux load-balance loss (switch-style) ---
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], e), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_prob) * e * m.router_aux_weight

    # --- sort (token,expert) pairs by expert ---
    flat_e = ids.reshape(-1)                                     # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]

    # --- per-expert capacity gather indices ---
    cap = _capacity(t, e, k, m.capacity_factor)
    counts = jnp.bincount(se, length=e)                          # (E,)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = starts[:, None] + jnp.arange(cap, dtype=jnp.int32)[None, :]  # (E,C)
    in_run = jnp.arange(cap)[None, :] < counts[:, None]
    pos_c = jnp.minimum(pos, t * k - 1)
    tok_idx = jnp.where(in_run, st[pos_c], 0)                    # (E,C)
    tok_w = jnp.where(in_run, sw[pos_c], 0.0)

    # --- expert FFN over gathered tokens ---
    xe = xf[tok_idx]                                             # (E,C,D)
    xe = logical(xe, ("experts", "capacity", "embed"))
    gate = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(xe.dtype))
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(xe.dtype))
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(xe.dtype))
    ye = logical(ye, ("experts", "capacity", "embed"))
    ye = ye * tok_w[..., None].astype(ye.dtype)

    # --- scatter back to token order ---
    out = jnp.zeros((t, d), ye.dtype)
    out = out.at[tok_idx.reshape(-1)].add(
        ye.reshape(-1, d) * in_run.reshape(-1, 1).astype(ye.dtype))
    out = out.reshape(b, s, d)

    if m.num_shared:
        out = out + apply_mlp(params["shared"], x, cfg.mlp_kind)
    return logical(out, ("batch", "seq", "embed")), aux
