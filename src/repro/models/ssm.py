"""Mamba-2 / SSD (state-space duality) block — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm (quadratic within a chunk,
linear across chunks); decode uses the O(1) recurrent state update. This is
the sub-quadratic family assigned the ``long_500k`` shape.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SSMConfig
from repro.models.layers import causal_conv1d, rmsnorm
from repro.parallel.sharding import logical


def make_ssm(make, path: str, cfg: ModelConfig):
    c: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_in = c.expand * d
    h = d_in // c.head_dim
    g = 1  # single B/C group
    n = c.state_dim
    conv_dim = d_in + 2 * g * n
    s = d ** -0.5
    return {
        # fused input projection: [z, x, B, C, dt]
        "w_in": make(f"{path}.w_in", (d, 2 * d_in + 2 * g * n + h),
                     ("embed", "mlp"), s),
        "conv_w": make(f"{path}.conv_w", (c.conv_width, conv_dim),
                       ("conv", "mlp"), 0.2),
        "a_log": make(f"{path}.a_log", (h,), ("heads",), init="zeros"),
        "dt_bias": make(f"{path}.dt_bias", (h,), ("heads",), init="zeros"),
        "d_skip": make(f"{path}.d_skip", (h,), ("heads",), init="ones"),
        "norm": make(f"{path}.norm", (d_in,), ("mlp",), init="zeros"),
        "w_out": make(f"{path}.w_out", (d_in, d), ("mlp", "embed"),
                      d_in ** -0.5),
    }


class SSMCache(NamedTuple):
    state: jax.Array   # (B, H, P, N)
    conv: jax.Array    # (B, K-1, conv_dim)


def init_ssm_cache(cfg: ModelConfig, batch: int, layers: int, dtype) -> SSMCache:
    c = cfg.ssm
    d_in = c.expand * cfg.d_model
    h = d_in // c.head_dim
    conv_dim = d_in + 2 * c.state_dim
    return SSMCache(
        state=jnp.zeros((layers, batch, h, c.head_dim, c.state_dim), jnp.float32),
        conv=jnp.zeros((layers, batch, c.conv_width - 1, conv_dim), dtype))


def _segsum(x):
    """x: (..., L) log-decays -> (..., L, L) lower-triangular cumulative sums."""
    l = x.shape[-1]
    xx = jnp.broadcast_to(x[..., None, :], x.shape + (l,))
    xx = jnp.swapaxes(xx, -1, -2)                  # (..., L(out), L(in))
    mask_lower = jnp.tril(jnp.ones((l, l), bool), k=-1)
    xx = jnp.where(mask_lower, xx, 0.0)
    out = jnp.cumsum(xx, axis=-2)
    mask_incl = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask_incl, out, -jnp.inf)


def ssd_chunked(x, dt, a, b, c, chunk: int,
                initial_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x: (B,L,H,P)  dt: (B,L,H)  a: (H,) negative reals
    b, c: (B,L,G,N) with H % G == 0.
    Returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    nc = l // chunk
    assert nc * chunk == l, "seq must be divisible by ssd chunk"

    xs = x.reshape(bsz, nc, chunk, h, p)
    dts = dt.reshape(bsz, nc, chunk, h)
    bs = jnp.repeat(b.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    cs = jnp.repeat(c.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    da = dts * a[None, None, None, :]              # (B,NC,CL,H) log-decay
    da_h = jnp.moveaxis(da, -1, 2)                 # (B,NC,H,CL)
    cum = jnp.cumsum(da_h, axis=-1)

    # intra-chunk (quadratic within chunk)
    ll = jnp.exp(_segsum(da_h))                    # (B,NC,H,CL,CL)
    y_diag = jnp.einsum("bzlhn,bzshn,bzhls,bzsh,bzshp->bzlhp",
                        cs, bs, ll, dts, xs)

    # chunk states
    decay_states = jnp.exp(cum[..., -1:] - cum)    # (B,NC,H,CL)
    states = jnp.einsum("bzshn,bzhs,bzsh,bzshp->bzhpn",
                        bs, decay_states, dts, xs)  # (B,NC,H,P,N)

    # inter-chunk recurrence: S_z = exp(sum da_z) * S_{z-1} + states_z
    chunk_decay = jnp.exp(cum[..., -1])            # (B,NC,H)
    s0 = (initial_state if initial_state is not None
          else jnp.zeros((bsz, h, p, n), x.dtype))

    def step(s_prev, inp):
        dec, st = inp                              # (B,H), (B,H,P,N)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    decs = jnp.moveaxis(chunk_decay, 1, 0)         # (NC,B,H)
    sts = jnp.moveaxis(states, 1, 0)               # (NC,B,H,P,N)
    final_state, prev_states = jax.lax.scan(step, s0, (decs, sts))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B,NC,H,P,N)

    # inter-chunk output
    state_decay = jnp.exp(cum)                     # (B,NC,H,CL)
    y_off = jnp.einsum("bzlhn,bzhpn,bzhl->bzlhp", cs, prev_states, state_decay)

    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y, final_state


def ssd_decode_step(x, dt, a, b, c, state):
    """One-token recurrent update. x (B,1,H,P); b,c (B,1,G,N); state (B,H,P,N)."""
    bsz, _, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    bt = jnp.repeat(b[:, 0], rep, axis=1)          # (B,H,N)
    ct = jnp.repeat(c[:, 0], rep, axis=1)
    dtt = dt[:, 0]                                  # (B,H)
    da = jnp.exp(dtt * a[None, :])                  # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dtt, bt, x[:, 0])
    state = state * da[..., None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", ct, state)
    return y[:, None], state                        # (B,1,H,P)


def apply_ssm(params, x, cfg: ModelConfig,
              cache: Optional[SSMCache] = None
              ) -> Tuple[jax.Array, Optional[SSMCache]]:
    """Mamba-2 block. x (B,S,D) -> (B,S,D). cache -> decode path."""
    c: SSMConfig = cfg.ssm
    bsz, s, d = x.shape
    d_in = c.expand * d
    h = d_in // c.head_dim
    g, n = 1, c.state_dim

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params["w_in"].astype(x.dtype))
    z, xb, bc, dt_raw = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + 2 * g * n], axis=-1)
    # conv over [x, B, C] jointly (mamba2 convention)
    conv_in = jnp.concatenate([xb, bc], axis=-1)   # (B,S,d_in+2gn)
    conv_out, new_conv = causal_conv1d(
        conv_in, params["conv_w"],
        cache.conv if cache is not None else None)
    conv_out = jax.nn.silu(conv_out)
    xc = conv_out[..., :d_in]
    b_mat = conv_out[..., d_in:d_in + g * n].reshape(bsz, s, g, n)
    c_mat = conv_out[..., d_in + g * n:].reshape(bsz, s, g, n)

    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    xh = xc.reshape(bsz, s, h, c.head_dim)
    xh = logical(xh, ("batch", "seq", "heads", "head_dim"))

    if cache is None:
        chunk = min(c.chunk, s)
        y, _ = ssd_chunked(xh.astype(jnp.float32), dt, a,
                           b_mat.astype(jnp.float32),
                           c_mat.astype(jnp.float32), chunk)
        new_cache = None
    elif s > 1:
        # prefill-into-cache: chunked SSD carrying the recurrent state
        chunk = min(c.chunk, s)
        y, new_state = ssd_chunked(xh.astype(jnp.float32), dt, a,
                                   b_mat.astype(jnp.float32),
                                   c_mat.astype(jnp.float32), chunk,
                                   initial_state=cache.state)
        new_cache = SSMCache(state=new_state, conv=new_conv)
    else:
        y, new_state = ssd_decode_step(
            xh.astype(jnp.float32), dt, a, b_mat.astype(jnp.float32),
            c_mat.astype(jnp.float32), cache.state)
        new_cache = SSMCache(state=new_state, conv=new_conv)

    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["norm"])
    out = jnp.einsum("bsk,kd->bsd", y, params["w_out"].astype(x.dtype))
    return logical(out, ("batch", "seq", "embed")), new_cache
