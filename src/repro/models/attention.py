"""Attention: GQA (+qk-norm, softcap, sliding window), MLA, cross-attention.

All softmax attention goes through one flash-style KV-blocked kernel
(``flash_attention``) — pure JAX ``lax.scan`` with online softmax, O(Sq·block)
score memory instead of O(Sq·Skv). Works for train (causal/local/bidir),
prefill, and decode (Sq=1 against a long cache).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import MLAConfig, ModelConfig
from repro.models.layers import apply_rope, rmsnorm, rope_table, softcap
from repro.parallel.sharding import current_act_rules, current_mesh, logical

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Flash-style blocked attention (pure JAX)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, q_pos, kv_pos, *, causal: bool,
                    window=None, logit_cap: float = 0.0,
                    kv_block: int = 1024, kv_valid: Optional[jax.Array] = None):
    """q: (B,Sq,H,D); k,v: (B,Skv,Hkv,D); positions: (B,Sq)/(B,Skv) int32.

    window: sliding-window width. May be a static int (0/None = global) or a
    traced scalar (0 = global) — the traced form lets a scanned layer stack
    alternate local/global per layer (gemma-2).
    kv_valid: (B,Skv) bool — False entries masked (decode cache padding).
    Returns (B,Sq,H,D).

    Custom VJP: the backward pass recomputes scores blockwise (the flash
    recipe) instead of letting AD save the O(Sq*Skv) scan residuals.
    """
    use_window = window is not None and not (isinstance(window, int)
                                             and window == 0)
    if q.shape[1] <= 8:
        # decode: direct einsum path. With the KV cache sequence-sharded over
        # the `model` axis, GSPMD turns the softmax + weighted sum into the
        # sequence-parallel (psum of partial max/sum) form automatically; the
        # scan path would instead all-gather the cache.
        return _direct_attention(q, k, v, q_pos, kv_pos, causal=causal,
                                 window=window if use_window else None,
                                 logit_cap=logit_cap, kv_valid=kv_valid)
    b, sq, h, d = q.shape
    skv = k.shape[1]
    kv_block = min(kv_block, skv)
    nblk = (skv + kv_block - 1) // kv_block
    pad = nblk * kv_block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        kv_valid = (jnp.pad(kv_valid, ((0, 0), (0, pad)))
                    if kv_valid is not None
                    else jnp.concatenate(
                        [jnp.ones((b, skv), bool),
                         jnp.zeros((b, pad), bool)], axis=1))
    elif kv_valid is None:
        kv_valid = jnp.ones((b, skv), bool)
    window_arr = jnp.asarray(window if use_window else 0, jnp.int32)
    out = _flash_core(q, k, v, q_pos, kv_pos, kv_valid, window_arr,
                      causal, bool(use_window), float(logit_cap),
                      int(kv_block))
    return out[:, :, :, :]


def _blk_mask(pblk, q_pos, vldblk, causal, use_window, window_arr):
    mask = vldblk[:, None, None, None, :]
    if causal:
        mask = mask & (pblk[:, None, None, None, :]
                       <= q_pos[:, None, None, :, None])
    if use_window:
        in_win = (pblk[:, None, None, None, :]
                  > q_pos[:, None, None, :, None] - window_arr)
        mask = mask & (in_win | (window_arr == 0))
    return mask


def _blk_scores(qg, kblk, scale, logit_cap):
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qg, kblk,
                   preferred_element_type=jnp.float32) * scale
    if logit_cap:
        s = softcap(s, logit_cap)
    return s


def _to_blocks(k, v, kv_pos, kv_valid, nblk, kv_block):
    b, _, hkv, d = k.shape
    kb = jnp.transpose(k, (0, 2, 1, 3)).reshape(b, hkv, nblk, kv_block, d)
    vb = jnp.transpose(v, (0, 2, 1, 3)).reshape(b, hkv, nblk, kv_block, d)
    posb = kv_pos.reshape(b, nblk, kv_block)
    validb = kv_valid.reshape(b, nblk, kv_block)
    return (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0),
            jnp.moveaxis(posb, 1, 0), jnp.moveaxis(validb, 1, 0))


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10))
def _flash_core(q, k, v, q_pos, kv_pos, kv_valid, window_arr,
                causal, use_window, logit_cap, kv_block):
    out, _ = _flash_fwd_impl(q, k, v, q_pos, kv_pos, kv_valid, window_arr,
                             causal, use_window, logit_cap, kv_block)
    return out


def _flash_fwd_impl(q, k, v, q_pos, kv_pos, kv_valid, window_arr,
                    causal, use_window, logit_cap, kv_block):
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    nblk = skv // kv_block
    scale = d ** -0.5
    qg = jnp.transpose(q.reshape(b, sq, hkv, g, d), (0, 2, 3, 1, 4))
    blks = _to_blocks(k, v, kv_pos, kv_valid, nblk, kv_block)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, pblk, vldblk = blk
        s = _blk_scores(qg, kblk, scale, logit_cap)
        mask = _blk_mask(pblk, q_pos, vldblk, causal, use_window, window_arr)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), blks)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))       # (B,Hkv,G,Sq)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, sq, h, d)
    return out.astype(q.dtype), lse


def _flash_fwd(q, k, v, q_pos, kv_pos, kv_valid, window_arr,
               causal, use_window, logit_cap, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, kv_pos, kv_valid, window_arr,
                               causal, use_window, logit_cap, kv_block)
    return out, (q, k, v, q_pos, kv_pos, kv_valid, window_arr, out, lse)


def _flash_bwd(causal, use_window, logit_cap, kv_block, res, dout):
    q, k, v, q_pos, kv_pos, kv_valid, window_arr, out, lse = res
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    nblk = skv // kv_block
    scale = d ** -0.5
    qg = jnp.transpose(q.reshape(b, sq, hkv, g, d), (0, 2, 3, 1, 4))
    dog = jnp.transpose(dout.reshape(b, sq, hkv, g, d), (0, 2, 3, 1, 4)
                        ).astype(jnp.float32)
    outg = jnp.transpose(out.reshape(b, sq, hkv, g, d), (0, 2, 3, 1, 4)
                         ).astype(jnp.float32)
    delta = jnp.sum(dog * outg, axis=-1)           # (B,Hkv,G,Sq)
    blks = _to_blocks(k, v, kv_pos, kv_valid, nblk, kv_block)

    def step(dq_acc, blk):
        kblk, vblk, pblk, vldblk = blk
        s = _blk_scores(qg, kblk, scale, logit_cap)
        mask = _blk_mask(pblk, q_pos, vldblk, causal, use_window, window_arr)
        p = jnp.where(mask, jnp.exp(s - lse[..., None]), 0.0)  # (B,K,G,Sq,C)
        dv_blk = jnp.einsum("bkgqc,bkgqd->bkcd", p, dog)
        dp = jnp.einsum("bkgqd,bkcd->bkgqc", dog, vblk.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        if logit_cap:
            # d/dx softcap(x) = 1 - (softcap(x)/cap)^2 ; s holds softcap(x)
            ds = ds * (1.0 - jnp.square(s / logit_cap))
        dq_acc = dq_acc + jnp.einsum("bkgqc,bkcd->bkgqd", ds,
                                     kblk.astype(jnp.float32)) * scale
        dk_blk = jnp.einsum("bkgqc,bkgqd->bkcd", ds, qg.astype(jnp.float32)
                            ) * scale
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    dqg, (dk_blks, dv_blks) = jax.lax.scan(step, dq0, blks)
    dq = jnp.transpose(dqg, (0, 3, 1, 2, 4)).reshape(b, sq, h, d).astype(q.dtype)
    # (nblk, B, Hkv, C, D) -> (B, Skv, Hkv, D)
    dk = jnp.transpose(jnp.moveaxis(dk_blks, 0, 2).reshape(b, hkv, skv, d),
                       (0, 2, 1, 3)).astype(k.dtype)
    dv = jnp.transpose(jnp.moveaxis(dv_blks, 0, 2).reshape(b, hkv, skv, d),
                       (0, 2, 1, 3)).astype(v.dtype)
    return dq, dk, dv, None, None, None, None


_flash_core.defvjp(_flash_fwd, _flash_bwd)


def _direct_attention(q, k, v, q_pos, kv_pos, *, causal, window, logit_cap,
                      kv_valid):
    """Unblocked attention for tiny Sq (decode). q: (B,Sq,H,D)."""
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k,
                   preferred_element_type=jnp.float32) * (d ** -0.5)
    if logit_cap:
        s = softcap(s, logit_cap)
    mask = (kv_valid if kv_valid is not None
            else jnp.ones((b, skv), bool))[:, None, None, None, :]
    if causal:
        mask = mask & (kv_pos[:, None, None, None, :]
                       <= q_pos[:, None, None, :, None])
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        in_win = (kv_pos[:, None, None, None, :]
                  > q_pos[:, None, None, :, None] - w)
        mask = mask & (in_win | (w == 0))
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def make_gqa(make, path: str, cfg: ModelConfig):
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    s = d ** -0.5
    p = {
        "wq": make(f"{path}.wq", (d, h, dh), ("embed", "heads", "head_dim"), s),
        "wk": make(f"{path}.wk", (d, hkv, dh), ("embed", "kv_heads", "head_dim"), s),
        "wv": make(f"{path}.wv", (d, hkv, dh), ("embed", "kv_heads", "head_dim"), s),
        "wo": make(f"{path}.wo", (h, dh, d), ("heads", "head_dim", "embed"),
                   (h * dh) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = make(f"{path}.q_norm", (dh,), ("head_dim",), init="zeros")
        p["k_norm"] = make(f"{path}.k_norm", (dh,), ("head_dim",), init="zeros")
    return p


def _maybe_repeat_kv(k, v, num_heads: int):
    """Repeat kv heads to full head count when TP wants it.

    With q heads sharded over a `model` axis that does not divide the kv-head
    count (e.g. 8 kv heads on a 16-way axis), the grouped (hkv, g) reshape
    inside flash attention makes the q sharding unpartitionable and GSPMD
    replicates the whole attention. Repeating kv to the full head count keeps
    every tensor sharded by `heads` — the repeated kv is *smaller* per device
    than a replicated un-repeated one.
    """
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return k, v
    rules = current_act_rules()
    if rules.get("heads") != "model":
        return k, v
    m = mesh.shape["model"]
    hkv = k.shape[2]
    if hkv % m == 0 or num_heads % m != 0 or num_heads == hkv:
        return k, v
    rep = num_heads // hkv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    k = logical(k, ("batch", "attn_seq", "heads", "head_dim"))
    v = logical(v, ("batch", "attn_seq", "heads", "head_dim"))
    return k, v


class KVCache(NamedTuple):
    """Ring-buffer KV cache. `pos` stores the absolute position held in each
    slot (-1 = empty), so windowed layers can use a cache of only
    `window_size` slots and wrap around."""

    k: jax.Array       # (B, S_max, Hkv, Dh)
    v: jax.Array
    pos: jax.Array     # (S_max,) int32 absolute position per slot, -1 empty
    index: jax.Array   # scalar int32: number of tokens written so far


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, layers: int,
                  dtype) -> KVCache:
    dh = cfg.resolved_head_dim
    shape = (layers, batch, max_len, cfg.num_kv_heads, dh)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.full((layers, max_len), -1, jnp.int32),
                   index=jnp.zeros((layers,), jnp.int32))


def gqa_attention(params, x, positions, cfg: ModelConfig, *,
                  causal: bool = True, window: int = 0,
                  cache: Optional[KVCache] = None):
    """x: (B,S,D); positions: (B,S). cache -> (out, new_cache_entry)."""
    b, sq, d = x.shape
    dh = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    q = logical(q, ("batch", "attn_seq", "heads", "head_dim"))
    k = logical(k, ("batch", "attn_seq", "kv_heads", "head_dim"))
    v = logical(v, ("batch", "attn_seq", "kv_heads", "head_dim"))
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    cos, sin = rope_table(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is None:
        kr, vr = _maybe_repeat_kv(k, v, cfg.num_heads)
        out = flash_attention(q, kr, vr, positions, positions, causal=causal,
                              window=window, logit_cap=cfg.attn_logit_softcap)
        new_cache = None
    elif sq >= cache.k.shape[1]:
        # bulk prefill: attend over the fresh k/v (identical to the cache
        # contents, but avoids gathering the sequence-sharded cache); write
        # the last S_max tokens into the cache in one shot.
        smax = cache.k.shape[1]
        kr, vr = _maybe_repeat_kv(k, v, cfg.num_heads)
        out = flash_attention(q, kr, vr, positions, positions, causal=causal,
                              window=window, logit_cap=cfg.attn_logit_softcap)
        new_cache = KVCache(
            k=k[:, sq - smax:].astype(cache.k.dtype),
            v=v[:, sq - smax:].astype(cache.v.dtype),
            pos=positions[0, sq - smax:].astype(jnp.int32),
            index=cache.index + sq)
    else:
        # decode/append: write k,v at slot index % S_max (ring buffer for
        # windowed caches; plain append while index < S_max)
        smax = cache.k.shape[1]
        write = cache.index % smax
        kc = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, write, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, write, 0, 0))
        new_pos = jax.lax.dynamic_update_slice(
            cache.pos, cache.index + jnp.arange(sq, dtype=jnp.int32), (write,))
        new_index = cache.index + sq
        kv_pos = jnp.broadcast_to(new_pos[None], (b, smax))
        kv_valid = kv_pos >= 0
        out = flash_attention(q, kc.astype(q.dtype), vc.astype(q.dtype),
                              positions, kv_pos, causal=causal, window=window,
                              logit_cap=cfg.attn_logit_softcap,
                              kv_valid=kv_valid)
        new_cache = KVCache(k=kc, v=vc, pos=new_pos, index=new_index)

    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return logical(out, ("batch", "seq", "embed")), new_cache


# ---------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# ---------------------------------------------------------------------------

def cross_attention(params, x, enc_kv, positions_q, positions_kv,
                    cfg: ModelConfig):
    """enc_kv: precomputed (k, v) from encoder output (B,Senc,Hkv,Dh)."""
    k, v = enc_kv
    dh = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
    out = flash_attention(q, k.astype(q.dtype), v.astype(q.dtype), positions_q,
                          positions_kv, causal=False,
                          logit_cap=cfg.attn_logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return logical(out, ("batch", "seq", "embed"))


def encode_cross_kv(params, enc_out, cfg: ModelConfig):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(enc_out.dtype))
    if cfg.qk_norm:
        k = rmsnorm(k, params["k_norm"])
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def make_mla(make, path: str, cfg: ModelConfig):
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv, dc = m.nope_head_dim, m.rope_head_dim, m.v_head_dim, m.kv_lora_rank
    s = d ** -0.5
    return {
        "wq": make(f"{path}.wq", (d, h, dn + dr), ("embed", "heads", "head_dim"), s),
        "w_dkv": make(f"{path}.w_dkv", (d, dc), ("embed", "kv_lora"), s),
        "w_kr": make(f"{path}.w_kr", (d, dr), ("embed", "head_dim"), s),
        "kv_norm": make(f"{path}.kv_norm", (dc,), ("kv_lora",), init="zeros"),
        "w_uk": make(f"{path}.w_uk", (dc, h, dn), ("kv_lora", "heads", "head_dim"),
                     dc ** -0.5),
        "w_uv": make(f"{path}.w_uv", (dc, h, dv), ("kv_lora", "heads", "head_dim"),
                     dc ** -0.5),
        "wo": make(f"{path}.wo", (h, dv, d), ("heads", "head_dim", "embed"),
                   (h * dv) ** -0.5),
    }


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, S_max, dc) — compressed latent
    k_rope: jax.Array  # (B, S_max, dr)
    index: jax.Array


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, layers: int,
                   dtype) -> MLACache:
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((layers, batch, max_len, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((layers, batch, max_len, m.rope_head_dim), dtype),
        index=jnp.zeros((layers,), jnp.int32))


def _mla_expanded(params, x, qn, qr, kr, c_kv, positions, cfg: ModelConfig):
    """Expanded (training/prefill) MLA attention."""
    m: MLAConfig = cfg.mla
    b, sq = x.shape[0], x.shape[1]
    h = cfg.num_heads
    dn, dr, dv = m.nope_head_dim, m.rope_head_dim, m.v_head_dim
    scale_dim = dn + dr
    kn = jnp.einsum("bsc,chk->bshk", c_kv, params["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsc,chk->bshk", c_kv, params["w_uv"].astype(x.dtype))
    k_full = jnp.concatenate(
        [kn, jnp.broadcast_to(kr[:, :, None, :], (b, sq, h, dr))], axis=-1)
    q_full = jnp.concatenate([qn, qr], axis=-1)
    # pin head-sharding: the rope half of k is a head-broadcast (replicated)
    # tensor — without the constraint GSPMD reshards the concat every flash
    # block and all-reduces in the backward scan
    names = ("batch", "attn_seq", "heads", "head_dim")
    k_full = logical(k_full, names)
    q_full = logical(q_full, names)
    # pad v to the score head-dim so the flash kernel sees uniform D
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, scale_dim - dv)))
    v_pad = logical(v_pad, names)
    out = flash_attention(q_full, k_full, v_pad, positions, positions,
                          causal=True)[..., :dv]
    return out, None


def mla_attention(params, x, positions, cfg: ModelConfig, *,
                  cache: Optional[MLACache] = None):
    m: MLAConfig = cfg.mla
    b, sq, d = x.shape
    h = cfg.num_heads
    dn, dr, dv = m.nope_head_dim, m.rope_head_dim, m.v_head_dim
    scale_dim = dn + dr

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    qn, qr = q[..., :dn], q[..., dn:]
    cos, sin = rope_table(positions, dr, cfg.rope_theta)
    qr = apply_rope(qr, cos, sin)

    c_kv = rmsnorm(jnp.einsum("bsd,dc->bsc", x, params["w_dkv"].astype(x.dtype)),
                   params["kv_norm"])
    kr = jnp.einsum("bsd,dr->bsr", x, params["w_kr"].astype(x.dtype))
    kr = apply_rope(kr[:, :, None, :], cos, sin)[:, :, 0, :]  # shared head

    if cache is not None and sq >= cache.c_kv.shape[1]:
        # bulk prefill: expanded attention + one-shot compressed cache write
        smax = cache.c_kv.shape[1]
        out, _ = _mla_expanded(params, x, qn, qr, kr, c_kv, positions, cfg)
        new_cache = MLACache(
            c_kv=c_kv[:, sq - smax:].astype(cache.c_kv.dtype),
            k_rope=kr[:, sq - smax:].astype(cache.k_rope.dtype),
            index=cache.index + sq)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
        return logical(y, ("batch", "seq", "embed")), new_cache

    if cache is None:
        out, new_cache = _mla_expanded(params, x, qn, qr, kr, c_kv, positions,
                                       cfg)
    else:
        # absorbed decode form: score via latent space, cache stays compressed
        ckc = jax.lax.dynamic_update_slice(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, cache.index, 0))
        krc = jax.lax.dynamic_update_slice(
            cache.k_rope, kr.astype(cache.k_rope.dtype), (0, cache.index, 0))
        new_index = cache.index + sq
        smax = ckc.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(smax, dtype=jnp.int32)[None],
                                  (b, smax))
        kv_valid = kv_pos < new_index
        # absorb W_uk into q: q_lat (B,S,H,dc)
        q_lat = jnp.einsum("bshk,chk->bshc", qn, params["w_uk"].astype(x.dtype))
        # scores in latent+rope space; treat (q_lat|qr) vs (c_kv|k_rope)
        q_cat = jnp.concatenate([q_lat, qr], axis=-1)          # (B,S,H,dc+dr)
        k_cat = jnp.concatenate([ckc, krc], axis=-1).astype(x.dtype)  # (B,Smax,dc+dr)
        k_cat = k_cat[:, :, None, :]                            # Hkv = 1
        # value = latent, padded to match score dim for the flash kernel
        v_lat = jnp.pad(ckc.astype(x.dtype),
                        ((0, 0), (0, 0), (0, dr)))[:, :, None, :]
        # flash divides by sqrt(dc+dr); rescale so the net scale is the
        # expanded form's 1/sqrt(dn+dr)
        out_lat = flash_attention(
            q_cat * (((m.kv_lora_rank + dr) ** 0.5) * (scale_dim ** -0.5)),
            k_cat, v_lat, positions, kv_pos, causal=True,
            kv_valid=kv_valid)[..., :m.kv_lora_rank]
        out = jnp.einsum("bshc,chk->bshk", out_lat,
                         params["w_uv"].astype(x.dtype))
        new_cache = MLACache(c_kv=ckc, k_rope=krc, index=new_index)

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return logical(y, ("batch", "seq", "embed")), new_cache
