"""Parameter system: params are plain pytrees; builders are interpreted twice.

A model is defined by a ``build(make)`` function that calls
``make(path, shape, names, ...)`` for every parameter. Three interpreters:

  init_params   -> arrays (random init, per-path key folding)
  param_shapes  -> jax.ShapeDtypeStruct tree (for dry-run / eval_shape)
  param_names   -> logical-dim-name tree (for sharding specs)

This gives flax-like ergonomics with zero dependencies and exact structural
agreement between the three trees.
"""
from __future__ import annotations

import hashlib
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def _path_key(key: jax.Array, path: str) -> jax.Array:
    h = int.from_bytes(hashlib.md5(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def init_params(build: Callable, key: jax.Array, dtype=jnp.float32):
    def make(path, shape, names, scale=1.0, init="normal", dtype_=None):
        dt = dtype_ or dtype
        if init == "zeros":
            return jnp.zeros(shape, dt)
        if init == "ones":
            return jnp.ones(shape, dt)
        k = _path_key(key, path)
        if init == "uniform_angle":
            return jax.random.uniform(k, shape, dt, -3.14159, 3.14159)
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    return build(make)


def param_shapes(build: Callable, dtype=jnp.float32):
    def make(path, shape, names, scale=1.0, init="normal", dtype_=None):
        return jax.ShapeDtypeStruct(shape, dtype_ or dtype)

    return build(make)


def param_names(build: Callable):
    def make(path, shape, names, scale=1.0, init="normal", dtype_=None):
        return tuple(names)

    return build(make)


def param_specs(build: Callable, mesh, rules=None):
    """PartitionSpec tree for the build's parameters."""
    from repro.parallel.sharding import PARAM_RULES, build_spec

    rules = rules or PARAM_RULES

    def make(path, shape, names, scale=1.0, init="normal", dtype_=None):
        return build_spec(shape, names, mesh, rules)

    return build(make)


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
