"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_a x_t)           (recurrence gate)
    i_t = sigmoid(W_i x_t)           (input gate)
    a_t = exp(-c * softplus(Λ) * r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training uses an associative scan over time (log-depth, parallel); decode
carries h. Bounded state -> assigned the ``long_500k`` shape.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, RGLRUConfig
from repro.models.layers import causal_conv1d
from repro.parallel.sharding import logical

_C = 8.0


def make_rglru(make, path: str, cfg: ModelConfig):
    c: RGLRUConfig = cfg.rglru
    d = cfg.d_model
    w = c.lru_width or d
    s = d ** -0.5
    return {
        "w_y": make(f"{path}.w_y", (d, w), ("embed", "mlp"), s),
        "w_x": make(f"{path}.w_x", (d, w), ("embed", "mlp"), s),
        "conv_w": make(f"{path}.conv_w", (c.conv_width, w), ("conv", "mlp"), 0.2),
        "w_a": make(f"{path}.w_a", (w, w), ("mlp", None), w ** -0.5),
        "w_i": make(f"{path}.w_i", (w, w), ("mlp", None), w ** -0.5),
        "lam": make(f"{path}.lam", (w,), ("mlp",), init="uniform_angle"),
        "w_out": make(f"{path}.w_out", (w, d), ("mlp", "embed"), w ** -0.5),
    }


class RGLRUCache(NamedTuple):
    h: jax.Array      # (B, W) recurrent state
    conv: jax.Array   # (B, K-1, W)


def init_rglru_cache(cfg: ModelConfig, batch: int, layers: int, dtype):
    c = cfg.rglru
    w = c.lru_width or cfg.d_model
    return RGLRUCache(
        h=jnp.zeros((layers, batch, w), jnp.float32),
        conv=jnp.zeros((layers, batch, c.conv_width - 1, w), dtype))


def _lru_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t via associative scan. a,b: (B,S,W)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def apply_rglru(params, x, cfg: ModelConfig,
                cache: Optional[RGLRUCache] = None
                ) -> Tuple[jax.Array, Optional[RGLRUCache]]:
    """Griffin recurrent block. x (B,S,D) -> (B,S,D)."""
    bsz, s, d = x.shape
    y_gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x,
                                    params["w_y"].astype(x.dtype)))
    xi = jnp.einsum("bsd,dw->bsw", x, params["w_x"].astype(x.dtype))
    xi, new_conv = causal_conv1d(xi, params["conv_w"],
                                 cache.conv if cache is not None else None)
    xi = logical(xi, ("batch", "seq", "mlp"))

    xf = xi.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xf,
                                  params["w_a"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xf,
                                  params["w_i"].astype(jnp.float32)))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)

    if cache is None:
        h = _lru_scan(a, gated)
        new_cache = None
    else:
        h0 = cache.h
        if s == 1:
            h = (a[:, 0] * h0 + gated[:, 0])[:, None]
            h_last = h[:, 0]
        else:
            h = _lru_scan(a, gated, h0)
            h_last = h[:, -1]
        new_cache = RGLRUCache(h=h_last, conv=new_conv)

    out = h.astype(x.dtype) * y_gate
    out = jnp.einsum("bsw,wd->bsd", out, params["w_out"].astype(x.dtype))
    return logical(out, ("batch", "seq", "embed")), new_cache
