"""Top-level model API: build/init/apply for every assigned architecture.

  model = Model(cfg)
  params = model.init(key)
  logits, aux = model.forward(params, batch)            # train/prefill
  logits, caches = model.decode_step(params, batch, caches)

`batch` is a dict:
  tokens           (B, S) int32            — LM tokens (decoder side)
  frontend_embeds  (B, F, D)               — VLM patch embeddings (optional)
  enc_embeds       (B, S_enc, D)           — audio frame embeddings (enc-dec)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import params as P
from repro.models.encdec import build_encdec_params, encdec_forward
from repro.models.transformer import build_params, init_caches, lm_forward


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_encdec = cfg.is_encoder_decoder

    # -- parameter builders ------------------------------------------------
    def _build(self, make):
        if self.is_encdec:
            return build_encdec_params(make, self.cfg)
        return build_params(make, self.cfg)

    def init(self, key: jax.Array):
        return P.init_params(self._build, key, dtype=jnp.dtype(self.cfg.param_dtype))

    def shapes(self):
        return P.param_shapes(self._build, dtype=jnp.dtype(self.cfg.param_dtype))

    def specs(self, mesh, rules=None):
        return P.param_specs(self._build, mesh, rules)

    # -- forward -----------------------------------------------------------
    def forward(self, params, batch: Dict[str, Any], features_only=False):
        """Training/scoring forward (no cache). Returns (logits, aux)."""
        cfg = self.cfg
        if self.is_encdec:
            out, _, aux, _ = encdec_forward(
                params, batch["tokens"], batch["enc_embeds"], cfg,
                features_only=features_only)
            return out, aux
        out, _, aux = lm_forward(
            params, batch["tokens"], cfg,
            frontend_embeds=batch.get("frontend_embeds"),
            features_only=features_only)
        return out, aux

    def unembed_table(self, params):
        return (params["embed"]["table"] if self.cfg.tie_embeddings
                else params["unembed"]["table"])

    # -- serving -----------------------------------------------------------
    def init_caches(self, batch: int, max_len: int):
        return init_caches(self.cfg, batch, max_len, jnp.dtype(self.cfg.dtype))

    def prefill(self, params, batch, caches):
        """Prefill the cache with a full prompt; returns (logits, caches, extras)."""
        cfg = self.cfg
        if self.is_encdec:
            logits, caches, _, enc_out = encdec_forward(
                params, batch["tokens"], batch["enc_embeds"], cfg,
                caches=caches, start_index=jnp.zeros((), jnp.int32))
            return logits, caches, {"enc_out": enc_out}
        logits, caches, _ = lm_forward(
            params, batch["tokens"], cfg, caches=caches,
            frontend_embeds=batch.get("frontend_embeds"),
            start_index=jnp.zeros((), jnp.int32))
        return logits, caches, {}

    def decode_step(self, params, batch, caches, index, extras=None):
        """One decode step. batch["tokens"]: (B, 1). index: scalar position."""
        cfg = self.cfg
        if self.is_encdec:
            logits, caches, _, _ = encdec_forward(
                params, batch["tokens"], batch.get("enc_embeds"), cfg,
                caches=caches, enc_out=(extras or {}).get("enc_out"),
                start_index=index)
            return logits, caches
        logits, caches, _ = lm_forward(params, batch["tokens"], cfg,
                                       caches=caches, start_index=index)
        return logits, caches


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def chunked_lm_loss(features, table, labels, cfg: ModelConfig,
                    loss_mask=None, n_chunks: int = 8):
    """Fused unembed + cross-entropy, scanned over sequence chunks.

    Never materializes the full (B, S, V) logits — each rematted chunk
    computes (B, S/n, V), reduces to per-token NLL, and is recomputed in the
    backward pass. This is the big-vocab memory fix (256k-vocab archs would
    otherwise hold multiple multi-GB f32 logits buffers).
    """
    from repro.models.layers import softcap as _softcap

    b, s, d = features.shape
    while s % n_chunks:
        n_chunks -= 1
    cs = s // n_chunks
    xc = features.reshape(b, n_chunks, cs, d).swapaxes(0, 1)  # (n, B, cs, D)
    lc = labels.reshape(b, n_chunks, cs).swapaxes(0, 1)
    mc = (loss_mask.reshape(b, n_chunks, cs).swapaxes(0, 1)
          if loss_mask is not None
          else jnp.ones((n_chunks, b, cs), jnp.float32))

    def body(carry, inp):
        tot, cnt = carry
        xb, lb, mb = inp
        logits = jnp.einsum("bsd,vd->bsv", xb, table.astype(xb.dtype))
        logits = _softcap(logits, cfg.final_logit_softcap)
        logits = logits.astype(jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:
            viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
            logits = jnp.where(viota < cfg.vocab_size, logits, -1e9)
        logz = jax.nn.logsumexp(logits, axis=-1)
        viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        ll = jnp.sum(jnp.where(viota == lb[..., None], logits, 0.0), axis=-1)
        nll = (logz - ll) * mb
        return (tot + jnp.sum(nll), cnt + jnp.sum(mb)), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)

def lm_loss(logits, labels, loss_mask=None):
    """Cross-entropy. labels: (B, S) int32; mask optional (B, S).

    The label logit is extracted with an iota-compare reduction instead of
    ``take_along_axis`` — a gather over the vocab dim would force GSPMD to
    all-gather the vocab-sharded logits (tens of GB); the masked reduction
    stays sharded and psums a scalar.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    onehot = vocab_iota == labels[..., None]
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - ll
    if loss_mask is not None:
        nll = nll * loss_mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(loss_mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Analytic parameter counts (for 6ND roofline)
# ---------------------------------------------------------------------------

def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    dh = cfg.resolved_head_dim
    total = cfg.vocab_size * d  # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d

    def attn_params():
        if cfg.mla is not None:
            m = cfg.mla
            dn, dr, dv, dc = (m.nope_head_dim, m.rope_head_dim, m.v_head_dim,
                              m.kv_lora_rank)
            return (d * cfg.num_heads * (dn + dr) + d * dc + d * dr
                    + dc * cfg.num_heads * (dn + dv) + cfg.num_heads * dv * d)
        return (d * cfg.num_heads * dh + 2 * d * cfg.num_kv_heads * dh
                + cfg.num_heads * dh * d)

    def mlp_params(ff):
        mult = 3 if cfg.mlp_kind == "swiglu" else 2
        return mult * d * ff

    def moe_params(active):
        m = cfg.moe
        routed = m.num_experts if not active else m.top_k
        p = d * m.num_experts  # router (always resident)
        p += routed * 3 * d * m.expert_ff
        p += mlp_params(m.expert_ff * m.num_shared) if m.num_shared else 0
        return p

    fam = cfg.family
    if fam == "ssm":
        c = cfg.ssm
        d_in = c.expand * d
        h = d_in // c.head_dim
        per = (d * (2 * d_in + 2 * c.state_dim + h)
               + c.conv_width * (d_in + 2 * c.state_dim)
               + 3 * h + d_in + d_in * d)
        total += cfg.num_layers * per
    elif fam == "hybrid":
        c = cfg.rglru
        w = c.lru_width or d
        per_rec = 2 * d * w + c.conv_width * w + 2 * w * w + w + w * d
        per_attn = attn_params()
        pat = c.block_pattern
        n_rec = sum(1 for k in pat if k == "recurrent")
        n_att = len(pat) - n_rec
        groups = cfg.num_layers // len(pat)
        total += groups * (n_rec * per_rec + n_att * per_attn
                           + len(pat) * mlp_params(cfg.d_ff))
    elif fam == "moe":
        m = cfg.moe
        first = m.first_moe_layer
        total += cfg.num_layers * attn_params()
        total += first * mlp_params(m.dense_ff or cfg.d_ff)
        total += (cfg.num_layers - first) * moe_params(active_only)
    else:
        layers = cfg.num_layers
        total += layers * (attn_params() + mlp_params(cfg.d_ff))
        if cfg.is_encoder_decoder:
            # encoder stack + decoder cross-attention
            total += cfg.num_encoder_layers * (attn_params()
                                               + mlp_params(cfg.d_ff))
            total += cfg.num_layers * attn_params()
    return int(total)
