"""Decoder-only LM assembly: heterogeneous layer stacks under lax.scan.

An architecture is a sequence of *stacks*; each stack is N structurally
identical layers whose parameters are created with a leading (N, ...) layer
dim and executed with ``jax.lax.scan`` (small HLO -> fast 512-device
compiles). Per-layer *value* variation inside a stack (e.g. gemma-2's
local/global alternation) is threaded as scanned-over arrays; *structural*
variation (dense-vs-MoE first layer, griffin's rec/rec/attn pattern) becomes
separate stacks or grouped layers.
"""
from __future__ import annotations


from jax.ad_checkpoint import checkpoint_name
from typing import Any, Dict, List, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod


# ---------------------------------------------------------------------------
# Stack descriptors
# ---------------------------------------------------------------------------

class Stack(NamedTuple):
    name: str
    n: int              # number of scanned units (layers or groups)
    mixer: str          # gqa | mla | ssm | griffin_group
    ffn: str            # mlp | moe | none
    d_ff: int           # ffn hidden size (dense path)
    pattern: tuple = ()  # griffin group pattern (per-stack)


def stacks_for(cfg: ModelConfig) -> List[Stack]:
    fam = cfg.family
    if fam == "ssm":
        return [Stack("layers", cfg.num_layers, "ssm", "none", 0)]
    if fam == "hybrid":
        pat = cfg.rglru.block_pattern
        n_full = cfg.num_layers // len(pat)
        out = [Stack("groups", n_full, "griffin_group", "mlp", cfg.d_ff,
                     pattern=tuple(pat))]
        rem = cfg.num_layers - n_full * len(pat)
        if rem:  # e.g. recurrentgemma-2b: 26 = 8*(r,r,a) + (r,r)
            out.append(Stack("tail_group", 1, "griffin_group", "mlp",
                             cfg.d_ff, pattern=tuple(pat[:rem])))
        return out
    if fam == "moe":
        mixer = "mla" if cfg.mla is not None else "gqa"
        first = cfg.moe.first_moe_layer
        out = []
        if first > 0:
            out.append(Stack("dense_layers", first, mixer, "mlp",
                             cfg.moe.dense_ff or cfg.d_ff))
        out.append(Stack("moe_layers", cfg.num_layers - first, mixer, "moe", 0))
        return out
    # dense / vlm / audio-decoder
    return [Stack("layers", cfg.num_layers, "gqa", "mlp", cfg.d_ff)]


# ---------------------------------------------------------------------------
# Single block (one layer) param build + apply
# ---------------------------------------------------------------------------

def make_block(make, path: str, cfg: ModelConfig, stack: Stack,
               cross_attn: bool = False):
    p: Dict[str, Any] = {}
    d = cfg.d_model
    if stack.mixer == "gqa":
        p["ln_mix"] = L.make_norm(make, f"{path}.ln_mix", d, cfg.norm_kind)
        p["mix"] = attn.make_gqa(make, f"{path}.mix", cfg)
    elif stack.mixer == "mla":
        p["ln_mix"] = L.make_norm(make, f"{path}.ln_mix", d, cfg.norm_kind)
        p["mix"] = attn.make_mla(make, f"{path}.mix", cfg)
    elif stack.mixer == "ssm":
        p["ln_mix"] = L.make_norm(make, f"{path}.ln_mix", d, cfg.norm_kind)
        p["mix"] = ssm_mod.make_ssm(make, f"{path}.mix", cfg)
    elif stack.mixer == "griffin_group":
        pat = stack.pattern or cfg.rglru.block_pattern
        for j, kind in enumerate(pat):
            p[f"g{j}_ln_mix"] = L.make_norm(make, f"{path}.g{j}.ln_mix", d,
                                            cfg.norm_kind)
            if kind == "recurrent":
                p[f"g{j}_mix"] = rglru_mod.make_rglru(make, f"{path}.g{j}.mix", cfg)
            else:
                p[f"g{j}_mix"] = attn.make_gqa(make, f"{path}.g{j}.mix", cfg)
            p[f"g{j}_ln_ffn"] = L.make_norm(make, f"{path}.g{j}.ln_ffn", d,
                                            cfg.norm_kind)
            p[f"g{j}_ffn"] = L.make_mlp(make, f"{path}.g{j}.ffn", d,
                                        stack.d_ff, cfg.mlp_kind)
    if cross_attn:
        p["ln_cross"] = L.make_norm(make, f"{path}.ln_cross", d, cfg.norm_kind)
        p["cross"] = attn.make_gqa(make, f"{path}.cross", cfg)

    if stack.mixer != "griffin_group":
        if stack.ffn == "mlp":
            p["ln_ffn"] = L.make_norm(make, f"{path}.ln_ffn", d, cfg.norm_kind)
            p["ffn"] = L.make_mlp(make, f"{path}.ffn", d, stack.d_ff,
                                  cfg.mlp_kind)
        elif stack.ffn == "moe":
            p["ln_ffn"] = L.make_norm(make, f"{path}.ln_ffn", d, cfg.norm_kind)
            p["ffn"] = moe_mod.make_moe(make, f"{path}.ffn", cfg)
    return p


def apply_block(p, x, positions, cfg: ModelConfig, stack: Stack,
                window, cache, cross_kv=None, enc_positions=None):
    """Apply one layer. window: scalar (0 = global). Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}

    if stack.mixer == "griffin_group":
        pat = stack.pattern or cfg.rglru.block_pattern
        for j, kind in enumerate(pat):
            h = L.apply_norm(p[f"g{j}_ln_mix"], x, cfg.norm_kind)
            if kind == "recurrent":
                sub = cache.get(f"g{j}") if cache else None
                out, nc = rglru_mod.apply_rglru(p[f"g{j}_mix"], h, cfg, sub)
                if nc is not None:
                    new_cache[f"g{j}"] = nc
            else:
                sub = cache.get(f"g{j}") if cache else None
                out, nc = attn.gqa_attention(
                    p[f"g{j}_mix"], h, positions, cfg, causal=True,
                    window=cfg.window_size, cache=sub)
                if nc is not None:
                    new_cache[f"g{j}"] = nc
            x = x + checkpoint_name(out, "mix_out")
            h = L.apply_norm(p[f"g{j}_ln_ffn"], x, cfg.norm_kind)
            out = L.apply_mlp(p[f"g{j}_ffn"], h, cfg.mlp_kind)
            x = x + checkpoint_name(out, "ffn_out")
        return x, new_cache, aux

    # --- mixer ---
    h = L.apply_norm(p["ln_mix"], x, cfg.norm_kind)
    if stack.mixer == "gqa":
        out, nc = attn.gqa_attention(p["mix"], h, positions, cfg, causal=True,
                                     window=window,
                                     cache=cache.get("kv") if cache else None)
        if nc is not None:
            new_cache["kv"] = nc
    elif stack.mixer == "mla":
        out, nc = attn.mla_attention(p["mix"], h, positions, cfg,
                                     cache=cache.get("mla") if cache else None)
        if nc is not None:
            new_cache["mla"] = nc
    elif stack.mixer == "ssm":
        out, nc = ssm_mod.apply_ssm(p["mix"], h, cfg,
                                    cache=cache.get("ssm") if cache else None)
        if nc is not None:
            new_cache["ssm"] = nc
    out = checkpoint_name(out, "mix_out")
    x = x + out

    # --- cross attention (enc-dec decoder) ---
    if cross_kv is not None:
        h = L.apply_norm(p["ln_cross"], x, cfg.norm_kind)
        x = x + attn.cross_attention(p["cross"], h, cross_kv, positions,
                                     enc_positions, cfg)

    # --- ffn ---
    if stack.ffn == "mlp":
        h = L.apply_norm(p["ln_ffn"], x, cfg.norm_kind)
        out = L.apply_mlp(p["ffn"], h, cfg.mlp_kind)
        x = x + checkpoint_name(out, "ffn_out")
    elif stack.ffn == "moe":
        h = L.apply_norm(p["ln_ffn"], x, cfg.norm_kind)
        out, aux_l = moe_mod.apply_moe(p["ffn"], h, cfg)
        x = x + checkpoint_name(out, "ffn_out")
        aux = aux + aux_l
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Per-layer value variation (windows)
# ---------------------------------------------------------------------------

def window_schedule(cfg: ModelConfig, stack: Stack) -> jnp.ndarray:
    """(n,) int32 window per layer; 0 = global attention."""
    if cfg.attn_kind == "local":
        return jnp.full((stack.n,), cfg.window_size, jnp.int32)
    if cfg.attn_kind == "local_global":
        # gemma-2: even layers local, odd layers global
        ids = jnp.arange(stack.n, dtype=jnp.int32)
        return jnp.where(ids % 2 == 0, cfg.window_size, 0)
    return jnp.zeros((stack.n,), jnp.int32)


# ---------------------------------------------------------------------------
# Full decoder-only LM
# ---------------------------------------------------------------------------

def build_params(make, cfg: ModelConfig, cross_attn: bool = False,
                 with_embed: bool = True):
    """Parameter tree for the decoder (stacked per stack)."""
    p: Dict[str, Any] = {}
    if with_embed:
        p["embed"] = L.make_embedding(make, "embed", cfg.padded_vocab,
                                      cfg.d_model)
    for stack in stacks_for(cfg):
        def stacked_make(path, shape, names, *a, **kw):
            return make(path, (stack.n,) + tuple(shape),
                        ("layers",) + tuple(names), *a, **kw)

        p[stack.name] = make_block(stacked_make, stack.name, cfg, stack,
                                   cross_attn=cross_attn)
    p["final_norm"] = L.make_norm(make, "final_norm", cfg.d_model, cfg.norm_kind)
    if not cfg.tie_embeddings and with_embed:
        p["unembed"] = {"table": make(
            "unembed.table", (cfg.padded_vocab, cfg.d_model),
            ("vocab", "embed"), cfg.d_model ** -0.5)}
    return p


#: tensors worth saving under selective remat: block-level outputs only.
#: Flash-attention internals (per-block scores) are deliberately NOT saved —
#: they are recomputed in the backward pass (standard flash recipe); saving
#: them costs O(S^2) memory.
SAVE_NAMES = ("mix_out", "ffn_out")


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.save_only_these_names(*SAVE_NAMES))


def run_stacks(params, x, positions, cfg: ModelConfig, caches=None,
               cross_kv=None, enc_positions=None):
    """Run every stack. caches: {stack_name: stacked cache pytree} or None.

    Returns (x, new_caches, aux_total).
    """
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    for stack in stacks_for(cfg):
        sp = params[stack.name]
        windows = window_schedule(cfg, stack)
        cache = caches.get(stack.name) if caches is not None else None

        def body(carry, per_layer):
            xx = carry
            lp, win, csl = per_layer
            xx, new_c, aux = apply_block(
                lp, xx, positions, cfg, stack, win, csl,
                cross_kv=cross_kv, enc_positions=enc_positions)
            return xx, (new_c, aux)

        body = _remat_wrap(body, cfg)
        if cache is None:
            # no cache: scan over (params, windows) only
            x, (new_c, auxs) = jax.lax.scan(
                lambda c, pl: body(c, (pl[0], pl[1], None)),
                x, (sp, windows))
        else:
            x, (new_c, auxs) = jax.lax.scan(body, x, (sp, windows, cache))
            new_caches[stack.name] = new_c
        aux_total = aux_total + jnp.sum(auxs)
    return x, new_caches, aux_total


def lm_forward(params, tokens, cfg: ModelConfig, *, caches=None,
               positions=None, frontend_embeds=None, cross_kv=None,
               enc_positions=None, start_index=None, features_only=False):
    """Decoder-only forward.

    tokens: (B, S) int32. frontend_embeds: (B, F, D) prepended (VLM).
    caches: per-stack stacked caches (decode). start_index: scalar cache fill.
    features_only: return final hidden states instead of logits (the trainer
    applies a chunked fused unembed+CE to avoid materializing full logits).
    Returns (logits_or_features, new_caches, aux).
    """
    x = L.embed(params["embed"], tokens, cfg)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        if start_index is not None:
            positions = start_index + jnp.arange(s, dtype=jnp.int32)[None, :]
            positions = jnp.broadcast_to(positions, (b, s))
        else:
            positions = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    x, new_caches, aux = run_stacks(params, x, positions, cfg, caches=caches,
                                    cross_kv=cross_kv,
                                    enc_positions=enc_positions)
    x = L.apply_norm(params["final_norm"], x, cfg.norm_kind)
    if features_only:
        return x, new_caches, aux
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["unembed"]["table"])
    logits = L.unembed({"table": table}, x, cfg)
    return logits, new_caches, aux


# ---------------------------------------------------------------------------
# Cache init (stacked per stack)
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype,
                cross_attn: bool = False):
    caches: Dict[str, Any] = {}
    for stack in stacks_for(cfg):
        if stack.mixer == "gqa":
            win = max_len
            if cfg.attn_kind == "local":
                win = min(max_len, cfg.window_size)
            caches[stack.name] = {"kv": attn.init_kv_cache(
                cfg, batch, win, stack.n, dtype)}
        elif stack.mixer == "mla":
            caches[stack.name] = {"mla": attn.init_mla_cache(
                cfg, batch, max_len, stack.n, dtype)}
        elif stack.mixer == "ssm":
            caches[stack.name] = {"ssm": ssm_mod.init_ssm_cache(
                cfg, batch, stack.n, dtype)}
        elif stack.mixer == "griffin_group":
            sub: Dict[str, Any] = {}
            for j, kind in enumerate(stack.pattern or cfg.rglru.block_pattern):
                if kind == "recurrent":
                    sub[f"g{j}"] = rglru_mod.init_rglru_cache(
                        cfg, batch, stack.n, dtype)
                else:
                    sub[f"g{j}"] = attn.init_kv_cache(
                        cfg, batch, min(max_len, cfg.window_size), stack.n,
                        dtype)
            caches[stack.name] = sub
    return caches
