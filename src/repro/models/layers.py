"""Shared NN layers: norms, MLPs, RoPE, embeddings, softcap."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.parallel.sharding import logical


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


def make_norm(make, path: str, d: int, kind: str):
    if kind == "layernorm":
        return {
            "scale": make(f"{path}.scale", (d,), ("embed",), init="ones"),
            "bias": make(f"{path}.bias", (d,), ("embed",), init="zeros"),
        }
    return {"scale": make(f"{path}.scale", (d,), ("embed",), init="zeros")}


def apply_norm(params, x, kind: str):
    if kind == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    return rmsnorm(x, params["scale"])


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def make_mlp(make, path: str, d_model: int, d_ff: int, kind: str,
             scale: Optional[float] = None):
    s_in = scale or d_model ** -0.5
    s_out = (d_ff) ** -0.5
    p = {
        "w_up": make(f"{path}.w_up", (d_model, d_ff), ("embed", "mlp"), s_in),
        "w_down": make(f"{path}.w_down", (d_ff, d_model), ("mlp", "embed"), s_out),
    }
    if kind == "swiglu":
        p["w_gate"] = make(f"{path}.w_gate", (d_model, d_ff), ("embed", "mlp"), s_in)
    return p


def apply_mlp(params, x, kind: str):
    # names cover the common (batch, seq, feature) case; a constraint with
    # None entries would force those dims REPLICATED, so batch/seq must be
    # named here.
    lead = ("batch", "seq")[:x.ndim - 1]
    up = jnp.einsum("...d,df->...f", x, params["w_up"].astype(x.dtype))
    up = logical(up, lead + ("mlp",))
    if kind == "swiglu":
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(x.dtype))
        h = jax.nn.silu(gate) * up
    elif kind == "squared_relu":
        h = jnp.square(jax.nn.relu(up))
    elif kind == "gelu":
        h = jax.nn.gelu(up)
    else:
        h = jax.nn.relu(up)
    out = jnp.einsum("...f,fd->...d", h, params["w_down"].astype(x.dtype))
    return logical(out, lead + ("embed",))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_table(positions, head_dim: int, theta: float):
    """positions (...,) -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def make_embedding(make, path: str, vocab: int, d_model: int):
    return {"table": make(f"{path}.table", (vocab, d_model),
                          ("vocab", "embed"), scale=1.0)}


def embed(params, tokens, cfg: ModelConfig):
    x = params["table"].astype(cfg.dtype)[tokens]
    if cfg.embedding_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return logical(x, ("batch", "seq", "embed"))


def unembed(params, x, cfg: ModelConfig, tied_table=None):
    table = tied_table if tied_table is not None else params["table"]
    logits = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    logits = softcap(logits, cfg.final_logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        # vocab-padding rows never win: mask to a large negative
        viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                         logits.ndim - 1)
        logits = jnp.where(viota < cfg.vocab_size, logits,
                           jnp.asarray(-1e9, logits.dtype))
    return logical(logits, ("batch", "seq", "vocab"))


def causal_conv1d(x, w, cache=None):
    """Depthwise causal temporal conv. x (B,S,C), w (K,C); cache (B,K-1,C)."""
    k = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1]] * w[i][None, None, :].astype(x.dtype)
    new_cache = xp[:, -(k - 1):] if k > 1 else jnp.zeros(
        (x.shape[0], 0, x.shape[2]), x.dtype)
    return out, new_cache
