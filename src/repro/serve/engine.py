"""Batched serving engine: prefill + decode with slot-based continuous batching.

A fixed pool of B slots; finished requests release their slot and the next
queued request is prefilled into it (its KV region reset by index masking —
the cache `pos` array makes stale entries invisible). Both phases are
single jit'd programs (Fig. 4 rule: one dispatch per step).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


def greedy_sample(logits):
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


class ServeEngine:
    """Synchronous batched engine (one host). For simplicity all slots share
    one decode length clock; per-slot completion is masked."""

    def __init__(self, model: Model, batch_slots: int, max_len: int):
        self.model = model
        self.b = batch_slots
        self.max_len = max_len
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    def _prefill_impl(self, params, tokens, caches):
        logits, caches, _ = (*self.model.prefill(params, {"tokens": tokens},
                                                 caches),)
        next_tok = greedy_sample(logits)
        return next_tok, caches

    def _decode_impl(self, params, tok, caches, index):
        logits, caches = self.model.decode_step(
            params, {"tokens": tok[:, None]}, caches, index)
        return greedy_sample(logits), caches

    def generate(self, params, requests: List[Request]) -> List[Request]:
        """Run all requests to completion with slot reuse."""
        pending = list(requests)
        active: List[Optional[Request]] = [None] * self.b
        while pending or any(a is not None for a in active):
            # fill free slots with the next wave (simple: waves of B)
            wave = []
            for i in range(self.b):
                if active[i] is None and pending:
                    active[i] = pending.pop(0)
                wave.append(active[i])
            live = [r for r in wave if r is not None]
            if not live:
                break
            plen = max(len(r.prompt) for r in live)
            toks = np.zeros((self.b, plen), np.int32)
            for i, r in enumerate(wave):
                if r is not None:
                    toks[i, -len(r.prompt):] = r.prompt  # left-pad
            caches = self.model.init_caches(self.b, self.max_len)
            next_tok, caches = self._prefill(params, jnp.asarray(toks), caches)
            for i, r in enumerate(wave):
                if r is not None:
                    r.out_tokens.append(int(next_tok[i]))
            steps = max(r.max_new_tokens for r in live) - 1
            tok = next_tok
            for s in range(steps):
                index = jnp.asarray(plen + s, jnp.int32)
                tok, caches = self._decode(params, tok, caches, index)
                for i, r in enumerate(wave):
                    if r is not None and len(r.out_tokens) < r.max_new_tokens:
                        r.out_tokens.append(int(tok[i]))
            for i, r in enumerate(wave):
                if r is not None:
                    r.done = True
                    active[i] = None
        return requests
