"""Cross-pod data parallelism with int8 error-feedback gradient compression.

The `pod` axis crosses the slow inter-pod links (DCN / optical), so its
gradient all-reduce is the one worth compressing. This wraps a per-pod train
step in ``shard_map`` over the pod axis: each pod computes grads on its local
batch shard, the pod-axis mean is taken with the int8 error-feedback
collective (``repro.parallel.collectives``), and the residual quantization
error is carried in the optimizer state so the update remains unbiased over
time (error feedback).

Inside a pod, GSPMD handles DP/TP/SP exactly as in the plain step — shard_map
is applied only over `pod`.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import OptimizerConfig
from repro.models.model import Model
from repro.optim.adamw import OptState, adamw_update
from repro.parallel.collectives import compressed_psum
from repro.train.train_step import make_loss_fn


class CompressedState(NamedTuple):
    opt: OptState
    error: Any          # error-feedback residual pytree (f32, like params)


def init_compressed_state(params, opt_state: OptState) -> CompressedState:
    err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return CompressedState(opt=opt_state, error=err)


def make_compressed_train_step(model: Model, opt_cfg: OptimizerConfig,
                               mesh: Mesh, pod_axis: str = "pod"):
    """train_step(params, CompressedState, batch) with int8-EF pod sync."""
    loss_fn = make_loss_fn(model)

    def local_step(params, state: CompressedState, batch):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        # pod-mean with int8 error feedback (slow-link compression)
        mean_grads, new_err = compressed_psum(grads, pod_axis, state.error)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, mean_grads, state.opt)
        metrics = {**metrics, **opt_metrics,
                   "loss": jax.lax.pmean(metrics["loss"], pod_axis)}
        return new_params, CompressedState(opt=new_opt, error=new_err), metrics

    # only the batch is pod-sharded; params/state replicated across pods
    def batch_spec(x):
        return P(pod_axis)

    def step(params, state, batch):
        in_specs = (
            jax.tree.map(lambda _: P(), params),
            jax.tree.map(lambda _: P(), state),
            jax.tree.map(lambda _: P(pod_axis), batch),
        )
        out_specs = (
            jax.tree.map(lambda _: P(), params),
            jax.tree.map(lambda _: P(), state),
            {"loss": P(), "aux": P(), "lr": P(), "grad_norm": P()},
        )
        fn = shard_map(local_step, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
        return fn(params, state, batch)

    return step
