"""Train step: loss, grad, microbatch accumulation, sharded AdamW update.

One jit'd program per step (the paper's Fig. 4 rule applied to training: no
per-item host round trips; data in, metrics out). Gradient reduction across
the data/pod axes is GSPMD-inserted from the shardings; optional int8
error-feedback compression for the pod axis lives in
``repro.parallel.collectives`` (shard_map path).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig, ParallelConfig
from repro.models.model import Model, chunked_lm_loss
from repro.optim.adamw import OptState, adamw_update


def make_loss_fn(model: Model):
    """Fused feature->chunked-CE loss (never materializes full logits)."""

    def loss_fn(params, batch):
        feats, aux = model.forward(params, batch, features_only=True)
        # next-token prediction: position t predicts token t+1
        tokens = batch["tokens"]
        if model.cfg.frontend == "vision":
            # frontend tokens are prepended; slice back to the text region
            f = model.cfg.frontend_tokens
            feats = feats[:, f:]
        loss = chunked_lm_loss(feats[:, :-1], model.unembed_table(params),
                               tokens[:, 1:], model.cfg,
                               batch.get("loss_mask", None))
        return loss + aux, {"loss": loss, "aux": aux}

    return loss_fn


def _split_microbatches(batch: Dict[str, Any], n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by {n} microbatches"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(model: Model, opt_cfg: OptimizerConfig,
                    parallel: Optional[ParallelConfig] = None,
                    grad_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).

    grad_shardings: optional pytree of NamedShardings applied to the
    per-microbatch gradients. With ZeRO-1 (params replicated over `data`)
    this forces a cheap per-microbatch reduce-scatter instead of a full
    all-reduce, deferring the expensive sync to the optimizer.
    """
    loss_fn = make_loss_fn(model)
    micro = parallel.microbatches if parallel else 1

    def shard_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def train_step(params, opt_state: OptState, batch):
        if micro > 1:
            mb = _split_microbatches(batch, micro)

            def acc_step(carry, one):
                gsum, msum = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, one)
                g = shard_grads(g)
                gsum = jax.tree.map(jnp.add, gsum, g)
                msum = jax.tree.map(jnp.add, msum, {"loss": m["loss"],
                                                    "aux": m["aux"]})
                return (gsum, msum), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32)}
            (gsum, msum), _ = jax.lax.scan(acc_step, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / micro, gsum)
            metrics = jax.tree.map(lambda m: m / micro, msum)
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = shard_grads(grads)
        new_params, new_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, **opt_metrics)
        return new_params, new_state, metrics

    return train_step


def make_eval_step(model: Model):
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
