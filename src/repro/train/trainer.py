"""Fault-tolerant training loop.

* auto-resume from the latest checkpoint (params, optimizer, data position)
* periodic async checkpoints, atomic publish, keep-N
* preemption handling: SIGTERM triggers a final checkpoint before exit
* straggler mitigation: a per-step wall-clock deadline; steps that exceed it
  are logged and counted (on real fleets this feeds the health controller
  that evicts slow hosts; here it is observable behaviour under test)
* elastic: restore re-shards onto the current mesh whatever its size
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax

from repro.ckpt.checkpoint import CheckpointManager
from repro.config import TrainConfig
from repro.data.tokens import DataPipeline
from repro.models.model import Model
from repro.optim.adamw import init_opt_state
from repro.train.train_step import make_train_step


@dataclass
class TrainResult:
    steps_run: int
    final_step: int
    losses: List[float] = field(default_factory=list)
    straggler_steps: int = 0
    resumed_from: Optional[int] = None


class Trainer:
    def __init__(self, cfg: TrainConfig, mesh=None,
                 param_shardings=None):
        self.cfg = cfg
        self.mesh = mesh
        self.model = Model(cfg.model)
        self.ckpt = CheckpointManager(cfg.checkpoint.directory,
                                      keep=cfg.checkpoint.keep,
                                      async_save=cfg.checkpoint.async_save)
        self.param_shardings = param_shardings
        self._preempted = False

    def _install_signal_handler(self):
        def handler(signum, frame):
            self._preempted = True

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on main thread (tests)

    def run(self, max_steps: Optional[int] = None) -> TrainResult:
        cfg = self.cfg
        self._install_signal_handler()
        key = jax.random.key(cfg.seed)

        params = self.model.init(key)
        opt_state = init_opt_state(params)
        start_step = 0
        resumed_from = None

        latest = self.ckpt.latest_step()
        if latest is not None:
            state = {"params": params, "opt": opt_state}
            shardings = None
            if self.param_shardings is not None:
                shardings = {"params": self.param_shardings,
                             "opt": jax.tree.map(
                                 lambda _: None, opt_state)}
            (restored, extra) = self.ckpt.restore(latest, state)
            params, opt_state = restored["params"], restored["opt"]
            start_step = int(extra.get("step", latest))
            resumed_from = latest

        pipeline = DataPipeline(cfg.model, cfg.shape, seed=cfg.seed,
                                start_step=start_step, mesh=self.mesh)
        step_fn = jax.jit(make_train_step(self.model, cfg.optimizer,
                                          cfg.parallel))

        total = max_steps if max_steps is not None else cfg.optimizer.total_steps
        losses: List[float] = []
        stragglers = 0
        step = start_step
        try:
            while step < total:
                batch = next(pipeline)
                t0 = time.monotonic()
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.monotonic() - t0
                if cfg.straggler_deadline_s and dt > cfg.straggler_deadline_s:
                    stragglers += 1
                losses.append(loss)
                step += 1
                if step % cfg.log_every == 0:
                    print(f"step {step} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms)", flush=True)
                if step % cfg.checkpoint.every_steps == 0 or self._preempted:
                    self.ckpt.save(step, {"params": params, "opt": opt_state},
                                   extra={"step": step,
                                          "data_state": pipeline.state()})
                if self._preempted:
                    break
        finally:
            pipeline.close()
            self.ckpt.wait()
        return TrainResult(steps_run=step - start_step, final_step=step,
                           losses=losses, straggler_steps=stragglers,
                           resumed_from=resumed_from)
