"""Config system: typed dataclasses, a registry, and CLI overrides.

Every selectable architecture registers a ``ModelConfig`` factory under an id
(``--arch <id>``). Configs are plain frozen dataclasses so they hash and can be
closed over by jit without retracing surprises.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (DeepSeek-style fine-grained MoE)."""

    num_experts: int = 0          # routed experts
    num_shared: int = 0           # always-on shared experts
    top_k: int = 0
    expert_ff: int = 0            # per-expert hidden size
    router_aux_weight: float = 0.001
    # layers [first_moe_layer, num_layers) are MoE; earlier layers are dense
    first_moe_layer: int = 1
    dense_ff: int = 0             # ff size of the dense (non-MoE) layers
    capacity_factor: float = 1.25  # per-expert token capacity multiplier


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 = full-rank q projection
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block config."""

    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block config."""

    lru_width: int = 0            # 0 -> d_model
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | moe | ssm | hybrid | vlm | audio | lartpc
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    head_dim: int = 0             # 0 -> d_model // num_heads
    d_ff: int = 256
    vocab_size: int = 1000
    max_seq_len: int = 8192
    # attention details
    attn_kind: str = "global"     # global | local | local_global | none
    window_size: int = 4096       # for local attention
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    # mlp
    mlp_kind: str = "swiglu"      # swiglu | squared_relu | gelu | relu
    # norm / embeddings
    norm_kind: str = "rmsnorm"    # rmsnorm | layernorm
    tie_embeddings: bool = False
    embedding_scale: bool = False  # gemma-style sqrt(d_model) input scaling
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # enc-dec
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    # multimodal stub frontends: number of precomputed embedding positions
    frontend: str = "none"        # none | vision | speech
    frontend_tokens: int = 0
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # remat: none | full | selective
    remat: str = "selective"

    #: embedding/unembedding tables are padded to a multiple of this so the
    #: vocab dim shards cleanly over the model axis (Megatron convention)
    vocab_pad_to: int = 256

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return (self.vocab_size + m - 1) // m * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model flops)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


# ---------------------------------------------------------------------------
# LArTPC sim config (the paper's own workload)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LArTPCConfig:
    name: str = "lartpc_uboone"
    family: str = "lartpc"
    # readout grid (paper: ~10k x 10k)
    num_wires: int = 2560          # one plane of MicroBooNE-like detector
    num_ticks: int = 9592          # readout window, 0.5 us ticks
    # depos
    num_depos: int = 100_000       # paper benchmarks 100k depos
    patch_wires: int = 20          # paper: ~20x20 patches
    patch_ticks: int = 20
    # padded (TPU-tile aligned) patch shape used by kernels
    pad_wires: int = 24
    pad_ticks: int = 128
    # physics-ish constants (arbitrary but shaped like the real thing)
    wire_pitch_mm: float = 3.0
    tick_us: float = 0.5
    drift_speed_mm_us: float = 1.6
    diffusion_long: float = 6.4    # mm^2/us-ish scaled
    diffusion_tran: float = 9.8
    # drift-stage diffusion shaping: width = sqrt(2 D t_drift) / metric
    #   * diffusion_scale + floor (floors keep patches resolvable; scale
    #   maps the synthetic diffusion constants onto patch-sized widths)
    diffusion_scale: float = 1e-2
    sigma_w_floor: float = 0.6     # wire units
    sigma_t_floor: float = 0.8     # tick units
    # drift-stage charge physics; defaults reproduce the seed behavior
    # (no attenuation, unit recombination survival)
    electron_lifetime_us: float = 0.0   # 0 disables lifetime attenuation
    recombination: float = 1.0          # flat recombination survival factor
    # jnp: vectorized transport; auto: resolve via the strategy registry
    drift_strategy: str = "jnp"
    nsigma: float = 3.0
    # electrons per depo (mean), fluctuation model
    electrons_per_depo: float = 5000.0
    fluctuate: bool = True
    # counter : threefry counter RNG, normal approximation (TPU-native)
    # pool    : paper-faithful pre-computed normal pool
    # relaxed : the counter draw with NaN-free reverse-mode gradients —
    #           value-identical forward (bit-for-bit with "counter"), but
    #           the zero-variance sqrt is reparameterized so jax.grad of
    #           the pipeline is finite (see docs/calibration.md)
    # none    : no fluctuation
    rng_strategy: str = "counter"  # counter | pool | relaxed | none
    # xla: one scatter HLO (best single-device default);
    # sort_segment: sorted sequential-traffic form (TPU-oriented);
    # pallas: owner-computes tile kernel (dense tile grid);
    # pallas_compact: owner-computes over OCCUPIED tiles only;
    # auto: resolve via the kernel-strategy registry / tuning cache
    # (repro.tune — see docs/tuning.md)
    scatter_strategy: str = "xla"
    # unfused: rasterize -> fluctuate -> scatter_add;
    # unfused_bf16: same chain with bfloat16 patches (half the HBM traffic);
    # fused_pallas: single rasterize+fluctuate+scatter kernel (in-kernel RNG);
    # fused_pallas_compact: fused kernel over occupied tiles only; auto
    charge_grid_strategy: str = "unfused"
    # patch array dtype between rasterize and scatter ("float32" |
    # "bfloat16"): bf16 halves the (N, pw, pt) HBM traffic; accumulation
    # into the readout grid always happens in float32
    patch_dtype: str = "float32"
    # rfft2 | fft2 | auto — frequency-domain convolution layout
    fft_strategy: str = "rfft2"
    pipeline: str = "fig4"         # fig3 | fig4
    # response
    response_ticks: int = 200
    response_wires: int = 21       # +-10 wires induction span
    # overall response amplitude (dimensionless gain on the normalized
    # kernel) and electronics shaping time [us] — exposed as config fields
    # so gradient-based calibration (docs/calibration.md) can fit them; the
    # defaults reproduce the previous hard-coded response bit-for-bit
    response_gain: float = 1.0
    response_shaping_us: float = 2.0
    noise_rms_adc: float = 1.2
    adc_per_electron: float = 0.01
    adc_baseline: float = 900.0
    # straight-through estimator for the digitize round/clip: forward values
    # are UNCHANGED (round-then-clip and clip-then-round agree for integer
    # rails) but the output stays float32 and gradients pass straight
    # through inside the ADC rails (zero outside). Default False keeps the
    # int16 seed path bit-identical; the fit driver flips it on.
    digitize_ste: bool = False
    dtype: str = "float32"
    # ---- multi-plane readout geometry (ISSUE 5 tentpole) ----
    # number of wire planes read out per event. 1 (the default) is the seed
    # single-plane readout, bit-identical to every pre-multi-plane revision;
    # 3 is the paper-faithful MicroBooNE-like U/V/W triple (two induction
    # planes at +-60 degrees, one vertical collection plane). The per-plane
    # tuples below describe the full triple and are consumed as the first
    # ``num_planes`` entries when ``num_planes > 1`` (see ``plane_specs``).
    num_planes: int = 1
    # wire ANGLE per plane, degrees from vertical; the pitch direction the
    # ``wire`` coordinate indexes is perpendicular to the wires
    plane_angles_deg: Tuple[float, ...] = (60.0, -60.0, 0.0)
    # per-plane wire pitch [mm]; () means ``wire_pitch_mm`` for every plane
    plane_pitches_mm: Tuple[float, ...] = ()
    # per-plane field-response type: "induction" (bipolar) | "collection"
    # (unipolar) — selects the plane's ``make_response`` kernel
    plane_types: Tuple[str, ...] = ("induction", "induction", "collection")
    # how the plane axis is dispatched when ``num_planes > 1`` (ISSUE 9):
    #   loop    : the original static Python loop — P charge-grid/convolve/
    #             noise programs and (distributed) P collectives per step
    #   stacked : one batched dispatch over a real (P, ...) array axis —
    #             plane-vmapped charge grid, one batched rfft2 with stacked
    #             per-plane response spectra, one batched noise draw, and a
    #             single reduce-scatter / all_to_all in the distributed
    #             executor. Bit-identical to "loop" (same per-plane
    #             fold_in subkeys)
    #   auto    : "stacked" for multi-plane configs, "loop" otherwise
    plane_batching: str = "auto"
    # ---- sim -> recon loop (ISSUE 6): deconvolution + hit finding ----
    # frequency-domain filter applied with the inverse response:
    #   wiener   : conj(R) / (|R|^2 + lambda * max|R|^2) — optimal-ish
    #              inversion with bounded gain where |R| is small
    #   gaussian : the same bounded inversion times a Gaussian low-pass
    #              along the time-frequency axis (DC gain exactly 1)
    deconv_filter: str = "wiener"
    # Wiener regularizer, as a fraction of max |R|^2 over the spectrum;
    # bounds the filter gain at 1 / (2 sqrt(lambda * max|R|^2))
    deconv_wiener_lambda: float = 2e-3
    # Gaussian low-pass cutoff, as a fraction of the time-axis Nyquist
    deconv_gauss_cut: float = 0.25
    # rfft2: direct half-spectrum inversion; fft_reuse: dispatch through the
    # tuned fft_convolve machinery (inverse filter as a DetectorResponse);
    # auto: tuning cache / backend default (plane-keyed, like fft_strategy)
    deconv_strategy: str = "rfft2"
    # scan: vectorized lax.scan threshold ROI finder (XLA); pallas: per-wire
    # Pallas scan kernel; auto (default): resolve via the strategy registry /
    # tuning cache — both strategies share one ROI-scan body, so the choice
    # is a pure perf decision (bit-identical outputs either way)
    hitfind_strategy: str = "auto"
    # hit threshold on the deconvolved charge, electrons per pixel; runs of
    # consecutive above-threshold ticks on one wire become hits
    hit_threshold: float = 500.0
    # HitSet capacity per plane (mask-padded, fixed shape for jit/vmap)
    max_hits: int = 4096
    # per-wire ROI capacity before compaction into the global HitSet
    max_hits_per_wire: int = 8
    # ---- fault tolerance (ISSUE 8): in-graph numeric sentinel ----
    # True wraps every float-producing stage with a jit-cheap
    # ``jnp.isfinite`` reduction, AND-ed into a ``finite_ok`` output flag
    # (per event under vmap) so the streaming layer can count events whose
    # pipeline went NaN/Inf mid-flight. Off (the default) adds NOTHING to
    # the traced program — bit-identical output (docs/robustness.md)
    check_finite: bool = False


class PlaneSpec(NamedTuple):
    """Resolved geometry of one readout plane (plain data, hashable)."""

    index: int
    kind: str          # "induction" | "collection"
    angle_deg: float   # wire angle from vertical, degrees
    pitch_mm: float    # wire pitch of this plane


def plane_specs(cfg: "LArTPCConfig") -> Tuple[PlaneSpec, ...]:
    """Resolved per-plane geometry of ``cfg``.

    ``num_planes == 1`` is the seed single-plane readout: identity
    projection (wires perpendicular to the generator's transverse axis,
    angle 0, pitch ``wire_pitch_mm``) with the bipolar induction response —
    the exact pre-multi-plane behavior, so the plane tuples are not
    consulted. ``num_planes > 1`` reads the first ``num_planes`` entries of
    ``plane_angles_deg`` / ``plane_pitches_mm`` / ``plane_types``.
    """
    if cfg.num_planes < 1:
        raise ValueError(f"num_planes must be >= 1, got {cfg.num_planes}")
    if cfg.num_planes == 1:
        return (PlaneSpec(0, "induction", 0.0, cfg.wire_pitch_mm),)
    pitches = cfg.plane_pitches_mm or (cfg.wire_pitch_mm,) * cfg.num_planes
    for name, tup in (("plane_angles_deg", cfg.plane_angles_deg),
                      ("plane_pitches_mm", pitches),
                      ("plane_types", cfg.plane_types)):
        if len(tup) < cfg.num_planes:
            raise ValueError(
                f"{name} has {len(tup)} entries < num_planes={cfg.num_planes}")
    for kind in cfg.plane_types[: cfg.num_planes]:
        if kind not in ("induction", "collection"):
            raise ValueError(f"unknown plane type {kind!r}; expected "
                             "'induction' or 'collection'")
    return tuple(
        PlaneSpec(p, cfg.plane_types[p], cfg.plane_angles_deg[p], pitches[p])
        for p in range(cfg.num_planes))


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                     # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


# ---------------------------------------------------------------------------
# Run/training config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParallelConfig:
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: str = "pod"
    fsdp: bool = True              # shard params over data axis
    expert_axis: str = "model"     # EP placement
    sequence_parallel: bool = False
    grad_compression: str = "none"  # none | int8_ef
    microbatches: int = 1
    remat_policy: str = "selective"


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"       # cosine | linear | constant


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str = "/tmp/repro_ckpt"
    every_steps: int = 50
    keep: int = 3
    async_save: bool = True


@dataclass(frozen=True)
class TrainConfig:
    model: Any = None
    shape: ShapeConfig = SHAPES["train_4k"]
    parallel: ParallelConfig = ParallelConfig()
    optimizer: OptimizerConfig = OptimizerConfig()
    checkpoint: CheckpointConfig = CheckpointConfig()
    seed: int = 0
    log_every: int = 10
    straggler_deadline_s: float = 0.0   # 0 disables


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], Any]] = {}
_SMOKE_REGISTRY: Dict[str, Callable[[], Any]] = {}


def register(arch_id: str, full: Callable[[], Any], smoke: Callable[[], Any]) -> None:
    _REGISTRY[arch_id] = full
    _SMOKE_REGISTRY[arch_id] = smoke


def get_config(arch_id: str, smoke: bool = False):
    import repro.configs  # noqa: F401  (triggers registration)

    table = _SMOKE_REGISTRY if smoke else _REGISTRY
    if arch_id not in table:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(table)}")
    return table[arch_id]()


def list_archs() -> Sequence[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


def apply_overrides(cfg, overrides: Dict[str, Any]):
    """dot.path=value overrides onto nested frozen dataclasses."""
    for key, value in overrides.items():
        parts = key.split(".")
        cfg = _apply_one(cfg, parts, value)
    return cfg


def _apply_one(cfg, parts, value):
    if len(parts) == 1:
        fld = {f.name: f for f in dataclasses.fields(cfg)}[parts[0]]
        typ = fld.type
        if isinstance(value, str):
            if typ in ("int", int):
                value = int(value)
            elif typ in ("float", float):
                value = float(value)
            elif typ in ("bool", bool):
                value = value.lower() in ("1", "true", "yes")
        return replace(cfg, **{parts[0]: value})
    sub = getattr(cfg, parts[0])
    return replace(cfg, **{parts[0]: _apply_one(sub, parts[1:], value)})
