"""Sharded AdamW + LR schedules (no optax dependency).

Optimizer state is a pytree mirroring params (m, v) and therefore inherits
the params' sharding (FSDP shards optimizer state for free — ZeRO-style).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any = None   # f32 master weights when params are low precision


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    low_precision = any(x.dtype != jnp.float32
                        for x in jax.tree.leaves(params))
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if low_precision else None)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.copy, zeros), master=master)


def lr_at(cfg: OptimizerConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        decay = 1.0 - frac
    else:  # cosine
        frac = jnp.clip((step - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(cfg: OptimizerConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics).

    Mixed precision: when the model params are bf16 the update is applied to
    the f32 master copy in `state.master` and the bf16 params are re-derived
    (so the forward/backward all-gathers move half the bytes).
    """
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        p32 = master if master is not None else p.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + cfg.weight_decay * p32
        new32 = p32 - lr * delta
        return new32.astype(p.dtype), m2, v2, (new32 if master is not None
                                               else None)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_mast = (jax.tree.leaves(state.master) if state.master is not None
                 else [None] * len(flat_p))
    new = [upd(p, g, m, v, mw) for p, g, m, v, mw
           in zip(flat_p, flat_g, flat_m, flat_v, flat_mast)]
    new_p = jax.tree.unflatten(treedef, [x[0] for x in new])
    new_m = jax.tree.unflatten(treedef, [x[1] for x in new])
    new_v = jax.tree.unflatten(treedef, [x[2] for x in new])
    new_master = (jax.tree.unflatten(treedef, [x[3] for x in new])
                  if state.master is not None else None)
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, OptState(step=step, m=new_m, v=new_v,
                           master=new_master), metrics
