"""Multi-plane (U/V/W) readout tests — the ISSUE 5 tentpole contract.

Three families:
  * geometry: the drift stage projects each depo's transverse position onto
    every plane's pitch direction (hand-checked coefficients), and the
    identity plane is bit-for-bit the single-plane drift;
  * executors: single / batched / streaming runs of a 3-plane config agree
    with each other and carry the leading plane axis (the distributed
    executor is covered by examples/sim_distributed.py --planes 3 in CI);
  * physics shape: induction planes produce bipolar waveforms, the
    collection plane unipolar ones — the paper's Fig. 2 signature.

Single-plane bit-identity with the pre-multi-plane revision is pinned
separately by the golden digests in tests/test_stages.py.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, plane_specs
from repro.core.batch import (empty_event, event_keys, make_batched_sim_fn,
                              pack_events)
from repro.core.depo import (generate_depos, generate_physical_depos,
                             generate_plane_depos)
from repro.core.drift import (PhysicalDepoSet, project_to_plane, transport,
                              transport_planes)
from repro.core.pipeline import make_sim_fn
from repro.core.response import make_plane_responses, make_response
from repro.core.stages import build_sim_graph

CFG = get_config("lartpc-uboone", smoke=True)
CFG3 = dataclasses.replace(CFG, num_planes=3)
#: deterministic physics for bitwise cross-checks
CFG3_QUIET = dataclasses.replace(CFG3, fluctuate=False)


class TestPlaneSpecs:
    def test_single_plane_is_seed_geometry(self):
        (spec,) = plane_specs(CFG)
        assert spec.kind == "induction"
        assert spec.angle_deg == 0.0
        assert spec.pitch_mm == CFG.wire_pitch_mm

    def test_default_triple_is_uvw(self):
        specs = plane_specs(CFG3)
        assert [s.kind for s in specs] == ["induction", "induction",
                                           "collection"]
        assert [s.angle_deg for s in specs] == [60.0, -60.0, 0.0]
        assert all(s.pitch_mm == CFG.wire_pitch_mm for s in specs)

    def test_validation(self):
        with pytest.raises(ValueError, match="num_planes"):
            plane_specs(dataclasses.replace(CFG, num_planes=0))
        with pytest.raises(ValueError, match="plane_angles_deg"):
            plane_specs(dataclasses.replace(CFG, num_planes=4))
        with pytest.raises(ValueError, match="plane type"):
            plane_specs(dataclasses.replace(
                CFG, num_planes=2, plane_types=("induction", "bogus")))


class TestProjection:
    def test_projection_coefficients(self):
        """Relative wire coordinates follow
        Δwire_p = (Δy_mm cos(angle) + Δz_mm sin(angle)) / pitch_p
        (the per-plane centering offset cancels in the difference)."""
        pd = PhysicalDepoSet(
            x=jnp.array([10.0, 10.0]), y=jnp.array([7.0, 12.0]),
            z=jnp.array([33.0, 20.0]), t=jnp.zeros(2), q=jnp.full(2, 1e3))
        for spec in plane_specs(CFG3):
            proj = project_to_plane(pd, spec, CFG3)
            rad = math.radians(spec.angle_deg)
            expect = ((12.0 - 7.0) * CFG3.wire_pitch_mm * math.cos(rad)
                      + (20.0 - 33.0) * math.sin(rad)) / spec.pitch_mm
            got = float(proj.y[1] - proj.y[0])
            np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)

    def test_projection_centered_on_grid(self):
        """Rotated planes are centered: the bulk of a generated event lands
        inside [0, num_wires) on EVERY plane (only the ±60° corner
        overhangs that num_wires wires cannot cover may clip)."""
        pd = generate_physical_depos(jax.random.key(0), CFG3)
        d = transport_planes(pd, CFG3)
        for p in range(3):
            w = np.asarray(d.wire[p])
            inb = ((w >= 0) & (w <= CFG3.num_wires - 1)).mean()
            assert inb > 0.8, (p, inb)
            # centered: the event's midpoint sits near the grid center
            mid = 0.5 * (w.min() + w.max())
            assert 0.2 * CFG3.num_wires < mid < 0.8 * CFG3.num_wires, (p, mid)

    def test_identity_plane_projection_is_bitwise_noop(self):
        """The angle-0, reference-pitch plane must not round-trip through
        unit constants: its projection returns the input leaves unchanged."""
        pd = generate_physical_depos(jax.random.key(0), CFG3)
        spec = plane_specs(CFG3)[2]
        proj = project_to_plane(pd, spec, CFG3)
        assert proj.y is pd.y

    def test_collection_plane_drift_equals_single_plane_drift(self):
        """Plane W (identity geometry) of the multi-plane transport is
        bit-for-bit the seed single-plane transport."""
        pd = generate_physical_depos(jax.random.key(1), CFG3)
        multi = transport_planes(pd, CFG3)
        single = transport(pd, CFG)
        for f in multi._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(multi, f)[2]),
                np.asarray(getattr(single, f)), err_msg=f)

    def test_plane_restriction_matches_full_transport(self):
        pd = generate_physical_depos(jax.random.key(2), CFG3)
        full = transport_planes(pd, CFG3)
        only1 = transport_planes(pd, CFG3, planes=(1,))
        np.testing.assert_array_equal(np.asarray(only1.wire[0]),
                                      np.asarray(full.wire[1]))

    def test_restricted_graph_selects_plane_from_predrifted_input(self):
        """A planes=(p,)-restricted graph fed FULL pre-drifted (P, N) depos
        selects plane p's rows — same output as feeding physical depos."""
        key = jax.random.key(8)
        pd = generate_physical_depos(key, CFG3_QUIET)
        graph = build_sim_graph(CFG3_QUIET, add_noise=False, planes=(2,))
        from_physical = graph.run(key, pd)
        from_predrifted = graph.run(key, transport_planes(pd, CFG3_QUIET))
        np.testing.assert_array_equal(np.asarray(from_physical.adc),
                                      np.asarray(from_predrifted.adc))

    def test_predrifted_wrong_plane_count_rejected(self):
        two_plane_depos = transport_planes(
            generate_physical_depos(jax.random.key(0), CFG3), CFG3,
            planes=(0, 1))
        with pytest.raises(ValueError, match="carry 2 planes"):
            build_sim_graph(CFG3).run(jax.random.key(0), two_plane_depos)

    def test_rotated_planes_differ(self):
        """U/V see genuinely different wire coordinates (z extent is real)."""
        pd = generate_physical_depos(jax.random.key(3), CFG3)
        d = transport_planes(pd, CFG3)
        assert float(jnp.abs(d.wire[0] - d.wire[1]).max()) > 1.0
        assert float(jnp.abs(d.wire[0] - d.wire[2]).max()) > 1.0


class TestExecutors:
    def test_single_event_shapes_and_dtype(self):
        key = jax.random.key(0)
        out = make_sim_fn(CFG3)(key, generate_physical_depos(key, CFG3))
        shape3 = (3, CFG3.num_wires, CFG3.num_ticks)
        assert out.adc.shape == shape3 and out.adc.dtype == jnp.int16
        assert out.signal.shape == shape3
        assert out.charge_grid.shape == shape3

    def test_physical_and_predrifted_inputs_agree(self):
        if jax.default_backend() != "cpu":
            pytest.skip("bitwise jit-vs-eager drift is CPU-specific")
        key = jax.random.key(4)
        sim = make_sim_fn(CFG3)
        a = sim(key, generate_physical_depos(key, CFG3))
        b = sim(key, generate_plane_depos(key, CFG3))
        np.testing.assert_array_equal(np.asarray(a.adc), np.asarray(b.adc))

    def test_planeless_depos_rejected(self):
        with pytest.raises(ValueError, match="planeless"):
            make_sim_fn(CFG3)(jax.random.key(0),
                              generate_depos(jax.random.key(0), CFG))

    def test_single_response_rejected(self):
        with pytest.raises(ValueError, match="single"):
            build_sim_graph(CFG3, make_response(CFG3))

    def test_batched_rows_equal_single_event_runs(self):
        """The vmap executor over 3-plane events matches per-event runs of
        the same graph — the multi-plane analogue of the single-plane
        equivalence pinned in test_stages."""
        key = jax.random.key(5)
        events = [generate_plane_depos(jax.random.fold_in(key, e), CFG3)
                  for e in range(2)]
        batch = pack_events(events)
        assert batch.wire.shape == (2, 3, CFG3.num_depos)
        keys = event_keys(key, range(2))
        out = make_batched_sim_fn(CFG3)(keys, batch)
        assert out.adc.shape == (2, 3, CFG3.num_wires, CFG3.num_ticks)
        sim = make_sim_fn(CFG3)
        for e in range(2):
            ref = sim(keys[e], batch.event(e))
            np.testing.assert_array_equal(np.asarray(out.adc[e]),
                                          np.asarray(ref.adc))

    def test_streaming_multi_plane(self):
        from repro.launch.sim import stream_simulate

        seen = {}

        def on_batch(b, n_valid, n_depos, dt, out):
            seen[b] = (n_valid, tuple(out.adc.shape))

        stats = stream_simulate(CFG3, num_events=3, batch_events=2,
                                on_batch=on_batch)
        assert stats["events"] == 3
        assert seen[0] == (2, (2, 3, CFG3.num_wires, CFG3.num_ticks))
        assert seen[1][0] == 1  # padded final batch reports 1 valid event

    def test_empty_event_padding_is_inert(self):
        """A short 3-plane batch pads with (P, 0)-shaped empty events whose
        rows produce a baseline-only readout."""
        key = jax.random.key(6)
        events = [generate_plane_depos(key, CFG3), empty_event(planes=3)]
        cfg = dataclasses.replace(CFG3_QUIET)
        out = make_batched_sim_fn(cfg, add_noise=False)(
            event_keys(key, range(2)), pack_events(events))
        pad_adc = np.asarray(out.adc[1])
        assert (pad_adc == int(cfg.adc_baseline)).all()


class TestPhysicsShape:
    """Bipolar induction / unipolar collection — the acceptance-criterion
    waveform check, on the noise-free deterministic chain."""

    @pytest.fixture(scope="class")
    def signal(self):
        key = jax.random.key(0)
        out = make_sim_fn(CFG3_QUIET, add_noise=False)(
            key, generate_physical_depos(key, CFG3_QUIET))
        return np.asarray(out.signal)

    def test_induction_planes_bipolar(self, signal):
        for p in (0, 1):
            pos, neg = signal[p].max(), -signal[p].min()
            assert pos > 0 and neg > 0.25 * pos, (p, pos, neg)

    def test_collection_plane_unipolar(self, signal):
        pos, neg = signal[2].max(), -signal[2].min()
        assert pos > 0
        assert neg <= 1e-3 * pos, (pos, neg)

    def test_adc_swings_both_ways_on_induction_only(self):
        key = jax.random.key(0)
        out = make_sim_fn(CFG3_QUIET, add_noise=False)(
            key, generate_physical_depos(key, CFG3_QUIET))
        adc = np.asarray(out.adc).astype(int) - int(CFG3_QUIET.adc_baseline)
        assert adc[0].min() < -5 and adc[0].max() > 5
        assert adc[1].min() < -5 and adc[1].max() > 5
        assert adc[2].min() >= -1 and adc[2].max() > 5

    def test_collection_plane_equals_single_plane_collection_run(self):
        """Plane W shares the seed geometry, so a 3-plane quiet run's third
        plane is bit-identical to a single-plane run with the collection
        response — multi-plane machinery adds no numeric drift."""
        key = jax.random.key(7)
        pd = generate_physical_depos(key, CFG3_QUIET)
        out3 = jax.jit(build_sim_graph(CFG3_QUIET, add_noise=False).run)(
            key, pd)
        cfg1 = dataclasses.replace(CFG3_QUIET, num_planes=1)
        resp = make_response(cfg1, plane="collection")
        out1 = jax.jit(build_sim_graph(cfg1, resp, add_noise=False).run)(
            key, pd)
        np.testing.assert_array_equal(np.asarray(out3.adc[2]),
                                      np.asarray(out1.adc))


class TestPlaneResponses:
    def test_make_plane_responses_kinds(self):
        resps = make_plane_responses(CFG3)
        assert [r.plane for r in resps] == ["induction", "induction",
                                            "collection"]
        # collection kernel is non-negative, induction kernel is bipolar
        assert float(resps[2].kernel.min()) >= 0.0
        assert float(resps[0].kernel.min()) < 0.0

    def test_fft_tuning_keyed_by_plane(self):
        """The fft_convolve tuning key carries the plane kind, so induction
        and collection decisions cannot alias (the autotune satellite)."""
        from repro.tune import autotune

        shape_i = autotune.op_shape("fft_convolve", CFG)
        assert shape_i["plane"] == "induction"
        shape_c = dict(shape_i, plane="collection")
        key_i = autotune.cache_key("fft_convolve", "cpu", "cpu", shape_i)
        key_c = autotune.cache_key("fft_convolve", "cpu", "cpu", shape_c)
        assert key_i != key_c
        assert "plane=induction" in key_i and "plane=collection" in key_c

    def test_multi_plane_auto_fft_stays_per_plane(self, tmp_path):
        """resolve_config on a multi-plane config must NOT bake one concrete
        fft strategy into the field (that would key every plane to the
        plane-0 decision): the field stays "auto" — resolved per dispatch
        with plane=resp.plane — and tuning produces one decision (and one
        cache key) per distinct plane kind."""
        import os

        from repro.tune import autotune

        cfg = dataclasses.replace(CFG3, fft_strategy="auto")
        cache = autotune.TuneCache(str(tmp_path / "cache.json"))
        os.environ.pop("REPRO_TUNE_CACHE", None)
        resolved, decisions = autotune.resolve_config_with_decisions(
            cfg, cache=cache)
        assert resolved.fft_strategy == "auto"
        fft_d = [d for d in decisions if d.op == "fft_convolve"]
        assert len(fft_d) == 2  # induction + collection
        planes = {d.cache_key.split("plane=")[1].split(";")[0]
                  for d in fft_d if "plane=" in d.cache_key}
        assert planes == {"collection", "induction"}
        # tuning measures each kind and persists per-kind cache entries
        # (other "auto" ops — e.g. hit_find — also reach the timer; give
        # their candidates a flat score so only the fft ranking is forced)
        fake = lambda name, thunk: {"rfft2": 1.0, "fft2": 2.0}.get(name, 1.0)  # noqa: E731
        _, tuned = autotune.resolve_config_with_decisions(
            cfg, tune=True, cache=cache, timer=fake)
        tuned_fft = [d for d in tuned if d.op == "fft_convolve"]
        assert {d.source for d in tuned_fft} == {"tuned"}
        keys = {d.cache_key for d in tuned_fft}
        assert len(keys) == 2
        for k in keys:
            assert cache.get(k)["strategy"] == "rfft2"
