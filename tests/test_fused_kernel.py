"""Fused rasterize+scatter kernel vs the unfused oracle."""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import LArTPCConfig
from repro.core.depo import generate_depos
from repro.kernels.fused_sim.ops import simulate_charge_grid
from repro.kernels.fused_sim.ref import simulate_charge_grid_ref

CFG = LArTPCConfig(num_wires=96, num_ticks=768, num_depos=128)


@pytest.mark.parametrize("tw,tt", [(32, 128), (64, 256)])
def test_matches_unfused(tw, tt):
    depos = generate_depos(jax.random.key(0), CFG, 128)
    g = simulate_charge_grid(depos, CFG, tw=tw, tt=tt)
    r = simulate_charge_grid_ref(depos, CFG)
    np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                               rtol=1e-5, atol=5e-2)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 50), n=st.integers(1, 64))
def test_property_fused_equals_oracle(seed, n):
    depos = generate_depos(jax.random.key(seed), CFG, n)
    g = simulate_charge_grid(depos, CFG)
    r = simulate_charge_grid_ref(depos, CFG)
    np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                               rtol=1e-5, atol=5e-2)


def test_charge_conserved():
    depos = generate_depos(jax.random.key(3), CFG, 64)
    g = simulate_charge_grid(depos, CFG)
    r = simulate_charge_grid_ref(depos, CFG)
    np.testing.assert_allclose(float(g.sum()), float(r.sum()), rtol=1e-6)
