"""Cross-pod int8-EF compressed DP: subprocess test with 2 forced devices."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.subprocess


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.config import ModelConfig, OptimizerConfig, ShapeConfig
from repro.data.tokens import make_batch
from repro.models.model import Model
from repro.optim.adamw import init_opt_state
from repro.train.train_step import make_train_step
from repro.train.compressed_dp import (init_compressed_state,
                                       make_compressed_train_step)

cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                  d_ff=64, vocab_size=128, remat="none", dtype="float32")
shape = ShapeConfig("t", "train", seq_len=32, global_batch=4)
opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=50,
                          schedule="constant")
model = Model(cfg)
params0 = model.init(jax.random.key(0))
mesh = jax.make_mesh((2,), ("pod",))

# exact (uncompressed) reference on one device
p_ref = params0
s_ref = init_opt_state(p_ref)
step_ref = jax.jit(make_train_step(model, opt_cfg))

# compressed 2-pod run
p_c = params0
s_c = init_compressed_state(p_c, init_opt_state(p_c))
step_c = jax.jit(make_compressed_train_step(model, opt_cfg, mesh))

losses_ref, losses_c = [], []
for t in range(10):
    batch = make_batch(cfg, shape, seed=0, step=t)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    p_ref, s_ref, m_ref = step_ref(p_ref, s_ref, batch)
    p_c, s_c, m_c = step_c(p_c, s_c, batch)
    losses_ref.append(float(m_ref["loss"]))
    losses_c.append(float(m_c["loss"]))

# compressed training tracks the exact run closely (int8 EF is unbiased)
drift = max(abs(a - b) for a, b in zip(losses_ref, losses_c))
final_gap = abs(losses_ref[-1] - losses_c[-1])
print("RESULTS:" + json.dumps({
    "drift": drift, "final_gap": final_gap,
    "ref0": losses_ref[0], "refN": losses_ref[-1], "cN": losses_c[-1]}))
"""


def test_compressed_dp_tracks_exact():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # pin children to CPU: with libtpu installed, an unset platform makes
    # the child block on /tmp/libtpu_lockfile held by the pytest process
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")]
    res = json.loads(line[0][len("RESULTS:"):])
    # both runs must learn, and compressed must track the exact loss curve
    assert res["refN"] < res["ref0"]
    assert res["drift"] < 0.08, res
    assert res["final_gap"] < 0.05, res
