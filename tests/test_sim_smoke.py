"""Fixed-seed smoke variants of the hypothesis-gated physics tests.

``test_core_sim``, ``test_kernels``, ``test_fused_kernel`` and
``test_models`` guard their property sweeps with a module-level
``pytest.importorskip("hypothesis")`` — which skips the WHOLE module,
including their plain statistical tests, on boxes without hypothesis
installed. These fixed-seed variants keep the load-bearing invariants
(noise calibration, charge conservation, strategy equivalence) exercised
everywhere, with a handful of pinned seeds standing in for each random
sweep. No hypothesis import anywhere in this file.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import LArTPCConfig
from repro.core.depo import generate_depos
from repro.core.fft_conv import digitize
from repro.core.noise import simulate_noise
from repro.core.pipeline import simulate_fig3, simulate_fig4
from repro.core.rasterize import rasterize
from repro.core.response import make_response
from repro.core.scatter import scatter_sort_segment, scatter_xla

CFG = LArTPCConfig(num_wires=64, num_ticks=256, num_depos=128,
                   response_wires=11, response_ticks=48)


class TestNoiseCalibrationSmoke:
    """Fixed-seed stand-ins for TestNoise in test_core_sim."""

    @pytest.mark.parametrize("num_ticks", [256, 257])
    def test_rms_matches_config_target(self, num_ticks):
        """Realized time-domain RMS hits the configured target within 5%
        with and without a Nyquist bin (Parseval normalization)."""
        cfg = dataclasses.replace(CFG, num_ticks=num_ticks, num_wires=128)
        noise = simulate_noise(jax.random.key(3), cfg)
        rms = float(jnp.sqrt(jnp.mean(noise ** 2)))
        assert abs(rms - cfg.noise_rms_adc) < 0.05 * cfg.noise_rms_adc, rms

    @pytest.mark.parametrize("seed", [0, 1, 7])
    def test_zero_mean_across_seeds(self, seed):
        noise = simulate_noise(jax.random.key(seed), CFG)
        assert abs(float(noise.mean())) < 0.1

    def test_spectrum_dc_and_nyquist_real(self):
        cfg = dataclasses.replace(CFG, num_ticks=256, num_wires=8)
        spec = jnp.fft.rfft(simulate_noise(jax.random.key(4), cfg), axis=-1)
        np.testing.assert_allclose(np.asarray(spec[:, 0].imag), 0.0,
                                   atol=1e-3)
        np.testing.assert_allclose(np.asarray(spec[:, -1].imag), 0.0,
                                   atol=1e-3)


class TestRasterizeSmoke:
    """Fixed-seed stand-ins for the rasterize property sweeps."""

    @pytest.mark.parametrize("seed,n", [(0, 64), (11, 17), (42, 100)])
    def test_nonneg_bounded_mass(self, seed, n):
        """Rasterized mass is non-negative and never exceeds the depo
        charge (3-sigma truncation only loses mass)."""
        depos = generate_depos(jax.random.key(seed), CFG, n)
        patches, _, _ = rasterize(depos, CFG)
        p = np.asarray(patches)
        assert (p >= -1e-4).all()
        sums = p.sum(axis=(1, 2))
        assert (sums <= np.asarray(depos.charge) * 1.01).all()


class TestScatterSmoke:
    """Fixed-seed stand-ins for the scatter strategy-equivalence sweep."""

    @pytest.mark.parametrize("seed,n", [(0, 128), (5, 1), (123, 77)])
    def test_strategies_agree(self, seed, n):
        depos = generate_depos(jax.random.key(seed), CFG, n)
        patches, w0, t0 = rasterize(depos, CFG)
        g1 = scatter_xla(patches, w0, t0, CFG)
        g2 = scatter_sort_segment(patches, w0, t0, CFG)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=5e-2)

    def test_total_charge_preserved(self):
        depos = generate_depos(jax.random.key(0), CFG, 64)
        patches, w0, t0 = rasterize(depos, CFG)
        grid = scatter_xla(patches, w0, t0, CFG)
        np.testing.assert_allclose(float(grid.sum()), float(patches.sum()),
                                   rtol=1e-5)


class TestFusedKernelSmoke:
    """Fixed-seed stand-ins for the fused rasterize+scatter oracle sweep."""

    @pytest.mark.parametrize("seed,n", [(0, 64), (17, 9)])
    def test_fused_equals_oracle(self, seed, n):
        from repro.kernels.fused_sim.ops import simulate_charge_grid
        from repro.kernels.fused_sim.ref import simulate_charge_grid_ref

        cfg = LArTPCConfig(num_wires=96, num_ticks=768, num_depos=128)
        depos = generate_depos(jax.random.key(seed), cfg, n)
        g = simulate_charge_grid(depos, cfg)
        r = simulate_charge_grid_ref(depos, cfg)
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-5, atol=5e-2)


class TestPipelineSmoke:
    """Fixed-seed stand-ins for TestPipelines/TestFFTConv in test_core_sim."""

    def test_fig3_equals_fig4_no_rng(self):
        cfg = dataclasses.replace(CFG, fluctuate=False, num_depos=24)
        depos = generate_depos(jax.random.key(0), cfg, 24)
        resp = make_response(cfg)
        key = jax.random.key(0)
        out3 = simulate_fig3(key, depos, resp, cfg, add_noise=False)
        out4 = simulate_fig4(key, depos, resp, cfg, add_noise=False)
        np.testing.assert_allclose(np.asarray(out3.charge_grid),
                                   np.asarray(out4.charge_grid),
                                   rtol=1e-4, atol=1e-2)
        assert (np.asarray(out3.adc) == np.asarray(out4.adc)).mean() > 0.999

    def test_digitize_range_and_dtype(self):
        sig = jnp.asarray(np.random.default_rng(0).standard_normal(
            (8, 32)).astype(np.float32)) * 1e6
        adc = digitize(sig, CFG)
        assert adc.dtype == jnp.int16
        assert int(adc.min()) >= 0 and int(adc.max()) <= 4095
