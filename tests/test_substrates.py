"""Substrate tests: optimizer, checkpoint manager, data pipeline, serving,
gradient compression, config system."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.config import (ModelConfig, OptimizerConfig, ShapeConfig,
                          apply_overrides, get_config, list_archs)
from repro.data.tokens import DataPipeline, make_batch
from repro.models.model import Model
from repro.optim.adamw import (adamw_update, clip_by_global_norm,
                               init_opt_state, lr_at)
from repro.parallel.collectives import dequantize_int8, quantize_int8


class TestAdamW:
    def test_quadratic_convergence(self):
        cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                              schedule="constant", weight_decay=0.0,
                              grad_clip=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_opt_state(params)
        target = jnp.array([1.0, 2.0])
        for _ in range(200):
            grads = {"w": 2 * (params["w"] - target)}
            params, state, _ = adamw_update(cfg, params, grads, state)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_grad_clip(self):
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
        assert float(norm) > 100.0

    def test_schedule_shapes(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              schedule="cosine")
        lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
        assert lrs[0] == 0.0
        assert abs(lrs[1] - 1.0) < 1e-6          # end of warmup
        assert lrs[-1] < 1e-6                    # cosine floor
        assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
        mgr.save(5, tree, extra={"step": 5})
        restored, extra = mgr.restore(5, tree)
        assert extra["step"] == 5
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_keep_rotation(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        tree = {"a": jnp.zeros(4)}
        for s in [1, 2, 3, 4]:
            mgr.save(s, tree)
        assert mgr.all_steps() == [3, 4]

    def test_latest_and_async(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
        tree = {"a": jnp.ones(8)}
        mgr.save(7, tree)
        mgr.wait()
        assert mgr.latest_step() == 7

    def test_crash_safety_tmp_ignored(self, tmp_path):
        """A partial (crashed) write must not be visible as a checkpoint."""
        mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
        os.makedirs(tmp_path / "step_00000009")  # no manifest.json inside
        assert mgr.all_steps() == []


class TestDataPipeline:
    CFG = ModelConfig(d_model=16, vocab_size=128, num_heads=2, num_kv_heads=2)
    SHAPE = ShapeConfig("t", "train", seq_len=16, global_batch=4)

    def test_deterministic(self):
        b1 = make_batch(self.CFG, self.SHAPE, seed=3, step=7)
        b2 = make_batch(self.CFG, self.SHAPE, seed=3, step=7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = make_batch(self.CFG, self.SHAPE, seed=3, step=8)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_restart_resumes_exactly(self):
        p1 = DataPipeline(self.CFG, self.SHAPE, seed=0, start_step=0)
        batches = [np.asarray(next(p1)["tokens"]) for _ in range(3)]
        state = p1.state()
        p1.close()
        p2 = DataPipeline(self.CFG, self.SHAPE, seed=0, start_step=state)
        nxt = np.asarray(next(p2)["tokens"])
        p2.close()
        expect = make_batch(self.CFG, self.SHAPE, seed=0, step=3)["tokens"]
        np.testing.assert_array_equal(nxt, expect)

    def test_tokens_in_range(self):
        b = make_batch(self.CFG, self.SHAPE, seed=0, step=0)
        assert b["tokens"].min() >= 0
        assert b["tokens"].max() < self.CFG.vocab_size


class TestGradCompression:
    def test_quantize_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.key(0), (1000,)) * 3.0
        q, scale = quantize_int8(x)
        err = np.asarray(dequantize_int8(q, scale) - x)
        assert np.abs(err).max() <= float(scale) * 0.51 + 1e-6

    def test_error_feedback_converges(self):
        """With error feedback, the running compressed sum tracks the truth."""
        rng = np.random.default_rng(0)
        e = jnp.zeros(64)
        total_true = np.zeros(64)
        total_comp = np.zeros(64)
        for i in range(50):
            g = jnp.asarray(rng.standard_normal(64).astype(np.float32))
            total_true += np.asarray(g)
            q, s = quantize_int8(g + e)
            deq = dequantize_int8(q, s)
            e = (g + e) - deq
            total_comp += np.asarray(deq)
        # residual error stays bounded by one quantization step
        assert np.abs(total_true - total_comp).max() < 0.3


class TestServeEngine:
    def test_batched_generation(self):
        from repro.serve.engine import Request, ServeEngine
        cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2,
                          num_kv_heads=2, d_ff=64, vocab_size=64)
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        eng = ServeEngine(model, batch_slots=2, max_len=32)
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, 64, size=(6,)).astype(np.int32),
                        max_new_tokens=4) for _ in range(5)]
        done = eng.generate(params, reqs)
        assert all(r.done for r in done)
        assert all(len(r.out_tokens) == 4 for r in done)
        assert all(0 <= t < 64 for r in done for t in r.out_tokens)

    def test_greedy_deterministic(self):
        from repro.serve.engine import Request, ServeEngine
        cfg = ModelConfig(num_layers=1, d_model=32, num_heads=2,
                          num_kv_heads=2, d_ff=64, vocab_size=64)
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        eng = ServeEngine(model, batch_slots=1, max_len=32)
        prompt = np.arange(5, dtype=np.int32)
        r1 = eng.generate(params, [Request(prompt=prompt, max_new_tokens=5)])
        r2 = eng.generate(params, [Request(prompt=prompt, max_new_tokens=5)])
        assert r1[0].out_tokens == r2[0].out_tokens


class TestConfigSystem:
    def test_registry_has_all_archs(self):
        archs = list_archs()
        assert len(archs) >= 11  # 10 assigned + lartpc

    def test_overrides(self):
        cfg = get_config("qwen3-32b")
        cfg2 = apply_overrides(cfg, {"num_layers": "8", "qk_norm": "false"})
        assert cfg2.num_layers == 8 and cfg2.qk_norm is False
        assert cfg.num_layers == 64  # frozen original untouched

    def test_unknown_arch_raises(self):
        with pytest.raises(KeyError):
            get_config("not-an-arch")
