"""Tests for the compiled-program contract auditor (ISSUE 10 layer 1).

Three tiers:

* pure unit tests of the diff/glob/policy machinery (no jax compile);
* in-process fixture programs with KNOWN broken contracts (host callback,
  donation present/absent) that ``extract_contract`` must flag;
* subprocess runs of the real CLI gate: ``--check`` green against the
  committed ``AUDIT_contracts.json``, and the seeded regressions
  (``--inject f64_noise`` / ``--inject no_donate``) trip it with a
  per-contract diff — the acceptance criterion of the issue.

Plus the meta-test: the committed baseline covers every production
executor (all four executors + recon + fit), so a new executor cannot
land without a contract.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import audit
from repro.analysis.audit import (INJECT_MODES, PROGRAMS,
                                  SCATTER_REDUCTION_COLLECTIVES,
                                  diff_contracts, expand_contract_names,
                                  extract_contract, policy_violations,
                                  program_names)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO, "AUDIT_contracts.json")


def _clean_contract(**over):
    c = {"collectives": {}, "dtypes": ["f32", "s32"], "scatter_dtypes": [],
         "donated_args": 0, "realized_aliases": 0, "host_calls": 0,
         "recompiles": 0}
    c.update(over)
    return c


class TestPolicy:
    def test_clean_contract_passes(self):
        assert policy_violations("p1/single", _clean_contract()) == []

    def test_f64_flagged(self):
        v = policy_violations("p1/single",
                              _clean_contract(dtypes=["f32", "f64"]))
        assert any("f64" in x for x in v)

    def test_host_calls_flagged(self):
        v = policy_violations("p1/single", _clean_contract(host_calls=2))
        assert any("host call" in x for x in v)

    def test_bf16_scatter_flagged(self):
        v = policy_violations("p1/single",
                              _clean_contract(scatter_dtypes=["bf16"]))
        assert any("accumulate" in x for x in v)

    def test_recompiles_flagged(self):
        v = policy_violations("p1/single", _clean_contract(recompiles=1))
        assert any("recompil" in x for x in v)

    def test_collective_in_local_program_flagged(self):
        """No registered single-device strategy declares collectives, so an
        all-reduce in p1/batched is a policy failure, not just drift."""
        v = policy_violations(
            "p1/batched", _clean_contract(collectives={"all-reduce": 1}))
        assert any("collective" in x for x in v)

    def test_declared_distributed_collectives_allowed(self):
        c = _clean_contract(collectives={"reduce-scatter": 2,
                                         "all-to-all": 2})
        assert policy_violations("p1/distributed_psum", c) == []

    def test_undeclared_distributed_collective_flagged(self):
        c = _clean_contract(collectives={"all-gather": 1})
        v = policy_violations("p1/distributed_psum", c)
        assert any("all-gather" in x for x in v)

    def test_strategy_table_kinds_are_real(self):
        from repro.analysis.hlo import COLLECTIVE_KINDS

        for kinds in SCATTER_REDUCTION_COLLECTIVES.values():
            assert set(kinds) <= set(COLLECTIVE_KINDS)


class TestDiffMachinery:
    BASE = {"p1/a": _clean_contract(), "p1/b": _clean_contract()}

    def test_identical_passes(self, capsys):
        assert diff_contracts(self.BASE, dict(self.BASE)) == 0
        assert "ok" in capsys.readouterr().out

    def test_field_drift_fails_with_diff(self, capsys):
        fresh = {"p1/a": _clean_contract(donated_args=3),
                 "p1/b": _clean_contract()}
        assert diff_contracts(self.BASE, fresh) == 1
        out = capsys.readouterr().out
        assert "p1/a: FAIL" in out
        assert "donated_args: 0 -> 3" in out

    def test_missing_fresh_contract_fails(self, capsys):
        assert diff_contracts(self.BASE, {"p1/a": _clean_contract()}) == 1
        assert "MISSING" in capsys.readouterr().out

    def test_new_contract_warns_not_fails(self, capsys):
        fresh = dict(self.BASE)
        fresh["p1/new"] = _clean_contract()
        assert diff_contracts(self.BASE, fresh) == 0
        assert "(new" in capsys.readouterr().out

    def test_policy_violation_fails_even_when_baseline_matches(self):
        """A baselined regression cannot be grandfathered: f64 in BOTH
        baseline and fresh still fails the policy layer."""
        bad = {"p1/a": _clean_contract(dtypes=["f32", "f64"])}
        assert diff_contracts(dict(bad), dict(bad)) == 1

    def test_glob_gates_subset(self, capsys):
        fresh = {"p1/a": _clean_contract(donated_args=9),
                 "p1/b": _clean_contract()}
        # gating only p1/b ignores the drifted p1/a
        assert diff_contracts(self.BASE, fresh, patterns=["p1/b"]) == 0

    def test_glob_matching_nothing_fails(self, capsys):
        assert diff_contracts(self.BASE, dict(self.BASE),
                              patterns=["p9/*"]) == 1
        assert "matched no" in capsys.readouterr().err

    def test_expand_names_mirror_check_regression_semantics(self, capsys):
        base, fresh = {"p1/a": {}}, {"p1/a": {}, "p1/c": {}}
        assert expand_contract_names(["p1/*"], base, fresh) == ["p1/a",
                                                               "p1/c"]
        # a glob matching only FRESH names gates nothing run-after-run
        assert expand_contract_names(["p1/c*"], base, fresh) == []
        # plain names pass through even when absent (reported MISSING later)
        assert expand_contract_names(["p1/zzz"], base, fresh) == ["p1/zzz"]


class TestFixturePrograms:
    """Known-contract fixture programs, extracted in-process."""

    def test_host_callback_fixture_flagged(self):
        def f(x):
            return jax.pure_callback(
                lambda a: a, jax.ShapeDtypeStruct(x.shape, x.dtype), x) * 2.0

        c = extract_contract(jax.jit(f), lambda i: (jnp.ones(8) * i,))
        assert c["host_calls"] >= 1
        assert any("host call" in v
                   for v in policy_violations("p1/fixture", c))

    def test_donation_fixture_contract(self):
        def f(x):
            return x * 2.0

        c = extract_contract(jax.jit(f, donate_argnums=(0,)),
                             lambda i: (jnp.ones((8, 8)) + i,))
        assert c["donated_args"] == 1
        assert c["realized_aliases"] == 1
        c0 = extract_contract(jax.jit(f), lambda i: (jnp.ones((8, 8)) + i,))
        assert c0["donated_args"] == 0

    def test_extra_allreduce_fixture_flagged(self):
        """A deliberate collective in a 'local' program — built with a
        1-device psum under shard_map — must trip the local policy."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(jax.devices("cpu")[:1], ("d",))

        def body(x):
            return jax.lax.psum(x, "d")

        f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("d"),
                              out_specs=P()))
        c = extract_contract(f, lambda i: (jnp.ones(8) + i,))
        assert c["collectives"].get("all-reduce", 0) >= 1
        assert any("collective" in v
                   for v in policy_violations("p1/fixture", c))

    def test_f64_fixture_flagged_under_x64(self):
        def f(x):
            return (x.astype(jnp.float64) * jnp.float64(1.5)  # repro-lint: disable=f64-literal
                    ).astype(jnp.float32)

        c = extract_contract(jax.jit(f), lambda i: (jnp.ones(8),), x64=True)
        assert "f64" in c["dtypes"]
        assert any("f64" in v for v in policy_violations("p1/fixture", c))


class TestBaselineCoverage:
    """Meta-tests: the committed baseline must cover the full production
    surface, so new executors can't land contract-free."""

    def test_baseline_exists_and_loads(self):
        contracts = audit.load_baseline(BASELINE)
        assert contracts

    def test_baseline_covers_every_program(self):
        contracts = audit.load_baseline(BASELINE)
        missing = [n for n in program_names((1, 3))
                   if n not in contracts]
        assert not missing, (
            f"AUDIT_contracts.json lacks {missing}; refresh with "
            "`python -m repro.analysis.audit --update`")

    def test_programs_cover_build_sim_graph_executors(self):
        """Every executor module that builds the production graph has an
        audited program. If a new `make_*` executor appears in a core
        module calling build_sim_graph, it must be added to
        audit.PROGRAMS (and the baseline) or this inventory fails."""
        covered = {p.name for p in PROGRAMS}
        # executor entry point -> audited program(s)
        inventory = {
            "repro.core.pipeline.make_sim_fn": {"single", "recon"},
            "repro.core.batch.make_batched_sim_fn": {"batched"},
            "repro.launch.sim.make_streaming_sim_fn": {"streaming"},
            "repro.core.distributed.make_distributed_sim": {
                "distributed_psum", "distributed_halo"},
            "repro.core.fit.make_fit_loss": {"fit_loss", "fit_grad"},
        }
        for entry, progs in inventory.items():
            assert progs <= covered, f"{entry} not audited"
        # and the inventory itself is current: every core executor factory
        # that exists is listed
        import importlib

        for entry in inventory:
            mod, fn = entry.rsplit(".", 1)
            assert hasattr(importlib.import_module(mod), fn), (
                f"{entry} vanished; update the audit inventory + PROGRAMS")

    def test_baseline_contracts_satisfy_policy(self):
        """The committed baseline itself must be violation-free — a bad
        baseline would bless regressions."""
        contracts = audit.load_baseline(BASELINE)
        for name, c in contracts.items():
            assert policy_violations(name, c) == [], name

    def test_streaming_contract_pins_donation(self):
        """The property the no_donate injection breaks: the streaming
        executor donates its full packed batch (6 EventBatch leaves +
        keys)."""
        contracts = audit.load_baseline(BASELINE)
        assert contracts["p1/streaming"]["donated_args"] == 7
        assert contracts["p3/streaming"]["donated_args"] == 7

    def test_stacked_distributed_contract_matches_single_plane(self):
        """PR 9's amortization property, now pinned as data: the 3-plane
        stacked distributed program runs the SAME collective counts as the
        1-plane program."""
        contracts = audit.load_baseline(BASELINE)
        assert (contracts["p3/distributed_psum"]["collectives"]
                == contracts["p1/distributed_psum"]["collectives"])


def _run_audit(*args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.audit", *args],
        env=env, capture_output=True, text=True, timeout=timeout, cwd=REPO)


@pytest.mark.subprocess
class TestCLIGate:
    """The real gate, end to end in fresh interpreters (the audit pins its
    own fake-device env before importing jax, so it needs a clean
    process)."""

    def test_check_passes_against_committed_baseline(self):
        proc = _run_audit("--check", "--planes", "1",
                          "--programs", "p1/single",
                          "--programs", "p1/streaming")
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "p1/single: ok" in proc.stdout

    def test_inject_f64_noise_fails_with_diff(self):
        proc = _run_audit("--check", "--planes", "1", "--quiet",
                          "--inject", "f64_noise",
                          "--programs", "p1/single")
        assert proc.returncode == 1, proc.stdout + proc.stderr
        assert "p1/single: FAIL" in proc.stdout
        assert "f64" in proc.stdout  # the per-field dtype diff names it
        assert "policy" in proc.stdout

    def test_inject_no_donate_fails_with_diff(self):
        proc = _run_audit("--check", "--planes", "1", "--quiet",
                          "--inject", "no_donate",
                          "--programs", "p1/streaming")
        assert proc.returncode == 1, proc.stdout + proc.stderr
        assert "p1/streaming: FAIL" in proc.stdout
        assert "donated_args: 7 -> 0" in proc.stdout

    def test_json_artifact_written(self, tmp_path):
        out = tmp_path / "contracts_fresh.json"
        proc = _run_audit("--check", "--planes", "1", "--quiet",
                          "--programs", "p1/single", "--json", str(out))
        assert proc.returncode == 0, proc.stdout + proc.stderr
        data = json.loads(out.read_text())
        assert "p1/single" in data["contracts"]

    def test_unknown_inject_mode_rejected(self):
        proc = _run_audit("--check", "--inject", "nonsense")
        assert proc.returncode == 2  # argparse choices error
        assert "--inject" in proc.stderr

    def test_inject_modes_documented(self):
        assert set(INJECT_MODES) == {"f64_noise", "x64", "no_donate",
                                     "host_callback", "extra_collective"}
