"""Multi-event batched engine (repro.core.batch) vs the per-event pipeline.

The contract under test: packing E ragged events into one padded (E, N_max)
EventBatch and running ``simulate_events`` (vmap'd fig4) is *bit-for-bit*
identical to a Python loop of per-event ``simulate_fig4`` calls on the same
padded rows, and zero-charge padding is exactly inert.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import LArTPCConfig
from repro.core.batch import (EventBatch, empty_event, event_keys,
                              make_batched_sim_fn, pack_events, pad_depos,
                              shard_events, simulate_events)
from repro.core.depo import DepoSet, generate_depos
from repro.core.pipeline import simulate_fig4
from repro.core.response import make_response
from repro.launch.sim import stream_simulate

CFG = LArTPCConfig(num_wires=64, num_ticks=256, num_depos=48,
                   response_wires=11, response_ticks=48)
RAGGED = [7, 16, 3, 12]


def _events(sizes, seed=100):
    key = jax.random.key(0)
    return [generate_depos(jax.random.fold_in(key, seed + i), CFG, n)
            for i, n in enumerate(sizes)]


class TestPackEvents:
    def test_shapes_and_counts(self):
        batch = pack_events(_events(RAGGED))
        assert batch.num_events == len(RAGGED)
        assert batch.max_depos == max(RAGGED)
        assert batch.wire.shape == (len(RAGGED), max(RAGGED))
        np.testing.assert_array_equal(np.asarray(batch.n_depos), RAGGED)
        assert batch.total_depos == sum(RAGGED)

    def test_padding_is_inert_rows(self):
        """Rows past n_depos[e] carry zero charge and positive sigma."""
        batch = pack_events(_events(RAGGED))
        for e, n in enumerate(RAGGED):
            assert np.all(np.asarray(batch.charge[e, n:]) == 0.0)
            assert np.all(np.asarray(batch.sigma_w[e, n:]) > 0.0)

    def test_pad_to_and_multiple(self):
        batch = pack_events(_events([5, 3]), pad_to=20)
        assert batch.max_depos == 20
        batch = pack_events(_events([5, 3]), pad_multiple=8)
        assert batch.max_depos == 8

    def test_event_roundtrip_exact(self):
        """Valid region of event(e) is the original depo data, bitwise."""
        events = _events(RAGGED)
        batch = pack_events(events)
        for e, ev in enumerate(events):
            got = batch.event(e)
            for f in DepoSet._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(got, f))[:ev.n],
                    np.asarray(getattr(ev, f)))

    def test_empty_event_and_oversize(self):
        batch = pack_events([empty_event(), _events([4])[0]])
        assert int(batch.n_depos[0]) == 0 and int(batch.n_depos[1]) == 4
        with pytest.raises(ValueError):
            pad_depos(_events([8])[0], 4)


class TestBatchedEqualsLoop:
    def test_bit_for_bit_ragged(self):
        """vmap'd batch == loop of simulate_fig4 on the padded rows,
        bit-for-bit, with fluctuation AND noise on (per-event keys)."""
        batch = pack_events(_events(RAGGED))
        keys = event_keys(jax.random.key(0), range(len(RAGGED)))
        resp = make_response(CFG)
        out = simulate_events(keys, batch, resp, CFG)
        for e in range(len(RAGGED)):
            ref = simulate_fig4(keys[e], batch.event(e), resp, CFG)
            np.testing.assert_array_equal(np.asarray(out.adc[e]),
                                          np.asarray(ref.adc))
            np.testing.assert_array_equal(np.asarray(out.signal[e]),
                                          np.asarray(ref.signal))
            np.testing.assert_array_equal(np.asarray(out.charge_grid[e]),
                                          np.asarray(ref.charge_grid))

    def test_bit_for_bit_jitted(self):
        """The jit'd production closure matches a jit'd per-event fig4."""
        batch = pack_events(_events(RAGGED))
        keys = event_keys(jax.random.key(0), range(len(RAGGED)))
        resp = make_response(CFG)
        sim = make_batched_sim_fn(CFG, resp=resp)
        out = sim(keys, batch)
        one = jax.jit(lambda k, d: simulate_fig4(k, d, resp, CFG))
        for e in range(len(RAGGED)):
            ref = one(keys[e], batch.event(e))
            np.testing.assert_array_equal(np.asarray(out.adc[e]),
                                          np.asarray(ref.adc))

    def test_padding_does_not_change_physics(self):
        """With deterministic physics (no fluctuation/noise), the padded row
        gives the same grid as the unpadded event — padding is exactly 0."""
        cfg = dataclasses.replace(CFG, fluctuate=False)
        events = _events([7])
        batch = pack_events(events, pad_to=32)
        resp = make_response(cfg)
        key = jax.random.key(3)
        ref = simulate_fig4(key, events[0], resp, cfg, add_noise=False)
        padded = simulate_fig4(key, batch.event(0), resp, cfg, add_noise=False)
        np.testing.assert_array_equal(np.asarray(ref.charge_grid),
                                      np.asarray(padded.charge_grid))
        np.testing.assert_array_equal(np.asarray(ref.adc),
                                      np.asarray(padded.adc))

    def test_pool_strategy_batched(self):
        """The paper-faithful pool RNG strategy also survives vmap."""
        cfg = dataclasses.replace(CFG, rng_strategy="pool")
        from repro.core.fluctuate import make_pool
        pool = make_pool(jax.random.key(9), 1 << 14)
        batch = pack_events(_events([5, 9]))
        keys = event_keys(jax.random.key(1), range(2))
        resp = make_response(cfg)
        out = simulate_events(keys, batch, resp, cfg, pool=pool)
        ref = simulate_fig4(keys[1], batch.event(1), resp, cfg, pool=pool)
        np.testing.assert_array_equal(np.asarray(out.adc[1]),
                                      np.asarray(ref.adc))


class TestRNGIndependence:
    def test_events_get_independent_randomness(self):
        """Identical depos under different per-event keys -> different ADC;
        identical keys -> identical ADC."""
        ev = _events([16])[0]
        batch = pack_events([ev, ev])
        resp = make_response(CFG)
        k_diff = event_keys(jax.random.key(0), [0, 1])
        out = simulate_events(k_diff, batch, resp, CFG)
        assert not np.array_equal(np.asarray(out.adc[0]),
                                  np.asarray(out.adc[1]))
        k_same = event_keys(jax.random.key(0), [5, 5])
        out = simulate_events(k_same, batch, resp, CFG)
        np.testing.assert_array_equal(np.asarray(out.adc[0]),
                                      np.asarray(out.adc[1]))

    def test_keys_match_serial_launcher(self):
        """event_keys(key, ids) == [fold_in(key, id) for id in ids], so a
        batched run replays the serial per-event key schedule."""
        key = jax.random.key(7)
        keys = event_keys(key, [0, 3, 11])
        for i, ev in enumerate([0, 3, 11]):
            np.testing.assert_array_equal(
                jax.random.key_data(keys[i]),
                jax.random.key_data(jax.random.fold_in(key, ev)))


class TestStreaming:
    def test_stream_counts_and_batches(self):
        stats = stream_simulate(CFG, num_events=5, batch_events=2, seed=0)
        assert stats["events"] == 5
        assert stats["depos"] == 5 * CFG.num_depos
        assert len(stats["batches"]) == 3
        # the ragged final batch reports only its real event
        assert stats["batches"][-1]["events"] == 1
        assert stats["wall_s"] > 0

    def test_stream_matches_direct_batch(self):
        """Streamed results equal a direct simulate_events call on the same
        event ids (same fold_in key schedule)."""
        got = {}
        stats = stream_simulate(
            CFG, num_events=2, batch_events=2, seed=0,
            on_batch=lambda b, nv, nd, dt, out: got.update({b: out}))
        assert stats["events"] == 2
        key = jax.random.key(0)
        events = [generate_depos(jax.random.fold_in(key, ev), CFG)
                  for ev in range(2)]
        batch = pack_events(events, pad_to=CFG.num_depos)
        ref = simulate_events(event_keys(key, range(2)), batch,
                              make_response(CFG), CFG)
        np.testing.assert_array_equal(np.asarray(got[0].adc),
                                      np.asarray(ref.adc))


class TestSharding:
    def test_shard_events_places_on_device(self):
        batch = shard_events(pack_events(_events([4, 4])))
        assert isinstance(batch, EventBatch)
        assert batch.wire.devices() == {jax.devices()[0]}

    def test_event_axis_rule_registered(self):
        from repro.parallel.sharding import ACT_RULES, build_spec
        assert "events" in ACT_RULES
        mesh = jax.make_mesh((1,), ("data",))
        spec = build_spec((4, 8), ("events", None), mesh, ACT_RULES)
        assert spec[0] == "data"

    def test_simulate_under_mesh(self):
        """The batched engine runs (and matches) under an active 1-device
        mesh — the sharding constraints are exercised, not just no-ops."""
        from repro.parallel.sharding import use_mesh
        batch = pack_events(_events([6, 6]))
        keys = event_keys(jax.random.key(0), range(2))
        resp = make_response(CFG)
        ref = simulate_events(keys, batch, resp, CFG)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with use_mesh(mesh):
            sim = make_batched_sim_fn(CFG, resp=resp)
            out = sim(event_keys(jax.random.key(0), range(2)),
                      shard_events(batch))
        np.testing.assert_array_equal(np.asarray(out.adc),
                                      np.asarray(ref.adc))
