"""End-to-end system tests for the paper's pipeline (Eq. 1): depos in,
ADC waveforms out, with the paper's own comparisons reproduced in miniature."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.core import generate_depos, make_sim_fn, simulate

CFG = get_config("lartpc-uboone", smoke=True)


def test_end_to_end_signal_formation():
    """Full pipeline: charge appears where tracks crossed, shaped by R."""
    key = jax.random.key(0)
    depos = generate_depos(key, CFG)
    out = simulate(key, depos, CFG)
    adc = np.asarray(out.adc, np.int64)
    assert adc.shape == (CFG.num_wires, CFG.num_ticks)
    # the signal region deviates from baseline where charge was deposited
    dev = np.abs(adc - CFG.adc_baseline)
    assert dev.max() > 5, "no signal formed"
    # charge grid is where the depos are
    grid = np.asarray(out.charge_grid)
    assert grid.sum() > 0
    occupied = (grid > 0).mean()
    assert 0.0 < occupied < 0.5, "tracks should be sparse"


def test_jit_sim_fn_reusable():
    sim = make_sim_fn(CFG)
    k1, k2 = jax.random.split(jax.random.key(0))
    d1 = generate_depos(k1, CFG)
    d2 = generate_depos(k2, CFG)
    o1 = sim(k1, d1)
    o2 = sim(k2, d2)  # same compiled program, new data
    assert not np.array_equal(np.asarray(o1.adc), np.asarray(o2.adc))


def test_noise_only_event():
    """Zero depos -> pure noise at the calibrated RMS around baseline."""
    cfg = dataclasses.replace(CFG, fluctuate=False)
    from repro.core.depo import DepoSet
    empty = DepoSet(*(jnp.zeros((4,)) for _ in range(5)))
    empty = empty._replace(sigma_w=jnp.ones(4), sigma_t=jnp.ones(4))
    out = simulate(jax.random.key(0), empty, cfg)
    adc = np.asarray(out.adc, np.float64)
    assert abs(adc.mean() - cfg.adc_baseline) < 2.0
    assert adc.std() < 20


def test_scatter_strategies_end_to_end():
    """All three scatter strategies give the same ADC output."""
    key = jax.random.key(3)
    depos = generate_depos(key, CFG)
    outs = {}
    for strat in ["xla", "sort_segment", "pallas"]:
        cfg = dataclasses.replace(CFG, scatter_strategy=strat,
                                  fluctuate=False)
        outs[strat] = np.asarray(simulate(key, depos, cfg,
                                          add_noise=False).adc)
    assert (outs["xla"] == outs["sort_segment"]).mean() > 0.999
    assert (outs["xla"] == outs["pallas"]).mean() > 0.999
