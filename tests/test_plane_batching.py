"""Plane-batching bit-identity matrix (ISSUE 9).

The stacked dispatch (``plane_batching="stacked"``) replaces the per-plane
Python loop with one batched program — vmapped charge grid, batched rfft2
convolve, vmapped noise — deriving the SAME per-plane ``fold_in(k, index)``
subkeys, so ADCs must stay bitwise equal to the loop on every executor.
This module pins that contract:

  * 3-plane ADC SHA-256 goldens per charge-grid strategy (stacked path);
    the loopable strategies must reproduce the same digest in loop mode.
  * stacked == loop bitwise across the single-event, batched-event, and
    streaming executors (the distributed executor is covered by the
    subprocess script below, which also counts collectives).
  * the multi-plane strategies (one launch rasterizes ALL planes) refuse
    per-plane dispatch, and ``resolve_plane_batching`` validates the knob.

Re-pin after an intentional physics/RNG change with
``python -m tests.test_plane_batching``.
"""
import hashlib
import json
import os
import subprocess
import sys

import dataclasses
import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.core.batch import event_keys, make_batched_sim_fn, pack_events
from repro.core.depo import generate_physical_depos, generate_plane_depos
from repro.core.pipeline import make_sim_fn
from repro.core.stages import (MULTIPLANE_CHARGE_GRID,
                               PLANE_VMAP_CHARGE_GRID,
                               resolve_plane_batching)

CFG = get_config("lartpc-uboone", smoke=True)
CFG3 = dataclasses.replace(CFG, num_planes=3)

#: 3-plane smoke ADCs, key 0, CPU, stacked dispatch. The multi-plane
#: strategies draw their own RNG streams (fused kernels: in-kernel counter
#: hash; multiplane_xla: single-hash erfinv counters), so their digests
#: differ from the threefry ``unfused`` chain — each pins its own.
GOLDEN_ADC3P_SHA256 = {
    "unfused":
        "d49fa450d1cca2b86aafffb5d2adc8b96bcf1c1cf200cb0e1255d8e8c9feb4c0",
    "unfused_bf16":
        "b293a0705c28d3b6fcf59d646488eca11d69297b223084e61ae29a71ee4ae655",
    "fused_pallas":
        "fe2aebcd5b32f57f3e13e1616f93aafd9754d036e11b0d604f5cacdef2b2ad4f",
    "fused_pallas_multiplane":
        "fe2aebcd5b32f57f3e13e1616f93aafd9754d036e11b0d604f5cacdef2b2ad4f",
    "fused_pallas_multiplane_compact":
        "fe2aebcd5b32f57f3e13e1616f93aafd9754d036e11b0d604f5cacdef2b2ad4f",
    "multiplane_xla":
        "5e10b157d42e84449b3881cff3525173cb55ae23d2045bbaa619908c616cce68",
}
#: strategies that support BOTH dispatch modes (everything except the
#: multi-plane-only launches, which refuse the per-plane loop)
LOOPABLE = ("unfused", "unfused_bf16", "fused_pallas")


def _cfg3(strategy: str, mode: str = "stacked"):
    return dataclasses.replace(CFG3, charge_grid_strategy=strategy,
                               plane_batching=mode)


def _adc3(cfg) -> np.ndarray:
    key = jax.random.key(0)
    return np.asarray(make_sim_fn(cfg)(key, generate_physical_depos(key, cfg)).adc)


def _sha(adc: np.ndarray) -> str:
    assert adc.dtype == np.int16, adc.dtype
    return hashlib.sha256(adc.tobytes()).hexdigest()


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


class TestGolden3P:
    @pytest.mark.parametrize("strategy", sorted(GOLDEN_ADC3P_SHA256))
    def test_stacked_adc_matches_pin(self, strategy):
        if not _on_cpu():
            pytest.skip("goldens pinned on CPU")
        assert _sha(_adc3(_cfg3(strategy))) == GOLDEN_ADC3P_SHA256[strategy]

    @pytest.mark.parametrize("strategy", LOOPABLE)
    def test_loop_reproduces_stacked_golden(self, strategy):
        """The loop path must hit the SAME pinned digest: stacked-vs-loop
        bit-identity proven against the goldens, not just against each
        other."""
        if not _on_cpu():
            pytest.skip("goldens pinned on CPU")
        assert _sha(_adc3(_cfg3(strategy, "loop"))) \
            == GOLDEN_ADC3P_SHA256[strategy]


class TestExecutorMatrix:
    """stacked == loop bitwise on every in-process executor (default
    threefry strategy; distributed is the subprocess suite below)."""

    def _pair(self, mode):
        return dataclasses.replace(CFG3, plane_batching=mode)

    def test_single_event_executor(self):
        np.testing.assert_array_equal(_adc3(self._pair("stacked")),
                                      _adc3(self._pair("loop")))

    def test_batched_executor(self):
        key = jax.random.key(11)
        events = [generate_plane_depos(jax.random.fold_in(key, e), CFG3)
                  for e in range(2)]
        batch, keys = pack_events(events), event_keys(key, range(2))
        outs = {m: np.asarray(make_batched_sim_fn(self._pair(m))(keys, batch).adc)
                for m in ("stacked", "loop")}
        np.testing.assert_array_equal(outs["stacked"], outs["loop"])

    def test_streaming_executor(self):
        from repro.launch.sim import stream_simulate

        adcs = {}
        for mode in ("stacked", "loop"):
            got = []
            stream_simulate(self._pair(mode), num_events=3, batch_events=2,
                            on_batch=lambda b, nv, nd, dt, out:
                            got.append(np.asarray(out.adc[:nv])))
            adcs[mode] = np.concatenate(got)
        np.testing.assert_array_equal(adcs["stacked"], adcs["loop"])


class TestDispatchRules:
    @pytest.mark.parametrize("strategy", MULTIPLANE_CHARGE_GRID)
    def test_multiplane_strategy_refuses_loop_mode(self, strategy):
        with pytest.raises(ValueError, match="FULL stacked"):
            _adc3(_cfg3(strategy, "loop"))

    def test_bad_mode_rejected(self):
        with pytest.raises(ValueError, match="plane_batching"):
            resolve_plane_batching(
                dataclasses.replace(CFG3, plane_batching="zigzag"))

    def test_auto_resolution(self):
        assert resolve_plane_batching(CFG3) == "stacked"
        assert resolve_plane_batching(CFG) == "loop"
        assert resolve_plane_batching(
            dataclasses.replace(CFG3, plane_batching="loop")) == "loop"

    def test_vmap_and_multiplane_sets_disjoint(self):
        assert not set(MULTIPLANE_CHARGE_GRID) & set(PLANE_VMAP_CHARGE_GRID)


class TestTunerKeys:
    """Plane-count-aware autotuner surface: a single-plane winner must not
    key (or be offered for) multi-plane dispatches."""

    def test_charge_grid_shape_carries_plane_count(self):
        from repro.tune import autotune

        assert autotune.op_shape("charge_grid", CFG)["num_planes"] == 1
        assert autotune.op_shape("charge_grid", CFG3)["num_planes"] == 3

    def test_multiplane_strategies_gated_on_plane_axis(self):
        from repro.tune import autotune, registry

        for num_planes, expect in ((1, False), (3, True)):
            cfg = dataclasses.replace(CFG, num_planes=num_planes)
            ctx = registry.make_context(
                cfg, autotune.op_shape("charge_grid", cfg))
            avail = registry.available_strategies("charge_grid", ctx)
            assert ("multiplane_xla" in avail) is expect

    def test_tuner_times_multiplane_candidates(self):
        """The 3-plane tuning problem offers the stacked candidates next to
        the looped single-plane ones — the mechanism by which the tuner
        "proves" the plane-batched path."""
        from repro.tune import autotune

        thunks = autotune.candidate_thunks("charge_grid", CFG3,
                                           sample_depos=32)
        assert "multiplane_xla" in thunks
        assert "unfused" in thunks
        out = thunks["multiplane_xla"]()
        assert out.shape == (3, CFG3.num_wires, CFG3.num_ticks)


# ---------------------------------------------------------------------------
# Distributed executor: subprocess with 8 forced host devices
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from repro.config import LArTPCConfig
from repro.core.depo import generate_depos, generate_physical_depos
from repro.core.drift import transport_planes
from repro.core.response import (make_distributed_plane_responses,
                                 make_distributed_response)
from repro.core.distributed import (bin_depos_by_wire, make_distributed_sim,
                                    padded_grid_shape, shard_depos)

results = {}
cfg3 = LArTPCConfig(num_wires=128, num_ticks=512, num_depos=256,
                    response_wires=11, response_ticks=64, num_planes=3)
mesh = jax.make_mesh((4, 2), ("data", "model"))
w_pad, _, _ = padded_grid_shape(cfg3, 8)
resp3 = make_distributed_plane_responses(cfg3, w_pad)
key = jax.random.key(0)
pdepos = generate_physical_depos(key, cfg3)
sd = shard_depos(pdepos, mesh)
cfg_loop = dataclasses.replace(cfg3, plane_batching="loop")
cfg_st = dataclasses.replace(cfg3, plane_batching="stacked")

# ---- stacked == loop bitwise (psum_scatter, noise + fluctuation on) ----
sim_loop = make_distributed_sim(mesh, cfg_loop, resp3, add_noise=True)
sim_st = make_distributed_sim(mesh, cfg_st, resp3, add_noise=True)
a_loop = np.asarray(sim_loop(key, sd))
a_st = np.asarray(sim_st(key, sd))
results["stacked_eq_loop"] = bool(np.array_equal(a_loop, a_st))

# ---- recon path: stacked == loop for adc / decon / hits ----
simr_loop = make_distributed_sim(mesh, cfg_loop, resp3, add_noise=True,
                                 recon=True)
simr_st = make_distributed_sim(mesh, cfg_st, resp3, add_noise=True,
                               recon=True)
al, dl, hl = simr_loop(key, sd)
as_, ds, hs = simr_st(key, sd)
results["recon_adc_eq"] = bool(np.array_equal(np.asarray(al), np.asarray(as_)))
results["recon_decon_close"] = bool(np.allclose(np.asarray(dl),
                                                np.asarray(ds), atol=1e-5))
results["recon_hits_eq"] = bool(all(
    np.array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(hl), jax.tree.leaves(hs))))

# ---- multi-plane halo: per plane BITWISE equal to the single-plane halo
# path (the strong check: the lifted restriction changes nothing per plane)
cfg3nf = dataclasses.replace(cfg_st, fluctuate=False)
ddepos = transport_planes(pdepos, cfg3nf)
binned = bin_depos_by_wire(ddepos, n_strips=4, w_pad=w_pad)
sdb = shard_depos(binned, mesh)
sim_halo = make_distributed_sim(mesh, cfg3nf, resp3,
                                scatter_reduction="halo", add_noise=False)
a_halo = np.asarray(sim_halo(key, sdb))
cfg1nf = dataclasses.replace(cfg3nf, num_planes=1)
plane_eq = []
for p in range(3):
    dp = jax.tree.map(lambda x: x[p], binned)
    sim1h = make_distributed_sim(mesh, cfg1nf, resp3[p],
                                 scatter_reduction="halo", add_noise=False)
    a1h = np.asarray(sim1h(key, shard_depos(dp, mesh)))
    plane_eq.append(bool(np.array_equal(a_halo[p], a1h)))
results["halo_per_plane_bitwise"] = plane_eq

# ---- multi-plane halo vs psum_scatter: same physics, different depo
# ordering (binned + filler rows), so equality is float-accumulation-loose
sim_ps = make_distributed_sim(mesh, cfg3nf, resp3, add_noise=False)
a_ps = np.asarray(sim_ps(key, sd))
results["halo_vs_psum_frac"] = float((a_halo == a_ps).mean())
results["halo_vs_psum_maxdiff"] = int(
    np.abs(a_halo.astype(int) - a_ps.astype(int)).max())

# ---- collective counts: ONE reduce-scatter + ONE all_to_all chain per
# step whatever the plane count; the loop pays P of each. Counting lives in
# repro.analysis.hlo (shared with the contract auditor) — defining
# instructions only, async -start/-done pairs counted once.
from repro.analysis.hlo import collective_counts

def counts(sim, k, d):
    return collective_counts(sim.lower(k, d).compile().as_text())

cfg1 = dataclasses.replace(cfg3, num_planes=1)
resp1 = make_distributed_response(cfg1, w_pad)
sd1 = shard_depos(generate_depos(key, cfg1), mesh)
sim1 = make_distributed_sim(mesh, cfg1, resp1, add_noise=True)
results["collectives_1p"] = counts(sim1, key, sd1)
results["collectives_3p_stacked"] = counts(sim_st, key, sd)
results["collectives_3p_loop"] = counts(sim_loop, key, sd)

print("RESULTS:" + json.dumps(results))
"""

pytestmark_subprocess = pytest.mark.subprocess


@pytest.fixture(scope="module")
def plane_dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")]
    assert line, proc.stdout
    return json.loads(line[0][len("RESULTS:"):])


@pytest.mark.subprocess
class TestDistributedPlaneBatching:
    def test_stacked_equals_loop_bitwise(self, plane_dist_results):
        assert plane_dist_results["stacked_eq_loop"]

    def test_recon_chain_equal(self, plane_dist_results):
        assert plane_dist_results["recon_adc_eq"]
        assert plane_dist_results["recon_decon_close"]
        assert plane_dist_results["recon_hits_eq"]

    def test_multiplane_halo_bitwise_per_plane(self, plane_dist_results):
        assert plane_dist_results["halo_per_plane_bitwise"] == [True] * 3

    def test_halo_vs_psum_scatter(self, plane_dist_results):
        # binned/filler depo reordering makes the comparison float-order
        # loose (the bitwise guarantee is the per-plane check above)
        assert plane_dist_results["halo_vs_psum_frac"] > 0.999
        assert plane_dist_results["halo_vs_psum_maxdiff"] <= 16

    def test_one_collective_chain_per_step(self, plane_dist_results):
        c1 = plane_dist_results["collectives_1p"]
        c_st = plane_dist_results["collectives_3p_stacked"]
        c_loop = plane_dist_results["collectives_3p_loop"]
        assert c_st == c1, (c_st, c1)  # plane count amortized away
        assert c_loop == {k: 3 * v for k, v in c1.items()}, (c_loop, c1)
        # the chains actually exist (the dicts aren't vacuously zero)
        assert c1["reduce-scatter"] > 0 and c1["all-to-all"] > 0, c1


if __name__ == "__main__":
    for strategy in sorted(GOLDEN_ADC3P_SHA256):
        print(f'    "{strategy}":\n        "{_sha(_adc3(_cfg3(strategy)))}",')
