"""Tile-boundary correctness (ISSUE-3 satellite).

A depo whose patch straddles a tile edge — and one clipped at the detector
edge — must produce BIT-IDENTICAL grids across every scatter-add strategy
and every (non-fluctuating) charge-grid strategy: a single depo leaves no
addition-order freedom, so any bit difference is a real binning/masking bug.
Plus int16 saturation for `digitize` at both ADC rails.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.config import LArTPCConfig
from repro.core.depo import DepoSet
from repro.core.fft_conv import digitize
from repro.core.pipeline import charge_grid_unfused
from repro.core.rasterize import rasterize

#: the Pallas strategies' default tile is (64, 256): wire 64 / tick 256 are
#: interior tile edges of this grid, wire 0 / tick 0 the detector edge
CFG = LArTPCConfig(num_wires=96, num_ticks=768, num_depos=1, fluctuate=False)


def one_depo(wire, tick, sigma_w=1.1, sigma_t=1.4, charge=4321.0) -> DepoSet:
    return DepoSet(wire=jnp.array([wire], jnp.float32),
                   tick=jnp.array([tick], jnp.float32),
                   sigma_w=jnp.array([sigma_w], jnp.float32),
                   sigma_t=jnp.array([sigma_t], jnp.float32),
                   charge=jnp.array([charge], jnp.float32))


#: (name, depo) cases: patch straddling interior tile edges, and a patch
#: clipped against the detector edge (depo_patch_origin clips w0/t0 to 0)
CASES = [
    ("straddle_wire_edge", one_depo(63.7, 100.2)),
    ("straddle_tick_edge", one_depo(30.0, 255.4)),
    ("straddle_corner", one_depo(63.7, 255.4)),
    ("detector_edge", one_depo(0.4, 2.0, sigma_w=0.8, sigma_t=1.0,
                               charge=999.0)),
]


class TestScatterTileBoundary:
    @pytest.mark.parametrize("name,depos", CASES, ids=[c[0] for c in CASES])
    def test_scatter_strategies_bit_identical(self, name, depos):
        patches, w0, t0 = rasterize(depos, CFG)
        grids = {n: np.asarray(s.fn(patches, w0, t0, CFG))
                 for n, s in tune.strategies("scatter_add").items()}
        ref = grids.pop("xla")
        assert float(np.abs(ref).sum()) > 0.0, "depo must deposit charge"
        # total mass lands on the grid (nothing dropped at the boundary)
        np.testing.assert_allclose(ref.sum(), float(patches.sum()), rtol=1e-5)
        for n, grid in grids.items():
            assert np.array_equal(ref, grid), (
                f"{name}: strategy {n!r} diverged bitwise from 'xla'")


class TestChargeGridTileBoundary:
    @pytest.mark.parametrize("name,depos", CASES, ids=[c[0] for c in CASES])
    def test_charge_grid_strategies_bit_identical(self, name, depos):
        """unfused / fused / fused_compact agree bit for bit: the fused
        kernel evaluates the same erf chain at the same absolute float
        coordinates, and compaction only reorders which grid step owns a
        tile (not the per-tile accumulation order)."""
        key = jax.random.key(0)
        ref = np.asarray(charge_grid_unfused(key, depos, CFG))
        ctx = tune.registry.make_context(
            CFG, tune.autotune.op_shape("charge_grid", CFG))
        for n, strat in tune.strategies("charge_grid").items():
            if "bf16" in n:
                continue  # narrower dtype is not bit-comparable by design
            if not strat.is_available(ctx):
                continue  # e.g. multi-plane strategies at num_planes=1
            grid = np.asarray(strat.fn(key, depos, CFG, None))
            assert np.array_equal(ref, grid), (
                f"{name}: strategy {n!r} diverged bitwise from 'unfused'")


class TestDigitizeSaturation:
    def test_int16_saturates_at_adc_rails(self):
        """digitize clamps to the 12-bit range at both rails and never wraps
        the int16 container."""
        cfg = dataclasses.replace(CFG, adc_baseline=900.0,
                                  adc_per_electron=1.0)
        # way past both rails, plus exact rail-hitting values
        sig = jnp.array([[-1e9, -901.0, -900.0, 0.0, 3195.0, 3196.0, 1e9]],
                        jnp.float32)
        adc = digitize(sig, cfg)
        assert adc.dtype == jnp.int16
        got = np.asarray(adc)[0]
        np.testing.assert_array_equal(got, [0, 0, 0, 900, 4095, 4095, 4095])

    def test_extreme_signal_never_wraps(self):
        rng = np.random.default_rng(1)
        sig = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32)
                          * 1e30)
        adc = np.asarray(digitize(sig, CFG))
        assert adc.min() >= 0 and adc.max() <= 4095
