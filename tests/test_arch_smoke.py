"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
asserting output shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeConfig, OptimizerConfig, get_config
from repro.configs import ARCH_IDS
from repro.data.tokens import make_batch, shard_batch
from repro.models.model import Model
from repro.optim.adamw import init_opt_state
from repro.train.train_step import make_train_step

SMOKE_SHAPE = ShapeConfig("smoke", "train", seq_len=32, global_batch=2)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_smoke(arch_id):
    cfg = get_config(arch_id, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = shard_batch(make_batch(cfg, SMOKE_SHAPE, seed=0, step=0))
    logits, aux = model.forward(params, batch)
    s_expect = SMOKE_SHAPE.seq_len
    assert logits.shape == (2, s_expect, cfg.padded_vocab), logits.shape
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_smoke(arch_id):
    cfg = get_config(arch_id, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    opt_state = init_opt_state(params)
    step = jax.jit(make_train_step(model, OptimizerConfig(lr=1e-3,
                                                          warmup_steps=1,
                                                          total_steps=10)))
    batch = shard_batch(make_batch(cfg, SMOKE_SHAPE, seed=0, step=0))
    new_params, new_state, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_state.step) == 1
    # params must actually change
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert changed


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_smoke(arch_id):
    """One prefill + two decode steps with the arch's cache type."""
    cfg = get_config(arch_id, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 16
    batch = shard_batch(make_batch(cfg, ShapeConfig("d", "train", s, b),
                                   seed=0, step=0))
    caches = model.init_caches(b, s + 4)
    logits, caches, extras = model.prefill(params, batch, caches)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    toks_seen = batch["tokens"].shape[1] + (
        cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    for i in range(2):
        logits, caches = model.decode_step(
            params, {"tokens": tok}, caches,
            jnp.asarray(toks_seen + i, jnp.int32), extras)
        assert logits.shape[1] == 1
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)


def test_full_configs_construct():
    """Full (paper-exact) configs build and report plausible param counts."""
    expect = {
        "mamba2-780m": (0.6e9, 1.1e9),
        # internvl2-1b's ViT frontend is a stub; the 0.49B is the LM backbone
        "internvl2-1b": (0.4e9, 1.3e9),
        "qwen3-32b": (25e9, 40e9),
        "nemotron-4-15b": (12e9, 19e9),
        "gemma2-2b": (2.0e9, 3.5e9),
        "stablelm-12b": (10e9, 15e9),
        "deepseek-moe-16b": (13e9, 20e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "recurrentgemma-2b": (2.2e9, 3.6e9),
        "seamless-m4t-large-v2": (1.4e9, 2.9e9),
    }
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        n = cfg.param_count()
        lo, hi = expect[arch_id]
        assert lo <= n <= hi, f"{arch_id}: {n/1e9:.2f}B params out of range"
