"""FFT-convolution and response-transform unit/property tests.

Covers the ISSUE 5 satellite fixes:
  * both ``fft_convolve`` strategies run on narrow (bfloat16) charge grids
    and return one identical dtype (the bf16 path used to crash rfft2 and
    return bf16 from fft2);
  * ``response.next_fast_len`` is provably minimal 5-smooth >= n;
  * ``fft_conv._full_spectrum`` reconstructs the exact Hermitian tail at
    odd and even padded widths.

No hypothesis dependency: the property sweeps are deterministic
enumerations, so these tests always run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import LArTPCConfig
from repro.core.fft_conv import _full_spectrum, fft_convolve
from repro.core.response import make_response, next_fast_len

CFG = LArTPCConfig(num_wires=64, num_ticks=256, num_depos=64,
                   response_wires=11, response_ticks=48)

STRATEGIES = ("rfft2", "fft2")
PATCH_DTYPES = ("float32", "bfloat16")


class TestConvolveDtypes:
    @pytest.mark.parametrize("strategy", STRATEGIES)
    @pytest.mark.parametrize("patch_dtype", PATCH_DTYPES)
    def test_strategy_runs_on_patch_dtype(self, strategy, patch_dtype):
        """Every (strategy, patch dtype) pair runs and returns float32 —
        the single upcast lives in ``_pad_grid``."""
        resp = make_response(CFG)
        grid = jax.random.uniform(
            jax.random.key(1), (CFG.num_wires, CFG.num_ticks),
            dtype=jnp.float32).astype(jnp.dtype(patch_dtype))
        out = fft_convolve(grid, resp, strategy)
        assert out.dtype == jnp.float32
        assert out.shape == (CFG.num_wires, CFG.num_ticks)

    @pytest.mark.parametrize("patch_dtype", PATCH_DTYPES)
    def test_strategies_agree_per_dtype(self, patch_dtype):
        """rfft2 and fft2 see the same upcast input, so they agree to FFT
        roundoff and share an output dtype."""
        resp = make_response(CFG)
        grid = jax.random.uniform(
            jax.random.key(2), (CFG.num_wires, CFG.num_ticks),
            dtype=jnp.float32).astype(jnp.dtype(patch_dtype))
        outs = [fft_convolve(grid, resp, s) for s in STRATEGIES]
        assert outs[0].dtype == outs[1].dtype
        scale = float(jnp.abs(outs[0]).max()) + 1e-30
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                                   atol=1e-4 * scale)

    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_full_chain_runs_bf16_patches(self, strategy):
        """End-to-end: the registry-default convolve no longer crashes a
        ``patch_dtype="bfloat16"`` / ``unfused_bf16`` simulation."""
        from repro.core.depo import generate_depos
        from repro.core.pipeline import make_sim_fn

        cfg = dataclasses.replace(CFG, patch_dtype="bfloat16",
                                  fft_strategy=strategy)
        key = jax.random.key(0)
        out = make_sim_fn(cfg)(key, generate_depos(key, cfg))
        assert out.adc.dtype == jnp.int16
        assert out.signal.dtype == jnp.float32


def _five_smooth_up_to(limit: int):
    vals = set()
    p2 = 1
    while p2 <= limit:
        p23 = p2
        while p23 <= limit:
            v = p23
            while v <= limit:
                vals.add(v)
                v *= 5
            p23 *= 3
        p2 *= 2
    return sorted(vals)


class TestNextFastLen:
    def test_five_smooth_at_least_n_and_minimal(self):
        """For every n <= 2048: the result divides into 2/3/5 factors only,
        is >= n, and equals the brute-force minimal 5-smooth value."""
        smooth = _five_smooth_up_to(1 << 12)
        for n in range(1, 2049):
            m = next_fast_len(n)
            assert m >= n, (n, m)
            r = m
            for p in (2, 3, 5):
                while r % p == 0:
                    r //= p
            assert r == 1, f"next_fast_len({n}) = {m} is not 5-smooth"
            expect = next(v for v in smooth if v >= n)
            assert m == expect, (n, m, expect)

    def test_spot_values(self):
        assert next_fast_len(1) == 1
        assert next_fast_len(2561) == 2592      # 2^5 * 3^4
        assert next_fast_len(9791) == 10000     # 2^4 * 5^4


class TestFullSpectrum:
    @pytest.mark.parametrize("tp", [40, 41])   # even and odd padded widths
    def test_hermitian_tail_exact(self, tp):
        """The reconstructed tail bins equal conj(half[-k1 % W, tp - k2])
        exactly — pure gather/conj, no transform roundoff allowed."""
        rng = np.random.default_rng(7)
        x = rng.standard_normal((16, tp)).astype(np.float32)
        half = np.asarray(jnp.fft.rfft2(jnp.asarray(x)))
        full = np.asarray(_full_spectrum(jnp.asarray(half), tp))
        nfreq = half.shape[1]
        assert full.shape == (16, tp)
        np.testing.assert_array_equal(full[:, :nfreq], half)
        for k2 in range(nfreq, tp):
            for k1 in range(16):
                expect = np.conj(half[(-k1) % 16, tp - k2])
                assert full[k1, k2] == expect, (k1, k2)

    @pytest.mark.parametrize("tp", [40, 41])
    def test_reconstruction_matches_fft2(self, tp):
        """fft2 of the real grid and the Hermitian reconstruction of its
        rfft2 half-spectrum are the same spectrum (to FFT roundoff)."""
        rng = np.random.default_rng(11)
        x = rng.standard_normal((12, tp)).astype(np.float32)
        full = np.asarray(_full_spectrum(jnp.fft.rfft2(jnp.asarray(x)), tp))
        ref = np.asarray(jnp.fft.fft2(jnp.asarray(x)))
        scale = np.abs(ref).max()
        np.testing.assert_allclose(full, ref, atol=1e-5 * scale)
