"""Stage-graph tests: golden end-to-end regression + executor equivalence.

The refactor contract (ISSUE 4): ``make_sim_fn``, ``make_batched_sim_fn``,
``make_distributed_sim`` (covered in tests/test_distributed.py) and
``stream_simulate`` all execute the SAME SimGraph, and the graph is
bit-for-bit with the pre-graph code. The pinned SHA-256 digests below were
captured from the seed revision (pre-refactor ``simulate_fig4``) on CPU —
any entry point drifting from them is a real regression.
"""
import dataclasses
import hashlib

import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.core.batch import event_keys, make_batched_sim_fn, pack_events
from repro.core.depo import generate_depos, generate_physical_depos
from repro.core.pipeline import make_sim_fn, simulate, simulate_fig4
from repro.core.response import make_response
from repro.core.stages import STAGE_ORDER, build_sim_graph

CFG = get_config("lartpc-uboone", smoke=True)

#: captured at the ISSUE 5 noise-normalization fix (CPU backend, default
#: smoke config, key 0) — the Parseval-correct ``noise_spectrum`` changes
#: the additive noise amplitude, which legitimately refreshed the seed-era
#: digests (every entry point moved together; cross-entry-point equality
#: held throughout). The multi-plane refactor landed ON these pins
#: unchanged: the default single-plane config is bit-identical before and
#: after. Digests are backend-specific (erf/FFT/threefry lowering), so the
#: pinned asserts are CPU-only. A jax upgrade that changes RNG or erf
#: lowering legitimately refreshes these: re-run
#: `python -m tests.test_stages` and paste the new values.
GOLDEN_ADC_SHA256 = {
    "unfused": "810aaba7c770755342f108b8199dbab5e76e0218601e2fd2831c035418f5cfaa",
    "unfused_bf16": "646abfc4c83037f6cb0a1d742a5c1122eaf69ef3b5ba4e96c57ae11fedb6293f",
    "fused_pallas": "861ba4477a055d2bf8da4c8d3aaa58952990c7e38311b1699564390fa5805a58",
    "fused_pallas_compact": "861ba4477a055d2bf8da4c8d3aaa58952990c7e38311b1699564390fa5805a58",
}
GOLDEN_BATCHED_E2_SHA256 = (
    "8f04e6fd99b66fafcdf2c86d0b60fe757156e395ba543c50efc840498ed4339a")

STRATEGIES = sorted(GOLDEN_ADC_SHA256)


def _sha(arr) -> str:
    a = np.ascontiguousarray(np.asarray(arr))
    assert a.dtype == np.int16, a.dtype
    return hashlib.sha256(a.tobytes()).hexdigest()


def _entry_points(cfg):
    """ADC grids from every single-event entry point that must agree: the
    graph executor (make_sim_fn), the legacy wrappers (simulate /
    simulate_fig4), and a raw SimGraph.run — all jit'd, the production
    form (eager bf16 rounds per-op and so differs from any jitted path)."""
    key = jax.random.key(0)
    depos = generate_depos(key, cfg)
    resp = make_response(cfg)
    graph = build_sim_graph(cfg, resp)
    return {
        "make_sim_fn": make_sim_fn(cfg, resp=resp)(key, depos).adc,
        "simulate": jax.jit(
            lambda k, d: simulate(k, d, cfg, resp=resp))(key, depos).adc,
        "simulate_fig4": jax.jit(
            lambda k, d: simulate_fig4(k, d, resp, cfg))(key, depos).adc,
        "graph_run_jit": jax.jit(graph.run)(key, depos).adc,
    }


class TestGolden:
    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_all_entry_points_agree(self, strategy):
        """Graph and legacy entry points produce one identical ADC grid."""
        cfg = dataclasses.replace(CFG, charge_grid_strategy=strategy)
        grids = _entry_points(cfg)
        digests = {name: _sha(adc) for name, adc in grids.items()}
        assert len(set(digests.values())) == 1, digests

    def test_eager_matches_jit_default_strategy(self):
        """For the float32 default chain, even the eager graph run is
        bit-identical to the jitted executor."""
        key = jax.random.key(0)
        depos = generate_depos(key, CFG)
        graph = build_sim_graph(CFG, make_response(CFG))
        eager = graph.run(key, depos).adc
        jitted = make_sim_fn(CFG)(key, depos).adc
        assert _sha(eager) == _sha(jitted)

    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_pinned_seed_digest(self, strategy):
        """Fixed key -> SHA of the int16 ADC grid equals the digest captured
        on the seed revision: the refactor is provably bit-for-bit."""
        if jax.default_backend() != "cpu":
            pytest.skip("pinned digests are CPU-lowering specific")
        cfg = dataclasses.replace(CFG, charge_grid_strategy=strategy)
        key = jax.random.key(0)
        adc = make_sim_fn(cfg)(key, generate_depos(key, cfg)).adc
        assert _sha(adc) == GOLDEN_ADC_SHA256[strategy]

    def test_batched_matches_seed_digest(self):
        if jax.default_backend() != "cpu":
            pytest.skip("pinned digests are CPU-lowering specific")
        key = jax.random.key(0)
        events = [generate_depos(jax.random.fold_in(key, e), CFG)
                  for e in range(2)]
        out = make_batched_sim_fn(CFG)(event_keys(key, range(2)),
                                       pack_events(events))
        assert _sha(out.adc) == GOLDEN_BATCHED_E2_SHA256

    def test_batched_rows_equal_single_event_runs(self):
        """The vmap executor and the single-event executor run the same
        graph: per-event rows are bit-identical."""
        key = jax.random.key(5)
        events = [generate_depos(jax.random.fold_in(key, e), CFG)
                  for e in range(3)]
        batch = pack_events(events)
        keys = event_keys(key, range(3))
        out = make_batched_sim_fn(CFG)(keys, batch)
        sim = make_sim_fn(CFG)
        for e in range(3):
            ref = sim(keys[e], batch.event(e))
            np.testing.assert_array_equal(np.asarray(out.adc[e]),
                                          np.asarray(ref.adc))


class TestGraphMechanics:
    def test_canonical_stage_order(self):
        graph = build_sim_graph(CFG, make_response(CFG))
        assert graph.stage_names == STAGE_ORDER

    def test_no_noise_drops_the_stage(self):
        graph = build_sim_graph(CFG, make_response(CFG), add_noise=False)
        assert "noise" not in graph.stage_names
        assert graph.stage_names[-1] == "digitize"

    def test_physical_input_drifts_inside_the_graph(self):
        """Feeding physical depos to any executor transports them through
        the drift stage — same ADC as pre-drifting by hand."""
        if jax.default_backend() != "cpu":
            # accelerator backends may FMA-fuse the in-graph drift sigma
            # math, making jit-drift vs eager-drift ulp-different
            pytest.skip("bitwise jit-vs-eager drift is CPU-specific")
        key = jax.random.key(1)
        pdepos = generate_physical_depos(key, CFG)
        sim = make_sim_fn(CFG)
        from_physical = sim(key, pdepos)
        from_detector = sim(key, generate_depos(key, CFG))
        np.testing.assert_array_equal(np.asarray(from_physical.adc),
                                      np.asarray(from_detector.adc))

    def test_stage_override(self):
        """SimGraph.replace swaps one stage without touching the executor
        (the mechanism the distributed pipeline specializes through)."""
        graph = build_sim_graph(CFG, make_response(CFG), add_noise=False)
        marker = {}

        def null_charge_grid(state):
            marker["ran"] = True
            import jax.numpy as jnp
            return state._replace(grid=jnp.zeros(
                (CFG.num_wires, CFG.num_ticks), jnp.float32))

        out = graph.replace(charge_grid=null_charge_grid).run(
            jax.random.key(0), generate_depos(jax.random.key(0), CFG))
        assert marker.get("ran")
        adc = np.asarray(out.adc)
        assert (adc == CFG.adc_baseline).all()  # zero grid -> baseline ADC

    def test_override_unknown_stage_raises(self):
        graph = build_sim_graph(CFG, make_response(CFG))
        with pytest.raises(KeyError, match="deconvolve"):
            graph.replace(deconvolve=lambda s: s)

    def test_graph_is_reusable_and_stateless(self):
        graph = build_sim_graph(CFG, make_response(CFG))
        key = jax.random.key(9)
        depos = generate_depos(key, CFG)
        a = graph.run(key, depos).adc
        b = graph.run(key, depos).adc
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_registry_ops_annotated(self):
        """Stages declare the hot op they dispatch, so tooling can map the
        timing board onto the strategy registry."""
        graph = build_sim_graph(CFG, make_response(CFG))
        ops = {s.name: s.op for s in graph.stages}
        assert ops["drift"] == "drift"
        assert ops["charge_grid"] == "charge_grid"
        assert ops["convolve"] == "fft_convolve"
        assert ops["noise"] is None and ops["digitize"] is None


class TestTimed:
    def test_timed_covers_every_stage_and_matches_run(self):
        graph = build_sim_graph(CFG, make_response(CFG))
        key = jax.random.key(0)
        pdepos = generate_physical_depos(key, CFG)
        out, timings = graph.timed(key, pdepos, warmup=0, iters=1)
        assert tuple(timings) == graph.stage_names
        assert all(t >= 0 for t in timings.values())
        ref = jax.jit(graph.run)(key, pdepos)
        np.testing.assert_array_equal(np.asarray(out.adc),
                                      np.asarray(ref.adc))

    def test_timed_batched(self):
        graph = build_sim_graph(CFG, make_response(CFG))
        key = jax.random.key(0)
        events = [generate_physical_depos(jax.random.fold_in(key, e), CFG)
                  for e in range(2)]
        batch = jax.tree.map(lambda *xs: jax.numpy.stack(xs), *events)
        keys = event_keys(key, range(2))
        out, timings = graph.timed(keys, batch, warmup=0, iters=1,
                                   batched=True)
        assert tuple(timings) == graph.stage_names
        assert np.asarray(out.adc).shape == (2, CFG.num_wires, CFG.num_ticks)


if __name__ == "__main__":
    # refresh helper: print current digests to paste into the pins above
    key = jax.random.key(0)
    for strategy in STRATEGIES:
        cfg = dataclasses.replace(CFG, charge_grid_strategy=strategy)
        adc = make_sim_fn(cfg)(key, generate_depos(key, cfg)).adc
        print(f'    "{strategy}": "{_sha(adc)}",')
    events = [generate_depos(jax.random.fold_in(key, e), CFG)
              for e in range(2)]
    out = make_batched_sim_fn(CFG)(event_keys(key, range(2)),
                                   pack_events(events))
    print(f'batched_E2: "{_sha(out.adc)}"')
