"""Fault-tolerance layer tests (ISSUE 8): the streaming executor must
survive poison events, transient OOMs, kills, and corrupt caches — WITHOUT
perturbing a single bit of any healthy event's ADC.

The contracts under test:

  * ingest validation quarantines invalid events; survivors are bit-identical
    to a run that never saw the poison (ids/keys preserved)
  * the batch journal makes a killed run resumable, and the resumed run's
    per-batch ADC SHA-256 digests equal a clean uninterrupted run's
  * OOM-class dispatch failures retry with halved batches — bit-identical
    (vmap row independence + fixed pad_to); non-OOM failures fail fast with
    a structured SimBatchError
  * the default path (validation on, clean input, no journal, check_finite
    off) is bit-identical to the pre-ISSUE-8 code — the pinned golden digest
    from tests/test_stages.py must still hold, with and without the sentinel
  * the autotune cache survives torn writes, garbage bytes, foreign schemas,
    and concurrent writers
"""
import dataclasses
import hashlib
import json
import os

import jax
import numpy as np
import pytest

from repro.config import LArTPCConfig, get_config
from repro.core.batch import (empty_event, event_keys, make_batched_sim_fn,
                              pack_events, screen_events)
from repro.core.depo import DepoSet, generate_depos
from repro.core.drift import PhysicalDepoSet
from repro.core.validate import (RunHealth, SimBatchError, check_depos,
                                 dead_letter, is_oom_error)
from repro.launch.journal import (JournalError, RunJournal,
                                  load_journal_records, run_fingerprint)
from repro.launch.sim import stream_simulate
from repro.testing.faults import (FaultPlan, InjectedDispatchError,
                                  InjectedOOM, corrupt_tune_cache)

# small config (test_event_batch conventions) — fast on CPU
CFG = LArTPCConfig(num_wires=64, num_ticks=256, num_depos=48,
                   response_wires=11, response_ticks=48)

# the seed-era pinned digest from tests/test_stages.py (smoke config, CPU,
# key 0): the default path with this module's layer present must still hit it
GOLDEN_UNFUSED_SHA = (
    "810aaba7c770755342f108b8199dbab5e76e0218601e2fd2831c035418f5cfaa")


def _depos(ev: int, cfg: LArTPCConfig = CFG, seed: int = 0) -> DepoSet:
    return generate_depos(jax.random.fold_in(jax.random.key(seed), ev), cfg)


def _nan_depos(ev: int) -> DepoSet:
    d = _depos(ev)
    q = np.array(np.asarray(d.charge))
    q[0] = np.nan
    return d._replace(charge=q)


# ---------------------------------------------------------------------------
# Validation rules
# ---------------------------------------------------------------------------


class TestValidation:
    def test_clean_event_passes(self):
        assert check_depos(_depos(0), CFG) == []

    def test_nan_charge_rejected(self):
        reasons = check_depos(_nan_depos(0), CFG)
        assert any("nonfinite charge" in r for r in reasons)

    def test_inf_position_rejected(self):
        d = _depos(0)
        w = np.array(np.asarray(d.wire))
        w[3] = np.inf
        reasons = check_depos(d._replace(wire=w), CFG)
        assert any("nonfinite wire" in r for r in reasons)

    def test_negative_charge_rejected(self):
        d = _depos(0)
        q = np.array(np.asarray(d.charge))
        q[1] = -5.0
        reasons = check_depos(d._replace(charge=q), CFG)
        assert any("negative charge" in r for r in reasons)

    def test_zero_sigma_rejected(self):
        d = _depos(0)
        s = np.zeros_like(np.asarray(d.sigma_w))
        reasons = check_depos(d._replace(sigma_w=s), CFG)
        assert any("non-positive sigma_w" in r for r in reasons)

    def test_far_out_of_frame_rejected_mild_overhang_ok(self):
        d = _depos(0)
        w = np.array(np.asarray(d.wire))
        w[0] = -1.5  # mild overhang: the rasterizer clips this — fine
        assert check_depos(d._replace(wire=w), CFG) == []
        w[0] = 1e7   # corruption-scale: reject
        reasons = check_depos(d._replace(wire=w), CFG)
        assert any("wire outside" in r for r in reasons)

    def test_oversize_rejected(self):
        d = _depos(0)
        assert check_depos(d, CFG, max_depos=d.n) == []
        reasons = check_depos(d, CFG, max_depos=d.n - 1)
        assert any("oversized" in r for r in reasons)

    def test_inconsistent_shapes_rejected(self):
        d = _depos(0)
        reasons = check_depos(
            d._replace(charge=np.asarray(d.charge)[:-1]), CFG)
        assert any("inconsistent leaf shapes" in r for r in reasons)

    def test_plane_axis_mismatch_rejected(self):
        d = _depos(0)
        stacked = type(d)(*[np.stack([np.asarray(a)] * 2)
                            for a in d])  # (2, N) leaves
        cfg3 = dataclasses.replace(CFG, num_planes=3)
        reasons = check_depos(stacked, cfg3)
        assert any("plane axis 2 != num_planes 3" in r for r in reasons)

    def test_physical_frame_rules(self):
        n = 16
        ok = PhysicalDepoSet(
            x=np.full(n, 5.0, np.float32), y=np.zeros(n, np.float32),
            z=np.zeros(n, np.float32), t=np.zeros(n, np.float32),
            q=np.full(n, 100.0, np.float32))
        assert check_depos(ok, CFG) == []
        bad_x = ok._replace(x=np.full(n, -3.0, np.float32))
        assert any("negative drift time" in r for r in check_depos(bad_x, CFG))
        bad_q = ok._replace(q=np.full(n, -1.0, np.float32))
        assert any("negative charge" in r for r in check_depos(bad_q, CFG))

    def test_screen_events_quarantines_and_counts(self):
        health = RunHealth()
        events = [_depos(0), _nan_depos(1), _depos(2)]
        kept, ids, letters = screen_events(events, [0, 1, 2], CFG,
                                           batch=7, health=health)
        assert ids == [0, 2] and len(kept) == 2
        assert health.quarantined == 1
        (letter,) = letters
        assert letter["event"] == 1 and letter["batch"] == 7
        assert letter["reasons"]
        json.dumps(letter)  # must be JSON-serializable as-is

    def test_dead_letter_shape(self):
        d = _depos(0)
        rec = dead_letter(3, 1, ["r"], d)
        assert rec == {"event": 3, "batch": 1, "reasons": ["r"],
                       "n_depos": d.n}


class TestOOMClassification:
    def test_injected_oom_is_oom(self):
        assert is_oom_error(InjectedOOM("RESOURCE_EXHAUSTED: boom"))

    def test_message_variants(self):
        assert is_oom_error(RuntimeError("CUDA out of memory"))
        assert is_oom_error(RuntimeError("OUT_OF_MEMORY while allocating"))

    def test_ordinary_errors_are_not(self):
        assert not is_oom_error(InjectedDispatchError("nope"))
        assert not is_oom_error(ValueError("shape mismatch"))


# ---------------------------------------------------------------------------
# Fault plan
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_parse(self):
        p = FaultPlan.parse("nan@0, neg@3,oversize@2,oom@1,oom@4x2,error@5")
        assert p.nan_events == {0} and p.negative_events == {3}
        assert p.oversized_events == {2}
        assert p.oom_batches == {1: 1, 4: 2}
        assert p.error_batches == {5}

    def test_parse_rejects_bad_specs(self):
        with pytest.raises(ValueError):
            FaultPlan.parse("explode@1")
        with pytest.raises(ValueError):
            FaultPlan.parse("nan@1x2")  # xN is oom-only

    def test_corrupt_event_nan_and_oversize(self):
        p = FaultPlan.parse("nan@0,oversize@1")
        d0 = p.corrupt_event(0, _depos(0))
        assert not np.isfinite(np.asarray(d0.charge)).all()
        d1 = p.corrupt_event(1, _depos(1))
        assert d1.n == 2 * _depos(1).n
        # unscheduled events pass through untouched (same object)
        d2 = _depos(2)
        assert p.corrupt_event(2, d2) is d2

    def test_oom_countdown(self):
        p = FaultPlan.parse("oom@0x2")
        for _ in range(2):
            with pytest.raises(InjectedOOM):
                p.before_dispatch(0)
        p.before_dispatch(0)  # budget spent: no raise

    def test_error_batch_always_raises(self):
        p = FaultPlan.parse("error@1")
        p.before_dispatch(0)
        for _ in range(2):
            with pytest.raises(InjectedDispatchError):
                p.before_dispatch(1)


# ---------------------------------------------------------------------------
# Journal
# ---------------------------------------------------------------------------


class TestJournal:
    def test_create_append_reload(self, tmp_path):
        path = str(tmp_path / "j.jsonl")
        with RunJournal(path, fingerprint="abc") as j:
            j.append_batch({"batch": 0, "events": 2})
            j.append_batch({"batch": 1, "events": 1})
        j2 = RunJournal(path, fingerprint="abc", resume=True)
        assert sorted(j2.completed) == [0, 1]
        assert j2.completed[1]["events"] == 1
        j2.close()

    def test_fingerprint_mismatch_rejected(self, tmp_path):
        path = str(tmp_path / "j.jsonl")
        RunJournal(path, fingerprint="abc").close()
        with pytest.raises(JournalError, match="fingerprint"):
            RunJournal(path, fingerprint="DIFFERENT", resume=True)

    def test_torn_final_line_tolerated(self, tmp_path):
        path = str(tmp_path / "j.jsonl")
        with RunJournal(path, fingerprint="abc") as j:
            j.append_batch({"batch": 0, "events": 2})
            j.append_batch({"batch": 1, "events": 2})
        with open(path, "a") as f:
            f.write('{"kind": "batch", "batch": 2, "eve')  # torn write
        j2 = RunJournal(path, fingerprint="abc", resume=True)
        assert sorted(j2.completed) == [0, 1]  # torn record dropped
        j2.close()
        # and the journal is APPENDABLE again after the torn line
        recs = load_journal_records(path)
        assert [r["batch"] for r in recs] == [0, 1]

    def test_garbage_file_rejected(self, tmp_path):
        path = str(tmp_path / "j.jsonl")
        with open(path, "w") as f:
            f.write("not a journal\n")
        with pytest.raises(JournalError):
            RunJournal(path, fingerprint="abc", resume=True)

    def test_fingerprint_covers_cfg_and_params(self):
        a = run_fingerprint(CFG, seed=0, batch_events=2)
        assert a == run_fingerprint(CFG, seed=0, batch_events=2)
        assert a != run_fingerprint(CFG, seed=1, batch_events=2)
        cfg2 = dataclasses.replace(CFG, num_wires=128)
        assert a != run_fingerprint(cfg2, seed=0, batch_events=2)


# ---------------------------------------------------------------------------
# Streaming fault tolerance (shared compiled sim via module fixture)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sim_fn():
    # one jit'd program shared by every streaming test (shape-polymorphic:
    # E=2 and E=4 launches each compile once)
    return make_batched_sim_fn(CFG, donate=False)


def _stream_rows(sim, cfg=CFG, num_events=4, batch_events=2, **kw):
    """stream_simulate + per-batch valid-region ADC capture."""
    rows = {}

    def grab(b, n_valid, n_depos, dt, out):
        rows[b] = np.array(np.asarray(out.adc)[:n_valid])

    stats = stream_simulate(cfg, num_events, batch_events, sim=sim,
                            on_batch=grab, **kw)
    return rows, stats


class TestStreamFaultTolerance:
    def test_clean_run_health(self, sim_fn):
        rows, stats = _stream_rows(sim_fn)
        assert stats["events"] == 4
        h = stats["health"]
        assert h["events_ok"] == 4 and h["quarantined"] == 0
        assert h["retries"] == 0 and h["resumed"] == 0

    def test_quarantine_preserves_survivors_bitwise(self, sim_fn):
        clean, _ = _stream_rows(sim_fn)
        rows, stats = _stream_rows(sim_fn, faults=FaultPlan.parse("nan@1"))
        h = stats["health"]
        assert h["quarantined"] == 1 and h["events_ok"] == 3
        assert stats["events"] == 3
        (letter,) = h["dead_letters"]
        assert letter["event"] == 1 and letter["batch"] == 0
        # batch 0 survivor (event 0) bit-identical to the clean run's row
        np.testing.assert_array_equal(rows[0][0], clean[0][0])
        # batch 1 untouched entirely
        np.testing.assert_array_equal(rows[1], clean[1])

    def test_validation_off_is_bit_identical_on_clean_input(self, sim_fn):
        on, _ = _stream_rows(sim_fn)
        off, _ = _stream_rows(sim_fn, validate=False)
        for b in on:
            np.testing.assert_array_equal(on[b], off[b])

    def test_oversized_event_quarantined_not_crash(self, sim_fn):
        rows, stats = _stream_rows(sim_fn,
                                   faults=FaultPlan.parse("oversize@2"))
        assert stats["health"]["quarantined"] == 1
        assert any("oversized" in r
                   for r in stats["health"]["dead_letters"][0]["reasons"])

    def test_retry_halving_is_bit_identical(self, sim_fn):
        clean, _ = _stream_rows(sim_fn, num_events=4, batch_events=4)
        rows, stats = _stream_rows(sim_fn, num_events=4, batch_events=4,
                                   faults=FaultPlan.parse("oom@0"))
        h = stats["health"]
        assert h["retries"] == 1 and h["halvings"] == 1
        np.testing.assert_array_equal(rows[0], clean[0])

    def test_nonretryable_fails_fast_with_context(self, sim_fn):
        with pytest.raises(SimBatchError) as ei:
            _stream_rows(sim_fn, faults=FaultPlan.parse("error@1"))
        e = ei.value
        assert e.batch == 1 and e.attempts == 1
        assert isinstance(e.cause, InjectedDispatchError)
        assert isinstance(e.__cause__, InjectedDispatchError)

    def test_retry_budget_exhausted_raises(self, sim_fn):
        with pytest.raises(SimBatchError) as ei:
            _stream_rows(sim_fn, faults=FaultPlan.parse("oom@0x9"),
                         max_retries=2)
        assert ei.value.attempts == 3  # initial + 2 retries
        assert is_oom_error(ei.value.cause)

    def test_resume_is_bit_identical(self, sim_fn, tmp_path):
        jpath = str(tmp_path / "run.jsonl")
        cpath = str(tmp_path / "clean.jsonl")
        _stream_rows(sim_fn, num_events=6, batch_events=2, journal=cpath)
        shas = {r["batch"]: r["adc_sha"]
                for r in load_journal_records(cpath)}
        # killed run: batch 1 dies permanently; batch 0 must be salvaged
        with pytest.raises(SimBatchError):
            _stream_rows(sim_fn, num_events=6, batch_events=2,
                         journal=jpath, faults=FaultPlan.parse("error@1"))
        done = {r["batch"] for r in load_journal_records(jpath)}
        assert done == {0}
        # resume: only batches 1..2 run; digests equal the clean run's
        rows, stats = _stream_rows(sim_fn, num_events=6, batch_events=2,
                                   journal=jpath, resume=True)
        assert sorted(rows) == [1, 2]  # batch 0 skipped, not re-run
        assert stats["health"]["resumed"] == 2
        assert stats["events"] == 6
        resumed = {r["batch"]: r["adc_sha"]
                   for r in load_journal_records(jpath)}
        assert resumed == shas

    def test_resume_wrong_config_rejected(self, sim_fn, tmp_path):
        jpath = str(tmp_path / "run.jsonl")
        _stream_rows(sim_fn, journal=jpath)
        with pytest.raises(JournalError, match="fingerprint"):
            _stream_rows(sim_fn, seed=99, journal=jpath, resume=True)

    def test_resume_without_journal_rejected(self, sim_fn):
        with pytest.raises(ValueError, match="journal"):
            stream_simulate(CFG, 2, sim=sim_fn, resume=True)

    def test_callback_error_does_not_lose_stats(self, sim_fn):
        def bad_callback(b, n_valid, n_depos, dt, out):
            raise KeyError("user bug")

        with pytest.warns(RuntimeWarning) as rec:
            stats = stream_simulate(CFG, 4, 2, sim=sim_fn,
                                    on_batch=bad_callback)
        assert sum("callback failed for batch" in str(w.message)
                   for w in rec) == 2
        assert stats["events"] == 4  # every batch still recorded
        assert len(stats["batches"]) == 2
        assert stats["health"]["callback_errors"] == 2

    def test_zero_events(self, sim_fn):
        stats = stream_simulate(CFG, 0, 2, sim=sim_fn)
        assert stats["events"] == 0 and stats["batches"] == []
        assert stats["health"]["events_ok"] == 0

    def test_negative_events_rejected(self, sim_fn):
        with pytest.raises(ValueError, match="num_events"):
            stream_simulate(CFG, -1, sim=sim_fn)

    def test_all_quarantined_batch_still_streams(self, sim_fn):
        rows, stats = _stream_rows(sim_fn,
                                   faults=FaultPlan.parse("nan@0,nan@1"))
        assert stats["health"]["quarantined"] == 2
        assert stats["events"] == 2  # batch 1's events survive
        assert rows[0].shape[0] == 0  # batch 0: all padding
        # batch 1 rows bit-identical to a clean run
        clean, _ = _stream_rows(sim_fn)
        np.testing.assert_array_equal(rows[1], clean[1])


# ---------------------------------------------------------------------------
# check_finite sentinel
# ---------------------------------------------------------------------------


class TestCheckFinite:
    def test_off_path_hits_seed_golden_pin(self):
        """The fault-tolerance layer must not move the default path by one
        bit: the seed-era pinned digest still holds (CPU lowering)."""
        if jax.default_backend() != "cpu":
            pytest.skip("pinned digests are CPU-lowering specific")
        from repro.core.pipeline import make_sim_fn

        cfg = get_config("lartpc-uboone", smoke=True)
        assert cfg.check_finite is False  # off by default
        key = jax.random.key(0)
        adc = np.ascontiguousarray(
            np.asarray(make_sim_fn(cfg)(key, generate_depos(key, cfg)).adc))
        assert hashlib.sha256(adc.tobytes()).hexdigest() == GOLDEN_UNFUSED_SHA

    def test_on_path_is_bitwise_identical_and_reports_ok(self):
        from repro.core.pipeline import make_sim_fn

        cfg = get_config("lartpc-uboone", smoke=True)
        key = jax.random.key(0)
        depos = generate_depos(key, cfg)
        base = make_sim_fn(cfg)(key, depos)
        checked = make_sim_fn(
            dataclasses.replace(cfg, check_finite=True))(key, depos)
        np.testing.assert_array_equal(np.asarray(base.adc),
                                      np.asarray(checked.adc))
        assert base.finite_ok is None       # off: empty pytree node
        assert bool(checked.finite_ok)      # on, clean input: True

    def test_sentinel_trips_on_nan_input(self):
        cfg = dataclasses.replace(CFG, check_finite=True)
        sim = make_batched_sim_fn(cfg, donate=False)
        events = [_depos(0), _nan_depos(1)]
        out = sim(event_keys(jax.random.key(0), [0, 1]),
                  pack_events(events, pad_to=CFG.num_depos))
        ok = np.asarray(out.finite_ok)
        assert ok.shape == (2,)
        assert bool(ok[0]) and not bool(ok[1])

    def test_stream_counts_nonfinite_events(self):
        cfg = dataclasses.replace(CFG, check_finite=True)
        sim = make_batched_sim_fn(cfg, donate=False)
        # validation OFF so the NaN reaches the device sentinel
        _, stats = _stream_rows(sim, cfg=cfg, validate=False,
                                faults=FaultPlan.parse("nan@1"))
        assert stats["health"]["nonfinite_events"] == 1
        assert stats["batches"][0]["nonfinite"] == 1
        assert stats["batches"][1]["nonfinite"] == 0


# ---------------------------------------------------------------------------
# Degenerate recon inputs
# ---------------------------------------------------------------------------


class TestDegenerateRecon:
    def test_empty_events_yield_zero_hits(self):
        """All-padding batches flow through deconvolve + hit_find: with no
        charge and no noise every mask is False and n_hits == 0."""
        cfg = dataclasses.replace(CFG, noise_rms_adc=0.0)
        sim = make_batched_sim_fn(cfg, donate=False, recon=True)
        batch = pack_events([empty_event(), empty_event()],
                            pad_to=cfg.num_depos)
        out = sim(event_keys(jax.random.key(0), [100, 101]), batch)
        assert int(np.asarray(out.hits.mask).sum()) == 0
        assert int(np.asarray(out.hits.n_hits).sum()) == 0

    def test_stream_recon_with_all_quarantined_batch(self):
        cfg = dataclasses.replace(CFG, noise_rms_adc=0.0)
        sim = make_batched_sim_fn(cfg, donate=False, recon=True)
        rows = {}

        def grab(b, n_valid, n_depos, dt, out):
            rows[b] = int(np.asarray(out.hits.mask)[:n_valid].sum())

        stats = stream_simulate(cfg, 4, 2, sim=sim, recon=True,
                                on_batch=grab,
                                faults=FaultPlan.parse("nan@0,nan@1"))
        assert stats["health"]["quarantined"] == 2
        assert rows[0] == 0          # fully-masked batch: zero hits
        assert stats["batches"][0]["hits"] == 0


# ---------------------------------------------------------------------------
# Tune-cache robustness
# ---------------------------------------------------------------------------


class TestTuneCacheRobustness:
    def _cache(self, tmp_path):
        from repro.tune.autotune import TuneCache

        return TuneCache(str(tmp_path / "tune_cache.json"))

    def test_roundtrip_stamps_schema(self, tmp_path):
        from repro.tune.autotune import SCHEMA_VERSION

        c = self._cache(tmp_path)
        c.put("k", {"strategy": "xla"})
        hit = self._cache(tmp_path).get("k")
        assert hit["strategy"] == "xla"
        assert hit["schema"] == SCHEMA_VERSION

    @pytest.mark.parametrize("mode", ["truncate", "garbage", "foreign"])
    def test_corruption_degrades_to_miss_and_recovers(self, tmp_path, mode):
        c = self._cache(tmp_path)
        c.put("op|cpu|cpu|n=1", {"strategy": "xla"})
        corrupt_tune_cache(c.path, mode)
        fresh = self._cache(tmp_path)
        assert fresh.get("op|cpu|cpu|n=1") is None  # miss, not crash
        # and a subsequent put writes a clean usable cache again
        fresh.put("op|cpu|cpu|n=1", {"strategy": "pallas"})
        assert self._cache(tmp_path).get("op|cpu|cpu|n=1")["strategy"] == \
            "pallas"

    def test_foreign_schema_entries_ignored_per_entry(self, tmp_path):
        c = self._cache(tmp_path)
        c.put("mine", {"strategy": "xla"})
        corrupt_tune_cache(c.path, "foreign")  # clobbers with foreign JSON
        fresh = self._cache(tmp_path)
        assert fresh.get("some|other|tool|key") is None
        assert fresh.get("scatter_add|cpu|cpu|num_depos=256") is None

    def test_concurrent_writers_merge_not_clobber(self, tmp_path):
        """Two cache handles (two processes, in spirit): the second writer
        re-reads disk on put, so the first writer's entry survives."""
        a = self._cache(tmp_path)
        b = self._cache(tmp_path)
        b.get("warm")  # b loads (empty) disk BEFORE a writes
        a.put("from_a", {"strategy": "xla"})
        b.put("from_b", {"strategy": "pallas"})
        final = self._cache(tmp_path)
        assert final.get("from_a")["strategy"] == "xla"
        assert final.get("from_b")["strategy"] == "pallas"

    def test_no_tmp_litter(self, tmp_path):
        c = self._cache(tmp_path)
        c.put("k", {"strategy": "xla"})
        litter = [f for f in os.listdir(tmp_path) if ".tmp" in f]
        assert litter == []

    def test_usable_hit_rejects_non_dict(self):
        from repro.tune import registry
        from repro.tune.autotune import _usable_hit, op_shape

        registry.ensure_registered()
        ctx = registry.make_context(CFG, op_shape("scatter_add", CFG))
        assert not _usable_hit("scatter_add", None, ctx)
        assert not _usable_hit("scatter_add", "just a string", ctx)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-v"]))
