"""Fault-tolerance integration tests: train, checkpoint, kill, resume."""
import dataclasses

import numpy as np

from repro.config import (CheckpointConfig, ModelConfig, OptimizerConfig,
                          ShapeConfig, TrainConfig)
from repro.train.trainer import Trainer

TINY = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                   d_ff=64, vocab_size=128, remat="none")
SHAPE = ShapeConfig("tiny", "train", seq_len=32, global_batch=4)


def _cfg(tmp_path, total=12, every=5):
    return TrainConfig(
        model=TINY, shape=SHAPE,
        optimizer=OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=total,
                                  schedule="cosine"),
        checkpoint=CheckpointConfig(directory=str(tmp_path), every_steps=every,
                                    keep=2, async_save=False),
        log_every=1000,
    )


def test_loss_decreases(tmp_path):
    trainer = Trainer(_cfg(tmp_path, total=30, every=100))
    result = trainer.run()
    assert result.steps_run == 30
    first = np.mean(result.losses[:5])
    last = np.mean(result.losses[-5:])
    assert last < first, (first, last)


def test_checkpoint_resume_continues(tmp_path):
    # run 12 steps with checkpoints at 5, 10
    t1 = Trainer(_cfg(tmp_path))
    r1 = t1.run(max_steps=12)
    assert r1.final_step == 12

    # "crash" and restart: a new trainer resumes from step 10, not 0
    t2 = Trainer(_cfg(tmp_path, total=15))
    r2 = t2.run(max_steps=15)
    assert r2.resumed_from == 10
    assert r2.steps_run == 5  # 10 -> 15


def test_resume_is_deterministic(tmp_path):
    """Uninterrupted run and crash+resume produce the same final loss."""
    t1 = Trainer(_cfg(tmp_path / "a", total=10, every=4))
    r1 = t1.run(max_steps=10)

    t2a = Trainer(_cfg(tmp_path / "b", total=10, every=4))
    t2a.run(max_steps=8)   # checkpoints at 4, 8; stop at 8
    t2b = Trainer(_cfg(tmp_path / "b", total=10, every=4))
    r2 = t2b.run(max_steps=10)
    assert r2.resumed_from == 8
    np.testing.assert_allclose(r1.losses[-1], r2.losses[-1], rtol=1e-4)


def test_straggler_detection(tmp_path):
    cfg = dataclasses.replace(_cfg(tmp_path), straggler_deadline_s=1e-9)
    result = Trainer(cfg).run(max_steps=3)
    assert result.straggler_steps == 3  # every step exceeds a 1ns deadline
