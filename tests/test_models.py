"""Model-layer unit tests: flash attention, MoE dispatch, SSD, RG-LRU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import ModelConfig, MoEConfig
from repro.models.attention import _direct_attention, flash_attention
from repro.models.moe import apply_moe, make_moe
from repro.models.params import init_params
from repro.models.rglru import _lru_scan
from repro.models.ssm import ssd_chunked, ssd_decode_step


def _pos(b, s):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


class TestFlashAttention:
    @pytest.mark.parametrize("s,h,hkv,d,blk", [
        (64, 4, 4, 16, 16), (64, 8, 2, 32, 32), (48, 6, 1, 8, 16),
        (128, 4, 2, 64, 128),
    ])
    def test_matches_direct(self, s, h, hkv, d, blk):
        b = 2
        q = jax.random.normal(jax.random.key(1), (b, s, h, d))
        k = jax.random.normal(jax.random.key(2), (b, s, hkv, d))
        v = jax.random.normal(jax.random.key(3), (b, s, hkv, d))
        o1 = flash_attention(q, k, v, _pos(b, s), _pos(b, s), causal=True,
                             kv_block=blk)
        o2 = _direct_attention(q, k, v, _pos(b, s), _pos(b, s), causal=True,
                               window=None, logit_cap=0.0, kv_valid=None)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-5, atol=2e-5)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 100), win=st.sampled_from([0, 8, 17, 1000]),
           cap=st.sampled_from([0.0, 30.0]))
    def test_property_masking(self, seed, win, cap):
        b, s, h, d = 1, 32, 2, 8
        q = jax.random.normal(jax.random.key(seed), (b, s, h, d))
        k = jax.random.normal(jax.random.key(seed + 1), (b, s, h, d))
        v = jax.random.normal(jax.random.key(seed + 2), (b, s, h, d))
        o1 = flash_attention(q, k, v, _pos(b, s), _pos(b, s), causal=True,
                             window=win or None, logit_cap=cap, kv_block=8)
        o2 = _direct_attention(q, k, v, _pos(b, s), _pos(b, s), causal=True,
                               window=win or None, logit_cap=cap,
                               kv_valid=None)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=3e-5, atol=3e-5)

    def test_grad_matches(self):
        b, s, h, d = 1, 64, 2, 16
        q = jax.random.normal(jax.random.key(1), (b, s, h, d))
        k = jax.random.normal(jax.random.key(2), (b, s, h, d))
        v = jax.random.normal(jax.random.key(3), (b, s, h, d))

        def loss_flash(q, k, v):
            return (flash_attention(q, k, v, _pos(b, s), _pos(b, s),
                                    causal=True, kv_block=16) ** 2).sum()

        def loss_ref(q, k, v):
            return (_direct_attention(q, k, v, _pos(b, s), _pos(b, s),
                                      causal=True, window=None,
                                      logit_cap=0.0, kv_valid=None) ** 2).sum()

        g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=5e-4, atol=5e-4)

    def test_causality(self):
        """Future kv tokens must not influence earlier outputs."""
        b, s, h, d = 1, 32, 2, 8
        q = jax.random.normal(jax.random.key(1), (b, s, h, d))
        k = jax.random.normal(jax.random.key(2), (b, s, h, d))
        v = jax.random.normal(jax.random.key(3), (b, s, h, d))
        o1 = flash_attention(q, k, v, _pos(b, s), _pos(b, s), causal=True,
                             kv_block=8)
        k2 = k.at[:, -1].set(99.0)
        v2 = v.at[:, -1].set(-99.0)
        o2 = flash_attention(q, k2, v2, _pos(b, s), _pos(b, s), causal=True,
                             kv_block=8)
        np.testing.assert_allclose(np.asarray(o1[:, :-1]),
                                   np.asarray(o2[:, :-1]), atol=1e-6)


class TestMoE:
    CFG = ModelConfig(family="moe", d_model=32, vocab_size=64, num_heads=2,
                      num_kv_heads=2,
                      moe=MoEConfig(num_experts=8, num_shared=1, top_k=2,
                                    expert_ff=16, first_moe_layer=0))

    def _params(self):
        return init_params(
            lambda mk: make_moe(mk, "moe", self.CFG), jax.random.key(0))

    def test_matches_dense_loop(self):
        """Sort-based dispatch == explicit per-token expert loop."""
        p = self._params()
        x = jax.random.normal(jax.random.key(1), (2, 16, 32))
        out, aux = apply_moe(p, x, self.CFG)

        # reference: route per token, run its experts directly
        xf = np.asarray(x.reshape(-1, 32), np.float64)
        logits = xf @ np.asarray(p["router"], np.float64)
        probs = np.exp(logits - logits.max(-1, keepdims=True))
        probs /= probs.sum(-1, keepdims=True)
        ref = np.zeros_like(xf)
        for t in range(xf.shape[0]):
            top = np.argsort(probs[t])[::-1][:2]
            w = probs[t][top] / probs[t][top].sum()
            for e, wt in zip(top, w):
                wg = np.asarray(p["w_gate"][e], np.float64)
                wu = np.asarray(p["w_up"][e], np.float64)
                wd = np.asarray(p["w_down"][e], np.float64)
                g = xf[t] @ wg
                u = xf[t] @ wu
                h = (g / (1 + np.exp(-g))) * u
                ref[t] += wt * (h @ wd)
        # add shared expert
        from repro.models.layers import apply_mlp
        shared = np.asarray(apply_mlp(p["shared"], x, "swiglu")).reshape(-1, 32)
        ref = ref + shared
        np.testing.assert_allclose(np.asarray(out).reshape(-1, 32), ref,
                                   rtol=5e-3, atol=5e-3)

    def test_aux_loss_balanced_router(self):
        """A perfectly uniform router gives aux ~= router_aux_weight."""
        p = self._params()
        p = jax.tree.map(lambda x: x, p)
        p["router"] = jnp.zeros_like(p["router"])  # uniform routing
        x = jax.random.normal(jax.random.key(1), (4, 64, 32))
        _, aux = apply_moe(p, x, self.CFG)
        w = self.CFG.moe.router_aux_weight
        assert abs(float(aux) - w) < 0.5 * w


class TestSSD:
    def test_chunked_matches_sequential(self):
        """Chunked SSD == naive sequential state recursion."""
        b, l, h, p, n = 1, 32, 2, 4, 8
        key = jax.random.key(0)
        x = jax.random.normal(jax.random.key(1), (b, l, h, p))
        dt = jax.nn.softplus(jax.random.normal(jax.random.key(2), (b, l, h)))
        a = -jnp.exp(jax.random.normal(jax.random.key(3), (h,)) * 0.3)
        bb = jax.random.normal(jax.random.key(4), (b, l, 1, n))
        cc = jax.random.normal(jax.random.key(5), (b, l, 1, n))
        y_chunk, final = ssd_chunked(x, dt, a, bb, cc, chunk=8)

        # sequential reference via the decode step
        state = jnp.zeros((b, h, p, n))
        ys = []
        for t in range(l):
            y, state = ssd_decode_step(x[:, t:t + 1], dt[:, t:t + 1], a,
                                       bb[:, t:t + 1], cc[:, t:t + 1], state)
            ys.append(y[:, 0])
        y_seq = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                                   rtol=2e-4, atol=2e-4)

    def test_initial_state_carried(self):
        """SSD over [first half; second half] == one pass (state handoff)."""
        b, l, h, p, n = 1, 32, 2, 4, 8
        x = jax.random.normal(jax.random.key(1), (b, l, h, p))
        dt = jax.nn.softplus(jax.random.normal(jax.random.key(2), (b, l, h)))
        a = -jnp.exp(jax.random.normal(jax.random.key(3), (h,)) * 0.3)
        bb = jax.random.normal(jax.random.key(4), (b, l, 1, n))
        cc = jax.random.normal(jax.random.key(5), (b, l, 1, n))
        y_full, s_full = ssd_chunked(x, dt, a, bb, cc, chunk=8)
        y1, s1 = ssd_chunked(x[:, :16], dt[:, :16], a, bb[:, :16],
                             cc[:, :16], chunk=8)
        y2, s2 = ssd_chunked(x[:, 16:], dt[:, 16:], a, bb[:, 16:],
                             cc[:, 16:], chunk=8, initial_state=s1)
        np.testing.assert_allclose(np.asarray(y_full[:, 16:]),
                                   np.asarray(y2), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                                   rtol=2e-4, atol=2e-4)


class TestRGLRU:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 50))
    def test_scan_matches_loop(self, seed):
        b, s, w = 2, 24, 8
        a = jax.nn.sigmoid(jax.random.normal(jax.random.key(seed), (b, s, w)))
        x = jax.random.normal(jax.random.key(seed + 1), (b, s, w))
        h = _lru_scan(a, x)
        ref = np.zeros((b, s, w), np.float32)
        an, xn = np.asarray(a), np.asarray(x)
        carry = np.zeros((b, w), np.float32)
        for t in range(s):
            carry = an[:, t] * carry + xn[:, t]
            ref[:, t] = carry
        np.testing.assert_allclose(np.asarray(h), ref, rtol=2e-4, atol=2e-4)


class TestParamSystem:
    def test_three_interpretations_agree(self):
        cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2,
                          num_kv_heads=2, d_ff=64, vocab_size=64)
        from repro.models.model import Model
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        shapes = m.shapes()
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(shapes)
        assert len(flat_p) == len(flat_s)
        for p, s in zip(flat_p, flat_s):
            assert p.shape == s.shape and p.dtype == s.dtype
