"""In-kernel RNG of the fused charge-grid kernel (ISSUE-3 tentpole).

The fused Pallas kernel applies binomial-approximation charge fluctuation
*inside* the kernel (counter RNG seeded per (depo, tile) from the sim key).
These tests pin the contract, in interpret mode:

  * statistical equivalence with ``fluctuate_counter``: matched per-patch
    mean and variance (different RNG streams, same distribution);
  * determinism: the same key reproduces the same grid bit for bit, and
    different keys differ;
  * ``key=None`` keeps the original deterministic (mean-field) behavior.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LArTPCConfig
from repro.core.depo import DepoSet, depo_patch_origin
from repro.core.fluctuate import (counter_normals, fluctuate_counter,
                                  hash_u32, uniform_from_bits)
from repro.core.rasterize import rasterize
from repro.kernels.fused_sim.ops import simulate_charge_grid
from repro.kernels.fused_sim.ref import simulate_charge_grid_ref

CFG = LArTPCConfig(num_wires=64, num_ticks=256, num_depos=16)


def lattice_depos(cfg=CFG, charge=10_000.0) -> DepoSet:
    """Non-overlapping identical-charge depos: per-depo patch sums can be
    read back from the grid exactly."""
    pw, pt = cfg.patch_wires, cfg.patch_ticks
    wires = np.arange(pw, cfg.num_wires - pw, pw + 8, dtype=np.float32)
    ticks = np.arange(pt, cfg.num_ticks - pt, pt + 12, dtype=np.float32)
    ww, tt = np.meshgrid(wires, ticks, indexing="ij")
    n = ww.size
    return DepoSet(wire=jnp.asarray(ww.ravel()), tick=jnp.asarray(tt.ravel()),
                   sigma_w=jnp.full((n,), 1.0), sigma_t=jnp.full((n,), 1.2),
                   charge=jnp.full((n,), charge))


class TestCounterHashRNG:
    """The portable (interpret-mode) half of the in-kernel RNG."""

    def test_uniform_bits_cover_unit_interval(self):
        u = np.asarray(uniform_from_bits(hash_u32(
            jnp.arange(1 << 14, dtype=jnp.uint32))))
        assert 0.0 <= u.min() and u.max() < 1.0
        assert abs(u.mean() - 0.5) < 0.01
        assert abs(u.std() - np.sqrt(1 / 12)) < 0.01

    def test_counter_normals_are_standard(self):
        z = np.asarray(counter_normals(
            jnp.uint32(123), jnp.uint32(456), jnp.uint32(789),
            jnp.arange(1 << 14, dtype=jnp.uint32)))
        assert abs(z.mean()) < 0.03
        assert abs(z.std() - 1.0) < 0.03
        # no serial correlation between adjacent counters
        assert abs(np.corrcoef(z[:-1], z[1:])[0, 1]) < 0.05

    def test_streams_are_independent(self):
        cnt = jnp.arange(1 << 12, dtype=jnp.uint32)
        z1 = np.asarray(counter_normals(jnp.uint32(1), jnp.uint32(2),
                                        jnp.uint32(3), cnt))
        z2 = np.asarray(counter_normals(jnp.uint32(1), jnp.uint32(2),
                                        jnp.uint32(4), cnt))
        assert abs(np.corrcoef(z1, z2)[0, 1]) < 0.05


class TestFusedFluctuation:
    def test_statistical_equivalence_with_fluctuate_counter(self):
        """Per-patch sums from the in-kernel RNG match fluctuate_counter's
        mean and variance (the ISSUE-3 acceptance contract)."""
        depos = lattice_depos()
        n = depos.n
        pw, pt = CFG.patch_wires, CFG.patch_ticks
        w0, t0 = depo_patch_origin(depos, CFG)
        w0h, t0h = np.asarray(w0), np.asarray(t0)
        patches, _, _ = rasterize(depos, CFG)

        fused_sums, ref_sums = [], []
        for s in range(16):
            key = jax.random.key(100 + s)
            g = np.asarray(simulate_charge_grid(depos, CFG, tw=32, tt=128,
                                                key=key))
            fused_sums.extend(
                g[w0h[i]:w0h[i] + pw, t0h[i]:t0h[i] + pt].sum()
                for i in range(n))
            fl = fluctuate_counter(key, patches, depos.charge)
            ref_sums.extend(np.asarray(fl.sum(axis=(1, 2))))
        fused = np.array(fused_sums)
        ref = np.array(ref_sums)
        # matched means (both ~= charge, modulo the clamp-at-zero bias both
        # share) and matched variances within sampling error
        assert abs(fused.mean() - ref.mean()) / ref.mean() < 0.01
        assert 0.7 < fused.std() / ref.std() < 1.4
        # and it really fluctuates: far from the zero-variance mean field
        assert fused.std() > 10.0

    def test_same_key_bitwise_reproducible_different_keys_differ(self):
        depos = lattice_depos()
        k1, k2 = jax.random.key(1), jax.random.key(2)
        a = np.asarray(simulate_charge_grid(depos, CFG, tw=32, tt=128, key=k1))
        b = np.asarray(simulate_charge_grid(depos, CFG, tw=32, tt=128, key=k1))
        c = np.asarray(simulate_charge_grid(depos, CFG, tw=32, tt=128, key=k2))
        assert np.array_equal(a, b)
        assert not np.array_equal(a, c)

    def test_no_key_keeps_mean_field_behavior(self):
        """key=None reproduces the original deterministic kernel exactly."""
        cfg = dataclasses.replace(CFG, fluctuate=False)
        depos = lattice_depos()
        g = np.asarray(simulate_charge_grid(depos, cfg, tw=32, tt=128))
        r = np.asarray(simulate_charge_grid_ref(depos, cfg))
        np.testing.assert_allclose(g, r, rtol=1e-5, atol=5e-2)

    def test_fluctuation_stays_within_patch_support(self):
        """Pixels outside every patch support stay exactly zero — the
        fluctuation term has zero variance where the mean is zero."""
        depos = lattice_depos()
        pw, pt = CFG.patch_wires, CFG.patch_ticks
        w0, t0 = depo_patch_origin(depos, CFG)
        g = np.asarray(simulate_charge_grid(depos, CFG, tw=32, tt=128,
                                            key=jax.random.key(3)))
        mask = np.zeros_like(g, dtype=bool)
        for i in range(depos.n):
            mask[int(w0[i]):int(w0[i]) + pw, int(t0[i]):int(t0[i]) + pt] = True
        assert (g[~mask] == 0.0).all()
        assert (g >= 0.0).all()
