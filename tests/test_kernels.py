"""Pallas kernel tests: shape/dtype sweeps + allclose vs the ref.py oracles
(interpret mode executes the kernel body on CPU)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import LArTPCConfig
from repro.core.depo import depo_patch_origin, generate_depos
from repro.core.rasterize import rasterize
from repro.kernels.rasterize.kernel import rasterize_pallas
from repro.kernels.rasterize.ops import _pad_depos, rasterize_depos
from repro.kernels.rasterize.ref import rasterize_ref
from repro.kernels.scatter_add.ops import bin_depos_to_tiles, scatter_add_tiles
from repro.kernels.scatter_add.ref import scatter_add_ref

CFG = LArTPCConfig(num_wires=96, num_ticks=768, num_depos=128)


def _setup(n=128, seed=0, cfg=CFG):
    depos = generate_depos(jax.random.key(seed), cfg, n)
    return depos


class TestRasterizeKernel:
    @pytest.mark.parametrize("pw,pt", [(20, 20), (12, 28), (8, 8), (24, 100)])
    def test_shape_sweep(self, pw, pt):
        cfg = dataclasses.replace(CFG, patch_wires=pw, patch_ticks=pt)
        depos = _setup(cfg=cfg)
        padded, n = _pad_depos(depos, 64)
        w0, t0 = depo_patch_origin(padded, cfg)
        pw_pad = (pw + 7) // 8 * 8
        pt_pad = 128
        shape = (padded.n, pw_pad, pt_pad)
        u1 = jax.random.uniform(jax.random.key(1), shape)
        u2 = jax.random.uniform(jax.random.key(2), shape)
        args = (padded.wire, padded.tick, padded.sigma_w, padded.sigma_t,
                padded.charge, w0, t0, u1, u2)
        kw = dict(pw=pw, pt=pt, pw_pad=pw_pad, pt_pad=pt_pad)
        out = rasterize_pallas(*args, depo_block=64, **kw)
        ref = rasterize_ref(*args, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=1e-3)

    @pytest.mark.parametrize("depo_block", [32, 64, 256])
    def test_block_size_sweep(self, depo_block):
        depos = _setup(256)
        p1, w0, t0 = rasterize_depos(jax.random.key(0), depos, CFG,
                                     depo_block=depo_block, fluctuate=False)
        ref, rw0, rt0 = rasterize(depos, CFG)
        np.testing.assert_allclose(
            np.asarray(p1[:, :CFG.patch_wires, :CFG.patch_ticks]),
            np.asarray(ref), rtol=2e-5, atol=1e-3)
        assert (np.asarray(w0) == np.asarray(rw0)).all()

    def test_padding_is_zero(self):
        depos = _setup(64)
        patches, _, _ = rasterize_depos(jax.random.key(0), depos, CFG,
                                        fluctuate=True)
        p = np.asarray(patches)
        assert (p[:, CFG.patch_wires:, :] == 0).all()
        assert (p[:, :, CFG.patch_ticks:] == 0).all()

    def test_fluctuation_statistics(self):
        """Fluctuated mass has ~binomial variance (normal approximation)."""
        n = 512
        from repro.core.depo import DepoSet
        depos = DepoSet(wire=jnp.full((n,), 40.0), tick=jnp.full((n,), 300.0),
                        sigma_w=jnp.full((n,), 1.0),
                        sigma_t=jnp.full((n,), 1.0),
                        charge=jnp.full((n,), 10_000.0))
        patches, _, _ = rasterize_depos(jax.random.key(3), depos, CFG,
                                        fluctuate=True)
        sums = np.asarray(patches.sum(axis=(1, 2)))
        assert abs(sums.mean() - 10_000.0) < 50.0
        assert 10.0 < sums.std() < 120.0  # nonzero but bounded


class TestScatterKernel:
    @pytest.mark.parametrize("tw,tt", [(32, 128), (64, 256), (128, 768)])
    def test_tile_sweep(self, tw, tt):
        depos = _setup(96)
        patches, w0, t0 = rasterize_depos(jax.random.key(0), depos, CFG,
                                          fluctuate=False)
        out = scatter_add_tiles(patches, w0, t0, num_wires=CFG.num_wires,
                                num_ticks=CFG.num_ticks, tw=tw, tt=tt)
        ref = scatter_add_ref(patches, w0, t0, num_wires=CFG.num_wires,
                              num_ticks=CFG.num_ticks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-2)

    def test_binning_covers_all_depos(self):
        depos = _setup(200)
        patches, w0, t0 = rasterize_depos(jax.random.key(0), depos, CFG,
                                          fluctuate=False)
        n, pw_pad, pt_pad = patches.shape
        ids, n_tiles = bin_depos_to_tiles(
            w0, t0, pw_pad, pt_pad, CFG.num_wires, CFG.num_ticks,
            tw=64, tt=256, k_max=256)
        got = np.asarray(ids)
        present = set(got[got >= 0].tolist())
        assert present == set(range(n)), "every depo must land in >=1 tile"

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 99), n=st.integers(1, 64))
    def test_property_kernel_equals_oracle(self, seed, n):
        depos = _setup(n, seed)
        patches, w0, t0 = rasterize_depos(jax.random.key(seed), depos, CFG,
                                          fluctuate=False)
        out = scatter_add_tiles(patches, w0, t0, num_wires=CFG.num_wires,
                                num_ticks=CFG.num_ticks)
        ref = scatter_add_ref(patches, w0, t0, num_wires=CFG.num_wires,
                              num_ticks=CFG.num_ticks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-2)

    def test_deterministic(self):
        """Owner-computes accumulation is bitwise deterministic (vs atomics)."""
        depos = _setup(128)
        patches, w0, t0 = rasterize_depos(jax.random.key(0), depos, CFG,
                                          fluctuate=False)
        a = np.asarray(scatter_add_tiles(patches, w0, t0,
                                         num_wires=CFG.num_wires,
                                         num_ticks=CFG.num_ticks))
        b = np.asarray(scatter_add_tiles(patches, w0, t0,
                                         num_wires=CFG.num_wires,
                                         num_ticks=CFG.num_ticks))
        assert (a == b).all()
