"""Unit tests for ``repro.analysis.hlo`` — the shared compiled-program
inspection API (ISSUE 10 layer 1).

Two tiers: synthetic HLO text pins the parsing semantics exactly
(``-start``/``-done`` merging, operand references not counted, tuple-type
dtype census, host-callback vs backend custom-calls), and small real jax
programs pin the jax-facing probes (donation request vs realized alias,
x64 leakage, pure_callback detection, cache-miss counting) against the
live lowering pipeline — if a jax upgrade changes the textual conventions,
these fail before the audit baseline silently drifts.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo

# ---------------------------------------------------------------------------
# Synthetic-text tier
# ---------------------------------------------------------------------------

SYNTHETIC = """\
HloModule jit_fn, input_output_alias={ {0}: (0, {}, may-alias), {1}: (2, {}, must-alias) }

fused_computation {
  p0 = f32[8,16]{1,0} parameter(0)
  ROOT m = f32[8,16]{1,0} multiply(p0, p0)
}

ENTRY main {
  %arg0 = f32[8,16]{1,0} parameter(0)
  %ar = f32[8,16]{1,0} all-reduce(%arg0), replica_groups={}
  %ars = f32[8,16]{1,0} all-reduce-start(%ar), replica_groups={}
  %ard = f32[8,16]{1,0} all-reduce-done(%ars)
  %a2a = f32[8,16]{1,0} all-to-all(%ard), replica_groups={}
  %rs = f32[4,16]{1,0} reduce-scatter(%a2a), replica_groups={}
  %cp-start = f32[4,16]{1,0} collective-permute-start(%rs)
  %cp-done = f32[4,16]{1,0} collective-permute-done(%cp-start)
  %sc = bf16[32,64]{1,0} scatter(%arg0, %arg0, %arg0), to_apply=fused_computation
  %fft = c64[8,9]{1,0} custom-call(%ard), custom_call_target="ducc_fft"
  %cb = (f32[8,16]{1,0}, s32[]) custom-call(%sc), custom_call_target="xla_python_cpu_callback"
  %inf = ((f32[2]{0}), token[]) infeed(%cb)
  %snd = (f32[2]{0}, u32[], token[]) send(%inf), is_host_transfer=true
  %snd2 = (f32[2]{0}, u32[], token[]) send(%snd), channel_id=3
  ROOT %t = (f32[8,16]{1,0}, f64[4]{0}, pred[]) tuple(%ar, %ar, %ar)
}
"""


class TestSyntheticText:
    def test_collective_counts_merges_async_pairs(self):
        counts = hlo.collective_counts(SYNTHETIC)
        # all-reduce: one sync + one -start (the -done is skipped)
        assert counts["all-reduce"] == 2
        assert counts["all-to-all"] == 1
        assert counts["reduce-scatter"] == 1
        assert counts["collective-permute"] == 1
        assert counts["all-gather"] == 0  # zeros kept: the dict is total

    def test_operand_references_not_counted(self):
        # "%ar" appears as an operand of several later instructions; only
        # its defining instruction counts
        one_ref = "  %x = f32[2]{0} add(%all-reduce-ish, %y)\n"
        assert hlo.collective_counts(one_ref)["all-reduce"] == 0

    def test_dtype_census_includes_tuple_elements(self):
        census = hlo.dtype_census(SYNTHETIC)
        assert census["f64"] == 1  # only inside the ROOT tuple type
        assert census["pred"] == 1
        assert census["bf16"] == 1
        assert census["c64"] == 1
        assert "f8e4m3fn" not in census

    def test_scatter_output_dtypes(self):
        assert hlo.scatter_output_dtypes(SYNTHETIC) == {"bf16"}

    def test_host_call_count(self):
        # callback custom-call + infeed + host-transfer send = 3;
        # ducc_fft and the channel-only send are NOT host calls
        assert hlo.host_call_count(SYNTHETIC) == 3

    def test_realized_alias_count(self):
        assert hlo.realized_alias_count(SYNTHETIC) == 2
        assert hlo.realized_alias_count("HloModule plain\n") == 0

    def test_iter_instructions_shapes(self):
        ops = [op for op, _, _ in hlo.iter_instructions(SYNTHETIC)]
        assert "parameter" in ops and "tuple" in ops
        assert "scatter" in ops


# ---------------------------------------------------------------------------
# Live-jax tier
# ---------------------------------------------------------------------------


class TestLiveJax:
    def test_donation_requested_and_realized(self):
        """Same-shape donated input: the request AND the realized alias are
        both visible."""

        def f(x):
            return x * 2.0

        jf = jax.jit(f, donate_argnums=(0,))
        lowered = jf.lower(jnp.ones((16, 16), jnp.float32))
        assert hlo.donated_arg_count(lowered) == 1
        assert hlo.realized_alias_count(lowered.compile().as_text()) == 1

    def test_donation_requested_but_unusable_still_counts(self):
        """Shape-changing program: XLA can't alias, but the jit-boundary
        request is still visible — the property the streaming contract
        pins on CPU."""
        import warnings

        def f(x):
            return jnp.sum(x)

        jf = jax.jit(f, donate_argnums=(0,))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            lowered = jf.lower(jnp.ones((16, 16), jnp.float32))
            txt = lowered.compile().as_text()
        assert hlo.donated_arg_count(lowered) == 1
        assert hlo.realized_alias_count(txt) == 0

    def test_no_donation_counts_zero(self):
        lowered = jax.jit(lambda x: x * 2.0).lower(jnp.ones(4))
        assert hlo.donated_arg_count(lowered) == 0

    def test_pure_callback_is_a_host_call(self):
        def f(x):
            return jax.pure_callback(
                lambda a: a, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

        txt = jax.jit(f).lower(jnp.ones(8)).compile().as_text()
        assert hlo.host_call_count(txt) >= 1

    def test_fft_custom_call_is_not_a_host_call(self):
        txt = jax.jit(lambda x: jnp.fft.rfft(x)).lower(
            jnp.ones(64)).compile().as_text()
        assert hlo.host_call_count(txt) == 0

    def test_x64_leak_shows_in_census(self):
        def f(x):
            return (x.astype(jnp.float64) * jnp.float64(1.0 + 1e-12)  # repro-lint: disable=f64-literal
                    ).astype(jnp.float32)

        with jax.experimental.enable_x64():
            txt = jax.jit(f).lower(
                jnp.ones(8, jnp.float32)).compile().as_text()
        assert "f64" in hlo.dtype_census(txt)
        # without x64 the cast silently no-ops (jax warns about the
        # truncation) — the audit MUST trace f64 injections under
        # enable_x64 or they vanish
        with pytest.warns(UserWarning, match="truncated"):
            txt32 = jax.jit(f).lower(
                jnp.ones(8, jnp.float32)).compile().as_text()
        assert "f64" not in hlo.dtype_census(txt32)

    def test_recompile_misses_stable_program(self):
        jf = jax.jit(lambda x: x + 1.0)
        assert hlo.recompile_misses(
            jf, lambda i: (jnp.full((4,), float(i)),)) == 0

    def test_recompile_misses_detects_shape_churn(self):
        jf = jax.jit(lambda x: x + 1.0)
        assert hlo.recompile_misses(
            jf, lambda i: (jnp.ones((4 + i,)),), calls=3) == 2


class TestCollectiveCountsOnRealPrograms:
    """The migrated PR 9 property, through the shared API: single-device
    programs emit no collectives at all."""

    def test_single_device_sim_is_collective_free(self):
        from repro.config import get_config
        from repro.core.depo import generate_physical_depos
        from repro.core.pipeline import make_sim_fn

        cfg = get_config("lartpc-uboone", smoke=True)
        key = jax.random.key(0)
        txt = make_sim_fn(cfg).lower(
            key, generate_physical_depos(key, cfg)).compile().as_text()
        assert hlo.collective_counts(txt) == {
            k: 0 for k in hlo.COLLECTIVE_KINDS}
