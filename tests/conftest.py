import os

# tests run on the single real CPU device; dry-run owns the 512-device flag
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
