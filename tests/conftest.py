import os

# tests run on the single real CPU device; dry-run owns the 512-device flag
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "subprocess: spawns fresh interpreter(s) with forced host devices "
        "(slow; CI runs these in a dedicated job via '-m subprocess' and "
        "keeps them out of the per-version matrix with '-m \"not "
        "subprocess\"')")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
