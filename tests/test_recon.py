"""Sim -> recon round-trip: deconvolution + hit finding close the loop.

The contract under test, end to end: simulate depos to ADC, deconvolve the
ADC back to charge, scan for hits — and get the injected physics back.

 * noiseless runs recover the regularization-attenuated charge grid to a
   few percent (the Wiener inverse is exact up to the attenuation factor
   |R|^2 / (|R|^2 + lambda * max|R|^2) and ADC quantization);
 * noisy runs find hits at the injected depo positions/times;
 * multi-plane configs round-trip bipolar (U/V) and unipolar (W) responses
   through the same stages;
 * every executor (single-event jit, batched vmap, streaming driver; the
   distributed shard_map path lives in its own subprocess test below)
   produces the same hits, bit-for-bit where layouts match and as hit SETS
   where compaction layouts legitimately differ.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import LArTPCConfig
from repro.core.batch import (event_keys, make_batched_sim_fn, pack_events,
                              simulate_events)
from repro.core.deconvolve import (deconvolve, make_deconv_filter,
                                   measured_signal)
from repro.core.hitfind import HitSet, find_hits, hits_to_tuples
from repro.core.pipeline import make_sim_fn, simulate_fig4
from repro.core.depo import generate_depos, generate_physical_depos
from repro.core.response import make_response
from repro.core.stages import (FULL_STAGE_ORDER, RECON_STAGE_ORDER,
                               build_sim_graph)

CFG = LArTPCConfig(num_wires=64, num_ticks=256, num_depos=48,
                   response_wires=11, response_ticks=48)
NOISELESS = dataclasses.replace(CFG, fluctuate=False)


def _attenuated_reference(grid, resp, lam):
    """What a lambda-regularized Wiener inverse can recover at best: the
    charge grid low-pass filtered by |R|^2 / (|R|^2 + lam * max|R|^2)."""
    w, t = grid.shape
    padded = jnp.zeros(resp.pad_shape, jnp.float32).at[:w, :t].set(grid)
    power = jnp.abs(resp.freq) ** 2
    atten = power / (power + lam * power.max())
    return jnp.fft.irfft2(jnp.fft.rfft2(padded) * atten,
                          s=resp.pad_shape)[:w, :t]


def _interior(arr, cfg):
    """Region away from the crop-boundary wrap of the linear convolution."""
    rw, rt = cfg.response_wires, cfg.response_ticks
    return arr[rw:cfg.num_wires - rw, :cfg.num_ticks - 2 * rt]


class TestNoiselessRoundTrip:
    @pytest.mark.parametrize("plane", ["induction", "collection"])
    def test_recovers_attenuated_charge(self, plane):
        """ADC -> deconvolve returns the attenuated charge grid to a few
        percent, both response polarities (exact-inverse up to the
        regularization attenuation + ADC quantization)."""
        resp = make_response(NOISELESS, plane=plane)
        sim = make_sim_fn(NOISELESS, resp=resp, add_noise=False, recon=True)
        key = jax.random.key(0)
        out = sim(key, generate_depos(key, NOISELESS))
        ref = _attenuated_reference(out.charge_grid, resp,
                                    NOISELESS.deconv_wiener_lambda)
        got = np.asarray(_interior(out.decon, NOISELESS))
        want = np.asarray(_interior(ref, NOISELESS))
        scale = np.abs(want).max()
        assert scale > 100.0  # the event actually hit the interior
        rel = np.abs(got - want).max() / scale
        assert rel < 0.05, f"{plane}: rel={rel:.3e}"

    def test_collection_charge_sum_preserved(self):
        """Unipolar (collection) deconvolution preserves total charge —
        the physics quantity hits integrate downstream."""
        resp = make_response(NOISELESS, plane="collection")
        sim = make_sim_fn(NOISELESS, resp=resp, add_noise=False, recon=True)
        key = jax.random.key(1)
        out = sim(key, generate_depos(key, NOISELESS))
        ratio = float(out.decon.sum()) / float(out.charge_grid.sum())
        assert 0.85 < ratio < 1.25, ratio

    def test_default_graph_has_no_recon_stages(self):
        """recon=False (the default) leaves the forward chain untouched —
        no decon/hits outputs, no extra stages to pay for."""
        g = build_sim_graph(NOISELESS)
        assert tuple(s.name for s in g.stages) == FULL_STAGE_ORDER[:5]
        key = jax.random.key(0)
        out = jax.jit(g.run)(key, generate_physical_depos(key, NOISELESS))
        assert out.decon is None and out.hits is None
        g2 = build_sim_graph(NOISELESS, recon=True)
        assert tuple(s.name for s in g2.stages)[-2:] == RECON_STAGE_ORDER


class TestNoisyHitRecovery:
    def _run(self, seed=0, **over):
        cfg = dataclasses.replace(CFG, **over)
        resp = make_response(cfg, plane="collection")
        sim = make_sim_fn(cfg, resp=resp, recon=True)
        key = jax.random.key(seed)
        depos = generate_depos(jax.random.fold_in(key, 1), cfg)
        return cfg, depos, sim(key, depos)

    def test_hits_land_on_injected_depos(self):
        """With noise + fluctuation on, found hits sit within +/-2 wires and
        +/-5 ticks of an injected depo (collection plane: unipolar, so hit
        positions are directly physical)."""
        cfg, depos, out = self._run()
        hits = out.hits
        n = int(hits.mask.sum())
        assert n > 0
        hw = np.asarray(hits.wire)[np.asarray(hits.mask)]
        ht = np.asarray(hits.tick)[np.asarray(hits.mask)]
        dw = np.asarray(depos.wire)[None, :] - hw[:, None]
        dt = np.asarray(depos.tick)[None, :] - ht[:, None]
        near = (np.abs(dw) <= 2.0) & (np.abs(dt) <= 5.0)
        frac = near.any(axis=1).mean()
        assert frac > 0.8, f"only {frac:.2f} of {n} hits near a depo"

    def test_big_depos_are_found(self):
        """Large-charge depos (well above threshold + noise) each produce
        at least one nearby hit — the recall side of the round trip."""
        cfg, depos, out = self._run(seed=2)
        hits = out.hits
        hw = np.asarray(hits.wire)[np.asarray(hits.mask)]
        ht = np.asarray(hits.tick)[np.asarray(hits.mask)]
        q = np.asarray(depos.charge)
        big = q > 3000.0
        assert big.sum() >= 5
        dw = np.abs(np.asarray(depos.wire)[big][:, None] - hw[None, :]) <= 2.0
        dt = np.abs(np.asarray(depos.tick)[big][:, None] - ht[None, :]) <= 5.0
        found = (dw & dt).any(axis=1).mean()
        assert found > 0.8, f"only {found:.2f} of big depos recovered"

    def test_truncation_is_detectable_not_silent(self):
        """Starving the HitSet capacity shows up as n_hits > mask.sum()."""
        cfg, depos, out = self._run(max_hits=4, max_hits_per_wire=1)
        hits = out.hits
        assert int(hits.mask.sum()) <= 4
        assert int(hits.n_hits) > int(hits.mask.sum())

    def test_hitset_contract(self):
        """HitSet output contract: fixed capacity, mask-padded, wire-major
        order, int32 wires within range, zeroed padding rows."""
        cfg, depos, out = self._run(seed=3)
        hits = out.hits
        assert isinstance(hits, HitSet)
        assert hits.wire.shape == (cfg.max_hits,)
        assert hits.wire.dtype == jnp.int32 and hits.mask.dtype == jnp.bool_
        m = np.asarray(hits.mask)
        w = np.asarray(hits.wire)
        assert ((w[m] >= 0) & (w[m] < cfg.num_wires)).all()
        order = np.lexsort((np.asarray(hits.tick)[m], w[m]))
        assert (order == np.arange(m.sum())).all()  # stored wire-major
        assert (np.asarray(hits.charge)[~m] == 0.0).all()


class TestMultiPlaneRoundTrip:
    CFG3 = dataclasses.replace(CFG, num_planes=3)

    def test_bipolar_and_unipolar_planes_round_trip(self):
        """U/V (bipolar) and W (unipolar) all deconvolve back to signals
        that track their own charge grids (mean-subtracted correlation),
        and every plane finds hits."""
        cfg = dataclasses.replace(self.CFG3, fluctuate=False)
        sim = make_sim_fn(cfg, add_noise=False, recon=True)
        key = jax.random.key(0)
        out = sim(key, generate_physical_depos(key, cfg))
        assert out.decon.shape == (3, cfg.num_wires, cfg.num_ticks)
        assert out.hits.charge.shape == (3, cfg.max_hits)
        for p in range(3):
            d = np.asarray(out.decon[p]).ravel()
            g = np.asarray(out.charge_grid[p]).ravel()
            d = d - d.mean()
            g = g - g.mean()
            corr = float((d * g).sum() /
                         (np.linalg.norm(d) * np.linalg.norm(g) + 1e-30))
            assert corr > 0.8, f"plane {p}: corr={corr:.3f}"
            assert int(out.hits.mask[p].sum()) > 0, f"plane {p}: no hits"

    def test_collection_plane_keeps_charge(self):
        """Only the W (collection) plane is unipolar: its deconvolved charge
        sum matches its grid; the bipolar planes' sums cancel toward zero."""
        cfg = dataclasses.replace(self.CFG3, fluctuate=False)
        sim = make_sim_fn(cfg, add_noise=False, recon=True)
        key = jax.random.key(1)
        out = sim(key, generate_physical_depos(key, cfg))
        gsum = np.asarray(out.charge_grid.sum(axis=(1, 2)))
        dsum = np.asarray(out.decon.sum(axis=(1, 2)))
        ratio_w = dsum[2] / gsum[2]
        assert 0.85 < ratio_w < 1.25, ratio_w
        for p in (0, 1):
            # induction: the bipolar response suppresses the DC line, so the
            # recovered net charge is well below the unipolar plane's (the
            # discretized kernel leaves a small DC residual — not exactly 0)
            ratio_p = abs(dsum[p]) / abs(gsum[p])
            assert ratio_p < 0.5 * ratio_w, (p, ratio_p, ratio_w)


class TestExecutorEquivalence:
    @pytest.mark.parametrize("strategy", ["scan", "pallas"])
    def test_batched_bit_equal_single(self, strategy):
        """vmap'd recon == per-event recon, bit for bit, per hit_find
        strategy (noise + fluctuation on)."""
        cfg = dataclasses.replace(CFG, hitfind_strategy=strategy)
        resp = make_response(cfg)
        events = [generate_depos(jax.random.fold_in(jax.random.key(0), i),
                                 cfg, n) for i, n in enumerate([9, 17])]
        batch = pack_events(events)
        keys = event_keys(jax.random.key(0), range(2))
        out = simulate_events(keys, batch, resp, cfg, recon=True)
        for e in range(2):
            ref = simulate_fig4(keys[e], batch.event(e), resp, cfg,
                                recon=True)
            np.testing.assert_array_equal(np.asarray(out.adc[e]),
                                          np.asarray(ref.adc))
            np.testing.assert_array_equal(np.asarray(out.decon[e]),
                                          np.asarray(ref.decon))
            for f in HitSet._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(out.hits, f)[e]),
                    np.asarray(getattr(ref.hits, f)), err_msg=f)

    def test_scan_and_pallas_find_identical_hits(self):
        """The two hit_find strategies share the scan body: bit-identical
        HitSets on a real deconvolved event."""
        resp = make_response(CFG, plane="collection")
        sim = make_sim_fn(CFG, resp=resp, recon=True)
        key = jax.random.key(4)
        out = sim(key, generate_depos(key, CFG))
        h1 = find_hits(out.decon, CFG, "scan")
        h2 = find_hits(out.decon, CFG, "pallas")
        for f in HitSet._fields:
            np.testing.assert_array_equal(np.asarray(getattr(h1, f)),
                                          np.asarray(getattr(h2, f)),
                                          err_msg=f)
        assert int(h1.mask.sum()) > 0

    def test_streaming_matches_direct_batch(self):
        """The double-buffered streaming driver with recon=True hands back
        the same hits as a direct batched call on the same event ids."""
        from repro.launch.sim import stream_simulate

        got = {}
        stats = stream_simulate(
            CFG, num_events=2, batch_events=2, seed=0, recon=True,
            on_batch=lambda b, nv, nd, dt, out: got.update({b: out}))
        assert stats["events"] == 2
        key = jax.random.key(0)
        events = [generate_depos(jax.random.fold_in(key, ev), CFG)
                  for ev in range(2)]
        batch = pack_events(events, pad_to=CFG.num_depos)
        ref = simulate_events(event_keys(key, range(2)), batch,
                              make_response(CFG), CFG, recon=True)
        for f in HitSet._fields:
            np.testing.assert_array_equal(np.asarray(getattr(got[0].hits, f)),
                                          np.asarray(getattr(ref.hits, f)),
                                          err_msg=f)

    def test_unknown_strategies_fail_loudly(self):
        resp = make_response(CFG)
        filt = make_deconv_filter(resp, CFG)
        meas = measured_signal(jnp.full((CFG.num_wires, CFG.num_ticks),
                                        CFG.adc_baseline, jnp.int16), CFG)
        with pytest.raises(ValueError, match="deconvolve strategy"):
            deconvolve(meas, filt, "nope")
        with pytest.raises(ValueError, match="hit_find strategy"):
            find_hits(meas, CFG, "nope")


DIST_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.config import LArTPCConfig
from repro.core.deconvolve import deconvolve, make_deconv_filter, measured_signal
from repro.core.depo import generate_depos
from repro.core.distributed import (make_distributed_sim, padded_grid_shape,
                                    shard_depos)
from repro.core.hitfind import find_hits, hits_to_tuples
from repro.core.response import make_distributed_response

cfg = LArTPCConfig(num_wires=128, num_ticks=512, num_depos=256,
                   response_wires=11, response_ticks=64, fluctuate=False)
mesh = jax.make_mesh((4, 2), ("data", "model"))
w_pad, _, _ = padded_grid_shape(cfg, 8)
resp = make_distributed_response(cfg, w_pad)
key = jax.random.key(0)
depos = generate_depos(jax.random.fold_in(key, 1), cfg)
sim = make_distributed_sim(mesh, cfg, resp, add_noise=False, recon=True)
adc, decon, hits = sim(key, shard_depos(depos, mesh))

# single-device reference at the SAME cyclic (w_pad, T) shape
ref_decon = deconvolve(measured_signal(adc, cfg), make_deconv_filter(resp, cfg))
masked = jnp.where((jnp.arange(w_pad) < cfg.num_wires)[:, None], ref_decon, 0.0)
ref_hits = find_hits(masked, cfg)

r3 = lambda ts: sorted((w, round(t, 3), round(q, 1)) for w, t, q in ts)
results = {
    "decon_close": bool(np.allclose(np.asarray(decon), np.asarray(ref_decon),
                                    atol=1e-3)),
    "hits_equal": r3(hits_to_tuples(hits)) == r3(hits_to_tuples(ref_hits)),
    "n_stored": int(np.asarray(hits.mask).sum()),
    "n_hits_match": int(hits.n_hits) == int(ref_hits.n_hits),
}
print("RESULTS:" + json.dumps(results))
"""


@pytest.mark.subprocess
def test_distributed_round_trip_matches_single_device():
    """shard_map recon (8 forced host devices, pencil-FFT deconvolve +
    per-shard hit finding) reproduces the single-device hit set exactly."""
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", DIST_SCRIPT],
                          capture_output=True, text=True, env=env,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULTS:")][0]
    results = json.loads(line[len("RESULTS:"):])
    assert results["decon_close"], results
    assert results["hits_equal"], results
    assert results["n_hits_match"], results
    assert results["n_stored"] > 0, results
