"""GPipe pipeline-parallel schedule: subprocess test with 4 forced devices."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.subprocess


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("stage",))
n_stages, n_micro, mb, d = 4, 8, 2, 16
key = jax.random.key(0)
w = jax.random.normal(key, (n_stages, d, d)) * 0.3
b = jax.random.normal(jax.random.key(1), (n_stages, d)) * 0.1
params = {"w": w, "b": b}
x = jax.random.normal(jax.random.key(2), (n_micro, mb, d))

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

y = pipeline_apply(stage_fn, params, x, mesh, "stage")

# sequential reference
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ w[s] + b[s])
err = float(jnp.max(jnp.abs(y - ref)))
print("RESULTS:" + json.dumps({"err": err}))
"""


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # pin children to CPU: with libtpu installed, an unset platform makes
    # the child block on /tmp/libtpu_lockfile held by the pytest process
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")]
    res = json.loads(line[0][len("RESULTS:"):])
    assert res["err"] < 1e-5, res
