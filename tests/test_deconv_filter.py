"""Deconvolution filter properties: the inverse is bounded, real, and tuned.

Deterministic property sweeps (no hypothesis dependency): the Wiener and
Gaussian filters are checked against the spectral identities that make
deconvolution safe — a regularized inverse must never blow up near response
zeros (induction responses have a structural DC zero), must map real signals
to real signals, and must slot into the same per-plane tuning bucket as the
forward FFT convolve.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import LArTPCConfig, plane_specs
from repro.core.deconvolve import (DECONV_FILTERS, deconvolve,
                                   make_deconv_filter,
                                   make_plane_deconv_filters, measured_signal)
from repro.core.response import DetectorResponse, make_response

CFG = LArTPCConfig(num_wires=64, num_ticks=256, num_depos=48,
                   response_wires=11, response_ticks=48)
PLANES = ("induction", "collection")


class TestWienerFilter:
    @pytest.mark.parametrize("plane", PLANES)
    def test_gain_is_bounded(self, plane):
        """|G| <= 1 / (2 * sqrt(lam * max|R|^2)) everywhere — the 1/eps
        blow-up near response zeros is structurally impossible."""
        resp = make_response(CFG, plane=plane)
        filt = make_deconv_filter(resp, CFG, kind="wiener")
        lam = CFG.deconv_wiener_lambda
        bound = 1.0 / (2.0 * np.sqrt(lam * float(
            (jnp.abs(resp.freq) ** 2).max())))
        gmax = float(jnp.abs(filt.freq).max())
        assert gmax <= bound * 1.001, (gmax, bound)

    @pytest.mark.parametrize("plane", PLANES)
    def test_inverse_maps_real_to_real(self, plane):
        """G inherits the Hermitian symmetry of R: applying it to a real
        grid through the rfft2 path returns a (numerically) real grid, i.e.
        forward-then-inverse of a random real signal stays real and finite."""
        resp = make_response(CFG, plane=plane)
        filt = make_deconv_filter(resp, CFG, kind="wiener")
        rng = np.random.default_rng(0)
        meas = jnp.asarray(rng.standard_normal(
            (CFG.num_wires, CFG.num_ticks)).astype(np.float32)) * 100.0
        out = deconvolve(meas, filt)
        o = np.asarray(out)
        assert o.dtype == np.float32
        assert np.isfinite(o).all()

    @pytest.mark.parametrize("plane", PLANES)
    def test_attenuation_identity(self, plane):
        """G * R == |R|^2 / (|R|^2 + lam * max|R|^2): the round-trip transfer
        function is the attenuation factor — real, in [0, 1], and ~1 where
        the response is strong."""
        resp = make_response(CFG, plane=plane)
        filt = make_deconv_filter(resp, CFG, kind="wiener")
        lam = CFG.deconv_wiener_lambda
        power = np.abs(np.asarray(resp.freq)) ** 2
        got = np.asarray(filt.freq * resp.freq)
        want = power / (power + lam * power.max())
        np.testing.assert_allclose(got.imag, 0.0, atol=1e-5)
        np.testing.assert_allclose(got.real, want, rtol=1e-4, atol=1e-6)
        assert (want <= 1.0).all()
        assert want.max() > 0.99

    def test_lambda_trades_sharpness_for_gain(self):
        """Smaller lambda -> larger peak gain (sharper inverse); the
        regularization knob is monotone."""
        resp = make_response(CFG)
        gains = [float(jnp.abs(make_deconv_filter(
            resp, CFG, wiener_lambda=lam).freq).max())
            for lam in (1e-1, 1e-2, 1e-3)]
        assert gains[0] < gains[1] < gains[2], gains


class TestGaussianFilter:
    def test_dc_gain_is_one(self):
        """The time-frequency Gaussian window is exactly 1 at DC: total
        charge on a wire passes the extra low-pass untouched."""
        resp = make_response(CFG, plane="collection")
        w = make_deconv_filter(resp, CFG, kind="wiener")
        g = make_deconv_filter(resp, CFG, kind="gaussian")
        ratio = np.asarray(g.freq[:, 0]) / np.asarray(w.freq[:, 0])
        np.testing.assert_allclose(ratio, 1.0, rtol=1e-5)

    def test_attenuates_high_frequencies(self):
        """Away from DC the window monotonically suppresses the Wiener
        gain, reaching the cut-frequency attenuation at Nyquist."""
        resp = make_response(CFG)
        w = make_deconv_filter(resp, CFG, kind="wiener")
        g = make_deconv_filter(resp, CFG, kind="gaussian", gauss_cut=0.25)
        ratio = np.abs(np.asarray(g.freq)) / np.maximum(
            np.abs(np.asarray(w.freq)), 1e-30)
        # the window depends only on the tick-frequency column
        col = ratio.mean(axis=0)
        assert (np.diff(col) < 1e-6).all()  # non-increasing
        assert col[-1] < np.exp(-0.5 / 0.25 ** 2) * 1.05  # ~Nyquist cut

    def test_unknown_kind_fails(self):
        resp = make_response(CFG)
        with pytest.raises(ValueError, match="deconv filter"):
            make_deconv_filter(resp, CFG, kind="boxcar")
        assert set(DECONV_FILTERS) == {"wiener", "gaussian"}


class TestFilterAsResponse:
    def test_filter_is_a_detector_response(self):
        """The inverse filter reuses the DetectorResponse container (same
        pad_shape/plane), so the forward FFT machinery applies unchanged."""
        resp = make_response(CFG, plane="collection")
        filt = make_deconv_filter(resp, CFG)
        assert isinstance(filt, DetectorResponse)
        assert filt.pad_shape == resp.pad_shape
        assert filt.plane == resp.plane
        assert filt.freq.dtype == jnp.complex64

    def test_per_plane_filters_match_plane_kinds(self):
        cfg = dataclasses.replace(CFG, num_planes=3)
        from repro.core.response import make_plane_responses

        resps = make_plane_responses(cfg)
        filts = make_plane_deconv_filters(cfg)
        assert len(filts) == 3
        kinds = [s.kind for s in plane_specs(cfg)]
        assert kinds == ["induction", "induction", "collection"]
        # round-trip attenuation |G*R| at the tick-DC column: the bipolar
        # (induction) response has no DC content to recover, the unipolar
        # (collection) one passes DC nearly untouched
        att = [float(np.abs(np.asarray(f.freq)[:, 0] *
                            np.asarray(r.freq)[:, 0]).max())
               for f, r in zip(filts, resps)]
        assert att[0] < 0.2 and att[1] < 0.2, att
        assert att[2] > 0.9, att

    def test_measured_signal_inverts_digitize_scale(self):
        adc = jnp.full((4, 8), CFG.adc_baseline + 1, jnp.int16)
        meas = measured_signal(adc, CFG)
        np.testing.assert_allclose(np.asarray(meas),
                                   1.0 / CFG.adc_per_electron, rtol=1e-6)


class TestTuningIntegration:
    def test_both_ops_registered_with_strategies(self):
        from repro.tune import registry

        registry.ensure_registered()
        assert set(registry.strategies("deconvolve")) == {"rfft2",
                                                          "fft_reuse"}
        assert set(registry.strategies("hit_find")) == {"scan", "pallas"}

    def test_deconvolve_shares_plane_keyed_shape_bucket(self):
        """deconvolve tunes per plane KIND exactly like fft_convolve: same
        shape dict, plus the plane tag — an induction winner never leaks
        onto the collection plane."""
        from repro.tune.autotune import PLANE_KEYED_OPS, op_shape

        cfg = dataclasses.replace(CFG, num_planes=3)
        assert "deconvolve" in PLANE_KEYED_OPS
        assert "fft_convolve" in PLANE_KEYED_OPS
        for spec in plane_specs(cfg):
            # the per-plane resolver keys each decision by the plane kind
            # on top of the op's shape dims (same recipe both ops)
            sd = dict(op_shape("deconvolve", cfg), plane=spec.kind)
            sf = dict(op_shape("fft_convolve", cfg), plane=spec.kind)
            assert sd == sf
            assert sd["plane"] == spec.kind

    def test_hit_find_shape_bucket(self):
        from repro.tune.autotune import op_shape

        s = op_shape("hit_find", CFG)
        assert s == {"num_wires": CFG.num_wires, "num_ticks": CFG.num_ticks,
                     "max_hits_per_wire": CFG.max_hits_per_wire}

    def test_strategy_fields_resolve(self):
        """'auto' in the config resolves both recon strategy fields through
        the cache-or-default path without touching the tuner."""
        from repro.tune.autotune import OP_FIELDS

        assert OP_FIELDS["deconvolve"] == "deconv_strategy"
        assert OP_FIELDS["hit_find"] == "hitfind_strategy"
