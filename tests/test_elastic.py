"""Elastic restart: a checkpoint written by a 1-device job restores onto an
8-device mesh with full resharding, and training continues identically."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

pytestmark = pytest.mark.subprocess


SCRIPT_SAVE = r"""
import os, json
import jax
from repro.config import ModelConfig
from repro.models.model import Model
from repro.optim.adamw import init_opt_state
from repro.ckpt.checkpoint import CheckpointManager

cfg = ModelConfig(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
                  d_ff=64, vocab_size=256, remat="none", dtype="float32")
model = Model(cfg)
params = model.init(jax.random.key(0))
opt = init_opt_state(params)
mgr = CheckpointManager(os.environ["CKPT_DIR"], async_save=False)
mgr.save(3, {"params": params, "opt": opt}, extra={"step": 3})
print("SAVED")
"""

SCRIPT_RESTORE = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from jax.sharding import NamedSharding
from repro.config import ModelConfig
from repro.models.model import Model
from repro.optim.adamw import init_opt_state, OptState
from repro.ckpt.checkpoint import CheckpointManager
from repro.parallel.sharding import use_mesh, act_rules_for

cfg = ModelConfig(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
                  d_ff=64, vocab_size=256, remat="none", dtype="float32")
model = Model(cfg)
mesh = jax.make_mesh((4, 2), ("data", "model"))
params_t = model.init(jax.random.key(0))
opt_t = init_opt_state(params_t)
param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), model.specs(mesh))
shardings = {"params": param_sh,
             "opt": OptState(step=None, m=param_sh, v=param_sh, master=None)}
mgr = CheckpointManager(os.environ["CKPT_DIR"], async_save=False)
restored, extra = mgr.restore(3, {"params": params_t, "opt": opt_t},
                              shardings=None)
# reshard onto the mesh (elastic: checkpoint stores full logical arrays)
params = jax.tree.map(lambda x, s: jax.device_put(x, s),
                      restored["params"], param_sh)
# values identical to the original init regardless of mesh
ok = all(np.allclose(np.asarray(a), np.asarray(b))
         for a, b in zip(jax.tree.leaves(params),
                         jax.tree.leaves(params_t)))
sharded = any(len(x.sharding.device_set) > 1
              for x in jax.tree.leaves(params))
print("RESULTS:" + json.dumps({"values_ok": ok, "sharded": sharded,
                               "step": extra["step"]}))
"""


def test_elastic_restore_onto_bigger_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # pin children to CPU: with libtpu installed, an unset platform makes
    # the child block on /tmp/libtpu_lockfile held by the pytest process
    env["JAX_PLATFORMS"] = "cpu"
    with tempfile.TemporaryDirectory() as d:
        env["CKPT_DIR"] = d
        cwd = os.path.dirname(os.path.dirname(__file__))
        p1 = subprocess.run([sys.executable, "-c", SCRIPT_SAVE], env=env,
                            capture_output=True, text=True, timeout=600,
                            cwd=cwd)
        assert p1.returncode == 0 and "SAVED" in p1.stdout, p1.stderr[-2000:]
        p2 = subprocess.run([sys.executable, "-c", SCRIPT_RESTORE], env=env,
                            capture_output=True, text=True, timeout=600,
                            cwd=cwd)
        assert p2.returncode == 0, p2.stderr[-2000:]
        line = [l for l in p2.stdout.splitlines() if l.startswith("RESULTS:")]
        res = json.loads(line[0][len("RESULTS:"):])
        assert res["values_ok"] and res["sharded"] and res["step"] == 3
