"""Differentiable-sim gradient verification (ISSUE 7).

Three layers of protection for the calibration path:

  * the per-stage finite-difference matrix (``repro.core.gradcheck``): each
    stage's analytic gradient against central differences, per plane kind
    and with/without the recon chain — the same suite CI gates via
    ``launch/fit.py --gradcheck``;
  * exact STE/relaxed contracts asserted analytically (pass-through
    gradients inside the ADC rails, zero outside; NaN-free gradients at
    zero fluctuation variance), where finite differences of a quantized
    forward would be meaningless;
  * forward bit-identity: the differentiable graph's float32 forward equals
    the default graph's quantized int16 ADC exactly, and the default graph
    still reproduces the pinned golden SHA-256 digests — calibration
    machinery must not move the physics by one ulp.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core import fluctuate as fl
from repro.core.fft_conv import digitize
from repro.core.fit import fit_config, make_fit_loss, make_fit_targets
from repro.core.fit import FitParam, FitSpec
from repro.core.gradcheck import (finite_difference_grad, gradcheck,
                                  stage_gradcheck_cases,
                                  stage_gradcheck_suite)
from repro.core.stages import build_sim_graph

CFG = get_config("lartpc-uboone", smoke=True)


# ---------------------------------------------------------------------------
# The FD matrix
# ---------------------------------------------------------------------------


class TestStageMatrix:
    @pytest.mark.parametrize("case", stage_gradcheck_cases(),
                             ids=lambda c: c.name)
    def test_stage_gradient_matches_fd(self, case):
        """Every stage's analytic gradient agrees with central differences
        (per-case step/tolerance — see the gradcheck module docstring)."""
        (res,) = stage_gradcheck_suite(cases=[case])
        assert res.ok, (f"{res.name}: analytic {res.analytic} vs numeric "
                        f"{res.numeric} (rel_err {res.max_rel_err:.3e})")

    @pytest.mark.parametrize("plane", ["induction", "collection"])
    def test_response_gradient_per_plane_kind(self, plane):
        """The convolve-stage gradient holds for BOTH field-response
        families (bipolar induction / unipolar collection)."""
        from repro.core.depo import generate_depos
        from repro.core.fft_conv import fft_convolve
        from repro.core.response import make_response
        from repro.core.stages import compute_charge_grid

        cfg = fit_config(CFG)
        key = jax.random.key(3)
        depos = generate_depos(key, cfg)
        grid = compute_charge_grid(jax.random.fold_in(key, 2), depos, cfg)
        w = jax.random.normal(jax.random.fold_in(key, 1), grid.shape)

        def f(theta):
            tcfg = dataclasses.replace(cfg, response_gain=theta[0],
                                       response_shaping_us=theta[1])
            resp = make_response(tcfg, plane=plane)
            return jnp.sum(fft_convolve(grid, resp, tcfg.fft_strategy) * w
                           ) / grid.size

        res = gradcheck(f, jnp.asarray([1.3, 1.7]), name=f"convolve/{plane}",
                        eps=1e-3, rtol=3e-2)
        assert res.ok, res

    def test_fit_loss_gradcheck_with_recon_chain(self):
        """The full fit loss with the deconvolved-charge term is in the
        matrix; this pins that WITHOUT it the same loss still gradchecks
        (recon stages absent from the traced graph entirely)."""
        cfg = dataclasses.replace(fit_config(CFG),
                                  electrons_per_depo=150_000.0)
        spec = FitSpec(params=(FitParam("recombination"),))
        targets = make_fit_targets(cfg, jax.random.key(5), num_events=1)
        loss = make_fit_loss(cfg, spec, targets)

        def f(theta):
            return loss(theta * cfg.recombination)

        res = gradcheck(f, jnp.asarray([0.9]), name="e2e/no-recon",
                        eps=2e-2, rtol=2e-1, atol=1e-3)
        assert res.ok, res

    def test_finite_difference_grad_on_quadratic(self):
        """The FD helper itself: exact on a quadratic (central differences
        have no truncation error there)."""
        c = jnp.asarray([1.0, -2.0, 0.5])

        def f(x):
            return jnp.sum((x - c) ** 2)

        x0 = jnp.asarray([0.3, 0.1, -0.2])
        g = finite_difference_grad(f, x0, eps=1e-2)
        np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(x0 - c),
                                   rtol=1e-3, atol=1e-4)

    def test_gradcheck_flags_wrong_gradient(self):
        """A deliberately wrong custom gradient must FAIL the check — the
        suite's assertions are only meaningful if it can."""

        @jax.custom_vjp
        def bad_square(x):
            return jnp.sum(x * x)

        def fwd(x):
            return bad_square(x), x

        def bwd(x, g):
            return (3.0 * g * x,)  # wrong: should be 2 g x

        bad_square.defvjp(fwd, bwd)
        res = gradcheck(bad_square, jnp.asarray([1.5]), name="bad")
        assert not res.ok

    def test_nan_analytic_gradient_fails(self):
        """A NaN gradient path is an automatic failure (not a tolerance
        comparison against FD noise)."""

        def f(x):
            return jnp.sum(jnp.sqrt(x))  # d/dx sqrt at 0 -> inf/nan

        res = gradcheck(f, jnp.asarray([0.0]), name="nan")
        assert not res.ok


# ---------------------------------------------------------------------------
# Exact contracts: relaxed fluctuation and the STE digitizer
# ---------------------------------------------------------------------------


class TestRelaxedFluctuation:
    def test_forward_bit_identical_to_counter(self, rng_key):
        """The relaxed draw IS the counter draw forward: same key, same
        threefry normals, value-identical masking — bit-for-bit equal."""
        n = 64
        charge = jnp.abs(jax.random.normal(rng_key, (n,))) * 5000.0
        charge = charge.at[:4].set(0.0)  # zero-charge (padding) depos
        patches = jnp.abs(jax.random.normal(
            jax.random.fold_in(rng_key, 1),
            (n, CFG.patch_wires, CFG.patch_ticks))) * charge[:, None, None] / 50.0
        key = jax.random.fold_in(rng_key, 2)
        a = fl.fluctuate_counter(key, patches, charge)
        b = fl.fluctuate_counter_relaxed(key, patches, charge)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gradient_finite_at_zero_variance(self, rng_key):
        """jax.grad through the relaxed draw is NaN-free even where the
        binomial variance is exactly 0 (zero-charge padding depos, p=1
        saturated pixels) — the masked-sqrt reparameterization's reason to
        exist. The plain counter draw produces NaN there."""
        charge = jnp.asarray([0.0, 5000.0])
        patches = jnp.stack([jnp.zeros((4, 4)),
                             jnp.full((4, 4), 100.0)])
        key = jax.random.key(0)

        def loss_relaxed(scale):
            return jnp.sum(fl.fluctuate_counter_relaxed(
                key, patches * scale, charge * scale))

        g = jax.grad(loss_relaxed)(1.0)
        assert bool(jnp.isfinite(g))

        def loss_counter(scale):
            return jnp.sum(fl.fluctuate_counter(
                key, patches * scale, charge * scale))

        assert not bool(jnp.isfinite(jax.grad(loss_counter)(1.0)))


class TestDigitizeSTE:
    def test_forward_equals_quantized(self, rng_key):
        """STE forward values equal the int16 path exactly (round and clip
        commute on the integer rails), including above/below the rails."""
        sig = jax.random.uniform(rng_key, (64, 64), minval=-2e5,
                                 maxval=6e5)
        hard = digitize(sig, CFG)
        assert hard.dtype == jnp.int16
        soft = digitize(sig, dataclasses.replace(CFG, digitize_ste=True))
        assert soft.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(hard, np.float32),
                                      np.asarray(soft))

    def test_gradient_is_passthrough_inside_rails(self):
        """d(adc)/d(signal) is adc_per_electron inside the rails and 0
        outside — the straight-through contract, asserted analytically
        (FD over a staircase measures nothing)."""
        cfg = dataclasses.replace(CFG, digitize_ste=True)
        # baseline 900, gain 0.01: signal -2e5 -> adc -1100 (below rail 0),
        # 1e4 -> 1000 (inside), 5e5 -> 5900 (above rail 4095)
        sig = jnp.asarray([-2e5, 1e4, 5e5])
        g = jax.grad(lambda s: jnp.sum(digitize(s, cfg)))(sig)
        np.testing.assert_allclose(np.asarray(g),
                                   [0.0, cfg.adc_per_electron, 0.0],
                                   atol=1e-9)


# ---------------------------------------------------------------------------
# Forward bit-identity: calibration machinery must not move the defaults
# ---------------------------------------------------------------------------


class TestForwardIdentity:
    def test_default_graph_still_matches_golden_pins(self):
        """The default (non-STE, counter-sampling) graph reproduces the
        pinned ADC digests — the new config fields and traced-config
        branches left the bit-exact path untouched."""
        from test_stages import GOLDEN_ADC_SHA256, _sha
        from repro.core.depo import generate_depos

        key = jax.random.key(0)
        depos = generate_depos(key, CFG)
        adc = jax.jit(build_sim_graph(CFG, None).run)(key, depos).adc
        assert _sha(adc) == GOLDEN_ADC_SHA256["unfused"]

    def test_fit_graph_forward_equals_default_quantized(self):
        """fit_config's graph (relaxed + STE, float32) produces EXACTLY the
        default graph's int16 ADC values on the same event/key."""
        from repro.core.depo import generate_depos

        key = jax.random.key(7)
        depos = generate_depos(key, CFG)
        hard = jax.jit(build_sim_graph(CFG, None).run)(key, depos).adc
        soft = jax.jit(build_sim_graph(fit_config(CFG), None).run)(
            key, depos).adc
        assert soft.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(hard, np.float32),
                                      np.asarray(soft))

    def test_fit_loss_exactly_zero_at_truth(self):
        """The self-calibration contract: same keys -> same noise and
        fluctuation realizations -> loss exactly 0 at the true params."""
        cfg = dataclasses.replace(CFG, electron_lifetime_us=60.0,
                                  recombination=0.75)
        spec = FitSpec(params=(FitParam("electron_lifetime_us", lo=5.0,
                                        hi=500.0),
                               FitParam("recombination", lo=0.2, hi=1.0)))
        targets = make_fit_targets(cfg, jax.random.key(11), num_events=2)
        loss = jax.jit(make_fit_loss(cfg, spec, targets))
        assert float(loss(spec.true_theta(cfg))) == 0.0
        # and strictly positive away from truth (the minimum is real)
        off = spec.true_theta(dataclasses.replace(
            cfg, electron_lifetime_us=90.0, recombination=0.6))
        assert float(loss(off)) > 0.0
