"""Drift-stage tests: transport physics + seed bit-identity of the wrapper."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import LArTPCConfig
from repro.core.depo import DepoSet, generate_depos, generate_physical_depos
from repro.core.drift import PhysicalDepoSet, drift_depos, transport

CFG = LArTPCConfig(num_wires=64, num_ticks=256, num_depos=128,
                   response_wires=11, response_ticks=48)


def seed_generate_depos(key, cfg, n=None):
    """The seed repo's direct detector-frame generator, verbatim — the
    reference for the satellite requirement that ``generate_depos`` routed
    through the drift stage stays bit-for-bit at default physics."""
    n = n or cfg.num_depos
    n_tracks = max(1, n // 512)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    entry_w = jax.random.uniform(k1, (n_tracks,), minval=0.0,
                                 maxval=cfg.num_wires - 1.0)
    entry_t = jax.random.uniform(k2, (n_tracks,), minval=0.0,
                                 maxval=cfg.num_ticks - 1.0)
    theta = jax.random.uniform(k3, (n_tracks,), minval=-1.2, maxval=1.2)
    per = n // n_tracks + 1
    s = jnp.arange(per, dtype=jnp.float32)[None, :]
    wires = entry_w[:, None] + jnp.sin(theta)[:, None] * s * 0.5
    ticks = entry_t[:, None] + jnp.cos(theta)[:, None] * s * 2.0
    wires = wires.reshape(-1)[:n]
    ticks = ticks.reshape(-1)[:n]
    wires = jnp.clip(jnp.abs(wires), 0, cfg.num_wires - 1)
    ticks = jnp.clip(jnp.abs(ticks), 0, cfg.num_ticks - 1)
    drift_us = ticks * cfg.tick_us
    sigma_t = jnp.sqrt(2.0 * cfg.diffusion_long * drift_us) / (
        cfg.drift_speed_mm_us * cfg.tick_us
    ) * 1e-2 + 0.8
    sigma_w = jnp.sqrt(2.0 * cfg.diffusion_tran * drift_us) / (
        cfg.wire_pitch_mm) * 1e-2 + 0.6
    sigma_w = jnp.clip(sigma_w, 0.3, (cfg.patch_wires / 2 - 1) / cfg.nsigma)
    sigma_t = jnp.clip(sigma_t, 0.3, (cfg.patch_ticks / 2 - 1) / cfg.nsigma)
    charge = cfg.electrons_per_depo * jnp.exp(
        0.3 * jax.random.normal(k4, (n,)))
    return DepoSet(
        wire=wires.astype(jnp.float32),
        tick=ticks.astype(jnp.float32),
        sigma_w=sigma_w.astype(jnp.float32),
        sigma_t=sigma_t.astype(jnp.float32),
        charge=charge.astype(jnp.float32),
    )


def _linear_pdepos(n=64, t_drift_max=100.0, q=1000.0):
    """Depos on a drift-time ramp (fixed transverse position)."""
    x = jnp.linspace(0.0, t_drift_max, n)
    return PhysicalDepoSet(
        x=x.astype(jnp.float32),
        y=jnp.full((n,), 32.0, jnp.float32),
        z=jnp.zeros((n,), jnp.float32),
        t=jnp.zeros((n,), jnp.float32),
        q=jnp.full((n,), q, jnp.float32),
    )


class TestSeedBitIdentity:
    def test_generate_depos_matches_seed_default_physics(self):
        """generate_depos = physical generation + drift stage, bit-for-bit
        with the seed formulas at default physics, for several keys."""
        for seed in (0, 1, 7):
            key = jax.random.key(seed)
            new = generate_depos(key, CFG)
            ref = seed_generate_depos(key, CFG)
            for field in DepoSet._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(new, field)),
                    np.asarray(getattr(ref, field)), err_msg=field)

    def test_generate_depos_matches_seed_full_scale_shape(self):
        cfg = LArTPCConfig()  # full MicroBooNE-scale constants
        key = jax.random.key(3)
        new = generate_depos(key, cfg, 2048)
        ref = seed_generate_depos(key, cfg, 2048)
        for field in DepoSet._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(new, field)),
                np.asarray(getattr(ref, field)), err_msg=field)

    def test_wrapper_is_physical_plus_transport(self):
        key = jax.random.key(2)
        pdepos = generate_physical_depos(key, CFG)
        via_stage = transport(pdepos, CFG)
        direct = generate_depos(key, CFG)
        for field in DepoSet._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(via_stage, field)),
                np.asarray(getattr(direct, field)), err_msg=field)


class TestDriftPhysics:
    def test_attenuation_monotonic_in_drift_distance(self):
        """With a finite electron lifetime, surviving charge strictly
        decreases with drift time (equal deposited charge)."""
        cfg = dataclasses.replace(CFG, electron_lifetime_us=50.0)
        out = drift_depos(_linear_pdepos(), cfg)
        q = np.asarray(out.charge)
        assert (np.diff(q) < 0).all(), "attenuation must be monotonic"
        # endpoint sanity: exp(-t_max/lifetime) = exp(-2)
        np.testing.assert_allclose(q[-1] / q[0], np.exp(-100.0 / 50.0),
                                   rtol=1e-5)

    def test_no_lifetime_means_no_attenuation(self):
        out = drift_depos(_linear_pdepos(), CFG)  # lifetime disabled
        q = np.asarray(out.charge)
        np.testing.assert_array_equal(q, np.full_like(q, 1000.0))

    def test_recombination_scales_charge(self):
        cfg = dataclasses.replace(CFG, recombination=0.7)
        base = drift_depos(_linear_pdepos(), CFG)
        scaled = drift_depos(_linear_pdepos(), cfg)
        np.testing.assert_allclose(np.asarray(scaled.charge),
                                   0.7 * np.asarray(base.charge), rtol=1e-6)

    def test_diffusion_widths_grow_with_drift_time(self):
        out = drift_depos(_linear_pdepos(t_drift_max=60.0), CFG)
        sw, stt = np.asarray(out.sigma_w), np.asarray(out.sigma_t)
        # monotone non-decreasing (clipping may flatten the far end)
        assert (np.diff(sw) >= 0).all() and (np.diff(stt) >= 0).all()
        assert sw[0] >= CFG.sigma_w_floor - 1e-6
        assert stt[0] >= CFG.sigma_t_floor - 1e-6

    def test_sigma_floors_are_config_fields(self):
        cfg = dataclasses.replace(CFG, sigma_w_floor=1.1, sigma_t_floor=1.7)
        out = drift_depos(_linear_pdepos(t_drift_max=5.0), cfg)
        assert float(np.asarray(out.sigma_w).min()) >= 1.1 - 1e-6
        assert float(np.asarray(out.sigma_t).min()) >= 1.7 - 1e-6

    def test_sub_clip_floors_stay_effective(self):
        """Floors below the 0.3 numeric guard lower the guard with them —
        the configured floor is the real minimum width."""
        cfg = dataclasses.replace(CFG, sigma_w_floor=0.1, sigma_t_floor=0.15)
        pd = _linear_pdepos(n=4, t_drift_max=0.0)  # zero drift: pure floor
        out = drift_depos(pd, cfg)
        np.testing.assert_allclose(np.asarray(out.sigma_w), 0.1, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out.sigma_t), 0.15, rtol=1e-6)

    def test_arrival_tick_includes_deposition_time(self):
        pd = _linear_pdepos(n=8, t_drift_max=20.0)
        shifted = pd._replace(t=jnp.full((8,), 10.0, jnp.float32))
        base = drift_depos(pd, CFG)
        late = drift_depos(shifted, CFG)
        np.testing.assert_allclose(
            np.asarray(late.tick) - np.asarray(base.tick),
            np.full((8,), 10.0 / CFG.tick_us), rtol=1e-6)

    def test_from_mm_ingestion(self):
        """Metric-space (larnd-sim style) segments ingest through from_mm:
        mm positions land on the wires/ticks the geometry predicts."""
        x_mm = np.array([0.0, 16.0, 80.0], np.float32)     # drift distance
        y_mm = np.array([30.0, 60.0, 90.0], np.float32)    # transverse
        pd = PhysicalDepoSet.from_mm(x_mm, y_mm, 0.0 * x_mm, 0.0 * x_mm,
                                     np.full(3, 5000.0, np.float32), CFG)
        out = drift_depos(pd, CFG)
        np.testing.assert_allclose(np.asarray(out.wire),
                                   y_mm / CFG.wire_pitch_mm, rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(out.tick),
            x_mm / CFG.drift_speed_mm_us / CFG.tick_us, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(pd.x_mm(CFG)), x_mm, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(pd.y_mm(CFG)), y_mm, rtol=1e-6)

    def test_drift_is_jit_and_vmap_safe(self):
        pd = _linear_pdepos(n=16)
        eager = drift_depos(pd, CFG)
        jitted = jax.jit(lambda p: drift_depos(p, CFG))(pd)
        for field in DepoSet._fields:
            # XLA may fuse the sigma multiply-add into an FMA under jit, so
            # jit-vs-eager is ulp-close, not bitwise (the generator runs
            # eagerly on the host in every production path)
            np.testing.assert_allclose(np.asarray(getattr(eager, field)),
                                       np.asarray(getattr(jitted, field)),
                                       rtol=1e-6, atol=1e-6, err_msg=field)
        stacked = jax.tree.map(lambda x: jnp.stack([x, x]), pd)
        batched = jax.vmap(lambda p: drift_depos(p, CFG))(stacked)
        np.testing.assert_array_equal(np.asarray(batched.tick[0]),
                                      np.asarray(eager.tick))
