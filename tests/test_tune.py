"""Kernel-strategy registry + autotuner tests.

Covers the ISSUE-2 contract: cache round-trip (second call hits disk),
deterministic winner under a fake timer, and bit-for-bit strategy
equivalence on a fixed non-overlapping DepoSet.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.config import LArTPCConfig
from repro.core.depo import DepoSet, generate_depos
from repro.core.fft_conv import (fft_convolve, fft_convolve_fft2,
                                 fft_convolve_rfft2)
from repro.core.pipeline import (charge_grid_fused, charge_grid_unfused,
                                 make_sim_fn, simulate_fig4)
from repro.core.rasterize import rasterize
from repro.core.response import make_response
from repro.core.scatter import scatter_add

CFG = LArTPCConfig(num_wires=96, num_ticks=768, num_depos=64)

#: fake timings (seconds) — pallas / fused_pallas are made the deterministic
#: winners on purpose: the wall clock must play no part under an injected timer
FAKE_TIMES = {"xla": 3.0, "sort_segment": 2.0, "pallas": 1.0,
              "pallas_compact": 1.5,
              "unfused": 2.0, "unfused_bf16": 2.5, "fused_pallas": 1.0,
              "fused_pallas_compact": 1.5, "rfft2": 1.0, "fft2": 2.0,
              "scan": 2.0}  # hit_find: "pallas" (1.0) fake-wins over "scan"


def fake_timer(calls):
    def timer(name, thunk):
        calls.append(name)
        return FAKE_TIMES[name]

    return timer


def lattice_depos(cfg=CFG) -> DepoSet:
    """Depos whose patches cannot overlap (and sit fully inside the grid):
    every output pixel receives at most one contribution, so all scatter
    strategies must agree *bit for bit* — no addition-order slack."""
    pw, pt = cfg.patch_wires, cfg.patch_ticks
    wires = np.arange(pw, cfg.num_wires - pw, pw + 8, dtype=np.float32)
    ticks = np.arange(pt, cfg.num_ticks - pt, pt + 12, dtype=np.float32)
    ww, tt = np.meshgrid(wires, ticks, indexing="ij")
    n = ww.size
    return DepoSet(
        wire=jnp.asarray(ww.ravel()), tick=jnp.asarray(tt.ravel()),
        sigma_w=jnp.full((n,), 1.0), sigma_t=jnp.full((n,), 1.2),
        charge=jnp.linspace(500.0, 5000.0, n, dtype=np.float32))


class TestRegistry:
    def test_ops_and_candidates_registered(self):
        assert set(tune.list_ops()) >= {"scatter_add", "charge_grid",
                                        "fft_convolve"}
        assert set(tune.strategies("scatter_add")) == {
            "xla", "sort_segment", "pallas", "pallas_compact"}
        assert set(tune.strategies("charge_grid")) == {
            "unfused", "unfused_bf16", "fused_pallas",
            "fused_pallas_compact", "fused_pallas_multiplane",
            "fused_pallas_multiplane_compact", "multiplane_xla"}
        assert set(tune.strategies("fft_convolve")) == {"rfft2", "fft2"}

    def test_unknown_names_raise_with_known_list(self):
        with pytest.raises(KeyError, match="scatter_add"):
            tune.get_strategy("scatter_add", "atomics")
        with pytest.raises(KeyError, match="known"):
            tune.strategies("matmul")

    def test_availability_fused_competes_in_default_physics_config(self):
        """In-kernel counter RNG lifts the old fluctuate=False restriction:
        fused candidates are available under the default (counter) config and
        only the irreproducible pre-computed pool stream excludes them."""
        shape = tune.op_shape("charge_grid", CFG)
        ctx = tune.make_context(CFG, shape)  # fluctuate=True, counter RNG
        avail = tune.available_strategies("charge_grid", ctx)
        assert {"fused_pallas", "fused_pallas_compact"} <= set(avail)
        pooled = dataclasses.replace(CFG, rng_strategy="pool")
        ctx = tune.make_context(pooled, shape)
        avail = tune.available_strategies("charge_grid", ctx)
        assert "fused_pallas" not in avail
        assert "fused_pallas_compact" not in avail
        quiet = dataclasses.replace(CFG, fluctuate=False)
        ctx = tune.make_context(quiet, shape)
        assert "fused_pallas" in tune.available_strategies("charge_grid", ctx)

    def test_availability_pallas_excluded_at_production_grids_off_tpu(self):
        big = LArTPCConfig()  # 2560 x 9592: interpret-prohibitive on CPU
        ctx = tune.make_context(big, tune.op_shape("scatter_add", big),
                                backend="cpu")
        assert "pallas" not in tune.available_strategies("scatter_add", ctx)
        ctx_tpu = tune.make_context(big, tune.op_shape("scatter_add", big),
                                    backend="tpu")
        assert "pallas" in tune.available_strategies("scatter_add", ctx_tpu)

    def test_backend_defaults(self):
        assert tune.default_strategy("scatter_add", "cpu") == "xla"
        assert tune.default_strategy("fft_convolve", "tpu") == "rfft2"


class TestAutotuner:
    def test_deterministic_winner_under_fake_timer(self, tmp_path):
        calls = []
        cache = tune.TuneCache(str(tmp_path / "cache.json"))
        d = tune.tune_op("scatter_add", CFG, cache=cache,
                         timer=fake_timer(calls))
        assert d.strategy == "pallas"      # smallest fake time, not wall time
        assert d.source == "tuned"
        assert set(calls) == {"xla", "sort_segment", "pallas",
                              "pallas_compact"}

    def test_cache_roundtrip_second_call_hits_disk(self, tmp_path):
        path = str(tmp_path / "cache.json")
        calls = []
        d1 = tune.tune_op("scatter_add", CFG, cache=tune.TuneCache(path),
                          timer=fake_timer(calls))
        n_timed = len(calls)
        assert n_timed > 0 and d1.source == "tuned"
        # a FRESH TuneCache instance must find the decision on disk
        d2 = tune.tune_op("scatter_add", CFG, cache=tune.TuneCache(path),
                          timer=fake_timer(calls))
        assert d2.cache_hit and d2.strategy == d1.strategy
        assert len(calls) == n_timed, "cache hit must not re-time candidates"

    def test_force_retunes_past_the_cache(self, tmp_path):
        path = str(tmp_path / "cache.json")
        calls = []
        tune.tune_op("scatter_add", CFG, cache=tune.TuneCache(path),
                     timer=fake_timer(calls))
        n = len(calls)
        d = tune.tune_op("scatter_add", CFG, cache=tune.TuneCache(path),
                         timer=fake_timer(calls), force=True)
        assert d.source == "tuned" and len(calls) > n

    def test_shape_bucketing_shares_and_splits_keys(self):
        a = tune.cache_key("scatter_add", "cpu", "cpu", {"num_depos": 100_000})
        b = tune.cache_key("scatter_add", "cpu", "cpu", {"num_depos": 120_000})
        c = tune.cache_key("scatter_add", "cpu", "cpu", {"num_depos": 1_000})
        assert a == b and a != c

    def test_resolve_explicit_wins_over_cache(self, tmp_path):
        cache = tune.TuneCache(str(tmp_path / "cache.json"))
        tune.tune_op("scatter_add", CFG, cache=cache, timer=fake_timer([]))
        d = tune.resolve("scatter_add", CFG, cache=cache)  # cfg names "xla"
        assert d.source == "explicit" and d.strategy == "xla"

    def test_resolve_config_replaces_auto_fields(self, tmp_path):
        cache = tune.TuneCache(str(tmp_path / "cache.json"))
        cfg = dataclasses.replace(CFG, scatter_strategy="auto",
                                  fft_strategy="auto",
                                  charge_grid_strategy="auto")
        resolved = tune.resolve_config(cfg, tune=True, cache=cache,
                                       timer=fake_timer([]))
        assert resolved.scatter_strategy == "pallas"   # fake-timer winner
        assert resolved.fft_strategy == "rfft2"
        # fused competes (and fake-wins) even with fluctuate=True: the
        # in-kernel counter RNG lifted the old exclusion
        assert resolved.charge_grid_strategy == "fused_pallas"
        assert resolved.hitfind_strategy == "pallas"   # fake-timer winner
        # defaults-only resolution (no tuning, no cache entry)
        resolved2 = tune.resolve_config(
            cfg, cache=tune.TuneCache(str(tmp_path / "empty.json")))
        assert resolved2.scatter_strategy == tune.default_strategy(
            "scatter_add")

    def test_scatter_add_auto_uses_cached_winner(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "cache.json"))
        tuned = tune.tune_op("scatter_add", CFG, timer=fake_timer([]))
        assert tuned.strategy == "pallas"                # fake-timer winner
        cfg = dataclasses.replace(CFG, scatter_strategy="auto")
        # the auto path must resolve to the cached winner, from the cache
        d = tune.resolve("scatter_add", cfg)
        assert d.strategy == "pallas" and d.source == "cache"
        # and the dispatch itself must run that winner without error
        depos = lattice_depos(cfg)
        patches, w0, t0 = rasterize(depos, cfg)
        out = scatter_add(patches, w0, t0, cfg)
        ref = tune.get_strategy("scatter_add", "pallas").fn(
            patches, w0, t0, cfg)
        assert np.array_equal(np.asarray(out), np.asarray(ref))

    def test_cached_winner_ignored_when_predicate_fails(self, tmp_path):
        """A fused_pallas charge_grid winner tuned under the counter-RNG
        config must NOT be served from cache to a pool-RNG config (whose
        pre-computed stream the kernel cannot reproduce) — the cache key
        omits predicate inputs like `rng_strategy`."""
        cache = tune.TuneCache(str(tmp_path / "cache.json"))
        counter = dataclasses.replace(CFG, charge_grid_strategy="auto")
        d = tune.tune_op("charge_grid", counter, cache=cache,
                         timer=fake_timer([]))
        assert d.strategy == "fused_pallas"              # fake-timer winner
        pooled = dataclasses.replace(CFG, rng_strategy="pool",
                                     charge_grid_strategy="auto")
        d2 = tune.resolve("charge_grid", pooled, cache=cache)
        assert d2.strategy == "unfused"                  # not the stale hit
        assert d2.source == "default"
        # the counter-RNG config still gets its cached winner
        d3 = tune.resolve("charge_grid", counter, cache=cache)
        assert d3.strategy == "fused_pallas" and d3.cache_hit


class TestStrategyEquivalence:
    def test_scatter_strategies_bit_for_bit_on_fixed_deposet(self):
        """Every registered scatter strategy produces the IDENTICAL grid on a
        DepoSet whose patches never overlap (no addition-order freedom)."""
        depos = lattice_depos()
        patches, w0, t0 = rasterize(depos, CFG)
        grids = {name: np.asarray(strat.fn(patches, w0, t0, CFG))
                 for name, strat in tune.strategies("scatter_add").items()}
        ref_name, ref = next(iter(grids.items()))
        assert float(np.abs(ref).sum()) > 0.0
        for name, grid in grids.items():
            assert np.array_equal(ref, grid), (
                f"strategy {name!r} diverged bitwise from {ref_name!r}")

    def test_scatter_strategies_allclose_with_overlap(self):
        depos = generate_depos(jax.random.key(0), CFG, 128)
        patches, w0, t0 = rasterize(depos, CFG)
        grids = {name: np.asarray(strat.fn(patches, w0, t0, CFG))
                 for name, strat in tune.strategies("scatter_add").items()}
        ref = grids.pop("xla")
        for name, grid in grids.items():
            np.testing.assert_allclose(grid, ref, rtol=1e-4, atol=5e-2,
                                       err_msg=name)

    def test_fft_strategies_agree(self):
        resp = make_response(CFG)
        grid = jax.random.uniform(jax.random.key(1),
                                  (CFG.num_wires, CFG.num_ticks))
        a = np.asarray(fft_convolve_rfft2(grid, resp))
        b = np.asarray(fft_convolve_fft2(grid, resp))
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)

    def test_charge_grid_strategies_agree_without_fluctuation(self):
        cfg = dataclasses.replace(CFG, fluctuate=False)
        depos = generate_depos(jax.random.key(2), cfg, 96)
        key = jax.random.key(3)
        a = np.asarray(charge_grid_unfused(key, depos, cfg))
        b = np.asarray(charge_grid_fused(key, depos, cfg))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=5e-2)

    def test_all_charge_grid_strategies_agree_without_fluctuation(self):
        """Every registered candidate (incl. compact and bf16 variants)
        produces the same grid when fluctuation is off."""
        cfg = dataclasses.replace(CFG, fluctuate=False)
        depos = generate_depos(jax.random.key(7), cfg, 96)
        key = jax.random.key(8)
        ref = np.asarray(charge_grid_unfused(key, depos, cfg))
        ctx = tune.registry.make_context(
            cfg, tune.autotune.op_shape("charge_grid", cfg))
        for name, strat in tune.strategies("charge_grid").items():
            if not strat.is_available(ctx):
                continue  # e.g. multi-plane strategies at num_planes=1
            got = np.asarray(strat.fn(key, depos, cfg, None))
            tol = dict(rtol=1e-2, atol=2e1) if "bf16" in name else dict(
                rtol=1e-5, atol=5e-2)
            np.testing.assert_allclose(got, ref, err_msg=name, **tol)

    def test_fused_compact_matches_dense_bitwise_with_fluctuation(self):
        """Compaction preserves global tile ids, hence RNG streams: the
        compacted fused grid equals the dense fused grid BIT FOR BIT even
        with in-kernel fluctuation enabled."""
        from repro.core.pipeline import charge_grid_fused_compact

        depos = generate_depos(jax.random.key(9), CFG, 128)
        key = jax.random.key(10)
        dense = np.asarray(charge_grid_fused(key, depos, CFG))
        compact = np.asarray(charge_grid_fused_compact(key, depos, CFG))
        assert np.array_equal(dense, compact)

    def test_fused_raises_only_for_pool_rng(self):
        """The in-kernel RNG covers counter fluctuation; only the paper's
        pre-computed pool stream is irreproducible in kernel and rejected."""
        depos = generate_depos(jax.random.key(4), CFG, 8)
        pooled = dataclasses.replace(CFG, rng_strategy="pool")
        with pytest.raises(ValueError, match="pool"):
            charge_grid_fused(jax.random.key(0), depos, pooled)
        # the default counter config runs (and fluctuates: grid != mean grid)
        quiet = dataclasses.replace(CFG, fluctuate=False)
        mean = np.asarray(charge_grid_fused(jax.random.key(0), depos, quiet))
        fluct = np.asarray(charge_grid_fused(jax.random.key(0), depos, CFG))
        assert not np.array_equal(mean, fluct)
        assert abs(fluct.sum() - mean.sum()) / mean.sum() < 0.05


class TestFFTDispatch:
    """ISSUE-3 satellite: every concrete name routes through the registry."""

    def test_unknown_strategy_raises_value_error_with_candidates(self):
        resp = make_response(CFG)
        grid = jnp.zeros((CFG.num_wires, CFG.num_ticks))
        with pytest.raises(ValueError, match=r"fftw.*rfft2"):
            fft_convolve(grid, resp, "fftw")

    @pytest.mark.parametrize("name", ["rfft2", "fft2"])
    def test_concrete_names_route_through_registry(self, name, monkeypatch):
        """The old dispatch short-circuited 'rfft2' past the registry; now a
        registry override is honored for every concrete name."""
        from repro.tune import registry as reg

        calls = []
        orig = reg.get_strategy("fft_convolve", name)

        def spy(grid, resp):
            calls.append(name)
            return orig.fn(grid, resp)

        monkeypatch.setitem(reg._OPS["fft_convolve"], name,
                            dataclasses.replace(orig, fn=spy))
        resp = make_response(CFG)
        grid = jax.random.uniform(jax.random.key(0),
                                  (CFG.num_wires, CFG.num_ticks))
        out = fft_convolve(grid, resp, name)
        assert calls == [name]
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(orig.fn(grid, resp)),
                                   rtol=1e-6)


class TestPipelineIntegration:
    def test_fused_strategy_through_fig4(self):
        """The fused kernel is a first-class pipeline citizen: fig4 with
        charge_grid_strategy='fused_pallas' matches the unfused pipeline."""
        cfg = dataclasses.replace(CFG, fluctuate=False)
        fused = dataclasses.replace(cfg, charge_grid_strategy="fused_pallas")
        depos = generate_depos(jax.random.key(5), cfg, 64)
        resp = make_response(cfg)
        key = jax.random.key(6)
        a = simulate_fig4(key, depos, resp, cfg, add_noise=False)
        b = simulate_fig4(key, depos, resp, fused, add_noise=False)
        np.testing.assert_allclose(np.asarray(a.charge_grid),
                                   np.asarray(b.charge_grid),
                                   rtol=1e-5, atol=5e-2)
        assert (np.asarray(a.adc) == np.asarray(b.adc)).mean() > 0.999

    def test_make_sim_fn_resolves_auto_before_jit(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "cache.json"))
        cfg = dataclasses.replace(CFG, scatter_strategy="auto",
                                  fft_strategy="auto")
        sim = make_sim_fn(cfg)
        out = sim(jax.random.key(0), generate_depos(jax.random.key(1), cfg,
                                                    cfg.num_depos))
        ref = make_sim_fn(dataclasses.replace(cfg, scatter_strategy="xla",
                                              fft_strategy="rfft2"))(
            jax.random.key(0), generate_depos(jax.random.key(1), cfg,
                                              cfg.num_depos))
        assert np.array_equal(np.asarray(out.adc), np.asarray(ref.adc))
