"""Calibration machinery (``repro.core.fit``): FitParam/FitSpec transforms,
the differentiable-config audit, physical-event packing, and the optimizer
drivers — everything below the full fits exercised by ``launch/fit.py``
(--smoke in CI) and the gradient checks in ``tests/test_gradcheck.py``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core.batch import pack_physical_events
from repro.core.fit import (FitParam, FitSpec, assert_differentiable_config,
                            calibrate, fit_config, make_fit_loss,
                            make_fit_targets, run_fit, spec_from_names)
from repro.core.stages import build_sim_graph

CFG = get_config("lartpc-uboone", smoke=True)


# ---------------------------------------------------------------------------
# FitParam: transforms and validation
# ---------------------------------------------------------------------------


class TestFitParam:
    def test_transform_auto_resolution(self):
        assert FitParam("recombination").resolved_transform == "identity"
        assert FitParam("recombination", lo=0.1).resolved_transform == "log"
        assert (FitParam("recombination", lo=0.1, hi=1.0).resolved_transform
                == "sigmoid")

    @pytest.mark.parametrize("param,value", [
        (FitParam("recombination"), 0.75),
        (FitParam("electron_lifetime_us", lo=5.0), 60.0),
        (FitParam("noise_rms_adc", lo=0.2, hi=5.0), 1.2),
    ], ids=["identity", "log", "sigmoid"])
    def test_theta_value_round_trip(self, param, value):
        theta = param.to_theta(value)
        assert float(param.to_value(jnp.asarray(theta))) == pytest.approx(
            value, rel=1e-5)

    def test_bounds_enforced_by_transform(self):
        """The transform keeps the value inside the box for ANY theta — the
        optimizer never needs clipping."""
        p = FitParam("recombination", lo=0.2, hi=1.0)
        for theta in (-50.0, -1.0, 0.0, 3.0, 50.0):
            v = float(p.to_value(jnp.asarray(theta)))
            assert 0.2 <= v <= 1.0
        q = FitParam("electron_lifetime_us", lo=5.0)
        assert float(q.to_value(jnp.asarray(-40.0))) >= 5.0

    def test_unfittable_field_rejected(self):
        with pytest.raises(ValueError, match="not a fittable"):
            FitParam("num_wires")

    def test_sigmoid_needs_bounds(self):
        with pytest.raises(ValueError, match="needs"):
            FitParam("recombination", transform="sigmoid")
        with pytest.raises(ValueError, match="needs"):
            FitParam("recombination", lo=1.0, hi=0.5, transform="sigmoid")

    def test_unknown_transform_rejected(self):
        with pytest.raises(ValueError, match="unknown transform"):
            FitParam("recombination", transform="tanh")


class TestFitSpec:
    def test_empty_and_duplicate_rejected(self):
        with pytest.raises(ValueError, match="at least one"):
            FitSpec(params=())
        with pytest.raises(ValueError, match="duplicate"):
            FitSpec(params=(FitParam("recombination"),
                            FitParam("recombination")))

    def test_init_theta_prefers_explicit_init(self):
        spec = FitSpec(params=(FitParam("recombination", init=0.5),
                               FitParam("noise_rms_adc")))
        vals = spec.values(spec.init_theta(CFG))
        assert vals["recombination"] == pytest.approx(0.5)
        assert vals["noise_rms_adc"] == pytest.approx(CFG.noise_rms_adc)

    def test_true_theta_ignores_init(self):
        spec = FitSpec(params=(FitParam("recombination", init=0.5),))
        vals = spec.values(spec.true_theta(CFG))
        assert vals["recombination"] == pytest.approx(CFG.recombination)

    def test_apply_rebuilds_config(self):
        spec = FitSpec(params=(FitParam("recombination"),
                               FitParam("adc_baseline")))
        cfg = spec.apply(CFG, jnp.asarray([0.6, 850.0]))
        assert float(cfg.recombination) == pytest.approx(0.6)
        assert float(cfg.adc_baseline) == pytest.approx(850.0)
        # untouched fields keep their (Python-typed) values
        assert cfg.num_wires == CFG.num_wires

    def test_spec_from_names_bounds(self):
        spec = spec_from_names(["noise_rms_adc"], CFG, rel_bounds=4.0)
        (p,) = spec.params
        assert p.resolved_transform == "sigmoid"
        assert p.lo == pytest.approx(CFG.noise_rms_adc / 4.0)
        assert p.hi == pytest.approx(CFG.noise_rms_adc * 4.0)
        # a field currently at zero gets the unbounded identity transform
        assert dataclasses.asdict(
            spec_from_names(["electron_lifetime_us"], CFG).params[0]
        )["transform"] is None


# ---------------------------------------------------------------------------
# fit_config / assert_differentiable_config
# ---------------------------------------------------------------------------


class TestFitConfig:
    def test_enables_ste_and_relaxed(self):
        fcfg = fit_config(CFG)
        assert fcfg.digitize_ste
        assert fcfg.rng_strategy == "relaxed"
        assert_differentiable_config(fcfg)  # must not raise

    def test_pool_rng_rejected(self):
        cfg = dataclasses.replace(CFG, rng_strategy="pool")
        with pytest.raises(ValueError, match="pool"):
            fit_config(cfg)

    def test_auto_and_pallas_strategies_fall_back(self):
        cfg = dataclasses.replace(CFG, charge_grid_strategy="auto",
                                  scatter_strategy="pallas")
        fcfg = fit_config(cfg)
        assert fcfg.charge_grid_strategy == "unfused"
        assert fcfg.scatter_strategy == "xla"

    def test_default_config_fails_audit(self):
        with pytest.raises(ValueError, match="not differentiable"):
            assert_differentiable_config(CFG)


# ---------------------------------------------------------------------------
# Physical-event packing
# ---------------------------------------------------------------------------


class TestPackPhysicalEvents:
    def _events(self, sizes):
        from repro.core.depo import generate_physical_depos

        return [generate_physical_depos(jax.random.key(10 + i), CFG, n=n)
                for i, n in enumerate(sizes)]

    def test_ragged_pack_shapes(self):
        batch = pack_physical_events(self._events([700, 300]))
        assert batch.num_events == 2
        assert batch.max_depos == 700  # max over events, no extra padding
        np.testing.assert_array_equal(np.asarray(batch.n_depos),
                                      [700, 300])
        # padding rows carry zero charge
        assert float(jnp.abs(batch.q[1, 300:]).max()) == 0.0

    def test_pad_to_and_multiple(self):
        batch = pack_physical_events(self._events([100]), pad_to=130,
                                     pad_multiple=64)
        assert batch.max_depos == 192

    def test_empty_list_rejected(self):
        with pytest.raises(ValueError, match="at least one"):
            pack_physical_events([])

    def test_padding_is_inert_through_the_graph(self):
        """Extra q=0 rows contribute nothing: with the sampling stages off,
        the padded event's ADC equals the unpadded run bit-for-bit (zero
        charge -> zero patch -> zero scatter contribution).

        With ``rng_strategy="counter"`` the *realization* does shift —
        threefry pairs counter i with i + n/2 over the flattened (N, pw, pt)
        draw, so the normals depend on the padded length. That is why fit
        targets and the fit loss share ONE padded batch (same shapes, same
        keys): the self-calibration contract never compares runs of
        different padded lengths."""
        (ev,) = self._events([256])
        batch = pack_physical_events([ev], pad_to=320)
        key = jax.random.key(21)
        cfg = dataclasses.replace(CFG, rng_strategy="none")
        run = jax.jit(build_sim_graph(cfg, None).run)
        adc_plain = run(key, ev).adc
        adc_padded = run(key, batch.event(0)).adc
        np.testing.assert_array_equal(np.asarray(adc_plain),
                                      np.asarray(adc_padded))


# ---------------------------------------------------------------------------
# Optimizer drivers
# ---------------------------------------------------------------------------

_QSPEC = FitSpec(params=(FitParam("recombination"),
                         FitParam("adc_baseline")))
_QTARGET = jnp.asarray([0.7, -1.3])


def _quadratic(theta):
    return jnp.sum((theta - _QTARGET) ** 2)


class TestRunFit:
    def test_adam_converges_on_quadratic(self):
        res = run_fit(_quadratic, _QSPEC, jnp.zeros(2), steps=300, lr=0.05)
        np.testing.assert_allclose(np.asarray(res.theta),
                                   np.asarray(_QTARGET), atol=1e-3)
        assert res.loss < 1e-6
        assert res.steps == 300 and len(res.history) == 300

    def test_bfgs_converges_on_quadratic(self):
        res = run_fit(_quadratic, _QSPEC, jnp.zeros(2), steps=50,
                      optimizer="bfgs")
        np.testing.assert_allclose(np.asarray(res.theta),
                                   np.asarray(_QTARGET), atol=1e-4)
        assert res.steps <= 50

    def test_unknown_optimizer_rejected(self):
        with pytest.raises(ValueError, match="unknown optimizer"):
            run_fit(_quadratic, _QSPEC, jnp.zeros(2), optimizer="sgd")

    def test_callback_fires_on_log_every(self):
        seen = []
        run_fit(_quadratic, _QSPEC, jnp.zeros(2), steps=10, log_every=4,
                callback=lambda s, l, v: seen.append((s, sorted(v))))
        assert [s for s, _ in seen] == [4, 8, 10]
        assert seen[0][1] == ["adc_baseline", "recombination"]

    def test_relative_errors(self):
        res = run_fit(_quadratic, _QSPEC, jnp.zeros(2), steps=5)
        errs = res.relative_errors({"recombination": 1.0})
        assert set(errs) == {"recombination"}
        assert errs["recombination"] >= 0.0


class TestCalibrate:
    def test_short_fit_moves_toward_truth(self):
        """A deliberately short Adam run on one free parameter: the loss must
        drop sharply and the recovered value must close most of the gap to
        the truth (the full-convergence gate lives in launch/fit.py
        --smoke)."""
        cfg = dataclasses.replace(CFG, electrons_per_depo=150_000.0)
        truth = cfg.noise_rms_adc
        spec = FitSpec(params=(FitParam("noise_rms_adc", init=2.0 * truth,
                                        lo=truth / 4.0, hi=truth * 4.0),))
        targets = make_fit_targets(cfg, jax.random.key(31), num_events=1)
        loss_fn = jax.jit(make_fit_loss(cfg, spec, targets))
        l_init = float(loss_fn(spec.init_theta(cfg)))
        res = calibrate(cfg, spec, targets, steps=60, lr=0.3)
        assert res.loss < 0.5 * l_init
        assert res.relative_errors({"noise_rms_adc": truth})[
            "noise_rms_adc"] < 0.25


class TestMakeFitLoss:
    def test_decon_weight_requires_recon_targets(self):
        spec = FitSpec(params=(FitParam("recombination"),))
        targets = make_fit_targets(CFG, jax.random.key(1), num_events=1)
        with pytest.raises(ValueError, match="recon=True"):
            make_fit_loss(CFG, spec, targets, decon_weight=0.1)

    def test_loss_is_scalar_and_finite(self):
        spec = FitSpec(params=(FitParam("recombination"),))
        targets = make_fit_targets(CFG, jax.random.key(2), num_events=2)
        loss = jax.jit(make_fit_loss(CFG, spec, targets))
        val = loss(spec.init_theta(CFG) + 0.1)
        assert val.shape == ()
        assert bool(jnp.isfinite(val))
