"""Core LArTPC simulation tests: physics invariants + strategy equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.config import LArTPCConfig
from repro.core.depo import DepoSet, generate_depos
from repro.core.fft_conv import digitize, fft_convolve
from repro.core.noise import simulate_noise
from repro.core.pipeline import simulate_fig3, simulate_fig4
from repro.core.rasterize import rasterize, rasterize_one
from repro.core.response import make_response
from repro.core.scatter import scatter_sort_segment, scatter_xla

CFG = LArTPCConfig(num_wires=64, num_ticks=256, num_depos=128,
                   response_wires=11, response_ticks=48)


def _depos(n=64, seed=0):
    return generate_depos(jax.random.key(seed), CFG, n)


class TestRasterize:
    def test_mass_conservation(self):
        """Patch integrals equal depo charge when the Gaussian fits inside."""
        n = 32
        depos = DepoSet(
            wire=jnp.full((n,), 30.0) + jnp.arange(n) * 0.3,
            tick=jnp.full((n,), 128.0),
            sigma_w=jnp.full((n,), 1.0),
            sigma_t=jnp.full((n,), 1.5),
            charge=jnp.linspace(100.0, 5000.0, n),
        )
        patches, w0, t0 = rasterize(depos, CFG)
        sums = np.asarray(patches.sum(axis=(1, 2)))
        # 3-sigma truncation loses < 1.5% of the charge
        np.testing.assert_allclose(sums, np.asarray(depos.charge), rtol=0.015)

    def test_peak_at_center(self):
        # centers at x.5 put the peak unambiguously in bin [x, x+1)
        depos = DepoSet(wire=jnp.array([32.5]), tick=jnp.array([100.5]),
                        sigma_w=jnp.array([0.8]), sigma_t=jnp.array([1.0]),
                        charge=jnp.array([1000.0]))
        patches, w0, t0 = rasterize(depos, CFG)
        idx = np.unravel_index(np.argmax(np.asarray(patches[0])),
                               patches[0].shape)
        assert int(w0[0]) + idx[0] == 32
        assert int(t0[0]) + idx[1] == 100

    def test_batched_matches_single(self):
        depos = _depos(16)
        patches, w0, t0 = rasterize(depos, CFG)
        for i in [0, 7, 15]:
            single = rasterize_one(
                depos.wire[i], depos.tick[i], depos.sigma_w[i],
                depos.sigma_t[i], depos.charge[i],
                w0[i].astype(jnp.float32), t0[i].astype(jnp.float32),
                CFG.patch_wires, CFG.patch_ticks)
            np.testing.assert_allclose(np.asarray(patches[i]),
                                       np.asarray(single), rtol=1e-5,
                                       atol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(wire=st.floats(10, 50), tick=st.floats(30, 220),
           sw=st.floats(0.3, 2.0), stt=st.floats(0.3, 2.0),
           q=st.floats(1.0, 1e6))
    def test_property_nonneg_and_bounded(self, wire, tick, sw, stt, q):
        """Rasterized mass is non-negative and never exceeds the charge."""
        depos = DepoSet(wire=jnp.array([wire], jnp.float32),
                        tick=jnp.array([tick], jnp.float32),
                        sigma_w=jnp.array([sw], jnp.float32),
                        sigma_t=jnp.array([stt], jnp.float32),
                        charge=jnp.array([q], jnp.float32))
        patches, _, _ = rasterize(depos, CFG)
        p = np.asarray(patches)
        assert (p >= 0).all()
        assert p.sum() <= q * 1.01


class TestScatter:
    def test_strategies_agree(self):
        depos = _depos(128)
        patches, w0, t0 = rasterize(depos, CFG)
        g1 = scatter_xla(patches, w0, t0, CFG)
        g2 = scatter_sort_segment(patches, w0, t0, CFG)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-2)

    def test_total_charge_preserved(self):
        depos = _depos(64)
        patches, w0, t0 = rasterize(depos, CFG)
        grid = scatter_xla(patches, w0, t0, CFG)
        np.testing.assert_allclose(float(grid.sum()), float(patches.sum()),
                                   rtol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), n=st.integers(1, 100))
    def test_property_strategy_equivalence(self, seed, n):
        depos = _depos(n, seed)
        patches, w0, t0 = rasterize(depos, CFG)
        g1 = scatter_xla(patches, w0, t0, CFG)
        g2 = scatter_sort_segment(patches, w0, t0, CFG)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=5e-2)


class TestFFTConv:
    def test_matches_direct_convolution(self):
        cfg = dataclasses.replace(CFG, num_wires=16, num_ticks=64,
                                  response_wires=5, response_ticks=16)
        resp = make_response(cfg)
        rng = np.random.default_rng(0)
        grid = jnp.asarray(rng.standard_normal((16, 64)).astype(np.float32))
        out = np.asarray(fft_convolve(grid, resp))
        # direct 2-D convolution: out[w+dw-rw//2, t+dt] += k[dw,dt]*g[w,t]
        k = np.asarray(resp.kernel)
        rw, rt = k.shape
        ref = np.zeros((16, 64), np.float32)
        g = np.asarray(grid)
        for w in range(16):
            for dw in range(rw):
                wd = w + dw - rw // 2
                if not 0 <= wd < 16:
                    continue
                for dt in range(rt):
                    ref[wd, dt:] += k[dw, dt] * g[w, :64 - dt]
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)

    def test_impulse_response_recovery(self):
        """Convolving a unit impulse returns the kernel itself."""
        resp = make_response(CFG)
        grid = jnp.zeros((CFG.num_wires, CFG.num_ticks)).at[30, 50].set(1.0)
        out = np.asarray(fft_convolve(grid, resp))
        k = np.asarray(resp.kernel)
        rw = k.shape[0]
        got = out[30 - rw // 2:30 + rw // 2 + 1, 50:50 + k.shape[1]]
        np.testing.assert_allclose(got, k, atol=1e-4)

    def test_digitize_range(self):
        sig = jnp.asarray(np.random.default_rng(0).standard_normal(
            (8, 32)).astype(np.float32)) * 1e6
        adc = digitize(sig, CFG)
        assert adc.dtype == jnp.int16
        assert int(adc.min()) >= 0 and int(adc.max()) <= 4095


class TestNoise:
    def test_rms_calibrated(self):
        """Realized time-domain RMS matches the config target within 5%
        (regression for the self-cancelling ``rms * num_ticks`` chain that
        left the realization ~sqrt(2) high)."""
        noise = simulate_noise(jax.random.key(0), CFG)
        rms = float(jnp.sqrt(jnp.mean(noise ** 2)))
        assert abs(rms - CFG.noise_rms_adc) < 0.05 * CFG.noise_rms_adc, rms

    @pytest.mark.parametrize("num_ticks", [256, 257])
    def test_rms_calibrated_even_and_odd_windows(self, num_ticks):
        """Parseval normalization holds with and without a Nyquist bin."""
        cfg = dataclasses.replace(CFG, num_ticks=num_ticks, num_wires=128)
        noise = simulate_noise(jax.random.key(3), cfg)
        rms = float(jnp.sqrt(jnp.mean(noise ** 2)))
        assert abs(rms - cfg.noise_rms_adc) < 0.05 * cfg.noise_rms_adc, rms

    def test_spectrum_hermitian_bins_real(self):
        """The realized spectrum implied by the noise is well-formed: DC and
        Nyquist imaginary draws are zeroed, so the irfft round-trips —
        rfft(noise) reproduces a spectrum with real DC/Nyquist bins."""
        cfg = dataclasses.replace(CFG, num_ticks=256, num_wires=8)
        noise = simulate_noise(jax.random.key(4), cfg)
        spec = jnp.fft.rfft(noise, axis=-1)
        np.testing.assert_allclose(np.asarray(spec[:, 0].imag), 0.0, atol=1e-3)
        np.testing.assert_allclose(np.asarray(spec[:, -1].imag), 0.0, atol=1e-3)

    def test_zero_mean(self):
        noise = simulate_noise(jax.random.key(1), CFG)
        assert abs(float(noise.mean())) < 0.1


class TestPipelines:
    def test_fig3_equals_fig4_no_rng(self):
        """The naive per-depo pipeline and the batched pipeline agree exactly
        when fluctuation is off (paper F1: same physics, different speed)."""
        cfg = dataclasses.replace(CFG, fluctuate=False, num_depos=24)
        depos = _depos(24)
        resp = make_response(cfg)
        key = jax.random.key(0)
        out3 = simulate_fig3(key, depos, resp, cfg, add_noise=False)
        out4 = simulate_fig4(key, depos, resp, cfg, add_noise=False)
        np.testing.assert_allclose(np.asarray(out3.charge_grid),
                                   np.asarray(out4.charge_grid),
                                   rtol=1e-4, atol=1e-2)
        assert (np.asarray(out3.adc) == np.asarray(out4.adc)).mean() > 0.999

    def test_rng_strategies_same_statistics(self):
        """counter vs pool fluctuation give statistically identical grids."""
        depos = _depos(128)
        resp = make_response(CFG)
        cfg_c = dataclasses.replace(CFG, rng_strategy="counter")
        cfg_p = dataclasses.replace(CFG, rng_strategy="pool")
        from repro.core.fluctuate import make_pool
        pool = make_pool(jax.random.key(9), 1 << 16)
        out_c = simulate_fig4(jax.random.key(1), depos, resp, cfg_c,
                              add_noise=False)
        out_p = simulate_fig4(jax.random.key(2), depos, resp, cfg_p,
                              pool=pool, add_noise=False)
        tc = float(out_c.charge_grid.sum())
        tp = float(out_p.charge_grid.sum())
        assert abs(tc - tp) / tc < 0.02
