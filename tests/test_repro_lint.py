"""Tests for repro-lint (ISSUE 10 layer 2): every rule catches a minimal
synthetic violation, stays quiet on the idiomatic counterpart, and the
suppression machinery + repo sweep hold the gate at zero findings.
"""
import textwrap

from repro.analysis.lint import (RULES, lint_paths, lint_source,
                                 traced_function_names)


def findings(src, path="x.py"):
    return lint_source(textwrap.dedent(src), path)


def rules_of(fs):
    return [f.rule for f in fs]


class TestKeyReuse:
    def test_minimal_violation(self):
        fs = findings("""
            import jax
            def f(key):
                a = jax.random.normal(key, (3,))
                b = jax.random.uniform(key, (3,))
                return a + b
        """)
        assert rules_of(fs) == ["key-reuse"]

    def test_split_between_is_clean(self):
        fs = findings("""
            import jax
            def f(key):
                k1, k2 = jax.random.split(key)
                a = jax.random.normal(k1, (3,))
                b = jax.random.uniform(k2, (3,))
                return a + b
        """)
        assert fs == []

    def test_reassignment_resets(self):
        fs = findings("""
            import jax
            def f(key):
                a = jax.random.normal(key, (3,))
                key = jax.random.fold_in(key, 1)
                b = jax.random.normal(key, (3,))
                return a + b
        """)
        assert fs == []

    def test_exclusive_branches_are_clean(self):
        fs = findings("""
            import jax
            def f(key, mode):
                if mode == "a":
                    x = jax.random.normal(key, (3,))
                else:
                    x = jax.random.uniform(key, (3,))
                return x
        """)
        assert fs == []

    def test_returning_branch_is_clean(self):
        """The init_params idiom: a branch that returns consumes the key on
        an exclusive path."""
        fs = findings("""
            import jax
            def f(key, init):
                if init == "uniform":
                    return jax.random.uniform(key, (3,))
                return jax.random.normal(key, (3,))
        """)
        assert fs == []

    def test_branch_then_fallthrough_flagged(self):
        """A NON-returning branch consumption followed by a top-level one
        is a real reuse on that path."""
        fs = findings("""
            import jax
            def f(key, noisy):
                extra = 0.0
                if noisy:
                    extra = jax.random.normal(key, (3,))
                return jax.random.normal(key, (3,)) + extra
        """)
        assert rules_of(fs) == ["key-reuse"]

    def test_loop_body_pair_flagged(self):
        fs = findings("""
            import jax
            def f(key, n):
                out = []
                for i in range(n):
                    out.append(jax.random.normal(key, (3,)))
                    out.append(jax.random.uniform(key, (3,)))
                return out
        """)
        assert rules_of(fs) == ["key-reuse"]

    def test_fresh_key_per_iteration_clean(self):
        fs = findings("""
            import jax
            def f(key, n):
                out = []
                for i in range(n):
                    k = jax.random.fold_in(key, i)
                    out.append(jax.random.normal(k, (3,)))
                return out
        """)
        assert fs == []


JIT_HEADER = ("import jax\n"
              "import jax.numpy as jnp\n"
              "import numpy as np\n"
              "import functools\n")


def findings_jit(src, path="x.py"):
    """Like ``findings`` but with the jax import prolog prepended AFTER
    dedenting (mixing indented literals breaks textwrap.dedent)."""
    return lint_source(JIT_HEADER + textwrap.dedent(src), path)


class TestTracedBranch:
    def test_minimal_violation(self):
        fs = findings_jit("""
            @jax.jit
            def f(x):
                if x > 0:
                    return x
                return -x
        """)
        assert rules_of(fs) == ["traced-branch"]

    def test_while_flagged(self):
        fs = findings_jit("""
            @jax.jit
            def f(x):
                while x < 10:
                    x = x * 2
                return x
        """)
        assert "traced-branch" in rules_of(fs)

    def test_shape_branch_is_clean(self):
        fs = findings_jit("""
            @jax.jit
            def f(x):
                if x.shape[0] > 2:
                    return x[:2]
                return x
        """)
        assert fs == []

    def test_shape_derived_local_is_clean(self):
        """Assignment through static metadata must not taint (the
        stages.py `n_in = depos.wire.shape[-2]` idiom)."""
        fs = findings_jit("""
            @jax.jit
            def f(x):
                n = x.shape[0]
                if n != 3:
                    raise ValueError(n)
                return x
        """)
        assert fs == []

    def test_isinstance_guard_is_clean(self):
        fs = findings_jit("""
            @jax.jit
            def f(x):
                if isinstance(x, jax.Array):
                    return x * 2
                return x
        """)
        assert fs == []

    def test_static_argnames_param_is_clean(self):
        fs = findings_jit("""
            import functools
            @functools.partial(jax.jit, static_argnames=("flag",))
            def f(x, flag):
                if flag:
                    return x * 2
                return x
        """)
        assert fs == []

    def test_stage_fn_scope_detected(self):
        """Functions passed to Stage(...) count as traced."""
        fs = findings_jit("""
            def drift_fn(state):
                if state > 0:
                    return state
                return -state
            STAGE = Stage("drift", drift_fn)
        """)
        assert rules_of(fs) == ["traced-branch"]

    def test_factory_inner_def_detected(self):
        """Inner defs returned from *_stage/make_* factories count."""
        fs = findings_jit("""
            def noise_stage(cfg):
                def fn(state):
                    if state > 0:
                        return state
                    return -state
                return fn
        """)
        assert rules_of(fs) == ["traced-branch"]

    def test_plain_function_not_traced(self):
        fs = findings_jit("""
            def host_helper(x):
                if x > 0:
                    return x
                return -x
        """)
        assert fs == []


class TestHostSync:
    def test_item_flagged(self):
        fs = findings_jit("""
            @jax.jit
            def f(x):
                return x.sum().item()
        """)
        assert "host-sync" in rules_of(fs)

    def test_float_cast_flagged(self):
        fs = findings_jit("""
            @jax.jit
            def f(x):
                return float(x[0])
        """)
        assert "host-sync" in rules_of(fs)

    def test_np_asarray_flagged(self):
        fs = findings_jit("""
            @jax.jit
            def f(x):
                return np.asarray(x)
        """)
        assert "host-sync" in rules_of(fs)

    def test_outside_trace_is_clean(self):
        fs = findings_jit("""
            def report(x):
                return float(np.asarray(x).sum())
        """)
        assert fs == []

    def test_float_of_shape_is_clean(self):
        fs = findings_jit("""
            @jax.jit
            def f(x):
                return x / float(x.shape[0])
        """)
        assert fs == []


class TestMutableDefault:
    def test_list_default_flagged(self):
        fs = findings("""
            def f(x, acc=[]):
                acc.append(x)
                return acc
        """)
        assert rules_of(fs) == ["mutable-default"]

    def test_dict_and_call_defaults_flagged(self):
        fs = findings("""
            def f(x, cache={}, seen=set()):
                return x
        """)
        assert rules_of(fs) == ["mutable-default", "mutable-default"]

    def test_none_default_clean(self):
        fs = findings("""
            def f(x, acc=None, name="n", k=3):
                return x
        """)
        assert fs == []


class TestConfigReplaceGuard:
    def test_unguarded_replace_flagged(self):
        fs = findings_jit("""
            import dataclasses
            @jax.jit
            def f(theta, cfg):
                tcfg = dataclasses.replace(cfg, noise_rms_adc=theta[0])
                return tcfg
        """)
        assert rules_of(fs) == ["config-replace-guard"]

    def test_guarded_scope_is_clean(self):
        fs = findings_jit("""
            import dataclasses
            @jax.jit
            def f(theta, cfg):
                val = theta[0]
                if isinstance(val, jax.Array):
                    val = val
                tcfg = dataclasses.replace(cfg, noise_rms_adc=val)
                return tcfg
        """)
        assert fs == []

    def test_static_kwargs_clean(self):
        fs = findings_jit("""
            import dataclasses
            @jax.jit
            def f(x, cfg):
                tcfg = dataclasses.replace(cfg, num_planes=3)
                return tcfg
        """)
        assert fs == []


class TestF64Literal:
    def test_jnp_attribute_flagged(self):
        fs = findings_jit("""
            def f(x):
                return x.astype(jnp.float64)
        """)
        assert "f64-literal" in rules_of(fs)

    def test_dtype_kwarg_string_flagged(self):
        fs = findings_jit("""
            def f():
                return jnp.zeros(3, dtype="float64")
        """)
        assert "f64-literal" in rules_of(fs)

    def test_astype_string_flagged(self):
        fs = findings_jit("""
            def f(x):
                return x.astype("float64")
        """)
        assert "f64-literal" in rules_of(fs)

    def test_dtype_comparison_is_clean(self):
        """The fft_conv idiom: checking a dtype is not creating one."""
        fs = findings_jit("""
            def f(x):
                if x.dtype not in (jnp.float32, jnp.float64):
                    x = x.astype(jnp.float32)
                return x
        """)
        assert fs == []

    def test_data_string_is_clean(self):
        fs = findings("""
            TOKENS = ("f32", "f64", "float64")
            def f(c):
                return "f64" in c
        """)
        assert fs == []


class TestSuppressions:
    def test_line_suppression(self):
        fs = findings("""
            def f(x, acc=[]):  # repro-lint: disable=mutable-default
                return acc
        """)
        assert fs == []

    def test_line_suppression_other_rule_still_fires(self):
        fs = findings("""
            def f(x, acc=[]):  # repro-lint: disable=key-reuse
                return acc
        """)
        assert rules_of(fs) == ["mutable-default"]

    def test_file_suppression(self):
        fs = findings("""
            # repro-lint: disable-file=mutable-default
            def f(x, acc=[]):
                return acc
            def g(x, acc={}):
                return acc
        """)
        assert fs == []


class TestScopeDetection:
    def test_jit_call_marks_name(self):
        tree_src = textwrap.dedent("""
            import jax
            def body(x):
                return x
            run = jax.jit(body)
        """)
        import ast

        assert "body" in traced_function_names(ast.parse(tree_src))

    def test_lax_scan_marks_name(self):
        import ast

        tree_src = textwrap.dedent("""
            import jax
            def step(carry, x):
                return carry, x
            out = jax.lax.scan(step, 0, xs)
        """)
        assert "step" in traced_function_names(ast.parse(tree_src))

    def test_graph_replace_marks_kwarg(self):
        import ast

        tree_src = textwrap.dedent("""
            def noisy(state):
                return state
            graph = graph.replace(noise=noisy)
        """)
        assert "noisy" in traced_function_names(ast.parse(tree_src))


class TestGate:
    def test_rule_catalog_has_at_least_five_rules(self):
        assert len(RULES) >= 5

    def test_every_rule_name_is_kebab(self):
        for name in RULES:
            assert name == name.lower() and " " not in name

    def test_repo_src_is_clean(self):
        """The CI gate's contract: zero findings over src/."""
        assert lint_paths(["src"]) == []

    def test_parse_error_reported_not_raised(self):
        fs = findings("def broken(:\n")
        assert rules_of(fs) == ["parse-error"]
