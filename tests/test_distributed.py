"""Distributed tests: run in a subprocess with 8 forced host devices
(the main pytest process must keep the default single device)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.subprocess

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.config import LArTPCConfig, ModelConfig, SHAPES, ShapeConfig
from repro.core.depo import generate_depos
from repro.core.response import make_response, make_distributed_response
from repro.core.pipeline import simulate_fig4
from repro.core.distributed import (make_distributed_sim, shard_depos,
                                    padded_grid_shape)

results = {}

# ---- distributed LArTPC sim matches single-device cyclic reference ----
cfg = LArTPCConfig(num_wires=128, num_ticks=512, num_depos=256,
                   response_wires=11, response_ticks=64, fluctuate=False)
mesh = jax.make_mesh((4, 2), ("data", "model"))
w_pad, _, _ = padded_grid_shape(cfg, 8)
resp = make_distributed_response(cfg, w_pad)
key = jax.random.key(0)
depos = generate_depos(key, cfg)
sd = shard_depos(depos, mesh)
sim = make_distributed_sim(mesh, cfg, resp, add_noise=False)
adc = np.asarray(sim(key, sd))[:cfg.num_wires]

# single-device cyclic reference: scatter + rfft2 multiply at same shape
from repro.core.rasterize import rasterize
from repro.core.scatter import scatter_xla
patches, w0, t0 = rasterize(depos, cfg)
grid = scatter_xla(patches, w0, t0, cfg)
gpad = jnp.zeros((w_pad, cfg.num_ticks)).at[:cfg.num_wires].set(grid)
sig = jnp.fft.irfft2(jnp.fft.rfft2(gpad) * resp.freq,
                     s=(w_pad, cfg.num_ticks))[:cfg.num_wires]
from repro.core.fft_conv import digitize
ref_adc = np.asarray(digitize(sig.astype(jnp.float32), cfg))
results["sim_exact_frac"] = float((adc == ref_adc).mean())
results["sim_maxdiff"] = int(np.abs(adc.astype(int) - ref_adc.astype(int)).max())

# ---- halo-exchange scatter reduction matches psum_scatter ----
# halo needs depos pre-binned by wire strip (strip axis = first mesh axis)
from repro.core.distributed import bin_depos_by_wire
w_pad8, _, _ = padded_grid_shape(cfg, 8)
binned = bin_depos_by_wire(depos, n_strips=4, w_pad=w_pad8)
sdb = shard_depos(binned, mesh, axes=("data", "model"))
sim_halo = make_distributed_sim(mesh, cfg, resp, axes=("data", "model"),
                                scatter_reduction="halo", add_noise=False)
sim_ps = make_distributed_sim(mesh, cfg, resp, axes=("data", "model"),
                              scatter_reduction="psum_scatter",
                              add_noise=False)
a1 = np.asarray(sim_halo(key, sdb))
a2 = np.asarray(sim_ps(key, sdb))
results["halo_vs_psum_frac"] = float((a1 == a2).mean())
results["halo_maxdiff"] = int(np.abs(a1.astype(int) - a2.astype(int)).max())

# ---- sharded train step runs and matches single-device loss ----
from repro.config import OptimizerConfig
from repro.models.model import Model
from repro.optim.adamw import init_opt_state
from repro.train.train_step import make_train_step
from repro.data.tokens import make_batch, shard_batch
from repro.parallel.sharding import use_mesh, act_rules_for
from repro.launch.specs import build_train

mcfg = ModelConfig(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
                   d_ff=64, vocab_size=256, remat="none", dtype="float32")
shape = ShapeConfig("t", "train", seq_len=32, global_batch=8)
model = Model(mcfg)
params = model.init(jax.random.key(0))
opt = init_opt_state(params)
batch_np = make_batch(mcfg, shape, seed=0, step=0)

# single device
step1 = jax.jit(make_train_step(model, OptimizerConfig()))
_, _, m1 = step1(params, opt, shard_batch(batch_np))

# 8-device mesh via the launcher specs
with use_mesh(mesh, act_rules_for(mcfg, mesh)):
    fn, _, shardings, kw = build_train(mcfg, shape, mesh)
    psh, osh, bsh = shardings
    params_d = jax.device_put(params, psh)
    opt_d = jax.device_put(opt, osh)
    batch_d = {k: jax.device_put(v, bsh[k]) for k, v in batch_np.items()}
    step8 = jax.jit(fn, in_shardings=shardings, **kw)
    _, _, m8 = step8(params_d, opt_d, batch_d)
results["loss_1dev"] = float(m1["loss"])
results["loss_8dev"] = float(m8["loss"])

print("RESULTS:" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    # pin children to CPU: with libtpu installed, an unset platform makes
    # the child block on /tmp/libtpu_lockfile held by the pytest process
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")]
    assert line, proc.stdout
    return json.loads(line[0][len("RESULTS:"):])


def test_distributed_sim_matches_reference(dist_results):
    assert dist_results["sim_exact_frac"] > 0.999
    assert dist_results["sim_maxdiff"] <= 1


def test_halo_equals_psum_scatter(dist_results):
    assert dist_results["halo_vs_psum_frac"] > 0.999
    assert dist_results["halo_maxdiff"] <= 1  # float-order-only differences


def test_sharded_train_step_matches_single_device(dist_results):
    assert abs(dist_results["loss_1dev"] - dist_results["loss_8dev"]) < 2e-3
