"""The bench regression gate actually gates: no silent-pass configurations.

Regression tests for ``benchmarks/check_regression.py`` — most importantly
the silent failure modes where a ``--record`` selector matches nothing
worth gating and every CI run sails through green:

 * a glob matching zero records anywhere must fail;
 * a glob matching only FRESH records must fail (each match renders as a
   warn-only "(new)" row, so the committed family it was written to watch
   is not being compared against anything);
 * a plain record name found in neither file must fail (typo'd or removed
   benchmark);
 * a plain name present only in fresh keeps the documented warn-only
   behavior — new benchmarks land before their baseline numbers do.
"""
import importlib.util
import json
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_regression",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "benchmarks", "check_regression.py"))
cr = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(cr)


def _bench(path, rows):
    path.write_text(json.dumps(
        {"records": [{"name": n, "us_per_call": us} for n, us in rows]}))
    return str(path)


@pytest.fixture
def files(tmp_path):
    base = _bench(tmp_path / "base.json",
                  [("stages/a_total", 100.0), ("stages/b_total", 50.0),
                   ("pipeline/fig4", 10.0)])
    fresh = _bench(tmp_path / "fresh.json",
                   [("stages/a_total", 120.0), ("stages/b_total", 55.0),
                    ("pipeline/fig4", 11.0), ("stages/new_total", 5.0)])
    return base, fresh


class TestGatePasses:
    def test_glob_within_ratio(self, files, capsys):
        base, fresh = files
        assert cr.check(base, fresh, ["stages/*_total"], 2.0) == 0
        out = capsys.readouterr().out
        assert "stages/a_total" in out and "stages/b_total" in out

    def test_summary_reports_matched_count_per_glob(self, files, capsys):
        """The gate summary says how many rows each selector matched — a
        family glob that quietly shrank to one row shows in the CI log."""
        base, fresh = files
        assert cr.check(base, fresh,
                        ["stages/*_total", "pipeline/fig4"], 2.0) == 0
        out = capsys.readouterr().out
        assert "gated 4 record(s)" in out
        assert "'stages/*_total': 3" in out
        assert "'pipeline/fig4': 1" in out

    def test_fresh_only_name_warns_not_fails(self, files, capsys):
        """A plain name that exists only in fresh is a new benchmark:
        reported as (new), exit 0."""
        base, fresh = files
        assert cr.check(base, fresh, ["stages/new_total"], 2.0) == 0
        assert "(new)" in capsys.readouterr().out


class TestGateFails:
    def test_ratio_exceeded(self, files):
        base, fresh = files
        assert cr.check(base, fresh, ["stages/a_total"], 1.1) == 1

    def test_record_missing_from_fresh(self, tmp_path):
        base = _bench(tmp_path / "b.json", [("stages/gone", 10.0)])
        fresh = _bench(tmp_path / "f.json", [("stages/other", 10.0)])
        assert cr.check(base, fresh, ["stages/gone"], 2.0) == 1

    def test_glob_matching_nothing_fails(self, files, capsys):
        base, fresh = files
        assert cr.check(base, fresh, ["stages/nope_*"], 2.0) == 1
        assert "matched no records" in capsys.readouterr().err

    def test_glob_matching_only_fresh_fails(self, files, capsys):
        """THE silent case this gate used to have: a glob whose only matches
        are fresh-run rows gates nothing (all rows render as warn-only
        "(new)") — e.g. the committed baseline family was renamed away, or
        the rows were never committed. Must fail loudly."""
        base, fresh = files
        assert cr.check(base, fresh, ["stages/new_*"], 2.0) == 1
        err = capsys.readouterr().err
        assert "BASELINE" in err

    def test_plain_name_in_neither_file_fails(self, files, capsys):
        """A watched name matching nothing anywhere is a typo or a removed
        benchmark — previously printed '(new) nan' and passed."""
        base, fresh = files
        assert cr.check(base, fresh, ["stages/typo_total"], 2.0) == 1
        assert "MISSING" in capsys.readouterr().out

    def test_mixed_good_and_vanished_glob_still_fails(self, files):
        """One healthy glob does not mask a dead one."""
        base, fresh = files
        assert cr.check(base, fresh,
                        ["stages/a_total", "stages/nope_*"], 2.0) == 1


class TestExpandRecords:
    def test_glob_expands_against_union_preserving_order(self, files):
        base, fresh = files
        baseline = cr.load_records(base)
        freshr = cr.load_records(fresh)
        names = cr.expand_records(["pipeline/*", "stages/a_total"],
                                  baseline, freshr)
        assert names == ["pipeline/fig4", "stages/a_total"]

    def test_duplicates_collapse(self, files):
        base, fresh = files
        baseline = cr.load_records(base)
        freshr = cr.load_records(fresh)
        names = cr.expand_records(["stages/a_total", "stages/a_*"],
                                  baseline, freshr)
        assert names.count("stages/a_total") == 1
