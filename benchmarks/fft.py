"""The paper's "FT" stage: frequency-domain convolution timing (paper §5 —
the vendor-FFT-wrapper problem, solved here by XLA's portable FFT)."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.config import LArTPCConfig
from repro.core.fft_conv import fft_convolve
from repro.core.noise import simulate_noise
from repro.core.response import make_response


def main():
    for w, t in [(256, 1024), (512, 2048), (1024, 4096)]:
        cfg = LArTPCConfig(num_wires=w, num_ticks=t)
        resp = make_response(cfg)
        grid = simulate_noise(jax.random.key(0), cfg)  # any dense grid
        f = jax.jit(lambda g: fft_convolve(g, resp))
        dt = time_fn(f, grid, iters=3)
        emit(f"ft/fft_conv_{w}x{t}", dt,
             f"pix_per_s={w*t/dt:.3g}")


if __name__ == "__main__":
    main()
