"""LM substrate micro-benchmarks: smoke-scale train/decode step timing for
every assigned architecture (CPU; the TPU numbers come from the dry-run
roofline, not wall time)."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.config import OptimizerConfig, ShapeConfig, get_config
from repro.configs import ARCH_IDS
from repro.data.tokens import make_batch, shard_batch
from repro.models.model import Model
from repro.optim.adamw import init_opt_state
from repro.train.train_step import make_train_step

SHAPE = ShapeConfig("bench", "train", seq_len=64, global_batch=2)


def main():
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        opt = init_opt_state(params)
        step = jax.jit(make_train_step(model, OptimizerConfig()))
        batch = shard_batch(make_batch(cfg, SHAPE, 0, 0))
        t = time_fn(step, params, opt, batch, warmup=1, iters=3)
        tokens = SHAPE.global_batch * SHAPE.seq_len
        emit(f"lm/train_step_{arch}", t, f"tok_per_s={tokens/t:.3g}")


if __name__ == "__main__":
    main()
