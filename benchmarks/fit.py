"""Calibration-path timing board — the cost of differentiating the sim.

The fit loop's unit of work is one jitted ``value_and_grad`` evaluation of
the batched waveform loss (``repro.core.fit``); everything else (the Adam
update) is a handful of host-side vector ops. This board pins three numbers
on the smoke config so the CI gate catches the autodiff path regressing
independently of the forward path:

  fit/targets_build   : one-time cost — generate events, run the default
                        int16 graph over the batch (jit included).
  fit/loss_eval       : forward-only loss evaluation (differentiable graph:
                        relaxed fluctuation + STE digitizer), post-jit.
  fit/grad_eval       : ``jax.value_and_grad`` of the same loss, post-jit —
                        the per-step cost of a fit; the ratio to
                        ``loss_eval`` is the reverse-mode overhead.
  fit/adam_step       : one full optimizer step through ``run_fit`` (grad
                        eval + host Adam update), amortized over 20 steps.

``python benchmarks/fit.py`` writes BENCH_fit.json; CI diffs it against the
committed baseline via ``check_regression.py --record 'fit/*'``.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools

import jax

from benchmarks.common import emit, time_fn, write_json
from repro.config import get_config
from repro.core.fit import (FitParam, FitSpec, make_fit_loss,
                            make_fit_targets, run_fit)

NUM_EVENTS = 2
STEPS = 20


def main() -> None:
    cfg = get_config("lartpc-uboone", smoke=True)
    spec = FitSpec(params=(
        FitParam("electron_lifetime_us", init=150.0, lo=5.0, hi=500.0),
        FitParam("recombination", init=0.5, lo=0.2, hi=1.0),
    ))
    # truth away from the init, like the --smoke fit
    cfg = dataclasses.replace(cfg, electron_lifetime_us=60.0,
                              recombination=0.75)

    build = functools.partial(make_fit_targets, cfg, jax.random.key(0),
                              num_events=NUM_EVENTS)
    emit("fit/targets_build", time_fn(lambda: build().adc, warmup=1, iters=3),
         f"events={NUM_EVENTS};n={cfg.num_depos}")
    targets = build()

    loss_fn = jax.jit(make_fit_loss(cfg, spec, targets))
    vg = jax.jit(jax.value_and_grad(make_fit_loss(cfg, spec, targets)))
    theta0 = spec.init_theta(cfg)
    emit("fit/loss_eval", time_fn(loss_fn, theta0, iters=5),
         f"events={NUM_EVENTS};params={spec.n}")
    emit("fit/grad_eval", time_fn(lambda t: vg(t)[1], theta0, iters=5),
         f"events={NUM_EVENTS};params={spec.n}")

    def steps_of_fit():
        return run_fit(make_fit_loss(cfg, spec, targets), spec, theta0,
                       steps=STEPS, lr=0.2).loss

    t = time_fn(steps_of_fit, warmup=1, iters=2)
    emit("fit/adam_step", t / STEPS, f"steps={STEPS};amortized=1")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_fit.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main()
    print(f"wrote {write_json(args.out)}")
