"""Bench regression gate: diff a fresh BENCH_*.json against the committed
baseline and fail on a large slowdown of a named record.

CI runs the pipeline benchmark into a scratch file and compares it to the
repo's committed ``BENCH_pipeline.json``:

    python benchmarks/check_regression.py \
        --baseline BENCH_pipeline.json --fresh BENCH_pipeline_fresh.json \
        --record pipeline/fig4_batched --max-ratio 2.0

``--record`` values may be shell-style globs (fnmatch): a pattern expands
against the union of baseline and fresh record names, so families of rows —
e.g. the per-plane stage rows ``'stages/fig4_smoke3p_plane*_total_fused'``
— are gated without enumerating each plane. A glob must match at least one
*committed baseline* record, else the gate fails loudly: a glob that only
matches fresh rows is gating nothing (the committed family vanished — or
was never committed — and every run would silently pass as "(new)").

The diff table ends with a per-``--record`` summary of how many rows each
selector matched (``gated N record(s) — 'stages/…*': 12, …``), so a family
glob that quietly shrank is visible in the CI log even when every surviving
row passes.

Exit status 1 (with a diff table) when fresh/baseline exceeds the ratio for
any watched record; records missing from the fresh run also fail (a silently
vanished benchmark is a regression too). A plain (non-glob) record name
found in *neither* file fails — a watched name that matches nothing is a
typo or a removed benchmark, not a gate. Names missing from the baseline
but present in fresh only warn — new benchmarks land before their baseline
numbers do.
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import os
import sys


def load_records(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in data["records"]}


def expand_records(patterns: list, baseline: dict, fresh: dict,
                   counts: dict | None = None) -> list:
    """Expand glob patterns against all known record names (plain names
    pass through so a fully missing record still reports as MISSING).

    Returns [] — which the caller treats as failure — when a glob matches
    no *baseline* record: fresh-only matches would render as warn-only
    "(new)" rows, so such a glob gates nothing run after run.

    When ``counts`` is given it is filled with {pattern: matched count} —
    the gate summary prints it so a glob that quietly shrank from 12 rows
    to 1 is visible in the CI log."""
    known = sorted(set(baseline) | set(fresh))
    names: list = []
    for pat in patterns:
        if any(c in pat for c in "*?["):
            hits = [n for n in known if fnmatch.fnmatch(n, pat)]
            if counts is not None:
                counts[pat] = len(hits)
            if not hits:
                print(f"error: --record pattern {pat!r} matched no records",
                      file=sys.stderr)
                return []
            if not any(h in baseline for h in hits):
                print(f"error: --record pattern {pat!r} matched no "
                      "BASELINE records (fresh-only matches warn instead "
                      "of gating) — commit the baseline rows or fix the "
                      "pattern", file=sys.stderr)
                return []
            names.extend(h for h in hits if h not in names)
        else:
            if counts is not None:
                counts[pat] = 1
            if pat not in names:
                names.append(pat)
    return names


def check(baseline_path: str, fresh_path: str, records: list,
          max_ratio: float) -> int:
    baseline = load_records(baseline_path)
    fresh = load_records(fresh_path)
    counts: dict = {}
    records = expand_records(records, baseline, fresh, counts=counts)
    if not records:
        return 1
    failed = False
    print(f"{'record':<40} {'baseline_us':>12} {'fresh_us':>12} {'ratio':>7}")
    for name in records:
        if name not in baseline:
            if name not in fresh:
                # a plain name in NEITHER file: nothing is being gated —
                # typo or removed benchmark, either way fail loudly
                print(f"{name:<40} {'MISSING':>12} {'MISSING':>12} "
                      f"{'--':>7}  FAIL")
                failed = True
                continue
            print(f"{name:<40} {'(new)':>12} {fresh[name]:>12.1f} {'--':>7}")
            continue
        if name not in fresh:
            print(f"{name:<40} {baseline[name]:>12.1f} {'MISSING':>12} "
                  f"{'--':>7}  FAIL")
            failed = True
            continue
        ratio = fresh[name] / baseline[name] if baseline[name] > 0 else 0.0
        verdict = "FAIL" if ratio > max_ratio else "ok"
        print(f"{name:<40} {baseline[name]:>12.1f} {fresh[name]:>12.1f} "
              f"{ratio:>6.2f}x  {verdict}")
        failed = failed or ratio > max_ratio
    per_glob = ", ".join(f"{pat!r}: {n}" for pat, n in counts.items())
    print(f"gated {len(records)} record(s) — {per_glob}")
    if failed:
        print(f"\nregression: ratio exceeded {max_ratio:.1f}x "
              f"(or a watched record vanished)", file=sys.stderr)
    return 1 if failed else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_*.json to compare against")
    ap.add_argument("--fresh", required=True,
                    help="freshly produced BENCH_*.json")
    ap.add_argument("--record", action="append", required=True,
                    help="record name or fnmatch glob to gate (repeatable); "
                         "globs expand against baseline+fresh record names")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when fresh/baseline exceeds this (default 2x)")
    args = ap.parse_args()
    if not os.path.exists(args.baseline):
        # a branch without a committed baseline shouldn't hard-fail the
        # bench job — the gate simply has nothing to compare against yet.
        # (a missing FRESH file still fails loudly: the benchmark broke.)
        print(f"warning: no baseline {args.baseline!r} to gate against; "
              "skipping", file=sys.stderr)
        return 0
    return check(args.baseline, args.fresh, args.record, args.max_ratio)


if __name__ == "__main__":
    sys.exit(main())
