"""Paper Table 2 analogue: rasterization timing across strategies.

Columns of the paper's table map onto:
  ref-CPU        -> fig3 host loop with per-depo RNG *inside* the loop
                    (stateful bottleneck, here emulated with per-depo
                    counter RNG generated eagerly per dispatch)
  ref-CUDA       -> fig3 host loop with a pre-computed RNG pool (the paper's
                    factored-out RNG) — still per-depo dispatch
  ref-CPU-noRNG  -> fig3 host loop, no fluctuation
  fig4 (ours)    -> batched device-resident rasterization (one dispatch),
                    counter RNG fused — the paper's proposed fix (Fig. 4)

Timings on this host's CPU; the *ratios* reproduce the paper's findings
(F1: per-item dispatch dominates; F2: factoring RNG out is the big win).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.config import LArTPCConfig
from repro.core import fluctuate as fl
from repro.core.depo import depo_patch_origin, generate_depos
from repro.core.rasterize import rasterize, rasterize_one
from repro.kernels.rasterize.ops import rasterize_depos

N_DEPOS = 2000  # scaled from the paper's 100k to CPU-benchmark scale


def _fig3_loop(depos, cfg, rng_mode: str):
    pw, pt = cfg.patch_wires, cfg.patch_ticks

    @jax.jit
    def one(wire, tick, sw, st, q, w0, t0, key):
        patch = rasterize_one(wire, tick, sw, st, q, w0, t0, pw, pt)
        if rng_mode == "in_loop":
            normals = jax.random.normal(key, (pw, pt))
            qq = jnp.maximum(q, 1.0)
            p = jnp.clip(patch / qq, 0, 1)
            patch = jnp.maximum(
                patch + jnp.sqrt(jnp.maximum(patch * (1 - p), 0)) * normals, 0)
        elif rng_mode == "pool":
            normals = _POOL[: pw * pt].reshape(pw, pt)
            qq = jnp.maximum(q, 1.0)
            p = jnp.clip(patch / qq, 0, 1)
            patch = jnp.maximum(
                patch + jnp.sqrt(jnp.maximum(patch * (1 - p), 0)) * normals, 0)
        return patch

    w0s, t0s = depo_patch_origin(depos, cfg)
    w = np.asarray(depos.wire)
    t = np.asarray(depos.tick)
    sw = np.asarray(depos.sigma_w)
    st = np.asarray(depos.sigma_t)
    q = np.asarray(depos.charge)
    w0 = np.asarray(w0s, np.float32)
    t0 = np.asarray(t0s, np.float32)
    key = jax.random.key(0)

    def run():
        acc = 0.0
        for i in range(depos.n):
            patch = np.asarray(one(w[i], t[i], sw[i], st[i], q[i],
                                   w0[i], t0[i], jax.random.fold_in(key, i)))
            acc += patch[0, 0]
        return acc

    return run


_POOL = None


def main():
    global _POOL
    cfg = LArTPCConfig(num_wires=512, num_ticks=2048, num_depos=N_DEPOS)
    depos = generate_depos(jax.random.key(0), cfg)
    _POOL = fl.make_pool(jax.random.key(1), 1 << 16)

    # fig3 variants (per-depo dispatch, like the paper's Fig. 3 ports)
    t_inloop = time_fn(_fig3_loop(depos, cfg, "in_loop"), warmup=1, iters=1)
    emit("table2/fig3_rng_in_loop(ref-CPU)", t_inloop,
         f"n={N_DEPOS};per_depo_us={t_inloop/N_DEPOS*1e6:.1f}")
    t_pool = time_fn(_fig3_loop(depos, cfg, "pool"), warmup=1, iters=1)
    emit("table2/fig3_rng_pool(ref-CUDA)", t_pool,
         f"per_depo_us={t_pool/N_DEPOS*1e6:.1f}")
    t_norng = time_fn(_fig3_loop(depos, cfg, "none"), warmup=1, iters=1)
    emit("table2/fig3_no_rng(ref-CPU-noRNG)", t_norng,
         f"per_depo_us={t_norng/N_DEPOS*1e6:.1f}")

    # fig4: one batched dispatch (the paper's fix)
    @jax.jit
    def fig4(key, depos):
        patches, w0, t0 = rasterize(depos, cfg)
        return fl.fluctuate_counter(key, patches, depos.charge)

    t_fig4 = time_fn(fig4, jax.random.key(0), depos, iters=5)
    emit("table2/fig4_batched_fused_rng", t_fig4,
         f"per_depo_us={t_fig4/N_DEPOS*1e6:.3f};"
         f"speedup_vs_fig3={t_inloop/t_fig4:.0f}x")

    # fig4 without fluctuation (pure 2D sampling, paper col 3)
    @jax.jit
    def fig4_norng(depos):
        return rasterize(depos, cfg)[0]

    t4n = time_fn(fig4_norng, depos, iters=5)
    emit("table2/fig4_batched_no_rng", t4n,
         f"per_depo_us={t4n/N_DEPOS*1e6:.3f}")

    # Pallas kernel path (portability-layer comparison, interpret mode)
    t_pl = time_fn(
        lambda: rasterize_depos(jax.random.key(0), depos, cfg,
                                fluctuate=True),
        iters=2)
    emit("table3/fig4_pallas_interpret", t_pl,
         "interpret-mode-on-CPU;portability-tax-see-notes")


if __name__ == "__main__":
    main()
