"""Paper Fig. 5 analogue: scatter-add strategy scaling.

The paper scales Kokkos::atomic_add over OpenMP threads; the TPU-native
equivalents scale over problem size with three strategies (atomic-free):
  xla          : one scatter-add HLO
  sort_segment : radix sort + run collapse + sorted scatter
  pallas       : owner-computes tile binning (interpret mode on CPU)
Throughput is reported as depos/second.
"""
from __future__ import annotations

import functools

import jax

from benchmarks.common import emit, time_fn
from repro.config import LArTPCConfig
from repro.core.depo import generate_depos
from repro.core.rasterize import rasterize
from repro.core.scatter import scatter_sort_segment, scatter_xla
from repro.kernels.scatter_add.ops import scatter_add_tiles


def main():
    cfg = LArTPCConfig(num_wires=512, num_ticks=2048)
    for n in [512, 2048, 8192]:
        depos = generate_depos(jax.random.key(0), cfg, n)
        patches, w0, t0 = jax.jit(
            lambda d: rasterize(d, cfg))(depos)
        jax.block_until_ready(patches)

        f_xla = jax.jit(functools.partial(scatter_xla, cfg=cfg))
        t = time_fn(f_xla, patches, w0, t0, iters=3)
        emit(f"fig5/xla_scatter_n{n}", t, f"depos_per_s={n/t:.3g}")

        f_ss = jax.jit(functools.partial(scatter_sort_segment, cfg=cfg))
        t = time_fn(f_ss, patches, w0, t0, iters=3)
        emit(f"fig5/sort_segment_n{n}", t, f"depos_per_s={n/t:.3g}")

        if n <= 2048:  # interpret mode is slow; keep bounded
            import jax.numpy as jnp
            pad = jnp.pad(patches, ((0, 0), (0, 4), (0, 108)))
            t = time_fn(lambda: scatter_add_tiles(
                pad, w0, t0, num_wires=cfg.num_wires,
                num_ticks=cfg.num_ticks), iters=1)
            emit(f"fig5/pallas_interpret_n{n}", t, f"depos_per_s={n/t:.3g}")


if __name__ == "__main__":
    main()
