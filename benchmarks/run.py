"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Mapping to the paper:
  rasterization -> Table 2 (+ Table 3 portability note)
  scatter       -> Fig. 5 (scatter-add strategy scaling)
  pipeline      -> Fig. 3 vs Fig. 4 strategies (the headline comparison)
  stages        -> per-stage cost board (the papers' stage tables)
  fft           -> §5 "FT" stage
  tune          -> per-backend strategy board (registry + autotuner winners)
  lm_step       -> host-framework sanity timings for the 10 assigned archs
  fit           -> calibration path: loss/grad eval + per-step fit cost
  roofline      -> §Roofline report from the dry-run artifacts (if present)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (fft, fit, lm_step, pipeline, rasterization,
                            scatter, stages, tune)
    from benchmarks.common import write_json

    print("name,us_per_call,derived")
    for mod in [rasterization, scatter, pipeline, stages, fft, tune, lm_step,
                fit]:
        try:
            mod.main()
        except Exception:  # noqa: BLE001 — keep the harness going
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()

    print(f"wrote {write_json('BENCH_all.json')}", file=sys.stderr)

    # roofline summary (reads cached dry-run artifacts; skipped if absent)
    try:
        from benchmarks import roofline

        rows = roofline.load_all("pod1")
        ok = [r for r in rows if "skipped" not in r]
        if ok:
            worst = min(ok, key=lambda r: r["roofline_frac"])
            best = max(ok, key=lambda r: r["roofline_frac"])
            print(f"roofline/cells_analysed,{len(ok)},"
                  f"worst={worst['cell']}:{worst['roofline_frac']:.3f};"
                  f"best={best['cell']}:{best['roofline_frac']:.3f}")
    except Exception:  # noqa: BLE001
        traceback.print_exc()


if __name__ == "__main__":
    main()
