"""Fig. 3 vs Fig. 4 end-to-end pipeline comparison (the paper's headline).

fig3: per-depo dispatch + host accumulation + device FFT at the end.
fig4: one jit'd program for the whole event.
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import emit, time_fn
from repro.config import LArTPCConfig
from repro.core.depo import generate_depos
from repro.core.pipeline import simulate_fig3, simulate_fig4
from repro.core.response import make_response


def main():
    cfg = LArTPCConfig(num_wires=512, num_ticks=2048, num_depos=1000)
    depos = generate_depos(jax.random.key(0), cfg)
    resp = make_response(cfg)
    key = jax.random.key(1)

    t3 = time_fn(lambda: simulate_fig3(key, depos, resp, cfg).adc,
                 warmup=1, iters=1)
    emit("pipeline/fig3_per_depo", t3, f"n={cfg.num_depos}")

    fig4 = jax.jit(lambda k, d: simulate_fig4(k, d, resp, cfg).adc)
    t4 = time_fn(fig4, key, depos, iters=3)
    emit("pipeline/fig4_batched", t4,
         f"n={cfg.num_depos};speedup={t3/t4:.0f}x")

    # scatter strategy end-to-end effect
    for strat in ["xla", "sort_segment"]:
        c = dataclasses.replace(cfg, scatter_strategy=strat)
        f = jax.jit(lambda k, d: simulate_fig4(k, d, resp, c).adc)
        t = time_fn(f, key, depos, iters=3)
        emit(f"pipeline/fig4_scatter_{strat}", t, "")


if __name__ == "__main__":
    main()
