"""Fig. 3 vs Fig. 4 end-to-end pipeline comparison (the paper's headline),
extended one level up with the multi-event batched engine.

fig3         : per-depo dispatch + host accumulation + device FFT at the end.
fig4         : one jit'd program for the whole event.
batched fig4 : one jit'd vmap program for E whole events (repro.core.batch) —
               the fig3 -> fig4 -> batched-fig4 throughput trajectory.

``python benchmarks/pipeline.py`` sweeps E on the smoke config and writes
BENCH_pipeline.json; ``--full`` additionally sweeps the full
MicroBooNE-scale config (expensive — minutes on CPU).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, time_fn, write_json
from repro.config import LArTPCConfig, get_config
from repro.core.batch import (event_keys, make_batched_sim_fn, pack_events,
                              simulate_events)
from repro.core.depo import generate_depos
from repro.core.pipeline import simulate_fig3, simulate_fig4
from repro.core.response import make_response

BATCH_SIZES = (1, 2, 4, 8, 16)


def fig3_vs_fig4():
    cfg = LArTPCConfig(num_wires=512, num_ticks=2048, num_depos=1000)
    depos = generate_depos(jax.random.key(0), cfg)
    resp = make_response(cfg)
    key = jax.random.key(1)

    t3 = time_fn(lambda: simulate_fig3(key, depos, resp, cfg).adc,
                 warmup=1, iters=1)
    emit("pipeline/fig3_per_depo", t3,
         f"n={cfg.num_depos};depos_per_s={cfg.num_depos/t3:.3g}")

    fig4 = jax.jit(lambda k, d: simulate_fig4(k, d, resp, cfg).adc)
    t4 = time_fn(fig4, key, depos, iters=3)
    emit("pipeline/fig4_batched", t4,
         f"n={cfg.num_depos};depos_per_s={cfg.num_depos/t4:.3g};"
         f"speedup={t3/t4:.0f}x")

    # scatter strategy end-to-end effect
    for strat in ["xla", "sort_segment"]:
        c = dataclasses.replace(cfg, scatter_strategy=strat)
        f = jax.jit(lambda k, d: simulate_fig4(k, d, resp, c).adc)
        t = time_fn(f, key, depos, iters=3)
        emit(f"pipeline/fig4_scatter_{strat}", t, "")

    # fused charge-grid end to end, WITH the default counter fluctuation
    # (the in-kernel RNG lifted the old fluctuate=False restriction);
    # interpret-mode Pallas off-TPU, so one iteration is representative
    for strat in ["fused_pallas", "fused_pallas_compact"]:
        c = dataclasses.replace(cfg, charge_grid_strategy=strat)
        f = jax.jit(lambda k, d: simulate_fig4(k, d, resp, c).adc)
        t = time_fn(f, key, depos, iters=1)
        emit(f"pipeline/fig4_{strat}", t, f"n={cfg.num_depos};fluctuate=True")


def occupancy_sweep(iters: int = 2):
    """Charge-grid stage on a dense track vs diffuse depos, with the
    physics-default fluctuation ON (eager outer calls, so the compacted
    kernel measures true occupancy on the host) — see
    ``common.run_occupancy_board``. Records land in BENCH_pipeline.json
    next to the fig3/fig4 trajectory."""
    from benchmarks.common import run_occupancy_board

    run_occupancy_board("pipeline/", fluctuate=True, include_unfused=True,
                        iters=iters)


def event_batch_sweep(cfg: LArTPCConfig, tag: str,
                      batch_sizes=BATCH_SIZES, iters: int = 3):
    """Throughput of the vmap'd multi-event engine vs batch size E."""
    resp = make_response(cfg)
    key = jax.random.key(0)
    e_max = max(batch_sizes)
    events = [generate_depos(jax.random.fold_in(key, ev), cfg)
              for ev in range(e_max)]
    for e_sz in batch_sizes:
        batch = pack_events(events[:e_sz])
        keys = event_keys(key, range(e_sz))
        sim = make_batched_sim_fn(cfg, resp=resp)
        t = time_fn(lambda: sim(keys, batch).adc, iters=iters)
        n = batch.total_depos
        emit(f"pipeline/fig4_events_{tag}_E{e_sz}", t,
             f"events={e_sz};depos={n};depos_per_s={n/t:.3g};"
             f"events_per_s={e_sz/t:.3g}")


def verify_batched_equals_loop(cfg: LArTPCConfig, e_sz: int = 4) -> bool:
    """Batched engine == Python loop of per-event fig4, bit for bit."""
    resp = make_response(cfg)
    key = jax.random.key(2)
    events = [generate_depos(jax.random.fold_in(key, ev), cfg)
              for ev in range(e_sz)]
    batch = pack_events(events)
    keys = event_keys(key, range(e_sz))
    out = simulate_events(keys, batch, resp, cfg)
    ok = True
    for e in range(e_sz):
        ref = simulate_fig4(keys[e], batch.event(e), resp, cfg)
        ok = ok and np.array_equal(np.asarray(out.adc[e]), np.asarray(ref.adc))
    emit("pipeline/batched_equals_loop", 0.0, f"events={e_sz};match={ok}")
    return ok


def main(full: bool = False):
    fig3_vs_fig4()
    occupancy_sweep()
    smoke = get_config("lartpc-uboone", smoke=True)
    event_batch_sweep(smoke, "smoke")
    if not verify_batched_equals_loop(smoke):
        raise SystemExit(
            "batched simulate_events diverged from the per-event fig4 loop")
    if full:
        event_batch_sweep(get_config("lartpc-uboone"), "full", iters=1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also sweep the full MicroBooNE-scale config")
    ap.add_argument("--json", default="BENCH_pipeline.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(full=args.full)
    print(f"wrote {write_json(args.json)}")
