"""Benchmark utilities: timing, CSV emission, JSON result files."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List

import jax

#: every emit() appends here; write_json() snapshots it to a BENCH_*.json
RECORDS: List[Dict] = []


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kwargs) -> float:
    """Median wall-time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        r = fn(*args, **kwargs)
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kwargs)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
    RECORDS.append({"name": name, "us_per_call": seconds * 1e6,
                    "derived": derived})


def write_json(path: str) -> str:
    """Write all records emitted so far to a BENCH_*.json file."""
    with open(path, "w") as f:
        json.dump({"records": RECORDS}, f, indent=2)
        f.write("\n")
    return path


def run_occupancy_board(prefix: str, *, fluctuate: bool,
                        include_scatter: bool = False,
                        include_unfused: bool = False,
                        iters: int = 2) -> None:
    """Dense-grid vs active-tile-compacted kernels on a track-like depo set
    (most readout tiles empty) and a diffuse one (nearly all tiles hit).

    Kernel work is (launch tiles x k_max) grid steps: the compacted variants
    should win roughly n_tiles/n_active_bucket on the track set and tie on
    the diffuse set — the ISSUE-3 sparsity evidence. Shared by
    ``benchmarks/tune.py`` (kernel-level board, fluctuation off, plus the
    owner-computes scatter kernels) and ``benchmarks/pipeline.py``
    (charge-grid stage with the physics-default fluctuation, plus the
    unfused reference row); one definition so the boards cannot drift.
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.config import LArTPCConfig
    from repro.core.depo import depo_patch_origin, generate_depos
    from repro.core.pipeline import charge_grid_unfused
    from repro.core.rasterize import rasterize
    from repro.kernels.fused_sim.ops import (simulate_charge_grid,
                                             simulate_charge_grid_compact)
    from repro.kernels.scatter_add.ops import (count_active_tiles, next_pow2,
                                               scatter_add_tiles,
                                               scatter_add_tiles_compact)

    cfg = LArTPCConfig(num_wires=256, num_ticks=1024, num_depos=64,
                       fluctuate=fluctuate, response_wires=11,
                       response_ticks=64)
    tw, tt = 32, 128
    n_tiles = (cfg.num_wires // tw) * (cfg.num_ticks // tt)
    k_max = 256  # generous: no per-tile overflow even for the dense track
    key = jax.random.key(3) if fluctuate else None  # in-kernel RNG on/off
    depo_sets = {
        "track": generate_depos(jax.random.key(5), cfg),   # one dense track
        "diffuse": diffuse_depos(cfg, cfg.num_depos, seed=6),
    }
    unfused = jax.jit(lambda k, d: charge_grid_unfused(k, d, cfg))
    for tag, depos in depo_sets.items():
        w0, t0 = depo_patch_origin(depos, cfg)
        n_act = int(count_active_tiles(
            w0, t0, pw_pad=cfg.patch_wires, pt_pad=cfg.patch_ticks,
            num_wires=cfg.num_wires, num_ticks=cfg.num_ticks, tw=tw, tt=tt))
        occ = (f"n_active={n_act};n_cap={min(n_tiles, next_pow2(n_act))};"
               f"n_tiles={n_tiles};fluctuate={fluctuate}")
        if include_unfused:
            emit(f"{prefix}occupancy_{tag}_unfused",
                 time_fn(unfused, jax.random.key(3), depos, iters=iters), occ)
        dense = functools.partial(simulate_charge_grid, depos, cfg,
                                  tw=tw, tt=tt, k_max=k_max, key=key)
        compact = functools.partial(simulate_charge_grid_compact, depos, cfg,
                                    tw=tw, tt=tt, k_max=k_max, key=key)
        emit(f"{prefix}occupancy_{tag}_fused_dense",
             time_fn(dense, iters=iters), occ)
        emit(f"{prefix}occupancy_{tag}_fused_compact",
             time_fn(compact, iters=iters), occ)
        if not include_scatter:
            continue
        # owner-computes scatter-add over pre-rasterized (padded) patches;
        # these kernels bin by the PADDED extent, so their occupancy (and
        # the compact win) is measured with pad_wires/pad_ticks — annotating
        # them with the raw-patch occupancy above would overstate the win
        n_act_pad = int(count_active_tiles(
            w0, t0, pw_pad=cfg.pad_wires, pt_pad=cfg.pad_ticks,
            num_wires=cfg.num_wires, num_ticks=cfg.num_ticks, tw=tw, tt=tt))
        occ_pad = (f"n_active={n_act_pad};"
                   f"n_cap={min(n_tiles, next_pow2(n_act_pad))};"
                   f"n_tiles={n_tiles};fluctuate={fluctuate}")
        patches, _, _ = rasterize(depos, cfg)
        pad = jnp.zeros(
            (depos.n, cfg.pad_wires, cfg.pad_ticks), patches.dtype
        ).at[:, :cfg.patch_wires, :cfg.patch_ticks].set(patches)
        sdense = functools.partial(
            scatter_add_tiles, pad, w0, t0, num_wires=cfg.num_wires,
            num_ticks=cfg.num_ticks, tw=tw, tt=tt, k_max=k_max)
        scompact = functools.partial(
            scatter_add_tiles_compact, pad, w0, t0, num_wires=cfg.num_wires,
            num_ticks=cfg.num_ticks, tw=tw, tt=tt, k_max=k_max)
        emit(f"{prefix}occupancy_{tag}_scatter_dense",
             time_fn(sdense, iters=iters), occ_pad)
        emit(f"{prefix}occupancy_{tag}_scatter_compact",
             time_fn(scompact, iters=iters), occ_pad)


def run_plane_occupancy_board(prefix: str, *, iters: int = 2) -> None:
    """PER-PLANE active-tile occupancy of the 3-plane readout, plus the
    plane-batched charge-grid candidates on the same stacked depo set.

    The U/V projections smear the same track across different wire spans
    than the collection plane, so the planes occupy different tile counts —
    but the multi-plane compact kernel launches every plane at ONE shared
    capacity (the max over planes, bucketed). This board records each
    plane's occupancy next to the stacked kernels' cost, so a plane whose
    occupancy blows up the shared cap is visible in the tuning record.
    """
    import functools

    import jax

    from repro.config import LArTPCConfig, plane_specs
    from repro.core.depo import depo_patch_origin, generate_plane_depos
    from repro.core.pipeline import charge_grid_multiplane_xla
    from repro.kernels.fused_sim.ops import (
        simulate_charge_grid_multiplane,
        simulate_charge_grid_multiplane_compact)
    from repro.kernels.scatter_add.ops import count_active_tiles, next_pow2

    cfg = LArTPCConfig(num_wires=256, num_ticks=1024, num_depos=64,
                       num_planes=3, fluctuate=False, response_wires=11,
                       response_ticks=64)
    tw, tt = 32, 128
    n_tiles = (cfg.num_wires // tw) * (cfg.num_ticks // tt)
    depos = generate_plane_depos(jax.random.key(5), cfg)
    w0, t0 = depo_patch_origin(depos, cfg)
    per_plane = []
    for spec in plane_specs(cfg):
        p = spec.index
        n_act = int(count_active_tiles(
            w0[p], t0[p], pw_pad=cfg.patch_wires, pt_pad=cfg.patch_ticks,
            num_wires=cfg.num_wires, num_ticks=cfg.num_ticks, tw=tw, tt=tt))
        per_plane.append(n_act)
        emit(f"{prefix}occupancy3p_plane{p}_active", float(n_act) * 1e-6,
             f"kind={spec.kind};n_tiles={n_tiles};unit=tiles")
    cap = min(n_tiles, next_pow2(max(per_plane)))
    occ = (f"n_active={'/'.join(map(str, per_plane))};n_cap={cap};"
           f"n_tiles={n_tiles};planes=3;fluctuate=False")
    k_max = 256
    dense = functools.partial(simulate_charge_grid_multiplane, depos, cfg,
                              tw=tw, tt=tt, k_max=k_max, keys=None)
    compact = functools.partial(simulate_charge_grid_multiplane_compact,
                                depos, cfg, tw=tw, tt=tt, k_max=k_max,
                                keys=None)
    emit(f"{prefix}occupancy3p_fused_dense", time_fn(dense, iters=iters), occ)
    emit(f"{prefix}occupancy3p_fused_compact",
         time_fn(compact, iters=iters), occ)
    xla = jax.jit(lambda k, d: charge_grid_multiplane_xla(k, d, cfg))
    emit(f"{prefix}occupancy3p_multiplane_xla",
         time_fn(xla, jax.random.key(3), depos, iters=iters), occ)


def diffuse_depos(cfg, n: int, seed: int = 0):
    """Depos spread uniformly over the whole readout plane.

    The occupancy-sweep counterpart of ``generate_depos`` (whose track-like
    output concentrates charge in few readout tiles): diffuse depos touch
    ~every tile, so active-tile compaction degenerates to the dense layout.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.depo import DepoSet

    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    return DepoSet(
        wire=jax.random.uniform(k1, (n,), minval=0.0,
                                maxval=cfg.num_wires - 1.0),
        tick=jax.random.uniform(k2, (n,), minval=0.0,
                                maxval=cfg.num_ticks - 1.0),
        sigma_w=jnp.full((n,), 1.0),
        sigma_t=jnp.full((n,), 1.2),
        charge=cfg.electrons_per_depo * jnp.exp(
            0.3 * jax.random.normal(k3, (n,))),
    )
