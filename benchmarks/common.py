"""Benchmark utilities: timing, CSV emission, JSON result files."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List

import jax

#: every emit() appends here; write_json() snapshots it to a BENCH_*.json
RECORDS: List[Dict] = []


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kwargs) -> float:
    """Median wall-time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        r = fn(*args, **kwargs)
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kwargs)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
    RECORDS.append({"name": name, "us_per_call": seconds * 1e6,
                    "derived": derived})


def write_json(path: str) -> str:
    """Write all records emitted so far to a BENCH_*.json file."""
    with open(path, "w") as f:
        json.dump({"records": RECORDS}, f, indent=2)
        f.write("\n")
    return path
