"""Benchmark utilities: timing, CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kwargs) -> float:
    """Median wall-time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        r = fn(*args, **kwargs)
        jax.block_until_ready(r)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kwargs)
        jax.block_until_ready(r)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
