import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Perf hillclimbing driver (§Perf): measure a cell's roofline terms under
config variants, on the single-pod production mesh.

  PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen3-32b/train_4k \
      --variant baseline --variant bf16_params ...
  PYTHONPATH=src python -m benchmarks.hillclimb --cell lartpc/sim

Variants are named config mutations defined in VARIANTS below; each run
prints the three roofline terms + temp memory so iterations are comparable.
"""
import argparse
import dataclasses
import time

import jax

from repro.config import SHAPES, get_config
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_decode, build_prefill, build_train
from repro.parallel.sharding import act_rules_for, use_mesh

PEAK, HBM, LINK = 197e12, 819e9, 50e9


def _measure(compiled):
    acc = analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "compute_ms": acc["flops"] / PEAK * 1e3,
        "memory_ms": acc["hbm_bytes"] / HBM * 1e3,
        "collective_ms": acc["collective_bytes"] / LINK * 1e3,
        "temp_gib": mem.temp_size_in_bytes / 2**30,
        "flops": acc["flops"],
        "coll_by_kind": {k: round(v / 1e9, 3)
                         for k, v in acc["collectives"].items()},
        "top_coll": [(round(b / 1e9, 1), n[:90])
                     for b, n in acc.get("top_collectives", [])[:6]],
    }


# ---------------------------------------------------------------------------
# LM cell variants
# ---------------------------------------------------------------------------

def v_baseline(cfg):
    return cfg, None, None


def v_bf16_params(cfg):
    """bf16 params + f32 master in optimizer: halves param-gather bytes."""
    return dataclasses.replace(cfg, param_dtype="bfloat16"), None, None


def v_capacity_1_0(cfg):
    if cfg.moe is None:
        return cfg, None, None
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0),
        param_dtype="bfloat16"), None, None


def v_remat_full(cfg):
    return (dataclasses.replace(cfg, remat="full",
                                param_dtype="bfloat16"), None, None)


def v_tp_microbatch(n):
    """Drop sequence parallelism (its per-layer weight-grad all-reduce over
    the model axis dominates); recover activation memory with gradient
    accumulation over n microbatches instead."""

    def f(cfg):
        from repro.config import ParallelConfig
        from repro.parallel import sharding as shd

        rules = dict(shd.ACT_RULES, seq=None)
        return (cfg, ParallelConfig(microbatches=n), rules)

    return f


def v_tp_mb_bf16(n):
    def f(cfg):
        cfg2, par, rules = v_tp_microbatch(n)(cfg)
        return dataclasses.replace(cfg2, param_dtype="bfloat16"), par, rules

    return f


def v_zero1(cfg, mb=0, bf16=True, sp=True, cap=None):
    """ZeRO-1: TP-only params (replicated over data), fully-sharded optimizer
    state; grads reduce-scatter + params all-gather once per step."""
    from repro.config import ParallelConfig
    from repro.parallel import sharding as shd

    if bf16:
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    if cap is not None and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cap))
    rules = None if sp else dict(shd.ACT_RULES, seq=None)
    par = ParallelConfig(microbatches=mb) if mb else None
    return cfg, par, rules


VARIANTS = {
    "baseline": v_baseline,
    "bf16_params": v_bf16_params,
    "bf16+cap1.0": v_capacity_1_0,
    "bf16+remat_full": v_remat_full,
    "tp_mb4": v_tp_microbatch(4),
    "tp_mb8": v_tp_microbatch(8),
    "tp_mb8_bf16": v_tp_mb_bf16(8),
    "tp_mb16_bf16": v_tp_mb_bf16(16),
    "zero1_sp": lambda c: v_zero1(c),
    "zero1_sp_f32": lambda c: v_zero1(c, bf16=False),
    "zero1_mb4": lambda c: v_zero1(c, mb=4, sp=False),
    "zero1_sp_mb2": lambda c: v_zero1(c, mb=2),
    "zero1_sp_cap1": lambda c: v_zero1(c, cap=1.0),
    "zero1_sp_mb2_cap1": lambda c: v_zero1(c, mb=2, cap=1.0),
    "bf16_cap1_mb2": lambda c: (
        dataclasses.replace(
            v_capacity_1_0(c)[0], param_dtype="bfloat16"),
        __import__("repro.config", fromlist=["ParallelConfig"]
                   ).ParallelConfig(microbatches=2),
        None),
    "bf16_cap1_mb4": lambda c: (
        dataclasses.replace(
            v_capacity_1_0(c)[0], param_dtype="bfloat16"),
        __import__("repro.config", fromlist=["ParallelConfig"]
                   ).ParallelConfig(microbatches=4),
        None),
}

ZERO1 = {"zero1_sp", "zero1_sp_f32", "zero1_mb4", "zero1_sp_mb2",
         "zero1_sp_cap1", "zero1_sp_mb2_cap1"}


def run_lm_cell(arch_id: str, shape_name: str, variants):
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    for vname in variants:
        cfg, parallel, rules = VARIANTS[vname](get_config(arch_id))
        t0 = time.time()
        with use_mesh(mesh, rules or act_rules_for(cfg, mesh)):
            if shape.kind == "train":
                fn, args, sh, kw = build_train(cfg, shape, mesh,
                                               parallel=parallel,
                                               zero1=vname in ZERO1)
            elif shape.kind == "prefill":
                fn, args, sh, kw = build_prefill(cfg, shape, mesh)
            else:
                fn, args, sh, kw = build_decode(cfg, shape, mesh)
            compiled = (jax.jit(fn, in_shardings=sh, **kw)
                        .lower(*args).compile())
        m = _measure(compiled)
        m["compile_s"] = round(time.time() - t0, 1)
        dom = max(["compute_ms", "memory_ms", "collective_ms"],
                  key=lambda k: m[k])
        print(f"{arch_id}/{shape_name} [{vname}] "
              f"compute={m['compute_ms']:.1f}ms memory={m['memory_ms']:.1f}ms "
              f"collective={m['collective_ms']:.1f}ms (dom={dom.split('_')[0]}) "
              f"temp={m['temp_gib']:.1f}GiB coll_GB={m['coll_by_kind']}",
              flush=True)
        for b, n in m["top_coll"]:
            print(f"    {b:>8.1f} GB  {n}", flush=True)


# ---------------------------------------------------------------------------
# LArTPC sim cell (the paper's own technique on the production mesh)
# ---------------------------------------------------------------------------

def run_sim_cell(variants):
    import jax.numpy as jnp

    from repro.core.depo import DepoSet
    from repro.core.distributed import make_distributed_sim, padded_grid_shape
    from repro.core.response import make_distributed_response

    cfg = get_config("lartpc-uboone")  # full MicroBooNE scale, 100k depos
    mesh = jax.make_mesh((16, 16), ("data", "model"))
    nsh = 256
    w_pad, _, _ = padded_grid_shape(cfg, nsh)
    resp = make_distributed_response(cfg, w_pad)
    n = (cfg.num_depos + nsh - 1) // nsh * nsh
    depo_sds = DepoSet(*(jax.ShapeDtypeStruct((n,), jnp.float32)
                         for _ in range(5)))

    for strat in variants:
        sim = make_distributed_sim(mesh, cfg, resp, axes=("data", "model"),
                                   scatter_reduction=strat)
        t0 = time.time()
        key_abstract = jax.eval_shape(lambda: jax.random.key(0))
        compiled = sim.lower(key_abstract, depo_sds).compile()
        m = _measure(compiled)
        m["compile_s"] = round(time.time() - t0, 1)
        dom = max(["compute_ms", "memory_ms", "collective_ms"],
                  key=lambda k: m[k])
        print(f"lartpc/sim [{strat}] "
              f"compute={m['compute_ms']:.2f}ms memory={m['memory_ms']:.2f}ms "
              f"collective={m['collective_ms']:.2f}ms (dom={dom.split('_')[0]}) "
              f"temp={m['temp_gib']:.2f}GiB coll_GB={m['coll_by_kind']}",
              flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="<arch>/<shape> or lartpc/sim")
    ap.add_argument("--variant", action="append", default=[])
    args = ap.parse_args()
    if args.cell == "lartpc/sim":
        run_sim_cell(args.variant or ["psum_scatter", "halo"])
        return
    arch, shape = args.cell.split("/")
    run_lm_cell(arch, shape, args.variant or ["baseline"])


if __name__ == "__main__":
    main()
