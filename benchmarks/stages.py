"""Per-stage timing board — the papers' stage-cost tables on our backends.

The source paper (Table 2) and its OpenMP/SYCL follow-ups report *per-stage*
cost — drift, rasterize/scatter, convolve, noise, digitize — because the
stage profile is what picks the next porting target. The stage graph makes
that measurement structural: every stage boundary is a named
instrumentation point, so this board is just ``SimGraph.timed``.

  fig4    : single-event graph, physical-depo input (drift stage does real
            transport work).
  batched : the same graph vmapped over E events (the multi-event engine's
            device program), per-stage.

``python benchmarks/stages.py`` runs the smoke config and writes
BENCH_stages.json; ``--full`` adds the MicroBooNE-scale config (minutes on
CPU). Stage timings are measured with per-stage jit + blocking boundaries,
so their sum is an upper bound on the fused end-to-end program — the
``*_total_fused`` record reports the real fused cost for comparison.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from benchmarks.common import emit, time_fn, write_json
from repro.config import LArTPCConfig, get_config, plane_specs
from repro.core.batch import event_keys
from repro.core.depo import generate_physical_depos
from repro.core.response import make_response
from repro.core.stages import build_sim_graph
from repro.tune import resolve_config


def stage_board(cfg: LArTPCConfig, tag: str, iters: int = 3) -> None:
    """Single-event per-stage board (the fig4 path) on physical depos."""
    cfg = resolve_config(cfg)
    graph = build_sim_graph(cfg, make_response(cfg))
    key = jax.random.key(0)
    pdepos = generate_physical_depos(key, cfg)
    _, timings = graph.timed(key, pdepos, iters=iters)
    total = sum(timings.values())
    for name, sec in timings.items():
        emit(f"stages/fig4_{tag}_{name}", sec,
             f"frac={sec / total:.3f};n={cfg.num_depos}")
    fused = jax.jit(graph.run)
    t = time_fn(lambda: fused(key, pdepos).adc, iters=iters)
    emit(f"stages/fig4_{tag}_total_fused", t,
         f"stage_sum_us={total * 1e6:.1f};n={cfg.num_depos}")


def batched_stage_board(cfg: LArTPCConfig, tag: str, e_sz: int = 4,
                        iters: int = 3) -> None:
    """Per-stage board of the vmapped multi-event engine (E events/launch)."""
    cfg = resolve_config(cfg)
    graph = build_sim_graph(cfg, make_response(cfg))
    key = jax.random.key(0)
    events = [generate_physical_depos(jax.random.fold_in(key, ev), cfg)
              for ev in range(e_sz)]
    # pack the (x, y, z, t, q) physical leaves into one (E, N) pytree; the
    # events share a fixed depo count, so no padding is needed here
    batch = jax.tree.map(lambda *xs: jax.numpy.stack(xs), *events)
    keys = event_keys(key, range(e_sz))
    _, timings = graph.timed(keys, batch, iters=iters, batched=True)
    total = sum(timings.values())
    n = e_sz * cfg.num_depos
    for name, sec in timings.items():
        emit(f"stages/batched_{tag}_E{e_sz}_{name}", sec,
             f"frac={sec / total:.3f};events={e_sz};depos={n}")
    fused = jax.jit(jax.vmap(graph.run))
    t = time_fn(lambda: fused(keys, batch).adc, iters=iters)
    emit(f"stages/batched_{tag}_E{e_sz}_total_fused", t,
         f"stage_sum_us={total * 1e6:.1f};events={e_sz};"
         f"depos_per_s={n / t:.3g}")


def detector_frame_board(cfg: LArTPCConfig, tag: str, iters: int = 3) -> None:
    """Same graph fed already-drifted depos: the drift stage passes through,
    so its row should read ~0 — evidence the stage only costs when it works.
    """
    from repro.core.depo import generate_depos

    cfg = resolve_config(cfg)
    graph = build_sim_graph(cfg, make_response(cfg))
    key = jax.random.key(0)
    depos = generate_depos(key, cfg)
    _, timings = graph.timed(key, depos, iters=iters)
    for name, sec in timings.items():
        emit(f"stages/fig4_{tag}_predrifted_{name}", sec, "")


def plane_boards(cfg: LArTPCConfig, tag: str, iters: int = 3) -> None:
    """3-plane (U/V/W) boards: the full multi-plane graph per stage, plus
    PER-PLANE rows — the same graph restricted to one plane at a time — so
    the papers' per-plane cost tables are reproducible. Per-plane rows in
    the committed BENCH_stages.json are regression-gated in CI
    (``benchmarks/check_regression.py --record 'stages/...plane*...'``).
    """
    cfg = resolve_config(dataclasses.replace(cfg, num_planes=3))
    # The stacked 3-plane board re-TUNES the charge grid: the multi-plane
    # candidates (multiplane_xla, fused_pallas_multiplane*) only exist at
    # num_planes>1, so the hand-picked single-plane default would hide them.
    # Measuring here is what "the autotuner proves the plane-batched
    # strategies against the looped baseline" means; the per-plane rows
    # below keep the portable single-plane strategy set for comparability.
    tuned = resolve_config(
        dataclasses.replace(cfg, charge_grid_strategy="auto"), tune=True)
    key = jax.random.key(0)
    pdepos = generate_physical_depos(key, cfg)
    graph = build_sim_graph(tuned)
    _, timings = graph.timed(key, pdepos, iters=iters)
    total = sum(timings.values())
    for name, sec in timings.items():
        emit(f"stages/fig4_{tag}3p_{name}", sec,
             f"frac={sec / total:.3f};planes=3;n={cfg.num_depos};"
             f"charge_grid={tuned.charge_grid_strategy}")
    fused = jax.jit(graph.run)
    t = time_fn(lambda: fused(key, pdepos).adc, iters=iters)
    emit(f"stages/fig4_{tag}3p_total_fused", t,
         f"stage_sum_us={total * 1e6:.1f};planes=3;"
         f"charge_grid={tuned.charge_grid_strategy}")
    # Drift transports the event ONCE, whatever the plane count — but each
    # plane-restricted graph used to re-run (and re-count) the full
    # transport, so summing per-plane rows triple-counted it. Time it once,
    # report it as a shared row, and feed the per-plane graphs pre-drifted
    # depos so their drift rows are pure plane selection (~0).
    from repro.core.drift import transport_planes

    drift_once = jax.jit(lambda d: transport_planes(d, cfg))
    ddepos = jax.block_until_ready(drift_once(pdepos))
    tdrift = time_fn(lambda: drift_once(pdepos).wire, iters=iters)
    emit(f"stages/fig4_{tag}3p_drift_shared", tdrift,
         f"planes=3;shared=1;n={cfg.num_depos}")
    for spec in plane_specs(cfg):
        p = spec.index
        g = build_sim_graph(cfg, planes=(p,))
        _, pt = g.timed(key, ddepos, iters=iters)
        for name, sec in pt.items():
            emit(f"stages/fig4_{tag}3p_plane{p}_{name}", sec,
                 f"plane={p};kind={spec.kind}")
        fused_p = jax.jit(g.run)
        tp = time_fn(lambda: fused_p(key, ddepos).adc, iters=iters)
        emit(f"stages/fig4_{tag}3p_plane{p}_total_fused", tp,
             f"plane={p};kind={spec.kind}")


def recon_board(cfg: LArTPCConfig, tag: str, iters: int = 3) -> None:
    """Recon-chain board: the fig4 graph extended with the deconvolve +
    hit_find stages (``build_sim_graph(..., recon=True)``), per stage, plus
    one row per registered hit_find strategy at this shape — the recon
    analogue of the forward per-stage tables (the signal-processing
    follow-ups report deconvolution + hit finding as their workload).
    """
    from repro.core.deconvolve import make_deconv_filter, measured_signal
    from repro.core.hitfind import find_hits
    from repro.tune import registry
    from repro.tune.registry import TuneContext

    # hit_find defaults to "auto": tune-resolve so the recon rows report the
    # measured winner (the Pallas kernel where it wins), not the scan
    # reference the untuned cache falls back to
    cfg = resolve_config(cfg, tune=True)
    graph = build_sim_graph(cfg, make_response(cfg), recon=True)
    key = jax.random.key(0)
    pdepos = generate_physical_depos(key, cfg)
    _, timings = graph.timed(key, pdepos, iters=iters)
    total = sum(timings.values())
    for name, sec in timings.items():
        emit(f"stages/recon_{tag}_{name}", sec,
             f"frac={sec / total:.3f};n={cfg.num_depos}")
    fused = jax.jit(graph.run)
    t = time_fn(lambda: fused(key, pdepos).hits.n_hits, iters=iters)
    emit(f"stages/recon_{tag}_total_fused", t,
         f"stage_sum_us={total * 1e6:.1f};n={cfg.num_depos}")

    # per-strategy hit_find rows on a real deconvolved grid
    out = fused(key, pdepos)
    decon = out.decon if cfg.num_planes == 1 else out.decon[0]
    ctx = TuneContext(cfg=cfg, backend=jax.default_backend(),
                      device_kind=jax.devices()[0].device_kind,
                      shape={"num_wires": int(decon.shape[0]),
                             "num_ticks": int(decon.shape[1]),
                             "max_hits_per_wire": cfg.max_hits_per_wire})
    for name in sorted(registry.strategies("hit_find")):
        strat = registry.get_strategy("hit_find", name)
        if not strat.is_available(ctx):
            continue
        fn = jax.jit(lambda d, s=name: find_hits(d, cfg, s).n_hits)
        t = time_fn(lambda: fn(decon), iters=iters)
        emit(f"stages/recon_{tag}_hitfind_{name}", t,
             f"wires={decon.shape[0]};ticks={decon.shape[1]}")

    # deconvolve alone (ADC -> charge), per registered strategy
    filt = make_deconv_filter(make_response(cfg), cfg)
    adc = out.adc if cfg.num_planes == 1 else out.adc[0]
    meas = jax.block_until_ready(measured_signal(adc, cfg))
    from repro.core.deconvolve import deconvolve
    for name in sorted(registry.strategies("deconvolve")):
        fn = jax.jit(lambda m, s=name: deconvolve(m, filt, s))
        t = time_fn(lambda: fn(meas), iters=iters)
        emit(f"stages/recon_{tag}_deconv_{name}", t,
             f"wires={meas.shape[0]};ticks={meas.shape[1]}")


def main(full: bool = False):
    smoke = get_config("lartpc-uboone", smoke=True)
    stage_board(smoke, "smoke")
    batched_stage_board(smoke, "smoke")
    detector_frame_board(smoke, "smoke")
    plane_boards(smoke, "smoke")
    recon_board(smoke, "smoke")
    if full:
        full_cfg = get_config("lartpc-uboone")
        stage_board(full_cfg, "full", iters=1)
        batched_stage_board(full_cfg, "full", e_sz=2, iters=1)
        plane_boards(full_cfg, "full", iters=1)
        recon_board(full_cfg, "full", iters=1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also board the full MicroBooNE-scale config")
    ap.add_argument("--json", default="BENCH_stages.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(full=args.full)
    print(f"wrote {write_json(args.json)}")
