"""Strategy-comparison sweep: time every registered candidate of every hot op.

The paper compares scatter-add implementations per architecture by hand
(Fig. 5, and the Kokkos/OpenMP/SYCL follow-ups flip the winner again); this
module asks the kernel-strategy registry instead: for each hot op it times
all *available* candidates on the live backend at the given config's shape,
emits one record per (op, strategy), and records the tuner's decision —
``python benchmarks/tune.py`` writes the board to ``BENCH_tune.json``.

Candidates excluded by their availability predicate (e.g. Pallas interpret
mode at production grid sizes off-TPU) are reported as ``excluded`` rows so
the board never silently shrinks.
"""
from __future__ import annotations

import argparse

from benchmarks.common import (emit, run_occupancy_board,
                               run_plane_occupancy_board, time_fn, write_json)
from repro import tune
from repro.config import get_config


def sweep_op(op: str, cfg, tag: str, iters: int = 3,
             sample_depos: int | None = None) -> None:
    thunks = tune.candidate_thunks(op, cfg, sample_depos=sample_depos)
    ctx = tune.make_context(cfg, tune.op_shape(op, cfg))
    for name in sorted(tune.strategies(op)):
        if name not in thunks:
            emit(f"tune/{op}_{tag}_{name}", 0.0,
                 f"excluded=availability_predicate;backend={ctx.backend}")
            continue
        t = time_fn(thunks[name], iters=iters)
        emit(f"tune/{op}_{tag}_{name}", t, f"backend={ctx.backend}")
    decision = tune.tune_op(op, cfg, sample_depos=sample_depos)
    emit(f"tune/{op}_{tag}_winner", 0.0,
         f"strategy={decision.strategy};source={decision.source}")


def sweep_occupancy(iters: int = 2) -> None:
    """Kernel-level active-tile compaction board (fused + owner-computes
    scatter, fluctuation off) — see ``common.run_occupancy_board``."""
    run_occupancy_board("tune/", fluctuate=False, include_scatter=True,
                        iters=iters)
    # per-plane occupancy of the 3-plane readout + the plane-batched
    # charge-grid candidates (the stacked compact kernel shares one
    # capacity across planes — the sweep shows what each plane contributes)
    run_plane_occupancy_board("tune/", iters=iters)


def main(full: bool = False) -> None:
    smoke = get_config("lartpc-uboone", smoke=True)
    for op in tune.TUNABLE_OPS:
        sweep_op(op, smoke, "smoke")
    sweep_occupancy()
    if full:
        cfg = get_config("lartpc-uboone")
        for op in tune.TUNABLE_OPS:
            # cap the depo sample so the full-scale board stays minutes, not
            # hours, on CPU; the shape bucket still reflects the true config
            sweep_op(op, cfg, "full", iters=1, sample_depos=16384)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also sweep the full MicroBooNE-scale config")
    ap.add_argument("--json", default="BENCH_tune.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(full=args.full)
    print(f"wrote {write_json(args.json)}")
