"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run JSON cache and derives, per cell:

  compute    = HLO_FLOPs_per_device / peak_FLOPs            [s]
  memory     = HLO_bytes_per_device / HBM_bw                [s]
  collective = collective_bytes_per_device / link_bw        [s]

plus MODEL_FLOPS = 6·N·D (train) or 2·N_active·B (decode, per token) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs. ``cost_analysis()`` of the
SPMD-partitioned module reports per-device numbers (verified against 6ND).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "results", "roofline.md")
OUT_CSV = os.path.join(os.path.dirname(__file__), "results", "roofline.csv")


def model_flops(arch_id: str, shape_name: str) -> float:
    """Analytic useful FLOPs per device for the cell."""
    from repro.config import SHAPES, get_config

    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyse_cell(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n_dev = rec["n_devices"]
    t_compute = rec["flops"] / PEAK_FLOPS
    t_memory = rec["bytes_accessed"] / HBM_BW
    t_coll = rec["collectives"]["total_bytes"] / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf_global = model_flops(rec["arch"], rec["shape"])
    mf_dev = mf_global / n_dev
    useful_ratio = mf_dev / rec["flops"] if rec["flops"] > 0 else 0.0
    ideal = mf_dev / PEAK_FLOPS
    bound = max(terms.values())
    return {
        "cell": rec["cell"],
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_dev": mf_dev,
        "hlo_flops_dev": rec["flops"],
        "useful_ratio": useful_ratio,
        "roofline_frac": ideal / bound if bound > 0 else 0.0,
        "temp_gib": rec["memory"].get("temp_size_in_bytes", 0) / 2**30,
    }


def load_all(mesh: str = "pod1") -> List[Dict]:
    rows = []
    if not os.path.isdir(RESULTS_DIR):
        return rows
    for fn in sorted(os.listdir(RESULTS_DIR)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(RESULTS_DIR, fn)) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh:
            continue
        row = analyse_cell(rec)
        if row:
            rows.append(row)
        elif rec.get("status") == "skipped":
            rows.append({"cell": rec["cell"], "arch": rec["arch"],
                         "shape": rec["shape"], "mesh": rec["mesh"],
                         "skipped": rec.get("reason", "")})
    return rows


def render(rows: List[Dict]) -> str:
    hdr = ("| cell | compute [ms] | memory [ms] | collective [ms] | "
           "dominant | useful ratio | roofline frac | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['cell']} | — | — | — | skipped "
                         f"(sub-quadratic req.) | — | — | — |")
            continue
        lines.append(
            f"| {r['cell']} | {r['compute_s']*1e3:.2f} | "
            f"{r['memory_s']*1e3:.2f} | {r['collective_s']*1e3:.2f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {r['temp_gib']:.1f} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    all_md = []
    for mesh in ["pod1", "pod2"]:
        rows = load_all(mesh)
        if not rows:
            continue
        all_md.append(f"### Mesh {mesh} "
                      f"({'256 chips' if mesh == 'pod1' else '512 chips'})\n")
        all_md.append(render(rows))
    md = "\n".join(all_md)
    with open(OUT_MD, "w") as f:
        f.write(md)
    with open(OUT_CSV, "w") as f:
        f.write("cell,compute_s,memory_s,collective_s,dominant,"
                "useful_ratio,roofline_frac,temp_gib\n")
        for mesh in ["pod1", "pod2"]:
            for r in load_all(mesh):
                if "skipped" in r:
                    f.write(f"{r['cell']},,,,skipped,,,\n")
                else:
                    f.write(f"{r['cell']},{r['compute_s']},{r['memory_s']},"
                            f"{r['collective_s']},{r['dominant']},"
                            f"{r['useful_ratio']},{r['roofline_frac']},"
                            f"{r['temp_gib']}\n")
    print(md)


if __name__ == "__main__":
    main()
